//! Fig. 13: fabrication-cost improvement of (a) custom and (b)
//! homogeneous RRAM chiplet architectures over a monolithic die, per
//! DNN and tiles/chiplet. Paper shape: improvement ≈ 0 for ResNet-110
//! (tiny chip), >50 % for VGG-19-class models; roughly independent of
//! tiles/chiplet and of custom-vs-homogeneous.

use siam::config::{ChipMode, SiamConfig};
use siam::coordinator::simulate;
use siam::cost::CostModel;
use siam::util::table::Table;

fn improvement(
    model: &str,
    ds: &str,
    tiles: usize,
    homogeneous: bool,
) -> anyhow::Result<Option<f64>> {
    let base = SiamConfig::paper_default()
        .with_model(model, ds)
        .with_tiles_per_chiplet(tiles);
    let mono = simulate(&base.clone().with_chip_mode(ChipMode::Monolithic))?;
    let chip_cfg = if homogeneous {
        // smallest square count that fits
        let need = simulate(&base)?.num_chiplets_required;
        let side = (need as f64).sqrt().ceil() as usize;
        base.with_total_chiplets(side * side)
    } else {
        base
    };
    let chip = match simulate(&chip_cfg) {
        Ok(r) => r,
        Err(_) => return Ok(None),
    };
    let cost = CostModel::default();
    // cost compares *yielded silicon* — the passive interposer is not a die
    let per_chiplet = chip.silicon_area_mm2 / chip.num_chiplets as f64;
    Ok(Some(cost.improvement_pct(
        mono.silicon_area_mm2,
        chip.num_chiplets,
        per_chiplet,
    )))
}

fn main() -> anyhow::Result<()> {
    let nets = [
        ("resnet110", "cifar10"),
        ("vgg19", "cifar100"),
        ("resnet50", "imagenet"),
        ("vgg16", "imagenet"),
    ];
    let tiles_opts = [9usize, 16, 25, 36];

    for (title, homogeneous) in [
        ("Fig. 13a: custom chiplet architecture", false),
        ("Fig. 13b: homogeneous chiplet architecture", true),
    ] {
        println!("== {title}: fab-cost improvement vs monolithic, % ==\n");
        let mut headers = vec!["network".to_string()];
        headers.extend(tiles_opts.iter().map(|t| format!("{t} t/c")));
        let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut t = Table::new(&hdr);
        for (model, ds) in nets {
            let mut row = vec![model.to_string()];
            for &tiles in &tiles_opts {
                match improvement(model, ds, tiles, homogeneous)? {
                    Some(imp) => row.push(format!("{imp:.1}")),
                    None => row.push("-".into()),
                }
            }
            t.row(&row);
        }
        t.print();
        println!();
    }
    println!("paper anchors: ResNet-110 ≈ 0.6% improvement; VGG-19 > 50%;");
    println!("improvement ~flat across tiles/chiplet and similar for (a) and (b).");
    Ok(())
}
