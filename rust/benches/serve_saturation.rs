//! Serving saturation bench: stream Poisson traffic through the
//! layer-pipelined chiplet system at rising offered load and record the
//! throughput plateau, tail latencies and closed-loop scaling.
//!
//! Three sections, each gated on a calibration invariant before any
//! number is written:
//!
//! * **Closed loop, concurrency 1** — delivered throughput must equal
//!   the single-inference latency reciprocal within 1 % (the pipeline
//!   degenerates to sequential inference).
//! * **Open-loop saturation sweep** — offered load from 0.25× to 2× of
//!   the analytic bottleneck-stage rate; delivered throughput must
//!   plateau at that rate (asserted within 5 % at 2× overload).
//! * **Closed-loop concurrency ladder** — throughput approaching the
//!   same ceiling from below as the pipeline fills.
//!
//! Every number is written to `BENCH_serve.json` at the repository root
//! (schema `siam-bench-serve/v2`; see README, "Reading
//! BENCH_serve.json"). Pass `--quick` for the CI smoke variant.

use siam::config::SiamConfig;
use siam::coordinator::{simulate, SweepContext};
use siam::obs::RunMeta;
use siam::serve;
use siam::util::json::Json;
use siam::util::table::Table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench_t0 = Instant::now();
    let requests: usize = if quick { 400 } else { 4000 };
    let base = SiamConfig::paper_default().with_serve_requests(requests);
    // one shared context: every serving run below replays the same
    // cached stage outputs instead of re-simulating the design point
    let ctx = SweepContext::new(&base)?;
    let mut bench = Json::obj();
    bench
        .set("schema", "siam-bench-serve/v2")
        .set("quick", quick)
        .set("model", base.dnn.model.as_str())
        .set("dataset", base.dnn.dataset.as_str())
        .set("requests", requests);

    // ---- closed loop, concurrency 1: the calibration gate ------------
    println!("== Closed loop, concurrency 1: serving vs single-shot ==\n");
    let single = simulate(&base)?;
    let t0 = Instant::now();
    let c1 = serve::evaluate(&base.clone().with_serve_closed(1), &ctx)?;
    let c1_wall = t0.elapsed().as_secs_f64();
    let want_qps = 1.0e9 / single.total.latency_ns;
    let rel_err = (c1.throughput_qps - want_qps).abs() / want_qps;
    println!(
        "single-shot latency {:.3} ms => {:.2} inf/s; closed-1 delivered {:.2} inf/s (rel err {:.2e})",
        single.total.latency_ns / 1e6,
        want_qps,
        c1.throughput_qps,
        rel_err
    );
    assert!(
        rel_err < 0.01,
        "closed-loop concurrency 1 diverged from single-shot reciprocal: {rel_err}"
    );
    let mut co = Json::obj();
    co.set("concurrency_1_qps", c1.throughput_qps)
        .set("single_shot_qps", want_qps)
        .set("single_shot_ms", single.total.latency_ns / 1e6)
        .set("rel_err", rel_err)
        .set("sim_s", c1_wall);
    bench.set("closed_loop_calibration", co);

    println!(
        "\npipeline: {} stages, bottleneck stage {} at {:.3} ms => ceiling {:.2} inf/s\n",
        c1.num_stages,
        c1.bottleneck_stage,
        c1.bottleneck_service_ns / 1e6,
        c1.bottleneck_qps
    );
    bench
        .set("num_stages", c1.num_stages)
        .set("bottleneck_stage", c1.bottleneck_stage)
        .set("bottleneck_qps", c1.bottleneck_qps);

    // ---- open-loop saturation sweep ----------------------------------
    println!("== Open-loop saturation sweep (offered / bottleneck) ==\n");
    let fractions: &[f64] = if quick {
        &[0.5, 1.0, 2.0]
    } else {
        &[0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0]
    };
    let cap = c1.bottleneck_qps;
    let mut t = Table::new(&[
        "offered/cap",
        "offered inf/s",
        "delivered inf/s",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "shed %",
        "mean util %",
    ]);
    let mut sat = Vec::new();
    let mut last_delivered = 0.0;
    for &f in fractions {
        let rep = serve::evaluate(&base.clone().with_serve_open(f * cap), &ctx)?;
        t.row(&[
            format!("{f:.2}x"),
            format!("{:.1}", rep.offered_qps),
            format!("{:.1}", rep.throughput_qps),
            format!("{:.3}", rep.p50_ms),
            format!("{:.3}", rep.p95_ms),
            format!("{:.3}", rep.p99_ms),
            format!("{:.1}", 100.0 * rep.drop_rate()),
            format!("{:.1}", 100.0 * rep.mean_utilization),
        ]);
        let mut o = Json::obj();
        o.set("offered_fraction", f)
            .set("offered_qps", rep.offered_qps)
            .set("delivered_qps", rep.throughput_qps)
            .set("p50_ms", rep.p50_ms)
            .set("p95_ms", rep.p95_ms)
            .set("p99_ms", rep.p99_ms)
            .set("dropped", rep.dropped)
            .set("drop_rate", rep.drop_rate())
            .set("mean_utilization", rep.mean_utilization);
        sat.push(o);
        last_delivered = rep.throughput_qps;
    }
    t.print();
    // plateau gate: at 2x overload the delivered throughput sits at the
    // analytically computed bottleneck-stage service rate
    let plateau_rel_err = (last_delivered - cap).abs() / cap;
    assert!(
        plateau_rel_err < 0.05,
        "saturated throughput {last_delivered} diverged from bottleneck rate {cap}: {plateau_rel_err}"
    );
    println!(
        "\nplateau verified: delivered at 2.0x = {last_delivered:.1} inf/s vs analytic ceiling {cap:.1} inf/s (rel err {plateau_rel_err:.2e})\n"
    );
    bench.set("saturation", sat);
    let mut po = Json::obj();
    po.set("delivered_qps", last_delivered)
        .set("bottleneck_qps", cap)
        .set("rel_err", plateau_rel_err);
    bench.set("plateau", po);

    // ---- closed-loop concurrency ladder ------------------------------
    println!("== Closed-loop concurrency ladder ==\n");
    let concs: &[usize] = if quick { &[1, 8] } else { &[1, 2, 4, 8, 16, 32] };
    let mut t = Table::new(&[
        "concurrency",
        "delivered inf/s",
        "of ceiling %",
        "p99 ms",
        "mean util %",
        "uJ/inf under load",
    ]);
    let mut ladder = Vec::new();
    for &c in concs {
        let rep = serve::evaluate(&base.clone().with_serve_closed(c), &ctx)?;
        t.row(&[
            c.to_string(),
            format!("{:.1}", rep.throughput_qps),
            format!("{:.1}", 100.0 * rep.throughput_qps / cap),
            format!("{:.3}", rep.p99_ms),
            format!("{:.1}", 100.0 * rep.mean_utilization),
            format!("{:.2}", rep.energy_per_inference_pj / 1e6),
        ]);
        let mut o = Json::obj();
        o.set("concurrency", c)
            .set("delivered_qps", rep.throughput_qps)
            .set("p99_ms", rep.p99_ms)
            .set("mean_utilization", rep.mean_utilization)
            .set("energy_per_inference_pj", rep.energy_per_inference_pj);
        ladder.push(o);
    }
    t.print();
    bench.set("concurrency_ladder", ladder);

    // ---- machine-readable trajectory file ----------------------------
    let mut meta = RunMeta::for_config(&base);
    meta.model_source = single.model_source.clone();
    meta.wall_seconds = bench_t0.elapsed().as_secs_f64();
    bench.set("meta", meta.to_json());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    std::fs::write(path, bench.to_string_pretty() + "\n")?;
    println!("\nwrote {path}");
    Ok(())
}
