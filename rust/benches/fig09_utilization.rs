//! Fig. 9: IMC crossbar utilization of the *custom* chiplet architecture
//! across DNNs and tiles/chiplet. Paper shape: consistently >50 %;
//! ResNet-110 lowest; ResNet-50 / VGG-16 / VGG-19 above 75 %.

use siam::config::SiamConfig;
use siam::dnn::build_model;
use siam::mapping::map_dnn;
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("== Fig. 9: IMC utilization (custom architecture), % ==\n");
    let nets = [
        ("resnet110", "cifar10"),
        ("vgg19", "cifar100"),
        ("resnet50", "imagenet"),
        ("vgg16", "imagenet"),
    ];
    let tiles_opts = [4usize, 9, 16, 25, 36];

    let mut headers = vec!["network".to_string()];
    headers.extend(tiles_opts.iter().map(|t| format!("{t} t/c")));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr_refs);

    for (model, ds) in nets {
        let dnn = build_model(model, ds)?;
        let mut row = vec![model.to_string()];
        for &tiles in &tiles_opts {
            let cfg = SiamConfig::paper_default().with_tiles_per_chiplet(tiles);
            let map = map_dnn(&dnn, &cfg)?;
            row.push(format!("{:.1}", 100.0 * map.xbar_utilization()));
        }
        t.row(&row);
    }
    t.print();
    println!("\npaper shape: all >50%; ResNet-110 lowest; larger nets >75%.");
    Ok(())
}
