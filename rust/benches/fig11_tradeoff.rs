//! Fig. 11: NoP vs NoC trade-off for ResNet-110 on CIFAR-10.
//! (a) EDAP(NoP)/EDAP(NoC) ratio for homogeneous (several chiplet
//!     counts) and custom architectures vs tiles/chiplet — the ratio
//!     falls as tiles/chiplet grows; the custom curve is smallest and
//!     flattest.
//! (b) NoP and NoC EDP separately at 36 chiplets — NoP EDP falls,
//!     NoC EDP grows with tiles/chiplet.

use siam::config::SiamConfig;
use siam::dnn::build_model;
use siam::mapping::{build_traffic, map_dnn, Placement};
use siam::util::table::Table;

fn nets(cfg: &SiamConfig) -> anyhow::Result<(siam::noc::NocReport, siam::nop::NopReport)> {
    let dnn = build_model(&cfg.dnn.model, &cfg.dnn.dataset)?;
    let map = map_dnn(&dnn, cfg)?;
    let pl = Placement::new(map.num_chiplets);
    let traffic = build_traffic(&dnn, &map, &pl, cfg);
    Ok((
        siam::noc::evaluate(cfg, &traffic, map.num_chiplets),
        siam::nop::evaluate(cfg, &traffic, &pl),
    ))
}

fn main() -> anyhow::Result<()> {
    let tiles_opts = [4usize, 9, 16, 25, 36];

    println!("== Fig. 11a: EDAP(NoP) / EDAP(NoC), ResNet-110 ==\n");
    let mut headers = vec!["architecture".to_string()];
    headers.extend(tiles_opts.iter().map(|t| format!("{t} t/c")));
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr);
    for count in [Some(36usize), Some(64), Some(100), None] {
        let label = count
            .map(|c| format!("homogeneous {c}"))
            .unwrap_or_else(|| "custom".into());
        let mut row = vec![label];
        for &tiles in &tiles_opts {
            let mut cfg = SiamConfig::paper_default().with_tiles_per_chiplet(tiles);
            if let Some(c) = count {
                cfg = cfg.with_total_chiplets(c);
            }
            match nets(&cfg) {
                Ok((noc, nop)) => {
                    let ratio = nop.metrics.edap() / noc.metrics.edap().max(1e-30);
                    row.push(format!("{ratio:.2}"));
                }
                Err(_) => row.push("-".into()), // does not fit
            }
        }
        t.row(&row);
    }
    t.print();
    println!("\npaper shape: ratio falls with tiles/chiplet; custom smallest & flat.\n");

    println!("== Fig. 11b: NoP vs NoC EDP, 36 homogeneous chiplets ==\n");
    let mut t = Table::new(&["tiles/chiplet", "NoP EDP (pJ*ns)", "NoC EDP (pJ*ns)"]);
    for &tiles in &tiles_opts {
        let cfg = SiamConfig::paper_default()
            .with_tiles_per_chiplet(tiles)
            .with_total_chiplets(36);
        let (noc, nop) = nets(&cfg)?;
        t.row(&[
            tiles.to_string(),
            format!("{:.3e}", nop.metrics.edp()),
            format!("{:.3e}", noc.metrics.edp()),
        ]);
    }
    t.print();
    println!("\npaper shape: NoP EDP decreases, NoC EDP increases with tiles/chiplet.");
    Ok(())
}
