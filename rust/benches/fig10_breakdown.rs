//! Fig. 10: area / energy / latency breakdown into IMC circuit, NoC and
//! NoP for ResNet-110 on CIFAR-10 (custom RRAM chiplet architecture).
//! Paper shape: area dominated by NoP (~85 %); energy and latency
//! dominated by the IMC circuit (63.4 % / 69.7 %); NoC smallest in area
//! and energy.

use siam::config::SiamConfig;
use siam::coordinator::simulate;
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("== Fig. 10: component breakdown, ResNet-110 / CIFAR-10 (custom) ==\n");
    let rep = simulate(&SiamConfig::paper_default())?;
    let b = rep.component_breakdown();

    let mut t = Table::new(&["metric", "imc_circuit %", "noc %", "nop %"]);
    for (name, select) in [
        ("area", (|m: &siam::Metrics| m.area_um2) as fn(&siam::Metrics) -> f64),
        ("energy", |m| m.energy_pj),
        ("latency", |m| m.latency_ns),
    ] {
        let shares = b.shares(select);
        t.row(&[
            name.to_string(),
            format!("{:.1}", shares[0].1),
            format!("{:.1}", shares[1].1),
            format!("{:.1}", shares[2].1),
        ]);
    }
    t.print();
    println!("\npaper anchors: area NoP 84.7% (dominant), energy IMC 63.4%,");
    println!("latency IMC 69.7%; NoC contributes least to area and energy.");
    Ok(())
}
