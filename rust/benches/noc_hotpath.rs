//! L3 hot-path microbenchmark (§Perf): the interconnect simulation is
//! SIAM's dominant cost (the paper's BookSim runs are why VGG-16 takes
//! 4.26 h). This bench measures per-engine throughput — the flow-level
//! epoch engine against the per-packet scheduler — on synthetic and
//! real traces. The headline single-point speedup lives in
//! `table3_simtime` (and `BENCH_noc.json`); this binary is for quick
//! relative profiling while hacking on the engines.

use siam::config::SiamConfig;
use siam::dnn::build_model;
use siam::mapping::{build_traffic, map_dnn, Flow, Placement};
use siam::noc::{FlowSim, Mesh, PacketSim};
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) {
    // warm-up
    let mut total_packets = f();
    let t0 = Instant::now();
    for _ in 0..iters {
        total_packets = f();
    }
    let dt = t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "{name:<52} {:>10.3} ms/run   {:>8.1} Mpkt/s",
        dt * 1e3,
        total_packets as f64 / dt / 1e6
    );
}

fn main() -> anyhow::Result<()> {
    println!("== NoC/NoP hot-path throughput ==\n");

    // synthetic: uniform-random flows on a 6x6 mesh (irregular strides —
    // the flow-level engine delegates these to the per-packet scheduler,
    // so the two rows should roughly agree)
    let mesh = Mesh::new(36);
    let sim = PacketSim::new(&mesh);
    let mut flows = Vec::new();
    let mut rng = siam::util::Rng::new(1);
    for _ in 0..256 {
        let src = rng.below(36) as u32;
        let dst = rng.below(36) as u32;
        if src != dst {
            flows.push(Flow {
                src,
                dst,
                count: 2000,
                start: rng.below(8),
                stride: 1 + rng.below(4),
            });
        }
    }
    let total: u64 = flows.iter().map(|f| f.count).sum();
    bench("packet-level  synthetic 6x6 mesh, ~500k packets", 5, || {
        sim.run(&flows);
        total
    });
    let mut fsim = FlowSim::new(&mesh);
    bench("flow-level    synthetic 6x6 mesh, ~500k packets", 5, || {
        fsim.run(&flows);
        total
    });

    // real traces: all NoC epochs of ResNet-110 and ResNet-50
    for (model, ds) in [("resnet110", "cifar10"), ("resnet50", "imagenet")] {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model(model, ds)?;
        let map = map_dnn(&dnn, &cfg)?;
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let tile_mesh = Mesh::new(cfg.chiplet.tiles_per_chiplet);
        let tsim = PacketSim::new(&tile_mesh);
        let packets: u64 = traffic
            .noc_epochs
            .iter()
            .map(|e| Flow::total_packets(&e.flows))
            .sum();
        bench(
            &format!("packet-level  {model} full NoC trace ({packets} packets)"),
            3,
            || {
                for ep in &traffic.noc_epochs {
                    tsim.run(&ep.flows);
                }
                packets
            },
        );
        let mut fsim = FlowSim::new(&tile_mesh);
        bench(
            &format!("flow-level    {model} full NoC trace ({packets} packets)"),
            3,
            || {
                for ep in &traffic.noc_epochs {
                    fsim.run(&ep.flows);
                }
                packets
            },
        );
    }
    Ok(())
}
