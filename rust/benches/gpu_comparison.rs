//! Section 6.5: chiplet IMC (SIAM, 36 tiles/chiplet) vs Nvidia V100 and
//! T4 for batch-1 ResNet-50 / ImageNet. Paper anchors: IMC area 273 mm²
//! vs 525 (T4) / 815 (V100) mm²; energy-efficiency 130× (V100) and 72×
//! (T4).

use siam::config::SiamConfig;
use siam::coordinator::simulate;
use siam::gpu_baseline::{GpuBaseline, T4, V100};
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("== Section 6.5: SIAM chiplet IMC vs GPUs (ResNet-50, batch 1) ==\n");
    let cfg = SiamConfig::paper_default()
        .with_model("resnet50", "imagenet")
        .with_tiles_per_chiplet(36);
    let rep = simulate(&cfg)?;
    let imc_eff = rep.inferences_per_joule();

    let mut t = Table::new(&[
        "platform",
        "area mm2",
        "energy/inf mJ",
        "efficiency inf/J",
        "IMC advantage",
    ]);
    t.row(&[
        format!("SIAM IMC ({} chiplets)", rep.num_chiplets),
        format!("{:.0}", rep.total.area_mm2()),
        format!("{:.2}", rep.total.energy_mj()),
        format!("{imc_eff:.0}"),
        "1x".into(),
    ]);
    for gpu in [V100, T4] {
        let adv = imc_eff / gpu.inferences_per_joule();
        t.row(&[
            gpu.name.to_string(),
            format!("{:.0}", gpu.area_mm2),
            format!("{:.0}", gpu.energy_per_inference_mj()),
            format!("{:.1}", gpu.inferences_per_joule()),
            format!("{adv:.0}x"),
        ]);
    }
    t.print();

    let v = imc_eff / GpuBaseline::inferences_per_joule(&V100);
    let t4 = imc_eff / GpuBaseline::inferences_per_joule(&T4);
    println!("\nmeasured advantage: {v:.0}x vs V100, {t4:.0}x vs T4");
    println!("paper claims:       130x vs V100, 72x vs T4");
    println!("shape check: IMC wins by two orders of magnitude; V100/T4 ordering holds;");
    println!("IMC die area is the smallest of the three.");
    Ok(())
}
