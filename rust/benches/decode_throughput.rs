//! Autoregressive decode serving bench: prefill/decode split, KV-cache
//! residency and continuous batching for the zoo decoder (`gpt2_small`).
//!
//! Three sections, each gated on a calibration invariant before any
//! number is written:
//!
//! * **Closed loop, concurrency 1** — delivered tokens/s must equal the
//!   reciprocal of the analytic per-token latency (prefill amortised
//!   over the decode trajectory) within 1 %.
//! * **Continuous-batching ladder** — closed loop at batch cap `B`
//!   must strictly beat `B` sequential single-request runs (the fixed
//!   per-step cost amortises across the batch; the KV cache is sized to
//!   stay on chip so the identity is analytic).
//! * **KV-pressure sweep** — shrinking the global buffer must move the
//!   KV cache from fully resident (zero spill) to spilling through the
//!   DRAM model (non-zero spill bytes and latency).
//!
//! A final same-seed open-loop pair asserts bit-identical reports.
//! Every number is written to `BENCH_decode.json` at the repository
//! root (schema `siam-bench-decode/v1`). Pass `--quick` for the CI
//! smoke variant.

use siam::config::SiamConfig;
use siam::coordinator::SweepContext;
use siam::obs::RunMeta;
use siam::serve;
use siam::util::json::Json;
use siam::util::table::Table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench_t0 = Instant::now();
    let requests: usize = if quick { 8 } else { 32 };
    let tokens: usize = if quick { 8 } else { 32 };
    // Short prompt + a generous global buffer keep the KV cache fully
    // on chip for the calibration sections; the pressure sweep below
    // shrinks the buffer deliberately.
    let mut base = SiamConfig::paper_default()
        .with_model("gpt2_small", "seq32")
        .with_decode(tokens, 8, 1)
        .with_serve_requests(requests);
    base.system.global_buffer_kb = 64 * 1024;
    let ctx = SweepContext::new(&base)?;
    let mut bench = Json::obj();
    bench
        .set("schema", "siam-bench-decode/v1")
        .set("quick", quick)
        .set("model", base.dnn.model.as_str())
        .set("dataset", base.dnn.dataset.as_str())
        .set("requests", requests)
        .set("max_new_tokens", tokens);

    // ---- closed loop, concurrency 1: the calibration gate ------------
    println!("== Closed loop, concurrency 1: decode vs closed form ==\n");
    let t0 = Instant::now();
    let c1 = serve::evaluate_decode(&base.clone().with_serve_closed(1), &ctx)?;
    let c1_wall = t0.elapsed().as_secs_f64();
    let d1 = c1.decode.clone().expect("decode report");
    let want_tps = 1.0e9 / d1.per_token_ns;
    let rel_err = (d1.tokens_per_second - want_tps).abs() / want_tps;
    println!(
        "prefill {:.3} ms + {} decode steps => {:.2} tok/s closed form; delivered {:.2} tok/s (rel err {:.2e})",
        d1.prefill_ns / 1e6,
        d1.max_new_tokens - 1,
        want_tps,
        d1.tokens_per_second,
        rel_err
    );
    assert!(
        rel_err < 0.01,
        "closed-loop concurrency 1 diverged from per-token closed form: {rel_err}"
    );
    assert_eq!(
        d1.kv_spill_bytes_peak, 0,
        "calibration config must keep the KV cache on chip"
    );
    let mut co = Json::obj();
    co.set("concurrency_1_tokens_per_second", d1.tokens_per_second)
        .set("closed_form_tokens_per_second", want_tps)
        .set("per_token_ms", d1.per_token_ns / 1e6)
        .set("prefill_ms", d1.prefill_ns / 1e6)
        .set("ttft_p50_ms", d1.ttft_p50_ms)
        .set("tpot_p50_ms", d1.tpot_p50_ms)
        .set("rel_err", rel_err)
        .set("sim_s", c1_wall);
    bench.set("closed_loop_calibration", co);

    // ---- continuous-batching ladder ----------------------------------
    println!("\n== Continuous-batching ladder (closed loop at batch cap) ==\n");
    let caps: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut t = Table::new(&[
        "batch cap",
        "tok/s",
        "speedup",
        "TTFT p50 ms",
        "TPOT p50 ms",
        "occ peak",
        "KV peak kB",
    ]);
    let mut ladder = Vec::new();
    let mut tps_1 = 0.0;
    let mut tps_cap = 0.0;
    for &b in caps {
        let cfg = base
            .clone()
            .with_decode(tokens, 8, b)
            .with_serve_closed(b)
            .with_serve_requests(requests.max(b));
        let rep = serve::evaluate_decode(&cfg, &ctx)?;
        let d = rep.decode.clone().expect("decode report");
        if b == 1 {
            tps_1 = d.tokens_per_second;
        }
        tps_cap = d.tokens_per_second;
        t.row(&[
            b.to_string(),
            format!("{:.1}", d.tokens_per_second),
            format!("{:.2}x", d.tokens_per_second / tps_1),
            format!("{:.3}", d.ttft_p50_ms),
            format!("{:.3}", d.tpot_p50_ms),
            d.occupancy_peak.to_string(),
            format!("{:.1}", d.kv_peak_bytes as f64 / 1024.0),
        ]);
        let mut o = Json::obj();
        o.set("batch_cap", b)
            .set("tokens_per_second", d.tokens_per_second)
            .set("speedup", d.tokens_per_second / tps_1)
            .set("ttft_p50_ms", d.ttft_p50_ms)
            .set("tpot_p50_ms", d.tpot_p50_ms)
            .set("occupancy_peak", d.occupancy_peak)
            .set("occupancy_mean", d.occupancy_mean)
            .set("kv_peak_bytes", d.kv_peak_bytes as u64);
        ladder.push(o);
    }
    t.print();
    // batching gate: a batch of B sequential single-request runs takes
    // B times the closed-1 wall clock for the same token count, so
    // tokens/s at cap B must strictly exceed the closed-1 rate
    assert!(
        tps_cap > tps_1,
        "continuous batching at cap {} ({tps_cap} tok/s) failed to beat sequential ({tps_1} tok/s)",
        caps.last().unwrap()
    );
    println!(
        "\nbatching verified: cap {} delivers {:.1} tok/s vs {:.1} tok/s sequential ({:.2}x)\n",
        caps.last().unwrap(),
        tps_cap,
        tps_1,
        tps_cap / tps_1
    );
    bench.set("batching_ladder", ladder);
    let mut bo = Json::obj();
    bo.set("sequential_tokens_per_second", tps_1)
        .set("batched_tokens_per_second", tps_cap)
        .set("speedup", tps_cap / tps_1);
    bench.set("batching", bo);

    // ---- KV-pressure sweep -------------------------------------------
    println!("== KV-pressure sweep (global buffer kB vs spill) ==\n");
    let buffers_kb: &[usize] = if quick {
        &[64 * 1024, 256]
    } else {
        &[64 * 1024, 4096, 1024, 256]
    };
    let mut t = Table::new(&[
        "buffer kB",
        "KV peak kB",
        "spill peak kB",
        "spill ms",
        "tok/s",
    ]);
    let mut sweep = Vec::new();
    let mut spill_small = 0usize;
    let mut spill_large = usize::MAX;
    for &kb in buffers_kb {
        let mut cfg = base
            .clone()
            .with_decode(tokens, 8, 4)
            .with_serve_closed(4)
            .with_serve_requests(requests.max(4));
        cfg.system.global_buffer_kb = kb;
        let rep = serve::evaluate_decode(&cfg, &SweepContext::new(&cfg)?)?;
        let d = rep.decode.clone().expect("decode report");
        if kb == *buffers_kb.first().unwrap() {
            spill_large = d.kv_spill_bytes_peak;
        }
        spill_small = d.kv_spill_bytes_peak;
        t.row(&[
            kb.to_string(),
            format!("{:.1}", d.kv_peak_bytes as f64 / 1024.0),
            format!("{:.1}", d.kv_spill_bytes_peak as f64 / 1024.0),
            format!("{:.3}", d.spill_latency_ns / 1e6),
            format!("{:.1}", d.tokens_per_second),
        ]);
        let mut o = Json::obj();
        o.set("global_buffer_kb", kb)
            .set("kv_peak_bytes", d.kv_peak_bytes as u64)
            .set("kv_spill_bytes_peak", d.kv_spill_bytes_peak as u64)
            .set("spill_latency_ns", d.spill_latency_ns)
            .set("kv_nop_ns", d.kv_nop_ns)
            .set("tokens_per_second", d.tokens_per_second);
        sweep.push(o);
    }
    t.print();
    // pressure gate: resident at the large buffer, spilling at the small
    assert_eq!(spill_large, 0, "large buffer must hold the KV cache");
    assert!(
        spill_small > 0,
        "small buffer must force KV spill through the DRAM model"
    );
    println!("\npressure verified: spill 0 B at {} kB, {} B at {} kB\n", buffers_kb.first().unwrap(), spill_small, buffers_kb.last().unwrap());
    bench.set("kv_pressure", sweep);

    // ---- same-seed determinism gate ----------------------------------
    println!("== Same-seed determinism (open loop) ==\n");
    let mut open = base.clone().with_serve_open(0.0).with_decode(tokens, 8, 4);
    open.serve.seed = 42;
    let a = serve::evaluate_decode(&open, &ctx)?.to_json().to_string_pretty();
    let b = serve::evaluate_decode(&open, &ctx)?.to_json().to_string_pretty();
    assert_eq!(a, b, "same-seed decode runs must be bit-identical");
    println!("verified: two seed-42 open-loop reports are byte-identical\n");
    bench.set("determinism", {
        let mut o = Json::obj();
        o.set("seed", 42u64).set("bit_identical", true);
        o
    });

    // ---- machine-readable trajectory file ----------------------------
    let mut meta = RunMeta::for_config(&base);
    meta.model_source = c1.model_source.clone();
    meta.wall_seconds = bench_t0.elapsed().as_secs_f64();
    bench.set("meta", meta.to_json());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    std::fs::write(path, bench.to_string_pretty() + "\n")?;
    println!("wrote {path}");
    Ok(())
}
