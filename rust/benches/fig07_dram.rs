//! Fig. 7: DRAM engine experiments.
//! (a) EDP-prediction accuracy vs the fraction of the 3000-instruction
//!     stream actually simulated (paper: 50 % ⇒ <2 % EDP error).
//! (b) DRAM EDP (DDR4) across DNNs (paper: exponential growth with
//!     model size).

use siam::config::{DramConfig, DramKind, SiamConfig};
use siam::dnn::build_model;
use siam::dram;
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("== Fig. 7a: EDP accuracy vs simulated instruction fraction ==\n");
    let bytes = 3000 * 64; // the paper's 3000-instruction experiment
    let full = dram::estimate_with(
        bytes,
        &DramConfig {
            kind: DramKind::Ddr4,
            bus_bits: 64,
            subset_fraction: 1.0,
        },
    );
    let mut t = Table::new(&["fraction %", "EDP (pJ*ns)", "error %", "sim requests"]);
    for pct in [10, 25, 50, 75, 100] {
        let rep = dram::estimate_with(
            bytes,
            &DramConfig {
                kind: DramKind::Ddr4,
                bus_bits: 64,
                subset_fraction: pct as f64 / 100.0,
            },
        );
        let err = 100.0 * (rep.edp() - full.edp()).abs() / full.edp();
        t.row(&[
            pct.to_string(),
            format!("{:.4e}", rep.edp()),
            format!("{err:.2}"),
            format!("{:.0}", rep.requests as f64 * rep.simulated_fraction),
        ]);
    }
    t.print();
    println!("\npaper anchor: 50% of instructions ⇒ <2% EDP degradation.\n");

    println!("== Fig. 7b: DRAM EDP (DDR4) across DNNs ==\n");
    let mut t = Table::new(&["network", "model MB", "latency ms", "energy mJ", "EDP (pJ*ns)"]);
    for (model, ds) in [
        ("resnet110", "cifar10"),
        ("resnet50", "imagenet"),
        ("vgg19", "cifar100"),
        ("vgg16", "imagenet"),
    ] {
        let stats = build_model(model, ds)?.stats();
        let cfg = SiamConfig::paper_default();
        let rep = dram::estimate(&stats, &cfg);
        t.row(&[
            model.into(),
            format!("{:.1}", stats.model_bytes(8) as f64 / 1e6),
            format!("{:.2}", rep.latency_ns / 1e6),
            format!("{:.2}", rep.energy_pj / 1e9),
            format!("{:.3e}", rep.edp()),
        ]);
    }
    t.print();
    println!("\npaper shape: EDP grows super-linearly (~quadratically) with model size.");
    Ok(())
}
