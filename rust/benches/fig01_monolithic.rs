//! Fig. 1a: total chip area and normalized fabrication cost of a
//! *monolithic* RRAM IMC architecture across DNNs. Paper shape: area
//! grows with model size up to ~1200 mm² (DenseNet-110); cost grows
//! exponentially with area.

use siam::config::{ChipMode, SiamConfig};
use siam::coordinator::simulate;
use siam::cost::CostModel;
use siam::util::table::{eng, Table};

fn main() -> anyhow::Result<()> {
    println!("== Fig. 1a: monolithic IMC area & fabrication cost ==\n");
    let nets = [
        ("lenet5", "cifar10"),
        ("resnet110", "cifar10"),
        ("nin", "cifar10"),
        ("vgg19", "cifar100"),
        ("resnet50", "imagenet"),
        ("densenet110", "cifar10"),
        ("vgg16", "imagenet"),
    ];
    let cost = CostModel::default();
    let mut t = Table::new(&["network", "tiles", "area mm2", "norm. cost", "yield %"]);
    for (model, ds) in nets {
        let cfg = SiamConfig::paper_default()
            .with_model(model, ds)
            .with_chip_mode(ChipMode::Monolithic);
        let rep = simulate(&cfg)?;
        let area = rep.total.area_mm2();
        t.row(&[
            model.into(),
            rep.total_tiles.to_string(),
            eng(area),
            format!("{:.3}", cost.normalized_die_cost(area)),
            format!("{:.1}", 100.0 * cost.yield_of(area)),
        ]);
    }
    t.print();
    println!("\npaper anchors: ResNet-50 ≈ 802 tiles; DenseNet-110 ≈ 2184 tiles / ~1200 mm²;");
    println!("cost grows super-linearly (log-scale in the paper) with area. ");
    Ok(())
}
