//! Fig. 14: calibration against SIMBA silicon (Section 6.4).
//! (a) total energy vs tiles/chiplet (ResNet-50, VGG-16 / ImageNet);
//! (b) ResNet-110 latency + throughput vs chiplet count;
//! (c) normalized per-layer latency vs chiplet count for res3a_branch1
//!     and res5[a-c]_branch2b, printed next to the digitized SIMBA
//!     series;
//! (d) normalized PE cycles vs NoP speed-up, next to SIMBA's.

use siam::config::SiamConfig;
use siam::coordinator::{
    layer_cycles_vs_nop_speedup, layer_latency_vs_chiplets, simulate,
};
use siam::dnn::build_model;
use siam::util::table::{eng, Table};

/// Digitized trends from the SIMBA paper's figures (normalized to the
/// 1-chiplet / 1× point) — the comparison series the paper overlays.
const SIMBA_RES3A: &[(usize, f64)] = &[(1, 1.0), (2, 0.52), (4, 0.30), (8, 0.22), (16, 0.26)];
const SIMBA_RES5: &[(usize, f64)] = &[(1, 1.0), (2, 0.55), (4, 0.32), (8, 0.21)];
const SIMBA_NOP_SPEEDUP: &[(f64, f64)] = &[(1.0, 1.0), (2.0, 0.72), (4.0, 0.58), (8.0, 0.52)];

fn main() -> anyhow::Result<()> {
    // ---- (a)
    println!("== Fig. 14a: total energy vs tiles/chiplet (custom) ==\n");
    let mut t = Table::new(&["network", "tiles/chiplet", "chiplets", "energy uJ"]);
    for (model, ds) in [("resnet50", "imagenet"), ("vgg16", "imagenet")] {
        for tiles in [9usize, 16, 25, 36] {
            let rep = simulate(
                &SiamConfig::paper_default()
                    .with_model(model, ds)
                    .with_tiles_per_chiplet(tiles),
            )?;
            t.row(&[
                model.into(),
                tiles.to_string(),
                rep.num_chiplets.to_string(),
                eng(rep.total.energy_uj()),
            ]);
        }
    }
    t.print();
    println!("\nSIMBA trend: energy falls with more tiles/chiplet (fewer chiplets). \n");

    // ---- (b)
    println!("== Fig. 14b: ResNet-110 latency/throughput vs chiplet count ==\n");
    let mut t = Table::new(&["chiplets", "latency ms", "throughput inf/s"]);
    for count in [9usize, 16, 25, 36, 49, 64] {
        let rep = simulate(&SiamConfig::paper_default().with_total_chiplets(count))?;
        t.row(&[
            count.to_string(),
            eng(rep.total.latency_ms()),
            format!("{:.1}", rep.inferences_per_second()),
        ]);
    }
    t.print();
    println!("\nSIMBA/paper trend: small DNNs prefer few chiplets (latency rises with");
    println!("count). Our snake placement keeps round-robin neighbours adjacent, so");
    println!("the penalty is mostly flat here — deviation documented in EXPERIMENTS.md.\n");

    // ---- (c)  (SIMBA-like NoP bandwidth: SIMBA's GRS links are ~4x
    //             faster than the paper's default SIAM NoP budget)
    println!("== Fig. 14c: normalized layer latency vs chiplet count ==\n");
    let cfg = SiamConfig::paper_default().with_nop_speedup(4.0);
    let dnn = build_model("resnet50", "imagenet")?;
    for (layer, simba, counts) in [
        ("res3a_branch1", SIMBA_RES3A, &[1usize, 2, 4, 8, 16][..]),
        ("res5a_branch2b", SIMBA_RES5, &[1, 2, 4, 8][..]),
        ("res5b_branch2b", SIMBA_RES5, &[1, 2, 4, 8][..]),
        ("res5c_branch2b", SIMBA_RES5, &[1, 2, 4, 8][..]),
    ] {
        let pts = layer_latency_vs_chiplets(&cfg, &dnn, layer, counts)
            .ok_or_else(|| anyhow::anyhow!("layer {layer} not found"))?;
        let norm = pts[0].total_ns();
        let mut t = Table::new(&["chiplets", "SIAM (norm.)", "SIMBA silicon (norm.)"]);
        for p in &pts {
            let simba_v = simba
                .iter()
                .find(|(k, _)| *k == p.chiplets)
                .map(|(_, v)| format!("{v:.2}"))
                .unwrap_or_else(|| "-".into());
            t.row(&[
                p.chiplets.to_string(),
                format!("{:.2}", p.total_ns() / norm),
                simba_v,
            ]);
        }
        println!("layer {layer}:");
        t.print();
        println!();
    }

    // ---- (d)
    println!("== Fig. 14d: normalized PE cycles vs NoP speed-up (res3a_branch1, 4 chiplets) ==\n");
    let pts = layer_cycles_vs_nop_speedup(&cfg, &dnn, "res3a_branch1", 4, &[1.0, 2.0, 4.0, 8.0])
        .ok_or_else(|| anyhow::anyhow!("layer not found"))?;
    let mut t = Table::new(&["NoP speed-up", "SIAM (norm.)", "SIMBA silicon (norm.)"]);
    for (s, v) in &pts {
        let simba_v = SIMBA_NOP_SPEEDUP
            .iter()
            .find(|(k, _)| k == s)
            .map(|(_, v)| format!("{v:.2}"))
            .unwrap_or_else(|| "-".into());
        t.row(&[format!("{s}x"), format!("{v:.2}"), simba_v]);
    }
    t.print();
    println!("\nboth decrease with NoP bandwidth and saturate — matching SIMBA.");
    Ok(())
}
