//! Table 3: SIAM simulation (wall-clock) time per DNN, plus the Section
//! 6.6 comparison points. Paper (Xeon W-2133): ResNet-110 0.2 h, VGG-19
//! 0.36 h, ResNet-50 1.26 h, VGG-16 4.26 h — the *ordering* and the
//! roughly size-proportional growth are the reproducible shape (our
//! substrate is a Rust reimplementation, so absolute times are far
//! smaller).
//!
//! The second section measures what Table 3 is really about —
//! design-space-exploration throughput: the same Fig. 11/12 grid swept
//! by the serial reference engine and by the parallel memoizing engine
//! (`SweepBuilder`), with the rankings cross-checked point by point.
//! This is the before/after evidence for the sweep-engine rework logged
//! in CHANGES.md.

use siam::config::SiamConfig;
use siam::coordinator::{simulate, SweepBuilder};
use siam::util::table::Table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    println!("== Table 3: SIAM simulation time ==\n");
    let nets = [
        ("resnet110", "cifar10", 0.20),
        ("vgg19", "cifar100", 0.36),
        ("resnet50", "imagenet", 1.26),
        ("vgg16", "imagenet", 4.26),
    ];
    let mut t = Table::new(&[
        "network",
        "model size (M)",
        "sim time (s)",
        "paper (hours)",
        "paper-normalized",
    ]);
    let mut first: Option<f64> = None;
    for (model, ds, paper_h) in nets {
        let cfg = SiamConfig::paper_default().with_model(model, ds);
        let t0 = Instant::now();
        let rep = simulate(&cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let base = *first.get_or_insert(secs);
        t.row(&[
            model.into(),
            format!("{:.1}", rep.params as f64 / 1e6),
            format!("{secs:.3}"),
            format!("{paper_h:.2}"),
            format!("{:.1}x vs ResNet-110 (paper: {:.1}x)", secs / base, paper_h / 0.20),
        ]);
    }
    t.print();
    println!("\npaper shape: simulation time grows with model size;");
    println!("VGG-16 is the slowest, ResNet-110 the fastest.\n");

    println!("== DSE sweep wall-clock: serial vs parallel engine ==\n");
    let tiles = [4usize, 9, 16, 25, 36];
    let counts = [Some(16), Some(36), Some(64), Some(100), None];
    let mut t = Table::new(&[
        "network",
        "points",
        "serial (s)",
        "parallel (s)",
        "speedup",
        "epoch cache",
    ]);
    for (model, ds) in [("resnet110", "cifar10"), ("vgg19", "cifar100")] {
        let base = SiamConfig::paper_default().with_model(model, ds);
        let builder = SweepBuilder::new(&base).tiles(&tiles).chiplet_counts(&counts);

        let t0 = Instant::now();
        let serial = builder.clone().serial().run()?;
        let serial_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let parallel = builder.run()?;
        let parallel_s = t0.elapsed().as_secs_f64();

        // correctness gate: identical surviving points in identical order
        assert_eq!(serial.len(), parallel.len(), "{model}: point count differs");
        for (s, p) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(s.tiles_per_chiplet, p.tiles_per_chiplet);
            assert_eq!(s.total_chiplets, p.total_chiplets);
            assert_eq!(
                s.edap().to_bits(),
                p.edap().to_bits(),
                "{model}: EDAP diverged at {} t/c",
                s.tiles_per_chiplet
            );
        }

        t.row(&[
            model.into(),
            parallel.len().to_string(),
            format!("{serial_s:.2}"),
            format!("{parallel_s:.2}"),
            format!("{:.1}x", serial_s / parallel_s.max(1e-9)),
            "shared".into(),
        ]);
    }
    t.print();
    println!("\nrankings verified bit-identical between engines.");
    Ok(())
}
