//! Table 3: SIAM simulation (wall-clock) time per DNN, plus the Section
//! 6.6 comparison points. Paper (Xeon W-2133): ResNet-110 0.2 h, VGG-19
//! 0.36 h, ResNet-50 1.26 h, VGG-16 4.26 h — the *ordering* and the
//! roughly size-proportional growth are the reproducible shape (our
//! substrate is a Rust reimplementation, so absolute times are far
//! smaller).

use siam::config::SiamConfig;
use siam::coordinator::simulate;
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    println!("== Table 3: SIAM simulation time ==\n");
    let nets = [
        ("resnet110", "cifar10", 0.20),
        ("vgg19", "cifar100", 0.36),
        ("resnet50", "imagenet", 1.26),
        ("vgg16", "imagenet", 4.26),
    ];
    let mut t = Table::new(&[
        "network",
        "model size (M)",
        "sim time (s)",
        "paper (hours)",
        "paper-normalized",
    ]);
    let mut first: Option<f64> = None;
    for (model, ds, paper_h) in nets {
        let cfg = SiamConfig::paper_default().with_model(model, ds);
        let t0 = std::time::Instant::now();
        let rep = simulate(&cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let base = *first.get_or_insert(secs);
        t.row(&[
            model.into(),
            format!("{:.1}", rep.params as f64 / 1e6),
            format!("{secs:.3}"),
            format!("{paper_h:.2}"),
            format!("{:.1}x vs ResNet-110 (paper: {:.1}x)", secs / base, paper_h / 0.20),
        ]);
    }
    t.print();
    println!("\npaper shape: simulation time grows with model size;");
    println!("VGG-16 is the slowest, ResNet-110 the fastest.");
    Ok(())
}
