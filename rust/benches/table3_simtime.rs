//! Table 3: SIAM simulation (wall-clock) time per DNN, plus the Section
//! 6.6 comparison points. Paper (Xeon W-2133): ResNet-110 0.2 h, VGG-19
//! 0.36 h, ResNet-50 1.26 h, VGG-16 4.26 h — the *ordering* and the
//! roughly size-proportional growth are the reproducible shape (our
//! substrate is a Rust reimplementation, so absolute times are far
//! smaller).
//!
//! The later sections measure what Table 3 is really about — simulation
//! throughput:
//!
//! * **Epoch engine** — the flow-level engine (`FlowSim`) against the
//!   per-packet scheduler (`PacketSim`) on the full ResNet-110
//!   paper-default trace, single point, no caching: the tentpole
//!   speedup of the three-tier interconnect rework (target ≥5×).
//! * **DSE sweep** — the Fig. 11/12 grid swept by the serial reference
//!   engine and the parallel memoizing engine (`SweepBuilder`), with
//!   the rankings cross-checked point by point and the sharded epoch
//!   cache's hit rate reported.
//! * **Persistent cache** — the same grid swept cold (fresh
//!   `--cache-file`) and warm (re-run against the file the cold sweep
//!   wrote): the warm run must replay without a single epoch miss,
//!   rank bit-identically, and beat the cold run ≥10× (full grid
//!   only — the `--quick` grid is too small for a stable ratio).
//!
//! Every number is also written to `BENCH_noc.json` at the repository
//! root (see README, "Reading BENCH_noc.json") so the perf trajectory
//! is tracked across PRs. Pass `--quick` (CI smoke mode) to shrink the
//! grids to a seconds-scale run.

use siam::config::SiamConfig;
use siam::coordinator::{simulate, SweepBuilder};
use siam::dnn::build_model;
use siam::mapping::{build_traffic, map_dnn, Flow, Placement, Traffic};
use siam::noc::{EpochResult, FlowSim, Mesh, PacketSim};
use siam::obs::{Profiler, RunMeta};
use siam::util::json::Json;
use siam::util::table::Table;
use std::sync::Arc;
use std::time::Instant;

/// Serial accumulation of every NoC + NoP epoch of a traffic picture
/// under one engine — the single-point epoch-simulation workload.
fn run_all_epochs<F, G>(traffic: &Traffic, mut noc: F, mut nop: G) -> EpochResult
where
    F: FnMut(&[Flow]) -> EpochResult,
    G: FnMut(&[Flow]) -> EpochResult,
{
    let mut total = EpochResult::default();
    for ep in &traffic.noc_epochs {
        total.accumulate(&noc(&ep.flows));
    }
    for ep in &traffic.nop_epochs {
        total.accumulate(&nop(&ep.flows));
    }
    total
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench_t0 = Instant::now();
    let mut bench = Json::obj();
    bench.set("schema", "siam-bench-noc/v2").set("quick", quick);

    // ---- Table 3: end-to-end simulation time per DNN -----------------
    println!("== Table 3: SIAM simulation time ==\n");
    let nets: &[(&str, &str, f64)] = if quick {
        &[("resnet110", "cifar10", 0.20)]
    } else {
        &[
            ("resnet110", "cifar10", 0.20),
            ("vgg19", "cifar100", 0.36),
            ("resnet50", "imagenet", 1.26),
            ("vgg16", "imagenet", 4.26),
        ]
    };
    let mut t = Table::new(&[
        "network",
        "model size (M)",
        "sim time (s)",
        "paper (hours)",
        "paper-normalized",
    ]);
    let mut table3 = Vec::new();
    let mut first: Option<f64> = None;
    for &(model, ds, paper_h) in nets {
        let cfg = SiamConfig::paper_default().with_model(model, ds);
        let t0 = Instant::now();
        let rep = simulate(&cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let base = *first.get_or_insert(secs);
        t.row(&[
            model.into(),
            format!("{:.1}", rep.params as f64 / 1e6),
            format!("{secs:.3}"),
            format!("{paper_h:.2}"),
            format!("{:.1}x vs ResNet-110 (paper: {:.1}x)", secs / base, paper_h / 0.20),
        ]);
        let mut o = Json::obj();
        o.set("model", model).set("sim_s", secs).set("paper_hours", paper_h);
        table3.push(o);
    }
    t.print();
    println!("\npaper shape: simulation time grows with model size;");
    println!("VGG-16 is the slowest, ResNet-110 the fastest.\n");
    bench.set("table3", table3);

    // ---- Epoch engine: flow-level vs per-packet ----------------------
    println!("== Epoch engine: flow-level vs per-packet (ResNet-110 paper default) ==\n");
    let cfg = SiamConfig::paper_default();
    let dnn = build_model("resnet110", "cifar10")?;
    let map = map_dnn(&dnn, &cfg)?;
    let pl = Placement::new(map.num_chiplets);
    let traffic = build_traffic(&dnn, &map, &pl, &cfg);
    let noc_mesh = Mesh::new(cfg.chiplet.tiles_per_chiplet.max(2));
    let nop_mesh = Mesh::from_placement(&pl);
    let epochs = traffic.noc_epochs.len() + traffic.nop_epochs.len();
    let packets: u64 = traffic
        .noc_epochs
        .iter()
        .chain(&traffic.nop_epochs)
        .map(|e| Flow::total_packets(&e.flows))
        .sum();

    let iters = if quick { 2 } else { 5 };
    let time_engine = |run: &mut dyn FnMut() -> EpochResult| -> (f64, EpochResult) {
        let mut total = run(); // warm-up (also the checked result)
        let t0 = Instant::now();
        for _ in 0..iters {
            total = run();
        }
        (t0.elapsed().as_secs_f64() / iters as f64, total)
    };

    let p_noc = PacketSim::new(&noc_mesh);
    let p_nop = PacketSim::new(&nop_mesh);
    let (packet_s, packet_total) = time_engine(&mut || {
        run_all_epochs(&traffic, |f| p_noc.run(f), |f| p_nop.run(f))
    });

    let mut f_noc = FlowSim::new(&noc_mesh);
    let mut f_nop = FlowSim::new(&nop_mesh);
    let (flow_s, flow_total) = time_engine(&mut || {
        run_all_epochs(&traffic, |f| f_noc.run(f), |f| f_nop.run(f))
    });

    // correctness gates. (1) conservation is exact by construction.
    assert_eq!(packet_total.packets, flow_total.packets, "packet conservation");
    assert_eq!(packet_total.flit_hops, flow_total.flit_hops, "flit-hop conservation");
    // (2) hard gate: the flow-level engine's exactness contract is
    // against the brute-force (no-extrapolation) schedule — assert it
    // bit-for-bit on a deterministic subset of epochs.
    let mut brute_noc = PacketSim::new(&noc_mesh);
    brute_noc.extrapolate = false;
    let mut check_noc = FlowSim::new(&noc_mesh);
    for (i, ep) in traffic.noc_epochs.iter().enumerate().step_by(7) {
        assert_eq!(
            check_noc.run(&ep.flows),
            brute_noc.run(&ep.flows),
            "flow-level diverged from brute force on NoC epoch {i}"
        );
    }
    let mut brute_nop = PacketSim::new(&nop_mesh);
    brute_nop.extrapolate = false;
    let mut check_nop = FlowSim::new(&nop_mesh);
    for (i, ep) in traffic.nop_epochs.iter().enumerate().step_by(7) {
        assert_eq!(
            check_nop.run(&ep.flows),
            brute_nop.run(&ep.flows),
            "flow-level diverged from brute force on NoP epoch {i}"
        );
    }
    // (3) soft gate: the two production engines arm their (individually
    // exact-in-practice) steady-state extrapolations at different
    // rounds, so agreement is asserted within 1% and the exact residual
    // is recorded for trend tracking.
    let rel_err = (packet_total.completion_cycles as f64 - flow_total.completion_cycles as f64)
        .abs()
        / packet_total.completion_cycles.max(1) as f64;
    assert!(rel_err <= 1e-2, "completion diverged: rel {rel_err}");
    let exact = packet_total == flow_total;

    let speedup = packet_s / flow_s.max(1e-12);
    let mut t = Table::new(&["engine", "ms / full trace", "Mpkt/s", "vs packet-level"]);
    for (name, secs) in [("packet-level", packet_s), ("flow-level", flow_s)] {
        t.row(&[
            name.into(),
            format!("{:.3}", secs * 1e3),
            format!("{:.1}", packets as f64 / secs / 1e6),
            format!("{:.1}x", packet_s / secs.max(1e-12)),
        ]);
    }
    t.print();
    println!(
        "\n{epochs} epochs, {packets} packets; engines {} (completion rel err {rel_err:.2e})\n",
        if exact { "exactly identical" } else { "within tolerance" }
    );

    let mut eo = Json::obj();
    eo.set("trace", "resnet110 paper-default (all NoC+NoP epochs)")
        .set("epochs", epochs)
        .set("packets", packets)
        .set("packet_ms", packet_s * 1e3)
        .set("flow_ms", flow_s * 1e3)
        .set("speedup", speedup)
        .set("engines_exact", exact)
        .set("completion_rel_err", rel_err);
    bench.set("epoch_engine", eo);

    // ---- DSE sweep: serial vs parallel engine ------------------------
    println!("== DSE sweep wall-clock: serial vs parallel engine ==\n");
    let tiles: &[usize] = if quick { &[9, 16] } else { &[4, 9, 16, 25, 36] };
    let counts: &[Option<usize>] = if quick {
        &[None]
    } else {
        &[Some(16), Some(36), Some(64), Some(100), None]
    };
    let sweep_nets: &[(&str, &str)] = if quick {
        &[("resnet110", "cifar10")]
    } else {
        &[("resnet110", "cifar10"), ("vgg19", "cifar100")]
    };
    let mut t = Table::new(&[
        "network",
        "points",
        "serial (s)",
        "parallel (s)",
        "speedup",
        "epoch cache",
    ]);
    let mut sweeps = Vec::new();
    // one profiler across every parallel sweep: its per-stage host
    // wall-clock breakdown lands in the "profile" fragment below
    let prof = Arc::new(Profiler::new());
    for &(model, ds) in sweep_nets {
        let base = SiamConfig::paper_default().with_model(model, ds);
        let builder = SweepBuilder::new(&base).tiles(tiles).chiplet_counts(counts);

        let t0 = Instant::now();
        let serial = builder.clone().serial().run()?;
        let serial_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let parallel = builder.profile(prof.clone()).run()?;
        let parallel_s = t0.elapsed().as_secs_f64();

        // correctness gate: identical surviving points in identical order
        assert_eq!(serial.len(), parallel.len(), "{model}: point count differs");
        for (s, p) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(s.tiles_per_chiplet, p.tiles_per_chiplet);
            assert_eq!(s.total_chiplets, p.total_chiplets);
            assert_eq!(
                s.edap().to_bits(),
                p.edap().to_bits(),
                "{model}: EDAP diverged at {} t/c",
                s.tiles_per_chiplet
            );
        }

        let hit_rate = parallel.stats.epoch_hit_rate();
        t.row(&[
            model.into(),
            parallel.len().to_string(),
            format!("{serial_s:.2}"),
            format!("{parallel_s:.2}"),
            format!("{:.1}x", serial_s / parallel_s.max(1e-9)),
            format!("{:.0}% hits", 100.0 * hit_rate),
        ]);
        let mut o = Json::obj();
        o.set("model", model)
            .set("points", parallel.len())
            .set("serial_s", serial_s)
            .set("parallel_s", parallel_s)
            .set("speedup", serial_s / parallel_s.max(1e-9))
            .set("epoch_cache_hits", parallel.stats.epoch_hits)
            .set("epoch_cache_misses", parallel.stats.epoch_misses)
            .set("epoch_cache_hit_rate", hit_rate);
        sweeps.push(o);
    }
    t.print();
    println!("\nrankings verified bit-identical between engines.");
    bench.set("sweeps", sweeps);
    bench.set("profile", prof.to_json());

    // ---- persistent epoch cache: cold vs warm re-sweep ---------------
    println!("\n== Persistent epoch cache: cold vs warm re-sweep ==\n");
    let cache_dir = std::env::temp_dir().join("siam_bench_cache");
    std::fs::create_dir_all(&cache_dir)?;
    let cache_path = cache_dir.join(format!("table3_{}.cache", std::process::id()));
    let _ = std::fs::remove_file(&cache_path);
    let cache_str = cache_path.to_str().expect("utf-8 temp path").to_string();
    let base = SiamConfig::paper_default();
    let cached_builder =
        || SweepBuilder::new(&base).tiles(tiles).chiplet_counts(counts).cache_file(&cache_str);

    let t0 = Instant::now();
    let cold = cached_builder().run()?;
    let cold_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let warm = cached_builder().run()?;
    let warm_s = t0.elapsed().as_secs_f64();
    let _ = std::fs::remove_file(&cache_path);

    // correctness gates: a warm run replays — it never re-simulates and
    // never changes a ranking
    assert_eq!(warm.stats.epoch_misses, 0, "warm sweep re-simulated an epoch");
    assert!(warm.stats.epochs_hydrated > 0, "warm sweep hydrated nothing");
    // every grid point — evaluated or skipped as too small — was
    // fingerprinted by the cold run
    assert_eq!(
        warm.stats.points_known,
        tiles.len() * counts.len(),
        "incremental bookkeeping lost points"
    );
    assert_eq!(cold.len(), warm.len(), "cold/warm point count differs");
    for (c, w) in cold.points.iter().zip(&warm.points) {
        assert_eq!(c.tiles_per_chiplet, w.tiles_per_chiplet);
        assert_eq!(
            c.edap().to_bits(),
            w.edap().to_bits(),
            "warm EDAP diverged at {} t/c",
            c.tiles_per_chiplet
        );
    }
    let warm_speedup = cold_s / warm_s.max(1e-9);
    // perf gate: replaying epochs from disk must dominate re-simulating
    // them. Only on the full grid — the --quick smoke grid is too small
    // for a stable ratio.
    if !quick {
        assert!(
            warm_speedup >= 10.0,
            "warm re-sweep only {warm_speedup:.1}x over cold (gate: >=10x)"
        );
    }
    let mut t = Table::new(&["run", "wall (s)", "epoch misses", "hydrated", "speedup"]);
    t.row(&[
        "cold".into(),
        format!("{cold_s:.2}"),
        cold.stats.epoch_misses.to_string(),
        cold.stats.epochs_hydrated.to_string(),
        "1.0x".into(),
    ]);
    t.row(&[
        "warm".into(),
        format!("{warm_s:.2}"),
        warm.stats.epoch_misses.to_string(),
        warm.stats.epochs_hydrated.to_string(),
        format!("{warm_speedup:.1}x"),
    ]);
    t.print();
    println!("\nwarm rankings verified bit-identical to cold.");
    let mut co = Json::obj();
    co.set("grid_points", cold.len())
        .set("cold_s", cold_s)
        .set("warm_s", warm_s)
        .set("speedup", warm_speedup)
        .set("cold_misses", cold.stats.epoch_misses)
        .set("warm_misses", warm.stats.epoch_misses)
        .set("warm_hydrated", warm.stats.epochs_hydrated)
        .set("points_known", warm.stats.points_known);
    bench.set("persistent_cache", co);

    // ---- machine-readable trajectory file ----------------------------
    let mut meta = RunMeta::for_config(&SiamConfig::paper_default());
    meta.model_source = "builtin".into();
    meta.wall_seconds = bench_t0.elapsed().as_secs_f64();
    bench.set("meta", meta.to_json());
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_noc.json");
    std::fs::write(path, bench.to_string_pretty() + "\n")?;
    println!("\nwrote {path}");
    Ok(())
}
