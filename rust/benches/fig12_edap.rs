//! Fig. 12: overall EDAP (a) and total area (b) of homogeneous (16-100
//! chiplets) and custom RRAM chiplet architectures for ResNet-110 /
//! CIFAR-10, vs tiles per chiplet. Paper shape: custom beats
//! homogeneous; EDAP improves with more tiles/chiplet; homogeneous area
//! grows with tiles/chiplet while custom area shrinks.

use siam::config::SiamConfig;
use siam::coordinator::simulate;
use siam::util::table::Table;

fn main() -> anyhow::Result<()> {
    let tiles_opts = [4usize, 9, 16, 25, 36];
    let counts: [Option<usize>; 4] = [Some(36), Some(64), Some(100), None];

    for (name, select) in [
        (
            "Fig. 12a: overall EDAP (pJ*ns*mm2)",
            (|r: &siam::coordinator::SimReport| format!("{:.3e}", r.total.edap()))
                as fn(&siam::coordinator::SimReport) -> String,
        ),
        ("Fig. 12b: total area (mm2)", |r| {
            format!("{:.1}", r.total.area_mm2())
        }),
    ] {
        println!("== {name}, ResNet-110 / CIFAR-10 ==\n");
        let mut headers = vec!["architecture".to_string()];
        headers.extend(tiles_opts.iter().map(|t| format!("{t} t/c")));
        let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut t = Table::new(&hdr);
        for count in counts {
            let label = count
                .map(|c| format!("homogeneous {c}"))
                .unwrap_or_else(|| "custom".into());
            let mut row = vec![label];
            for &tiles in &tiles_opts {
                let mut cfg = SiamConfig::paper_default().with_tiles_per_chiplet(tiles);
                if let Some(c) = count {
                    cfg = cfg.with_total_chiplets(c);
                }
                match simulate(&cfg) {
                    Ok(rep) => row.push(select(&rep)),
                    Err(_) => row.push("-".into()),
                }
            }
            t.row(&row);
        }
        t.print();
        println!();
    }
    println!("paper shape: custom < homogeneous EDAP everywhere; homogeneous area");
    println!("grows with tiles/chiplet (fixed count × bigger chiplet), custom shrinks.");
    Ok(())
}
