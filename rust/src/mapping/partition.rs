//! Algorithm 1: layer-wise partition of a DNN onto IMC chiplets.

use crate::config::{ChipMode, ChipletStructure, SiamConfig};
use crate::dnn::Dnn;

/// Crossbars a layer occupies on one chiplet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChipletShare {
    /// Chiplet id.
    pub chiplet: usize,
    /// Crossbars of the layer placed on that chiplet.
    pub xbars: usize,
}

/// Mapping of one weight-bearing layer (Eq. 1 + Algorithm 1 lines 4-9).
#[derive(Debug, Clone)]
pub struct LayerMapping {
    /// Index into `dnn.layers`.
    pub layer_idx: usize,
    /// N_i^r — rows of crossbars.
    pub rows: usize,
    /// N_i^c — columns of crossbars.
    pub cols: usize,
    /// N_i^Total = rows × cols.
    pub xbars: usize,
    /// Chiplets hosting the layer and how many crossbars on each
    /// (uniform split per the paper's workload-balance rule).
    pub chiplets: Vec<ChipletShare>,
    /// Fraction of programmed cells within the allocated crossbars.
    pub cell_utilization: f64,
}

impl LayerMapping {
    /// Does this layer span more than one chiplet (global accumulator on)?
    pub fn spans_chiplets(&self) -> bool {
        self.chiplets.len() > 1
    }

    /// Tiles the layer occupies on a given chiplet.
    pub fn tiles_on(&self, chiplet: usize, xbars_per_tile: usize) -> usize {
        self.chiplets
            .iter()
            .find(|s| s.chiplet == chiplet)
            .map(|s| s.xbars.div_ceil(xbars_per_tile))
            .unwrap_or(0)
    }
}

/// Output of the partition & mapping engine.
#[derive(Debug, Clone)]
pub struct MappingResult {
    /// Per weight-layer mapping, in execution order.
    pub per_layer: Vec<LayerMapping>,
    /// Chiplets the architecture *contains* (= required for custom,
    /// user-fixed for homogeneous).
    pub num_chiplets: usize,
    /// Chiplets the DNN actually occupies.
    pub num_chiplets_required: usize,
    /// Crossbars used per chiplet (length = num_chiplets).
    pub chiplet_used_xbars: Vec<usize>,
    /// Crossbars per chiplet (S).
    pub chiplet_capacity: usize,
}

impl MappingResult {
    /// Fig. 9 metric: used crossbars over allocated capacity in *used*
    /// chiplets.
    pub fn xbar_utilization(&self) -> f64 {
        let used: usize = self.chiplet_used_xbars.iter().sum();
        let cap = self.num_chiplets_required * self.chiplet_capacity;
        if cap == 0 {
            0.0
        } else {
            used as f64 / cap as f64
        }
    }

    /// Cell-level utilization: programmed cells over cells in allocated
    /// crossbars (accounts for partially-filled edge crossbars).
    pub fn cell_utilization(&self) -> f64 {
        let (mut used, mut cap) = (0.0, 0.0);
        for lm in &self.per_layer {
            used += lm.cell_utilization * lm.xbars as f64;
            cap += lm.xbars as f64;
        }
        if cap == 0.0 {
            0.0
        } else {
            used / cap
        }
    }

    /// Total crossbars mapped.
    pub fn total_xbars(&self) -> usize {
        self.per_layer.iter().map(|l| l.xbars).sum()
    }

    /// Total IMC tiles (for comparisons against [34]'s tile counts).
    pub fn total_tiles(&self, xbars_per_tile: usize) -> usize {
        self.total_xbars().div_ceil(xbars_per_tile)
    }
}

/// Errors from Algorithm 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MappingError {
    /// Homogeneous architecture too small (Algorithm 1 line 12).
    ExceedsChiplets { required: usize, available: usize },
    /// The DNN has no weight layers.
    EmptyDnn,
}

impl std::fmt::Display for MappingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MappingError::ExceedsChiplets {
                required,
                available,
            } => write!(
                f,
                "DNN requires {required} chiplets but the homogeneous architecture \
                 provides only {available}; increase total_chiplets"
            ),
            MappingError::EmptyDnn => write!(f, "DNN contains no weight layers"),
        }
    }
}

impl std::error::Error for MappingError {}

/// Eq. 1: crossbar rows/columns for a layer, including multi-bit cells
/// and optional weight sparsity (rows compress).
pub fn eq1_rows_cols(
    weight_rows: usize,
    weight_cols: usize,
    weight_bits: u8,
    bits_per_cell: u8,
    xbar_rows: usize,
    xbar_cols: usize,
    sparsity: f64,
) -> (usize, usize, f64) {
    let eff_rows = ((weight_rows as f64) * (1.0 - sparsity)).ceil().max(1.0) as usize;
    let cols_per_weight = (weight_bits as usize).div_ceil(bits_per_cell as usize);
    let total_cols = weight_cols * cols_per_weight;
    let n_r = eff_rows.div_ceil(xbar_rows);
    let n_c = total_cols.div_ceil(xbar_cols);
    // programmed cells / allocated cells
    let util = (eff_rows * total_cols) as f64 / ((n_r * xbar_rows) * (n_c * xbar_cols)) as f64;
    (n_r, n_c, util)
}

/// Algorithm 1 with the paper's packing rules:
/// * a layer needing more than one chiplet gets dedicated chiplets with a
///   uniform split (workload balance);
/// * custom mode packs small layers into shared chiplets (first-fit into
///   the open chiplet) for high utilization and allocates exactly the
///   required count;
/// * homogeneous mode spreads the layers round-robin across *all* of the
///   user-fixed chiplets (Fig. 4 left: the generic architecture uses the
///   whole array, leaving unused crossbars inside chiplets) and errors
///   out if the DNN does not fit.
///
/// Monolithic chip mode maps everything onto one "chiplet" with unbounded
/// capacity (used for the Fig. 1/13 baselines).
pub fn map_dnn(dnn: &Dnn, cfg: &SiamConfig) -> Result<MappingResult, MappingError> {
    let widx = dnn.weight_layers();
    if widx.is_empty() {
        return Err(MappingError::EmptyDnn);
    }
    let s = cfg.chiplet_size_xbars();
    let monolithic = cfg.system.chip_mode == ChipMode::Monolithic;
    let homogeneous = !monolithic && cfg.system.structure == ChipletStructure::Homogeneous;
    let fixed_count = cfg.system.total_chiplets.unwrap_or(0);

    // ---- pass 1: Eq. 1 geometry for every weight layer
    let mut geom = Vec::with_capacity(widx.len());
    for (li, &idx) in widx.iter().enumerate() {
        let layer = &dnn.layers[idx];
        let sparsity = cfg
            .dnn
            .sparsity
            .as_ref()
            .and_then(|v| v.get(li))
            .copied()
            .unwrap_or(0.0);
        geom.push((
            idx,
            eq1_rows_cols(
                layer.weight_rows(),
                layer.weight_cols(),
                cfg.dnn.weight_precision,
                cfg.device.bits_per_cell,
                cfg.chiplet.xbar_rows,
                cfg.chiplet.xbar_cols,
                sparsity,
            ),
        ));
    }
    let total_all: usize = geom.iter().map(|(_, (r, c, _))| r * c).sum();

    // ---- pass 2: sequential packing at an effective capacity.
    // Custom: capacity = S (exactly the required chiplets are built).
    // Homogeneous: the DNN is balanced over the *whole* fixed array, so
    // the effective capacity shrinks to ~N_total/C (Fig. 4 left: generic
    // architectures leave unused crossbars in every chiplet). If packing
    // fragmentation overflows the array, the capacity is relaxed toward
    // S before giving up (Algorithm 1's error path).
    let pack = |cap: usize| -> (Vec<LayerMapping>, Vec<usize>) {
        let mut per_layer = Vec::with_capacity(geom.len());
        let mut used: Vec<usize> = Vec::new();
        let mut open: Option<usize> = None;
        for &(idx, (rows, cols, cell_util)) in &geom {
            let total = rows * cols;
            let chiplets = if monolithic {
                if used.is_empty() {
                    used.push(0);
                }
                used[0] += total;
                vec![ChipletShare {
                    chiplet: 0,
                    xbars: total,
                }]
            } else if let Some(oc) = open.filter(|&oc| used[oc] + total <= cap) {
                used[oc] += total;
                if used[oc] == cap {
                    open = None;
                }
                vec![ChipletShare {
                    chiplet: oc,
                    xbars: total,
                }]
            } else {
                let n_chip = total.div_ceil(cap);
                let base = total / n_chip;
                let extra = total % n_chip;
                let mut shares = Vec::with_capacity(n_chip);
                for j in 0..n_chip {
                    let x = base + usize::from(j < extra);
                    let id = used.len();
                    used.push(x);
                    shares.push(ChipletShare {
                        chiplet: id,
                        xbars: x,
                    });
                }
                let last = shares.last().unwrap();
                open = (used[last.chiplet] < cap).then_some(last.chiplet);
                shares
            };
            per_layer.push(LayerMapping {
                layer_idx: idx,
                rows,
                cols,
                xbars: total,
                chiplets,
                cell_utilization: cell_util,
            });
        }
        (per_layer, used)
    };

    let (per_layer, mut used) = if monolithic {
        pack(usize::MAX)
    } else if homogeneous {
        if fixed_count == 0 {
            return Err(MappingError::ExceedsChiplets {
                required: 1,
                available: 0,
            });
        }
        // Balance over the array, with a locality floor of S/4: the
        // generic architecture both *spreads* the DNN across the fixed
        // array (Fig. 14b: more chiplets => longer paths) and
        // *localizes* more when chiplets are bigger (Fig. 11b: NoP cost
        // falls with tiles/chiplet). Relax on fragmentation.
        let mut cap = total_all
            .div_ceil(fixed_count)
            .max(s.div_ceil(4))
            .max(1)
            .min(s);
        loop {
            let (pl, u) = pack(cap);
            if u.len() <= fixed_count {
                break (pl, u);
            }
            if cap >= s {
                return Err(MappingError::ExceedsChiplets {
                    required: u.len(),
                    available: fixed_count,
                });
            }
            cap = (cap + cap / 4 + 1).min(s);
        }
    } else {
        pack(s)
    };

    let required = used.len();
    let num_chiplets = if monolithic {
        1
    } else if homogeneous {
        fixed_count
    } else {
        required
    };
    used.resize(num_chiplets, 0);

    Ok(MappingResult {
        per_layer,
        num_chiplets,
        num_chiplets_required: required,
        chiplet_used_xbars: used,
        chiplet_capacity: if monolithic { usize::MAX } else { s },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;

    #[test]
    fn eq1_matches_paper_example() {
        // ResNet-50 conv example from Section 1: 8-bit, 128x128 crossbars.
        // res2a_branch2b: 3x3x64 -> 64: rows=576 -> N_r=5, cols=64*8=512
        // -> N_c=4 => 20 crossbars.
        let (r, c, util) = eq1_rows_cols(576, 64, 8, 1, 128, 128, 0.0);
        assert_eq!((r, c), (5, 4));
        assert!(util > 0.85 && util <= 1.0);
    }

    #[test]
    fn eq1_multibit_cells_halve_columns() {
        let (_, c1, _) = eq1_rows_cols(128, 64, 8, 1, 128, 128, 0.0);
        let (_, c2, _) = eq1_rows_cols(128, 64, 8, 2, 128, 128, 0.0);
        assert_eq!(c1, 4);
        assert_eq!(c2, 2);
    }

    #[test]
    fn eq1_sparsity_compresses_rows() {
        let (r0, _, _) = eq1_rows_cols(1024, 64, 8, 1, 128, 128, 0.0);
        let (r5, _, _) = eq1_rows_cols(1024, 64, 8, 1, 128, 128, 0.5);
        assert_eq!(r0, 8);
        assert_eq!(r5, 4);
    }

    #[test]
    fn uniform_split_balances_within_one_xbar() {
        let dnn = build_model("vgg16", "imagenet").unwrap();
        let map = map_dnn(&dnn, &SiamConfig::paper_default()).unwrap();
        for lm in &map.per_layer {
            if lm.spans_chiplets() {
                let min = lm.chiplets.iter().map(|c| c.xbars).min().unwrap();
                let max = lm.chiplets.iter().map(|c| c.xbars).max().unwrap();
                assert!(max - min <= 1, "imbalanced split {min}..{max}");
            }
        }
    }

    #[test]
    fn monolithic_uses_single_chip() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let cfg = SiamConfig::paper_default().with_chip_mode(crate::config::ChipMode::Monolithic);
        let map = map_dnn(&dnn, &cfg).unwrap();
        assert_eq!(map.num_chiplets, 1);
        assert!(map.per_layer.iter().all(|l| !l.spans_chiplets()));
    }

    #[test]
    fn small_layers_share_chiplets() {
        // LeNet-5 is tiny: everything must fit in very few chiplets.
        let dnn = build_model("lenet5", "cifar10").unwrap();
        let map = map_dnn(&dnn, &SiamConfig::paper_default()).unwrap();
        assert!(
            map.num_chiplets_required <= 2,
            "lenet used {} chiplets",
            map.num_chiplets_required
        );
    }
}
