//! Algorithm 1: layer-wise partition of a DNN onto IMC chiplets —
//! classic single-kind systems (monolithic / homogeneous / custom) and
//! heterogeneous chiplet classes (`[[system.chiplet_class]]`), where
//! each weight layer is assigned to the cheapest class that fits and
//! first-fit packed within that class.

use crate::config::{ChipMode, ChipletStructure, SiamConfig};
use crate::dnn::Dnn;

/// Crossbars a layer occupies on one chiplet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChipletShare {
    /// Chiplet id.
    pub chiplet: usize,
    /// Crossbars of the layer placed on that chiplet.
    pub xbars: usize,
}

/// Mapping of one weight-bearing layer (Eq. 1 + Algorithm 1 lines 4-9).
#[derive(Debug, Clone)]
pub struct LayerMapping {
    /// Index into `dnn.layers`.
    pub layer_idx: usize,
    /// N_i^r — rows of crossbars.
    pub rows: usize,
    /// N_i^c — columns of crossbars.
    pub cols: usize,
    /// N_i^Total = rows × cols.
    pub xbars: usize,
    /// Chiplet class hosting the layer: index into the resolved class
    /// list (`SiamConfig::resolved_chiplet_classes`); 0 for single-kind
    /// systems. All of a layer's chiplets belong to this one class.
    pub class: usize,
    /// Chiplets hosting the layer and how many crossbars on each
    /// (uniform split per the paper's workload-balance rule).
    pub chiplets: Vec<ChipletShare>,
    /// Fraction of programmed cells within the allocated crossbars.
    pub cell_utilization: f64,
}

impl LayerMapping {
    /// Does this layer span more than one chiplet (global accumulator on)?
    pub fn spans_chiplets(&self) -> bool {
        self.chiplets.len() > 1
    }

    /// Tiles the layer occupies on a given chiplet.
    pub fn tiles_on(&self, chiplet: usize, xbars_per_tile: usize) -> usize {
        self.chiplets
            .iter()
            .find(|s| s.chiplet == chiplet)
            .map(|s| s.xbars.div_ceil(xbars_per_tile))
            .unwrap_or(0)
    }
}

/// Output of the partition & mapping engine.
#[derive(Debug, Clone)]
pub struct MappingResult {
    /// Per weight-layer mapping, in execution order.
    pub per_layer: Vec<LayerMapping>,
    /// Chiplets the architecture *contains* (= required for custom,
    /// user-fixed for homogeneous, Σ per-class budgets for classes).
    pub num_chiplets: usize,
    /// Chiplets the DNN actually occupies.
    pub num_chiplets_required: usize,
    /// Crossbars used per chiplet (length = num_chiplets).
    pub chiplet_used_xbars: Vec<usize>,
    /// Largest per-chiplet crossbar capacity in the system (S for
    /// single-kind systems, `usize::MAX` for monolithic). Heterogeneous
    /// systems vary per chiplet — see `chiplet_capacities`.
    pub chiplet_capacity: usize,
    /// Class index of each chiplet (into the resolved class list; all
    /// zeros for single-kind systems). Chiplets of one class occupy one
    /// contiguous id block.
    pub chiplet_class: Vec<usize>,
    /// Crossbar capacity of each chiplet (its class's S; `usize::MAX`
    /// for the monolithic pseudo-chiplet).
    pub chiplet_capacities: Vec<usize>,
}

impl MappingResult {
    /// Fig. 9 metric: used crossbars over allocated capacity in *used*
    /// chiplets.
    pub fn xbar_utilization(&self) -> f64 {
        let used: usize = self.chiplet_used_xbars.iter().sum();
        let cap: usize = self
            .chiplet_used_xbars
            .iter()
            .zip(&self.chiplet_capacities)
            .filter(|&(&u, _)| u > 0)
            .map(|(_, &c)| c)
            .sum();
        if cap == 0 {
            0.0
        } else {
            used as f64 / cap as f64
        }
    }

    /// Chiplets of each class, indexed like the resolved class list
    /// (`[num_chiplets]` for single-kind systems).
    pub fn chiplets_per_class(&self) -> Vec<usize> {
        let nclass = self.chiplet_class.iter().copied().max().unwrap_or(0) + 1;
        let mut counts = vec![0usize; nclass];
        for &k in &self.chiplet_class {
            counts[k] += 1;
        }
        counts
    }

    /// Cell-level utilization: programmed cells over cells in allocated
    /// crossbars (accounts for partially-filled edge crossbars).
    pub fn cell_utilization(&self) -> f64 {
        let (mut used, mut cap) = (0.0, 0.0);
        for lm in &self.per_layer {
            used += lm.cell_utilization * lm.xbars as f64;
            cap += lm.xbars as f64;
        }
        if cap == 0.0 {
            0.0
        } else {
            used / cap
        }
    }

    /// Total crossbars mapped.
    pub fn total_xbars(&self) -> usize {
        self.per_layer.iter().map(|l| l.xbars).sum()
    }

    /// Total IMC tiles (for comparisons against [34]'s tile counts).
    pub fn total_tiles(&self, xbars_per_tile: usize) -> usize {
        self.total_xbars().div_ceil(xbars_per_tile)
    }
}

/// Errors from Algorithm 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MappingError {
    /// Homogeneous architecture too small (Algorithm 1 line 12).
    ExceedsChiplets { required: usize, available: usize },
    /// The DNN has no weight layers.
    EmptyDnn,
    /// Fault remap: the surviving chiplets (after kills, yield losses
    /// and crossbar faults) cannot host the DNN's crossbars.
    InsufficientSurvivingCapacity {
        /// Crossbars the DNN needs.
        needed_xbars: usize,
        /// Crossbars left across all surviving chiplets.
        available_xbars: usize,
    },
    /// A `[fault] kill_chiplets` or `[serve] fail_chiplet` id does not
    /// exist in the architecture (spares included).
    FaultTargetOutOfRange {
        /// The offending chiplet id.
        chiplet: usize,
        /// Chiplets the architecture contains, spares included.
        num_chiplets: usize,
    },
}

impl std::fmt::Display for MappingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MappingError::ExceedsChiplets {
                required,
                available,
            } => write!(
                f,
                "DNN requires {required} chiplets but the homogeneous architecture \
                 provides only {available}; increase total_chiplets"
            ),
            MappingError::EmptyDnn => write!(f, "DNN contains no weight layers"),
            MappingError::InsufficientSurvivingCapacity {
                needed_xbars,
                available_xbars,
            } => write!(
                f,
                "DNN needs {needed_xbars} crossbars but only {available_xbars} survive \
                 the injected faults; add spare_chiplets or reduce the fault load"
            ),
            MappingError::FaultTargetOutOfRange {
                chiplet,
                num_chiplets,
            } => write!(
                f,
                "fault targets chiplet {chiplet} but the architecture has only \
                 {num_chiplets} chiplets (spares included)"
            ),
        }
    }
}

impl std::error::Error for MappingError {}

/// Eq. 1: crossbar rows/columns for a layer, including multi-bit cells
/// and optional weight sparsity (rows compress).
pub fn eq1_rows_cols(
    weight_rows: usize,
    weight_cols: usize,
    weight_bits: u8,
    bits_per_cell: u8,
    xbar_rows: usize,
    xbar_cols: usize,
    sparsity: f64,
) -> (usize, usize, f64) {
    let eff_rows = ((weight_rows as f64) * (1.0 - sparsity)).ceil().max(1.0) as usize;
    let cols_per_weight = (weight_bits as usize).div_ceil(bits_per_cell as usize);
    let total_cols = weight_cols * cols_per_weight;
    let n_r = eff_rows.div_ceil(xbar_rows);
    let n_c = total_cols.div_ceil(xbar_cols);
    // programmed cells / allocated cells
    let util = (eff_rows * total_cols) as f64 / ((n_r * xbar_rows) * (n_c * xbar_cols)) as f64;
    (n_r, n_c, util)
}

/// Algorithm 1 with the paper's packing rules:
/// * a layer needing more than one chiplet gets dedicated chiplets with a
///   uniform split (workload balance);
/// * custom mode packs small layers into shared chiplets (first-fit into
///   the open chiplet) for high utilization and allocates exactly the
///   required count;
/// * homogeneous mode spreads the layers round-robin across *all* of the
///   user-fixed chiplets (Fig. 4 left: the generic architecture uses the
///   whole array, leaving unused crossbars inside chiplets) and errors
///   out if the DNN does not fit.
///
/// Monolithic chip mode maps everything onto one "chiplet" with unbounded
/// capacity (used for the Fig. 1/13 baselines).
///
/// With `[[system.chiplet_class]]` blocks configured the class-aware
/// packer runs instead (see [`map_dnn`]'s class path): each weight layer
/// goes to the cheapest class that fits (EDAP proxy: compute energy ×
/// latency × allocated crossbar area, times a chiplet-spanning penalty),
/// first-fit within its class. A single class identical to the base
/// config degenerates to the classic custom (`count` unset) or
/// homogeneous (`count` set) path and reproduces it bit-for-bit.
///
/// # Examples
///
/// ```
/// use siam::config::{ChipletClassConfig, SiamConfig};
/// use siam::dnn::build_model;
/// use siam::mapping::map_dnn;
///
/// let base = SiamConfig::paper_default();
/// let mut little = ChipletClassConfig::from_base(&base, "little");
/// little.xbar_rows = 64;
/// little.xbar_cols = 64;
/// little.adc_bits = 3;
/// let big = ChipletClassConfig::from_base(&base, "big");
/// let cfg = base.with_chiplet_classes(vec![big, little]);
///
/// let dnn = build_model("resnet110", "cifar10").unwrap();
/// let map = map_dnn(&dnn, &cfg).unwrap();
/// // every chiplet belongs to one of the two classes
/// assert!(map.chiplet_class.iter().all(|&k| k < 2));
/// // and every layer lives entirely inside its owning class
/// for lm in &map.per_layer {
///     assert!(lm.chiplets.iter().all(|s| map.chiplet_class[s.chiplet] == lm.class));
/// }
/// ```
pub fn map_dnn(dnn: &Dnn, cfg: &SiamConfig) -> Result<MappingResult, MappingError> {
    let widx = dnn.weight_layers();
    if widx.is_empty() {
        return Err(MappingError::EmptyDnn);
    }
    let s = cfg.chiplet_size_xbars();
    let monolithic = cfg.system.chip_mode == ChipMode::Monolithic;
    if !monolithic && cfg.has_hetero_classes() {
        return map_dnn_classes(dnn, cfg, &widx);
    }
    // A degenerate single class (field-identical to the base config)
    // runs the classic paths with the class's budget, reproducing them
    // bit-for-bit.
    let (homogeneous, fixed_count) = if monolithic {
        (false, 0)
    } else if let Some(count) = cfg.degenerate_class_mode() {
        (count.is_some(), count.unwrap_or(0))
    } else {
        (
            cfg.system.structure == ChipletStructure::Homogeneous,
            cfg.system.total_chiplets.unwrap_or(0),
        )
    };

    // ---- pass 1: Eq. 1 geometry for every weight layer
    let mut geom = Vec::with_capacity(widx.len());
    for (li, &idx) in widx.iter().enumerate() {
        let layer = &dnn.layers[idx];
        let sparsity = cfg
            .dnn
            .sparsity
            .as_ref()
            .and_then(|v| v.get(li))
            .copied()
            .unwrap_or(0.0);
        geom.push((
            idx,
            eq1_rows_cols(
                layer.weight_rows(),
                layer.weight_cols(),
                cfg.dnn.weight_precision,
                cfg.device.bits_per_cell,
                cfg.chiplet.xbar_rows,
                cfg.chiplet.xbar_cols,
                sparsity,
            ),
        ));
    }
    let total_all: usize = geom.iter().map(|(_, (r, c, _))| r * c).sum();

    // ---- pass 2: sequential packing at an effective capacity.
    // Custom: capacity = S (exactly the required chiplets are built).
    // Homogeneous: the DNN is balanced over the *whole* fixed array, so
    // the effective capacity shrinks to ~N_total/C (Fig. 4 left: generic
    // architectures leave unused crossbars in every chiplet). If packing
    // fragmentation overflows the array, the capacity is relaxed toward
    // S before giving up (Algorithm 1's error path).
    let pack = |cap: usize| -> (Vec<LayerMapping>, Vec<usize>) {
        let mut per_layer = Vec::with_capacity(geom.len());
        let mut used: Vec<usize> = Vec::new();
        let mut open: Option<usize> = None;
        for &(idx, (rows, cols, cell_util)) in &geom {
            let total = rows * cols;
            let chiplets = if monolithic {
                if used.is_empty() {
                    used.push(0);
                }
                used[0] += total;
                vec![ChipletShare {
                    chiplet: 0,
                    xbars: total,
                }]
            } else if let Some(oc) = open.filter(|&oc| used[oc] + total <= cap) {
                used[oc] += total;
                if used[oc] == cap {
                    open = None;
                }
                vec![ChipletShare {
                    chiplet: oc,
                    xbars: total,
                }]
            } else {
                let n_chip = total.div_ceil(cap);
                let base = total / n_chip;
                let extra = total % n_chip;
                let mut shares = Vec::with_capacity(n_chip);
                for j in 0..n_chip {
                    let x = base + usize::from(j < extra);
                    let id = used.len();
                    used.push(x);
                    shares.push(ChipletShare {
                        chiplet: id,
                        xbars: x,
                    });
                }
                let last = shares.last().unwrap();
                open = (used[last.chiplet] < cap).then_some(last.chiplet);
                shares
            };
            per_layer.push(LayerMapping {
                layer_idx: idx,
                rows,
                cols,
                xbars: total,
                class: 0,
                chiplets,
                cell_utilization: cell_util,
            });
        }
        (per_layer, used)
    };

    let (per_layer, mut used) = if monolithic {
        pack(usize::MAX)
    } else if homogeneous {
        if fixed_count == 0 {
            return Err(MappingError::ExceedsChiplets {
                required: 1,
                available: 0,
            });
        }
        // Balance over the array, with a locality floor of S/4: the
        // generic architecture both *spreads* the DNN across the fixed
        // array (Fig. 14b: more chiplets => longer paths) and
        // *localizes* more when chiplets are bigger (Fig. 11b: NoP cost
        // falls with tiles/chiplet). Relax on fragmentation.
        let mut cap = total_all
            .div_ceil(fixed_count)
            .max(s.div_ceil(4))
            .max(1)
            .min(s);
        loop {
            let (pl, u) = pack(cap);
            if u.len() <= fixed_count {
                break (pl, u);
            }
            if cap >= s {
                return Err(MappingError::ExceedsChiplets {
                    required: u.len(),
                    available: fixed_count,
                });
            }
            cap = (cap + cap / 4 + 1).min(s);
        }
    } else {
        pack(s)
    };

    let required = used.len();
    let num_chiplets = if monolithic {
        1
    } else if homogeneous {
        fixed_count
    } else {
        required
    };
    used.resize(num_chiplets, 0);

    let cap = if monolithic { usize::MAX } else { s };
    Ok(MappingResult {
        per_layer,
        num_chiplets,
        num_chiplets_required: required,
        chiplet_used_xbars: used,
        chiplet_capacity: cap,
        chiplet_class: vec![0; num_chiplets],
        chiplet_capacities: vec![cap; num_chiplets],
    })
}

/// Incremental re-statement of the classic `pack` rules, used by the
/// class-aware packer: first-fit into the open chiplet, dedicated
/// uniform-split chiplets for layers that overflow it.
///
/// Deliberately a *separate* implementation from `map_dnn`'s `pack`
/// closure: the legacy closure is the bit-compatibility reference for
/// every pre-heterogeneity release and stays untouched. `place` must
/// mirror its rules exactly (and the bounded-class relaxation loop in
/// [`map_dnn`]'s class path mirrors the homogeneous loop) — the
/// degenerate-identity regression tests in this file and in
/// `coordinator::pipeline` pin the two implementations together; edit
/// either side only in lock-step.
struct ClassPacker {
    cap: usize,
    used: Vec<usize>,
    open: Option<usize>,
}

impl ClassPacker {
    fn new(cap: usize) -> ClassPacker {
        ClassPacker {
            cap,
            used: Vec::new(),
            open: None,
        }
    }

    /// Chiplets this class would have to add to host `xbars` now.
    fn extra_chiplets(&self, xbars: usize) -> usize {
        if self.open.is_some_and(|oc| self.used[oc] + xbars <= self.cap) {
            0
        } else {
            xbars.div_ceil(self.cap)
        }
    }

    /// Place a layer, returning its `(local chiplet id, crossbars)`
    /// shares — exactly the classic `pack` behavior.
    fn place(&mut self, xbars: usize) -> Vec<(usize, usize)> {
        if let Some(oc) = self.open.filter(|&oc| self.used[oc] + xbars <= self.cap) {
            self.used[oc] += xbars;
            if self.used[oc] == self.cap {
                self.open = None;
            }
            vec![(oc, xbars)]
        } else {
            let n_chip = xbars.div_ceil(self.cap);
            let base = xbars / n_chip;
            let extra = xbars % n_chip;
            let mut shares = Vec::with_capacity(n_chip);
            for j in 0..n_chip {
                let x = base + usize::from(j < extra);
                let id = self.used.len();
                self.used.push(x);
                shares.push((id, x));
            }
            let last = shares.last().unwrap().0;
            self.open = (self.used[last] < self.cap).then_some(last);
            shares
        }
    }
}

/// The class-aware packer behind [`map_dnn`] for genuinely
/// heterogeneous systems.
///
/// Phase A assigns each weight layer, in execution order, to the
/// cheapest class that fits — cost is an EDAP proxy (the layer's
/// compute energy × latency on that class × the crossbar area it would
/// allocate there, times a spanning penalty for layers that overflow
/// one chiplet), and a bounded class "fits" while a first-fit
/// simulation at full capacity (the densest packing) stays within its
/// budget. Phase B packs each class: unbounded classes replay the
/// first-fit packing, bounded classes balance their layers across the
/// fixed budget exactly like the classic homogeneous path (shrunken
/// effective capacity, relaxed on fragmentation). Chiplets of one class
/// occupy one contiguous global id block, in class order.
fn map_dnn_classes(
    dnn: &Dnn,
    cfg: &SiamConfig,
    widx: &[usize],
) -> Result<MappingResult, MappingError> {
    use crate::circuit::CircuitEstimator;
    let classes = cfg.resolved_chiplet_classes();
    let effs: Vec<SiamConfig> = classes.iter().map(|c| cfg.class_effective(c)).collect();
    let nclass = classes.len();

    // ---- per-class Eq.-1 geometry + EDAP-proxy cost per weight layer.
    // Recomputed per map_dnn call (mapping runs per sweep point and has
    // no cache handle): the cost model is closed-form arithmetic, a few
    // flops per (layer, class) — the cached path in
    // `CircuitEstimator::estimate_cached` is what avoids the *per-point*
    // whole-model vectors downstream.
    struct Geo {
        rows: usize,
        cols: usize,
        xbars: usize,
        util: f64,
        cost: f64,
    }
    let mut geo: Vec<Vec<Geo>> = Vec::with_capacity(widx.len());
    {
        let ests: Vec<CircuitEstimator> = effs.iter().map(CircuitEstimator::new).collect();
        let unit_areas: Vec<f64> = ests.iter().map(|e| e.xbar_unit_area()).collect();
        for (li, &idx) in widx.iter().enumerate() {
            let layer = &dnn.layers[idx];
            let sparsity = cfg
                .dnn
                .sparsity
                .as_ref()
                .and_then(|v| v.get(li))
                .copied()
                .unwrap_or(0.0);
            let mut per_class = Vec::with_capacity(nclass);
            for (k, class) in classes.iter().enumerate() {
                let (rows, cols, util) = eq1_rows_cols(
                    layer.weight_rows(),
                    layer.weight_cols(),
                    cfg.dnn.weight_precision,
                    class.bits_per_cell,
                    class.xbar_rows,
                    class.xbar_cols,
                    sparsity,
                );
                let xbars = rows * cols;
                let lc = ests[k].layer_cost(layer, li);
                // EDAP proxy × spanning penalty: a layer overflowing one
                // chiplet of this class splits across div_ceil(xbars, S)
                // dedicated chiplets, each adding NoP partial-sum
                // reduction traffic — penalize linearly so big layers
                // prefer classes big enough to hold them.
                let span = xbars.div_ceil(class.capacity_xbars()).max(1);
                let cost =
                    lc.energy_pj * lc.latency_ns * (xbars as f64 * unit_areas[k]) * span as f64;
                per_class.push(Geo {
                    rows,
                    cols,
                    xbars,
                    util,
                    cost,
                });
            }
            geo.push(per_class);
        }
    }

    // ---- phase A: cheapest class that fits, in execution order
    let mut ff: Vec<ClassPacker> = classes
        .iter()
        .map(|c| ClassPacker::new(c.capacity_xbars()))
        .collect();
    let mut assigned: Vec<usize> = Vec::with_capacity(widx.len());
    for per_class in &geo {
        let mut best: Option<(usize, f64)> = None;
        for (k, class) in classes.iter().enumerate() {
            let g = &per_class[k];
            let fits = match class.count {
                None => true,
                Some(budget) => ff[k].used.len() + ff[k].extra_chiplets(g.xbars) <= budget,
            };
            if fits && best.is_none_or(|(_, c)| g.cost < c) {
                best = Some((k, g.cost));
            }
        }
        let Some((k, _)) = best else {
            // every class is bounded and none can host the layer
            let available: usize = classes.iter().filter_map(|c| c.count).sum();
            let required = (0..nclass)
                .map(|k| ff[k].used.len() + ff[k].extra_chiplets(per_class[k].xbars))
                .min()
                .unwrap_or(1)
                .max(available + 1);
            return Err(MappingError::ExceedsChiplets {
                required,
                available,
            });
        };
        ff[k].place(per_class[k].xbars);
        assigned.push(k);
    }

    // ---- phase B: pack each class's layers
    let mut class_shares: Vec<Vec<Vec<(usize, usize)>>> = vec![Vec::new(); nclass];
    let mut class_used: Vec<Vec<usize>> = Vec::with_capacity(nclass);
    for (k, class) in classes.iter().enumerate() {
        let lys: Vec<usize> = (0..widx.len()).filter(|&li| assigned[li] == k).collect();
        let s_k = class.capacity_xbars();
        match class.count {
            None => {
                let mut packer = ClassPacker::new(s_k);
                for &li in &lys {
                    class_shares[k].push(packer.place(geo[li][k].xbars));
                }
                class_used.push(packer.used);
            }
            Some(budget) => {
                if budget == 0 {
                    return Err(MappingError::ExceedsChiplets {
                        required: 1,
                        available: 0,
                    });
                }
                let total: usize = lys.iter().map(|&li| geo[li][k].xbars).sum();
                let mut cap = total
                    .div_ceil(budget)
                    .max(s_k.div_ceil(4))
                    .max(1)
                    .min(s_k);
                let (shares, mut used) = loop {
                    let mut packer = ClassPacker::new(cap);
                    let shares: Vec<Vec<(usize, usize)>> = lys
                        .iter()
                        .map(|&li| packer.place(geo[li][k].xbars))
                        .collect();
                    if packer.used.len() <= budget {
                        break (shares, packer.used);
                    }
                    if cap >= s_k {
                        return Err(MappingError::ExceedsChiplets {
                            required: packer.used.len(),
                            available: budget,
                        });
                    }
                    cap = (cap + cap / 4 + 1).min(s_k);
                };
                used.resize(budget, 0);
                class_shares[k] = shares;
                class_used.push(used);
            }
        }
    }

    // ---- global chiplet ids: contiguous block per class, class order
    let mut offsets = Vec::with_capacity(nclass);
    let mut total_chiplets = 0usize;
    for used in &class_used {
        offsets.push(total_chiplets);
        total_chiplets += used.len();
    }

    let mut next_in_class = vec![0usize; nclass];
    let mut per_layer = Vec::with_capacity(widx.len());
    for (li, &idx) in widx.iter().enumerate() {
        let k = assigned[li];
        let g = &geo[li][k];
        let shares = &class_shares[k][next_in_class[k]];
        next_in_class[k] += 1;
        per_layer.push(LayerMapping {
            layer_idx: idx,
            rows: g.rows,
            cols: g.cols,
            xbars: g.xbars,
            class: k,
            chiplets: shares
                .iter()
                .map(|&(local, x)| ChipletShare {
                    chiplet: offsets[k] + local,
                    xbars: x,
                })
                .collect(),
            cell_utilization: g.util,
        });
    }

    let mut chiplet_used = Vec::with_capacity(total_chiplets);
    let mut chiplet_class = Vec::with_capacity(total_chiplets);
    let mut chiplet_capacities = Vec::with_capacity(total_chiplets);
    for (k, used) in class_used.iter().enumerate() {
        chiplet_used.extend_from_slice(used);
        chiplet_class.extend(used.iter().map(|_| k));
        chiplet_capacities.extend(used.iter().map(|_| classes[k].capacity_xbars()));
    }
    let required = chiplet_used.iter().filter(|&&u| u > 0).count();
    Ok(MappingResult {
        per_layer,
        num_chiplets: total_chiplets,
        num_chiplets_required: required,
        chiplet_used_xbars: chiplet_used,
        chiplet_capacity: chiplet_capacities.iter().copied().max().unwrap_or(0),
        chiplet_class,
        chiplet_capacities,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;

    #[test]
    fn eq1_matches_paper_example() {
        // ResNet-50 conv example from Section 1: 8-bit, 128x128 crossbars.
        // res2a_branch2b: 3x3x64 -> 64: rows=576 -> N_r=5, cols=64*8=512
        // -> N_c=4 => 20 crossbars.
        let (r, c, util) = eq1_rows_cols(576, 64, 8, 1, 128, 128, 0.0);
        assert_eq!((r, c), (5, 4));
        assert!(util > 0.85 && util <= 1.0);
    }

    #[test]
    fn eq1_multibit_cells_halve_columns() {
        let (_, c1, _) = eq1_rows_cols(128, 64, 8, 1, 128, 128, 0.0);
        let (_, c2, _) = eq1_rows_cols(128, 64, 8, 2, 128, 128, 0.0);
        assert_eq!(c1, 4);
        assert_eq!(c2, 2);
    }

    #[test]
    fn eq1_sparsity_compresses_rows() {
        let (r0, _, _) = eq1_rows_cols(1024, 64, 8, 1, 128, 128, 0.0);
        let (r5, _, _) = eq1_rows_cols(1024, 64, 8, 1, 128, 128, 0.5);
        assert_eq!(r0, 8);
        assert_eq!(r5, 4);
    }

    #[test]
    fn uniform_split_balances_within_one_xbar() {
        let dnn = build_model("vgg16", "imagenet").unwrap();
        let map = map_dnn(&dnn, &SiamConfig::paper_default()).unwrap();
        for lm in &map.per_layer {
            if lm.spans_chiplets() {
                let min = lm.chiplets.iter().map(|c| c.xbars).min().unwrap();
                let max = lm.chiplets.iter().map(|c| c.xbars).max().unwrap();
                assert!(max - min <= 1, "imbalanced split {min}..{max}");
            }
        }
    }

    #[test]
    fn monolithic_uses_single_chip() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let cfg = SiamConfig::paper_default().with_chip_mode(crate::config::ChipMode::Monolithic);
        let map = map_dnn(&dnn, &cfg).unwrap();
        assert_eq!(map.num_chiplets, 1);
        assert!(map.per_layer.iter().all(|l| !l.spans_chiplets()));
    }

    #[test]
    fn small_layers_share_chiplets() {
        // LeNet-5 is tiny: everything must fit in very few chiplets.
        let dnn = build_model("lenet5", "cifar10").unwrap();
        let map = map_dnn(&dnn, &SiamConfig::paper_default()).unwrap();
        assert!(
            map.num_chiplets_required <= 2,
            "lenet used {} chiplets",
            map.num_chiplets_required
        );
    }

    use crate::config::{ChipletClassConfig, MemCell};

    fn big_little_cfg() -> SiamConfig {
        let base = SiamConfig::paper_default();
        let big = ChipletClassConfig::from_base(&base, "big");
        let mut little = ChipletClassConfig::from_base(&base, "little");
        little.cell = MemCell::Sram;
        little.xbar_rows = 64;
        little.xbar_cols = 64;
        little.tiles_per_chiplet = 8;
        little.xbars_per_tile = 8;
        little.adc_bits = 3;
        little.nop_ebit_pj = 0.3;
        base.with_chiplet_classes(vec![big, little])
    }

    fn assert_mappings_identical(a: &MappingResult, b: &MappingResult) {
        assert_eq!(a.num_chiplets, b.num_chiplets);
        assert_eq!(a.num_chiplets_required, b.num_chiplets_required);
        assert_eq!(a.chiplet_used_xbars, b.chiplet_used_xbars);
        assert_eq!(a.chiplet_capacity, b.chiplet_capacity);
        assert_eq!(a.chiplet_capacities, b.chiplet_capacities);
        assert_eq!(a.per_layer.len(), b.per_layer.len());
        for (x, y) in a.per_layer.iter().zip(&b.per_layer) {
            assert_eq!(x.layer_idx, y.layer_idx);
            assert_eq!((x.rows, x.cols, x.xbars), (y.rows, y.cols, y.xbars));
            assert_eq!(x.chiplets, y.chiplets);
            assert_eq!(
                x.cell_utilization.to_bits(),
                y.cell_utilization.to_bits(),
                "cell utilization drifted"
            );
        }
    }

    #[test]
    fn degenerate_single_class_reproduces_custom_bitwise() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let base = SiamConfig::paper_default();
        let legacy = map_dnn(&dnn, &base).unwrap();
        let one = base
            .clone()
            .with_chiplet_classes(vec![ChipletClassConfig::from_base(&base, "only")]);
        let class = map_dnn(&dnn, &one).unwrap();
        assert_mappings_identical(&legacy, &class);
    }

    #[test]
    fn degenerate_single_class_reproduces_homogeneous_bitwise() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let base = SiamConfig::paper_default();
        let legacy = map_dnn(&dnn, &base.clone().with_total_chiplets(36)).unwrap();
        let mut only = ChipletClassConfig::from_base(&base, "only");
        only.count = Some(36);
        let class = map_dnn(&dnn, &base.clone().with_chiplet_classes(vec![only])).unwrap();
        assert_mappings_identical(&legacy, &class);
    }

    #[test]
    fn class_packer_matches_classic_pack_bitwise() {
        // a single class differing from the base only in a field the
        // packer ignores (NoP driver energy) forces the class path
        // while keeping every packing input identical — pinning
        // ClassPacker / the bounded relaxation loop to the classic
        // `pack` closure bit-for-bit
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let base = SiamConfig::paper_default();
        for budget in [None, Some(36)] {
            let legacy_cfg = match budget {
                None => base.clone(),
                Some(n) => base.clone().with_total_chiplets(n),
            };
            let legacy = map_dnn(&dnn, &legacy_cfg).unwrap();
            let mut only = ChipletClassConfig::from_base(&base, "only");
            only.count = budget;
            only.nop_ebit_pj = 0.53; // hetero trigger, mapping-invariant
            let cfg = base.clone().with_chiplet_classes(vec![only]);
            assert!(cfg.has_hetero_classes(), "tweaked class must not be degenerate");
            let class = map_dnn(&dnn, &cfg).unwrap();
            assert_mappings_identical(&legacy, &class);
        }
    }

    #[test]
    fn big_little_splits_across_both_classes() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &big_little_cfg()).unwrap();
        let counts = map.chiplets_per_class();
        assert_eq!(counts.len(), 2);
        assert!(
            counts.iter().all(|&c| c > 0),
            "expected a mixed split, got {counts:?}"
        );
        // a layer lives entirely inside its owning class
        for lm in &map.per_layer {
            assert!(lm
                .chiplets
                .iter()
                .all(|s| map.chiplet_class[s.chiplet] == lm.class));
        }
        // per-chiplet capacity respected, class blocks contiguous
        for (c, (&used, &cap)) in map
            .chiplet_used_xbars
            .iter()
            .zip(&map.chiplet_capacities)
            .enumerate()
        {
            assert!(used <= cap, "chiplet {c} over capacity: {used} > {cap}");
        }
        assert!(
            map.chiplet_class.windows(2).all(|w| w[0] <= w[1]),
            "class id blocks must be contiguous"
        );
        // big-little on ResNet-110: the heavy stage-3 backbone stays on
        // the big RRAM class, the small early layers go little
        let big_xbars: usize = map
            .per_layer
            .iter()
            .filter(|lm| lm.class == 0)
            .map(|lm| lm.xbars)
            .sum();
        assert!(big_xbars > 0, "big class unused");
    }

    #[test]
    fn bounded_class_budget_respected() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let mut cfg = big_little_cfg();
        cfg.system.chiplet_classes[1].count = Some(4);
        let map = map_dnn(&dnn, &cfg).unwrap();
        let counts = map.chiplets_per_class();
        assert_eq!(counts[1], 4, "bounded class must contribute its budget");
        // overflow from the bounded little class lands on unbounded big
        assert!(counts[0] > 0);
    }

    #[test]
    fn all_bounded_classes_too_small_error() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let mut cfg = big_little_cfg();
        cfg.system.chiplet_classes[0].count = Some(1);
        cfg.system.chiplet_classes[1].count = Some(1);
        match map_dnn(&dnn, &cfg) {
            Err(MappingError::ExceedsChiplets { required, available }) => {
                assert_eq!(available, 2);
                assert!(required > available);
            }
            other => panic!("expected overflow, got {other:?}"),
        }
    }
}
