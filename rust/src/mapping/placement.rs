//! Chiplet placement on the interposer mesh.
//!
//! Chiplets are placed row-major on the smallest square mesh that holds
//! them (the paper places chiplets "to achieve the least Manhattan
//! distance" for the sequential layer chain — row-major snake order is
//! the optimal sequential embedding on a mesh). Two special nodes are
//! appended: the global accumulator/buffer and the DRAM chiplet, attached
//! at the mesh boundary (Fig. 2 of the paper).


/// Row-major snake placement of chiplets + special nodes on the
/// interposer mesh.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Mesh width (columns).
    pub width: usize,
    /// Mesh height (rows), including the extra row for special nodes if
    /// needed.
    pub height: usize,
    /// Number of compute chiplets.
    pub chiplets: usize,
    /// Node id of the global accumulator + buffer.
    pub accumulator_node: usize,
    /// Node id of the DRAM chiplet.
    pub dram_node: usize,
}

impl Placement {
    /// Place `chiplets` compute chiplets plus the two special nodes.
    pub fn new(chiplets: usize) -> Placement {
        assert!(chiplets > 0);
        // smallest square that holds the compute chiplets
        let side = (chiplets as f64).sqrt().ceil() as usize;
        let width = side.max(1);
        // special nodes go into the remaining slots of the square, or an
        // extra row below it.
        let total = chiplets + 2;
        let height = total.div_ceil(width);
        Placement {
            width,
            height,
            chiplets,
            accumulator_node: chiplets,
            dram_node: chiplets + 1,
        }
    }

    /// Total mesh nodes (compute chiplets + accumulator + DRAM).
    pub fn nodes(&self) -> usize {
        self.chiplets + 2
    }

    /// (row, col) of a node id. Row-major snake order: odd rows run
    /// right-to-left so consecutive ids are always mesh neighbours.
    pub fn coord(&self, node: usize) -> (usize, usize) {
        let r = node / self.width;
        let c = node % self.width;
        if r % 2 == 0 {
            (r, c)
        } else {
            (r, self.width - 1 - c)
        }
    }

    /// Manhattan hop distance between two nodes.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ra, ca) = self.coord(a);
        let (rb, cb) = self.coord(b);
        ra.abs_diff(rb) + ca.abs_diff(cb)
    }

    /// Total links in the mesh (for area accounting): 2·W·H − W − H.
    pub fn links(&self) -> usize {
        let (w, h) = (self.width, self.height);
        2 * w * h - w - h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_placement() {
        let p = Placement::new(16);
        assert_eq!(p.width, 4);
        assert_eq!(p.nodes(), 18);
        assert!(p.height >= 5); // 16 compute + 2 specials need a 5th row
    }

    #[test]
    fn snake_order_keeps_neighbours_adjacent() {
        let p = Placement::new(16);
        for i in 0..15 {
            assert_eq!(p.hops(i, i + 1), 1, "nodes {i},{} not adjacent", i + 1);
        }
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let p = Placement::new(9);
        assert_eq!(p.hops(0, 0), 0);
        assert_eq!(p.hops(0, 8), p.hops(8, 0));
    }

    #[test]
    fn single_chiplet() {
        let p = Placement::new(1);
        assert_eq!(p.width, 1);
        assert_eq!(p.nodes(), 3);
        assert_eq!(p.coord(2), (2, 0));
    }

    #[test]
    fn link_count() {
        let p = Placement::new(16); // 4 wide, >=5 tall
        let expected = 2 * p.width * p.height - p.width - p.height;
        assert_eq!(p.links(), expected);
    }
}
