//! Chiplet placement on the interposer mesh.
//!
//! The default embedding places chiplets row-major on the smallest
//! square mesh that holds them (the paper places chiplets "to achieve
//! the least Manhattan distance" for the sequential layer chain —
//! row-major snake order is the optimal sequential embedding on a
//! mesh). Two special nodes are appended: the global accumulator/buffer
//! and the DRAM chiplet, attached at the mesh boundary (Fig. 2 of the
//! paper).
//!
//! `placement = "dataflow"` instead *optimizes* the embedding against
//! the actual inter-chiplet traffic: [`Placement::dataflow`] orders the
//! nodes to minimize the weighted NoP hop-distance of the inter-layer
//! flows (greedy construction refined by pairwise swaps), which matters
//! once heterogeneous chiplet classes break the neat sequential chain.
//! Both policies occupy the same mesh footprint, so placement changes
//! only distances — never area.

use super::traffic::Traffic;

/// Symmetric inter-node traffic weights driving the dataflow placement:
/// `w(a, b)` counts the NoP packets exchanged between nodes `a` and `b`
/// (direction ignored — hop distance is symmetric).
#[derive(Debug, Clone)]
pub struct TrafficMatrix {
    n: usize,
    w: Vec<u64>,
}

impl TrafficMatrix {
    /// An all-zero matrix over `n` nodes.
    pub fn new(n: usize) -> TrafficMatrix {
        TrafficMatrix { n, w: vec![0; n * n] }
    }

    /// Accumulate `packets` between `a` and `b` (self-traffic ignored).
    pub fn add(&mut self, a: usize, b: usize, packets: u64) {
        if a != b {
            self.w[a * self.n + b] += packets;
            self.w[b * self.n + a] += packets;
        }
    }

    /// Packets exchanged between `a` and `b`.
    pub fn get(&self, a: usize, b: usize) -> u64 {
        self.w[a * self.n + b]
    }

    /// Nodes the matrix covers.
    pub fn nodes(&self) -> usize {
        self.n
    }

    /// Total packets touching node `a`.
    pub fn node_weight(&self, a: usize) -> u128 {
        self.w[a * self.n..(a + 1) * self.n]
            .iter()
            .map(|&x| x as u128)
            .sum()
    }

    /// Weights of one mapped DNN's NoP epochs over `nodes` mesh nodes
    /// (compute chiplets + accumulator + DRAM).
    pub fn from_nop_traffic(traffic: &Traffic, nodes: usize) -> TrafficMatrix {
        let mut m = TrafficMatrix::new(nodes);
        for ep in &traffic.nop_epochs {
            for f in &ep.flows {
                m.add(f.src as usize, f.dst as usize, f.count);
            }
        }
        m
    }
}

/// Embedding of chiplets + special nodes on the interposer mesh.
///
/// The default ([`Placement::new`]) is row-major snake order;
/// [`Placement::dataflow`] permutes node→slot to minimize weighted NoP
/// hop-distance. Node ids are stable across policies — only the
/// coordinates move — so Algorithm-2 traces built against one placement
/// remain valid under another.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Mesh width (columns).
    pub width: usize,
    /// Mesh height (rows), including the extra row for special nodes if
    /// needed.
    pub height: usize,
    /// Number of compute chiplets.
    pub chiplets: usize,
    /// Node id of the global accumulator + buffer.
    pub accumulator_node: usize,
    /// Node id of the DRAM chiplet.
    pub dram_node: usize,
    /// Optional node→slot permutation (`None` = identity, the row-major
    /// snake order every pre-dataflow release used).
    slots: Option<Vec<usize>>,
}

/// (row, col) of a snake-order slot index on a `width`-wide mesh: odd
/// rows run right-to-left so consecutive slots are always neighbours.
fn slot_coord(width: usize, slot: usize) -> (usize, usize) {
    let r = slot / width;
    let c = slot % width;
    if r % 2 == 0 {
        (r, c)
    } else {
        (r, width - 1 - c)
    }
}

impl Placement {
    /// Place `chiplets` compute chiplets plus the two special nodes in
    /// row-major snake order.
    pub fn new(chiplets: usize) -> Placement {
        assert!(chiplets > 0);
        // smallest square that holds the compute chiplets
        let side = (chiplets as f64).sqrt().ceil() as usize;
        let width = side.max(1);
        // special nodes go into the remaining slots of the square, or an
        // extra row below it.
        let total = chiplets + 2;
        let height = total.div_ceil(width);
        Placement {
            width,
            height,
            chiplets,
            accumulator_node: chiplets,
            dram_node: chiplets + 1,
            slots: None,
        }
    }

    /// Dataflow-aware placement: permute the nodes of the row-major
    /// footprint to minimize `Σ w(a,b) · hops(a,b)` over `weights`.
    ///
    /// Deterministic two-step optimizer: a greedy construction (heaviest
    /// node first, each into the free slot minimizing its cost against
    /// the already-placed nodes) refined by pairwise-swap passes until
    /// no swap improves (each applied swap strictly reduces the cost, so
    /// refinement is monotone — an invariant the tests assert). Falls
    /// back to the identity embedding when the optimizer cannot beat it,
    /// so a dataflow placement never costs more hops than row-major.
    ///
    /// # Examples
    ///
    /// ```
    /// use siam::mapping::{weighted_hop_cost, Placement, TrafficMatrix};
    ///
    /// let rowmajor = Placement::new(7);
    /// let mut w = TrafficMatrix::new(rowmajor.nodes());
    /// w.add(0, 6, 1_000_000); // one dominant chiplet pair
    /// let optimized = Placement::dataflow(7, &w);
    /// // the heavy pair lands on neighbouring slots...
    /// assert_eq!(optimized.hops(0, 6), 1);
    /// // ...and the objective can only improve over row-major
    /// assert!(weighted_hop_cost(&optimized, &w) <= weighted_hop_cost(&rowmajor, &w));
    /// ```
    pub fn dataflow(chiplets: usize, weights: &TrafficMatrix) -> Placement {
        let base = Placement::new(chiplets);
        let n = base.nodes();
        assert_eq!(weights.nodes(), n, "weight matrix must cover all nodes");
        let greedy = greedy_slots(&base, weights);
        let refined = refine_slots(&base, weights, greedy);
        let mut candidate = base.clone();
        candidate.slots = Some(refined);
        if weighted_hop_cost(&candidate, weights) < weighted_hop_cost(&base, weights) {
            candidate
        } else {
            base
        }
    }

    /// True when this placement permutes the row-major embedding.
    pub fn is_permuted(&self) -> bool {
        self.slots.is_some()
    }

    /// Total mesh nodes (compute chiplets + accumulator + DRAM).
    pub fn nodes(&self) -> usize {
        self.chiplets + 2
    }

    /// (row, col) of a node id.
    pub fn coord(&self, node: usize) -> (usize, usize) {
        let slot = match &self.slots {
            Some(s) => s[node],
            None => node,
        };
        slot_coord(self.width, slot)
    }

    /// Manhattan hop distance between two nodes.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ra, ca) = self.coord(a);
        let (rb, cb) = self.coord(b);
        ra.abs_diff(rb) + ca.abs_diff(cb)
    }

    /// Total links in the mesh (for area accounting): 2·W·H − W − H.
    pub fn links(&self) -> usize {
        let (w, h) = (self.width, self.height);
        2 * w * h - w - h
    }
}

/// The dataflow objective: `Σ_{a<b} w(a,b) · hops(a,b)` in exact
/// integer arithmetic.
pub fn weighted_hop_cost(p: &Placement, weights: &TrafficMatrix) -> u128 {
    let n = p.nodes().min(weights.nodes());
    let mut cost = 0u128;
    for a in 0..n {
        for b in (a + 1)..n {
            let w = weights.get(a, b);
            if w > 0 {
                cost += w as u128 * p.hops(a, b) as u128;
            }
        }
    }
    cost
}

/// Greedy construction: nodes in descending total-traffic order (ties
/// by id), each into the free slot minimizing its weighted distance to
/// the already-placed nodes (ties by slot index).
fn greedy_slots(base: &Placement, weights: &TrafficMatrix) -> Vec<usize> {
    let n = base.nodes();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&a| (std::cmp::Reverse(weights.node_weight(a)), a));
    let mut slot_of: Vec<usize> = vec![usize::MAX; n];
    let mut free: Vec<bool> = vec![true; n];
    for &node in &order {
        let mut best = (u128::MAX, usize::MAX);
        for (slot, &is_free) in free.iter().enumerate() {
            if !is_free {
                continue;
            }
            let (r, c) = slot_coord(base.width, slot);
            let mut cost = 0u128;
            for other in 0..n {
                if slot_of[other] == usize::MAX {
                    continue;
                }
                let w = weights.get(node, other);
                if w > 0 {
                    let (or, oc) = slot_coord(base.width, slot_of[other]);
                    cost += w as u128 * (r.abs_diff(or) + c.abs_diff(oc)) as u128;
                }
            }
            if (cost, slot) < best {
                best = (cost, slot);
            }
        }
        slot_of[node] = best.1;
        free[best.1] = false;
    }
    slot_of
}

/// Pairwise-swap refinement: repeatedly swap the slots of any node pair
/// whose swap strictly reduces the objective; stop at a fixed point
/// (bounded pass count for safety). Monotone by construction.
fn refine_slots(base: &Placement, weights: &TrafficMatrix, mut slots: Vec<usize>) -> Vec<usize> {
    let n = base.nodes();
    let pair_cost = |node: usize, slot: usize, slots: &[usize], skip: usize| -> u128 {
        let (r, c) = slot_coord(base.width, slot);
        let mut cost = 0u128;
        for other in 0..n {
            if other == node || other == skip {
                continue;
            }
            let w = weights.get(node, other);
            if w > 0 {
                let (or, oc) = slot_coord(base.width, slots[other]);
                cost += w as u128 * (r.abs_diff(or) + c.abs_diff(oc)) as u128;
            }
        }
        cost
    };
    for _pass in 0..(2 * n).max(8) {
        let mut improved = false;
        for i in 0..n {
            for j in (i + 1)..n {
                // cost touching i or j before and after the swap; all
                // other terms are unchanged. The i<->j term itself is
                // invariant under the swap (hop distance is symmetric).
                let before = pair_cost(i, slots[i], &slots, j) + pair_cost(j, slots[j], &slots, i);
                let after = pair_cost(i, slots[j], &slots, j) + pair_cost(j, slots[i], &slots, i);
                if after < before {
                    slots.swap(i, j);
                    improved = true;
                }
            }
        }
        if !improved {
            break;
        }
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_placement() {
        let p = Placement::new(16);
        assert_eq!(p.width, 4);
        assert_eq!(p.nodes(), 18);
        assert!(p.height >= 5); // 16 compute + 2 specials need a 5th row
    }

    #[test]
    fn snake_order_keeps_neighbours_adjacent() {
        let p = Placement::new(16);
        for i in 0..15 {
            assert_eq!(p.hops(i, i + 1), 1, "nodes {i},{} not adjacent", i + 1);
        }
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let p = Placement::new(9);
        assert_eq!(p.hops(0, 0), 0);
        assert_eq!(p.hops(0, 8), p.hops(8, 0));
    }

    #[test]
    fn single_chiplet() {
        let p = Placement::new(1);
        assert_eq!(p.width, 1);
        assert_eq!(p.nodes(), 3);
        assert_eq!(p.coord(2), (2, 0));
    }

    #[test]
    fn link_count() {
        let p = Placement::new(16); // 4 wide, >=5 tall
        let expected = 2 * p.width * p.height - p.width - p.height;
        assert_eq!(p.links(), expected);
    }

    /// Deterministic pseudo-random weights for optimizer tests.
    fn random_matrix(n: usize, seed: u64) -> TrafficMatrix {
        let mut m = TrafficMatrix::new(n);
        let mut x = seed | 1;
        for a in 0..n {
            for b in (a + 1)..n {
                // xorshift64
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                if x % 3 != 0 {
                    m.add(a, b, x % 1000);
                }
            }
        }
        m
    }

    #[test]
    fn rowmajor_coords_are_identity_embedding() {
        // the `slots: None` path must reproduce the pre-dataflow
        // arithmetic bit-for-bit: row-major snake order over node ids
        let p = Placement::new(7);
        for node in 0..p.nodes() {
            let r = node / p.width;
            let c = node % p.width;
            let c = if r % 2 == 0 { c } else { p.width - 1 - c };
            assert_eq!(p.coord(node), (r, c));
        }
        assert!(!p.is_permuted());
    }

    #[test]
    fn dataflow_never_costs_more_than_rowmajor() {
        for seed in [1u64, 7, 42, 1234] {
            for chiplets in [3usize, 6, 14, 23] {
                let base = Placement::new(chiplets);
                let m = random_matrix(base.nodes(), seed);
                let opt = Placement::dataflow(chiplets, &m);
                assert!(
                    weighted_hop_cost(&opt, &m) <= weighted_hop_cost(&base, &m),
                    "dataflow worse than rowmajor for n={chiplets} seed={seed}"
                );
                // same footprint: only distances move, never area
                assert_eq!((opt.width, opt.height), (base.width, base.height));
                assert_eq!(opt.links(), base.links());
            }
        }
    }

    #[test]
    fn swap_refinement_never_increases_cost() {
        for seed in [3u64, 99] {
            let base = Placement::new(11);
            let m = random_matrix(base.nodes(), seed);
            let greedy = greedy_slots(&base, &m);
            let mut g = base.clone();
            g.slots = Some(greedy.clone());
            let before = weighted_hop_cost(&g, &m);
            let refined = refine_slots(&base, &m, greedy);
            let mut r = base.clone();
            r.slots = Some(refined);
            assert!(
                weighted_hop_cost(&r, &m) <= before,
                "swap pass increased the objective"
            );
        }
    }

    #[test]
    fn dataflow_is_deterministic() {
        let base = Placement::new(9);
        let m = random_matrix(base.nodes(), 5);
        let a = Placement::dataflow(9, &m);
        let b = Placement::dataflow(9, &m);
        for node in 0..a.nodes() {
            assert_eq!(a.coord(node), b.coord(node));
        }
    }

    #[test]
    fn dataflow_places_heavy_pair_adjacent() {
        // one dominant pair must end up on neighbouring slots
        let mut m = TrafficMatrix::new(9); // 7 chiplets + 2 specials
        m.add(0, 6, 1_000_000);
        m.add(1, 2, 3);
        let p = Placement::dataflow(7, &m);
        assert_eq!(p.hops(0, 6), 1, "heavy pair not adjacent");
    }

    #[test]
    fn dataflow_is_a_permutation() {
        let base = Placement::new(13);
        let m = random_matrix(base.nodes(), 11);
        let p = Placement::dataflow(13, &m);
        let mut seen = vec![false; p.nodes()];
        for node in 0..p.nodes() {
            let (r, c) = p.coord(node);
            // coordinates must map back to distinct in-range slots
            let slot = r * p.width + if r % 2 == 0 { c } else { p.width - 1 - c };
            assert!(slot < p.nodes(), "slot {slot} out of the occupied range");
            assert!(!seen[slot], "slot {slot} assigned twice");
            seen[slot] = true;
        }
    }

    #[test]
    fn traffic_matrix_symmetry() {
        let mut m = TrafficMatrix::new(4);
        m.add(0, 2, 10);
        m.add(2, 0, 5);
        m.add(1, 1, 99); // self-traffic ignored
        assert_eq!(m.get(0, 2), 15);
        assert_eq!(m.get(2, 0), 15);
        assert_eq!(m.get(1, 1), 0);
        assert_eq!(m.node_weight(0), 15);
    }
}
