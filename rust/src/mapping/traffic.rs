//! Traffic generation — Algorithm 2 of the paper, at both granularities:
//! tile-level flows inside each chiplet (NoC) and chiplet-level flows on
//! the interposer (NoP), plus the global accumulator / buffer access
//! counts the circuit engine needs.
//!
//! Traces are *flow-compressed*: Algorithm 2 enumerates packets
//! `(s, d, k)` with `k` advancing once per source iteration and once per
//! packet round; packet `n` of pair `(s, d)` is injected at
//! `n·(n_src+1) + s_idx`. A [`Flow`] stores `(src, dst, count, start,
//! stride)` instead of materializing billions of tuples; the network
//! simulators consume flows directly.

use super::partition::MappingResult;
use super::placement::Placement;
use crate::config::SiamConfig;
use crate::dnn::{Dnn, LayerKind};

/// A compressed packet sequence between one source and one destination.
///
/// `Hash`/`Eq` make whole flow traces usable as cache keys (see
/// [`crate::noc::EpochCache`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Flow {
    /// Source node id (tile for NoC epochs, chiplet for NoP epochs).
    pub src: u32,
    /// Destination node id.
    pub dst: u32,
    /// Number of packets.
    pub count: u64,
    /// Injection cycle of the first packet.
    pub start: u64,
    /// Cycles between consecutive packets of this flow.
    pub stride: u64,
}

impl Flow {
    /// Total packets across a slice of flows.
    pub fn total_packets(flows: &[Flow]) -> u64 {
        flows.iter().map(|f| f.count).sum()
    }
}

/// One timestamp epoch (Algorithm 2 resets k per layer pair).
pub type Epoch = Vec<Flow>;

/// Sort an epoch's flows into the canonical `(start, src, dst, count,
/// stride)` order.
///
/// Two epochs containing the same flow multiset always serialize to the
/// same trace, so order-permuted but otherwise identical epochs produce
/// one [`crate::noc::EpochCache`] fingerprint (one miss, then hits) and
/// one well-defined schedule — the simulators process flows with tied
/// start cycles in trace order, so canonicalization also pins that tie
/// break. [`build_traffic`] canonicalizes every epoch it emits.
pub fn canonicalize_flows(flows: &mut [Flow]) {
    flows.sort_unstable_by_key(|f| (f.start, f.src, f.dst, f.count, f.stride));
}

/// An epoch tagged with the weight-layer position that produced it, so
/// the coordinator can overlap epochs belonging to the same layer
/// (chiplets of one layer communicate in parallel) while serializing
/// across layers.
#[derive(Debug, Clone)]
pub struct LabeledEpoch {
    /// Position in the weight-layer sequence.
    pub layer: usize,
    /// Chiplet the epoch runs on (NoC epochs; 0 for NoP).
    pub chiplet: usize,
    /// The epoch's flow-compressed packet trace.
    pub flows: Epoch,
}

/// Complete traffic picture for one mapped DNN.
#[derive(Debug, Clone, Default)]
pub struct Traffic {
    /// NoP epochs (chiplet-granularity), one per layer transition that
    /// crosses chiplets (activations, partial sums, skip edges).
    pub nop_epochs: Vec<LabeledEpoch>,
    /// NoC epochs (tile-granularity), tagged with chiplet + layer.
    pub noc_epochs: Vec<LabeledEpoch>,
    /// Logical activation volume crossing chiplets, bits.
    pub inter_chiplet_bits: f64,
    /// Logical activation volume moving tile-to-tile inside chiplets, bits.
    pub intra_chiplet_bits: f64,
    /// Global accumulator additions (partial-sum reduction).
    pub accumulator_adds: u64,
    /// Global buffer write accesses (elements).
    pub global_buffer_writes: u64,
    /// Global buffer read accesses (elements).
    pub global_buffer_reads: u64,
}

/// Packets per (src, dst) pair when `total_packets` worth of data is
/// sliced uniformly across the sources (each source multicasts its slice
/// to every destination — the uniform split of Section 4.2).
fn per_source(total_packets: u64, srcs: usize) -> u64 {
    total_packets.div_ceil(srcs.max(1) as u64)
}

/// Algorithm 2 inner loops for one (source set, destination set) pair.
fn alg2_flows(srcs: &[u32], dsts: &[u32], packets_per_pair: u64, epoch: &mut Epoch) {
    if packets_per_pair == 0 || srcs.is_empty() || dsts.is_empty() {
        return;
    }
    let stride = srcs.len() as u64 + 1;
    for (si, &s) in srcs.iter().enumerate() {
        for &d in dsts {
            if s == d {
                continue;
            }
            epoch.push(Flow {
                src: s,
                dst: d,
                count: packets_per_pair,
                start: si as u64,
                stride,
            });
        }
    }
}

/// Tile ranges occupied by each weight layer on each chiplet.
/// `tile_ranges[layer][k] = (chiplet, first_tile, n_tiles)`. Tile
/// geometry is per chiplet (`xbars_per_tile[c]`, `tiles_per_chiplet[c]`)
/// so heterogeneous classes lay out correctly; single-kind systems pass
/// uniform vectors and reproduce the classic layout.
fn assign_tiles(
    map: &MappingResult,
    xbars_per_tile: &[usize],
    tiles_per_chiplet: &[usize],
) -> Vec<Vec<(usize, usize, usize)>> {
    let mut cursor = vec![0usize; map.num_chiplets];
    let mut out = Vec::with_capacity(map.per_layer.len());
    for lm in &map.per_layer {
        let mut spans = Vec::with_capacity(lm.chiplets.len());
        for share in &lm.chiplets {
            let tiles = share.xbars.div_ceil(xbars_per_tile[share.chiplet]).max(1);
            let tiles = tiles.min(tiles_per_chiplet[share.chiplet]);
            let first = cursor[share.chiplet] % tiles_per_chiplet[share.chiplet];
            cursor[share.chiplet] += tiles;
            spans.push((share.chiplet, first, tiles));
        }
        out.push(spans);
    }
    out
}

fn tile_ids(first: usize, n: usize, tiles_per_chiplet: usize) -> Vec<u32> {
    (0..n)
        .map(|i| ((first + i) % tiles_per_chiplet) as u32)
        .collect()
}

/// Build NoC + NoP traffic for a mapped DNN (Algorithm 2 at both levels).
pub fn build_traffic(
    dnn: &Dnn,
    map: &MappingResult,
    placement: &Placement,
    cfg: &SiamConfig,
) -> Traffic {
    let q = cfg.dnn.activation_precision as u64;
    let w_noc = cfg.chiplet.noc_width as u64;
    let w_nop = cfg.system.nop.bits_per_cycle();
    // per-chiplet tile geometry: the owning class's figures (uniform —
    // and equal to the base [chiplet] block — for single-kind systems)
    let classes = cfg.resolved_chiplet_classes();
    // partial sums carry accumulated precision (weight + act + log2 of
    // the *owning class's* crossbar rows — smaller crossbars accumulate
    // a narrower row sum); single-kind systems reduce to the base value
    let q_partial_of: Vec<u64> = classes
        .iter()
        .map(|c| {
            (cfg.dnn.weight_precision as u64 + q + (c.xbar_rows as f64).log2() as u64).min(32)
        })
        .collect();
    let tiles_of: Vec<usize> = map
        .chiplet_class
        .iter()
        .map(|&k| classes[k].tiles_per_chiplet)
        .collect();
    let xbars_pt_of: Vec<usize> = map
        .chiplet_class
        .iter()
        .map(|&k| classes[k].xbars_per_tile)
        .collect();
    let widx = dnn.weight_layers();
    let tiles = assign_tiles(map, &xbars_pt_of, &tiles_of);

    let mut t = Traffic::default();

    // NoP port inside a chiplet is reached through tile 0 (the tile
    // adjacent to the chiplet's NoP router, Fig. 2).
    const NOP_PORT_TILE: u32 = 0;

    for li in 0..map.per_layer.len() {
        let lm = &map.per_layer[li];
        let layer = &dnn.layers[lm.layer_idx];
        // activations leaving this weight layer (after its fused
        // pool/relu ops): the input of the next weight layer, or this
        // layer's ofm for the last one.
        let (a_out, next) = if li + 1 < map.per_layer.len() {
            let nl = &dnn.layers[map.per_layer[li + 1].layer_idx];
            (nl.ifm.elems() as u64, Some(li + 1))
        } else {
            (layer.ofm.elems() as u64, None)
        };

        let src_chiplets: Vec<u32> = lm.chiplets.iter().map(|s| s.chiplet as u32).collect();

        // ---- partial-sum reduction over the NoP (layer spans chiplets)
        if lm.spans_chiplets() {
            let q_partial = q_partial_of[lm.class];
            let n = lm.chiplets.len() as u64;
            let out_elems = layer.ofm.elems() as u64;
            t.accumulator_adds += (n - 1) * out_elems;
            t.global_buffer_writes += n * out_elems;
            t.global_buffer_reads += out_elems;
            let np = (out_elems * q_partial).div_ceil(w_nop);
            let mut epoch = Epoch::new();
            alg2_flows(
                &src_chiplets,
                &[placement.accumulator_node as u32],
                np,
                &mut epoch,
            );
            canonicalize_flows(&mut epoch);
            t.inter_chiplet_bits += (n * out_elems * q_partial) as f64;
            t.nop_epochs.push(LabeledEpoch {
                layer: li,
                chiplet: 0,
                flows: epoch,
            });
        }

        // ---- attention head exchange over the NoP: a spanning
        // attention layer shards its heads across chiplets, and
        // assembling the concatenated head outputs for the O projection
        // is an all-to-all among the layer's chiplets — each ships its
        // `L·D/n` output slice to every peer. Layers that fit one
        // chiplet concatenate locally and add nothing.
        if lm.spans_chiplets() {
            if let LayerKind::Attention { dim, .. } | LayerKind::CausalAttention { dim, .. } =
                layer.kind
            {
                let seq = (layer.ifm.h * layer.ifm.w) as u64;
                let n = src_chiplets.len() as u64;
                let slice_bits = (seq * dim as u64 * q).div_ceil(n);
                let np = slice_bits.div_ceil(w_nop);
                let mut epoch = Epoch::new();
                alg2_flows(&src_chiplets, &src_chiplets, np, &mut epoch);
                canonicalize_flows(&mut epoch);
                if !epoch.is_empty() {
                    t.inter_chiplet_bits += (n * (n - 1) * slice_bits) as f64;
                    t.nop_epochs.push(LabeledEpoch {
                        layer: li,
                        chiplet: 0,
                        flows: epoch,
                    });
                }
            }
        }

        // ---- activations to the next weight layer
        if let Some(nj) = next {
            let nm = &map.per_layer[nj];
            let dst_chiplets: Vec<u32> = nm.chiplets.iter().map(|s| s.chiplet as u32).collect();
            let np_nop = (a_out * q).div_ceil(w_nop);
            let np_noc = (a_out * q).div_ceil(w_noc);

            // effective source: the accumulator if we just reduced there
            let eff_srcs: Vec<u32> = if lm.spans_chiplets() {
                vec![placement.accumulator_node as u32]
            } else {
                src_chiplets.clone()
            };
            let crosses = eff_srcs != dst_chiplets || eff_srcs.len() > 1;
            if crosses {
                let mut epoch = Epoch::new();
                alg2_flows(
                    &eff_srcs,
                    &dst_chiplets,
                    per_source(np_nop, eff_srcs.len()),
                    &mut epoch,
                );
                canonicalize_flows(&mut epoch);
                if !epoch.is_empty() {
                    t.inter_chiplet_bits +=
                        (a_out * q) as f64 * dst_chiplets.len() as f64;
                    t.nop_epochs.push(LabeledEpoch {
                        layer: li,
                        chiplet: 0,
                        flows: epoch,
                    });
                }
            }

            // NoC inside each participating chiplet
            for (k, share) in lm.chiplets.iter().enumerate() {
                let (c, first, n_t) = tiles[li][k];
                debug_assert_eq!(c, share.chiplet);
                let srcs = tile_ids(first, n_t, tiles_of[c]);
                // destination tiles: next layer's tiles if co-resident,
                // else the NoP port tile.
                let co = tiles[nj].iter().find(|(cc, _, _)| *cc == c);
                let dsts = match co {
                    Some(&(_, f2, n2)) if !crosses => tile_ids(f2, n2, tiles_of[c]),
                    _ => vec![NOP_PORT_TILE],
                };
                let mut epoch = Epoch::new();
                alg2_flows(&srcs, &dsts, per_source(np_noc, srcs.len()), &mut epoch);
                canonicalize_flows(&mut epoch);
                if !epoch.is_empty() {
                    t.intra_chiplet_bits += (a_out * q) as f64;
                    t.noc_epochs.push(LabeledEpoch {
                        layer: li,
                        chiplet: c,
                        flows: epoch,
                    });
                }
            }
            // incoming side: NoP port -> next layer's tiles
            if crosses {
                for &(c, f2, n2) in &tiles[nj] {
                    let dsts = tile_ids(f2, n2, tiles_of[c]);
                    let mut epoch = Epoch::new();
                    alg2_flows(&[NOP_PORT_TILE], &dsts, np_noc, &mut epoch);
                    canonicalize_flows(&mut epoch);
                    if !epoch.is_empty() {
                        t.intra_chiplet_bits += (a_out * q) as f64;
                        t.noc_epochs.push(LabeledEpoch {
                            layer: nj,
                            chiplet: c,
                            flows: epoch,
                        });
                    }
                }
            }
        }
    }

    // ---- embedding-table lookups stream from the global buffer (the
    // table lives off-crossbar): one read per produced element.
    for l in &dnn.layers {
        if let LayerKind::Embedding { .. } = l.kind {
            t.global_buffer_reads += l.ofm.elems() as u64;
        }
    }

    // ---- residual / concat skip edges: source activations shipped to the
    // chiplets that perform the add (owner of the consuming layer).
    let owner_of = |layer_idx: usize| -> Option<&Vec<(usize, usize, usize)>> {
        // nearest preceding weight layer's tiles
        let wpos = widx.iter().rposition(|&w| w <= layer_idx)?;
        tiles.get(wpos)
    };
    for (i, l) in dnn.layers.iter().enumerate() {
        if let LayerKind::ResidualAdd { from } | LayerKind::Concat { from } = l.kind {
            let (Some(src_t), Some(dst_t)) = (owner_of(from), owner_of(i)) else {
                continue;
            };
            let src_c: Vec<u32> = src_t.iter().map(|&(c, _, _)| c as u32).collect();
            let dst_c: Vec<u32> = dst_t.iter().map(|&(c, _, _)| c as u32).collect();
            if src_c == dst_c {
                continue; // buffered locally
            }
            let elems = dnn.layers[from].ofm.elems() as u64;
            let np = per_source((elems * q).div_ceil(w_nop), src_c.len());
            let mut epoch = Epoch::new();
            alg2_flows(&src_c, &dst_c, np, &mut epoch);
            canonicalize_flows(&mut epoch);
            if !epoch.is_empty() {
                t.inter_chiplet_bits += (elems * q) as f64 * dst_c.len() as f64;
                t.nop_epochs.push(LabeledEpoch {
                    layer: widx.iter().rposition(|&w| w <= i).unwrap_or(0),
                    chiplet: 0,
                    flows: epoch,
                });
            }
        }
    }

    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;
    use crate::mapping::map_dnn;

    fn setup(model: &str, ds: &str, cfg: &SiamConfig) -> (Traffic, MappingResult) {
        let dnn = build_model(model, ds).unwrap();
        let map = map_dnn(&dnn, cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let t = build_traffic(&dnn, &map, &pl, cfg);
        (t, map)
    }

    #[test]
    fn alg2_timestamp_semantics() {
        let mut e = Epoch::new();
        alg2_flows(&[0, 1], &[2, 3], 5, &mut e);
        assert_eq!(e.len(), 4);
        // stride is n_src + 1 = 3; source 1 starts one cycle later
        assert!(e.iter().all(|f| f.stride == 3));
        assert_eq!(e.iter().find(|f| f.src == 0).unwrap().start, 0);
        assert_eq!(e.iter().find(|f| f.src == 1).unwrap().start, 1);
        assert_eq!(Flow::total_packets(&e), 20);
    }

    #[test]
    fn alg2_skips_self_loops() {
        let mut e = Epoch::new();
        alg2_flows(&[0, 1], &[1, 2], 1, &mut e);
        assert!(e.iter().all(|f| f.src != f.dst));
        assert_eq!(e.len(), 3);
    }

    #[test]
    fn resnet110_generates_traffic() {
        let cfg = SiamConfig::paper_default();
        let (t, map) = setup("resnet110", "cifar10", &cfg);
        assert!(t.intra_chiplet_bits > 0.0);
        assert!(t.inter_chiplet_bits > 0.0);
        assert!(t.noc_epochs.iter().all(|e| e.chiplet < map.num_chiplets));
        // residual network with spanning layers must use the accumulator
        if map.per_layer.iter().any(|l| l.spans_chiplets()) {
            assert!(t.accumulator_adds > 0);
        }
    }

    #[test]
    fn bigger_chiplets_reduce_nop_share() {
        // Fig. 11 trend: more tiles per chiplet localizes computation.
        let cfg4 = SiamConfig::paper_default().with_tiles_per_chiplet(4);
        let cfg36 = SiamConfig::paper_default().with_tiles_per_chiplet(36);
        let (t4, _) = setup("resnet110", "cifar10", &cfg4);
        let (t36, _) = setup("resnet110", "cifar10", &cfg36);
        assert!(
            t36.inter_chiplet_bits < t4.inter_chiplet_bits,
            "NoP volume should shrink: {} vs {}",
            t36.inter_chiplet_bits,
            t4.inter_chiplet_bits
        );
    }

    #[test]
    fn spanning_attention_adds_head_exchange_epochs() {
        // bert_base attention blocks overflow one paper-default chiplet,
        // so every one of them must contribute an all-to-all exchange
        // among exactly its own chiplets
        let cfg = SiamConfig::paper_default().with_model("bert_base", "seq128");
        let dnn = build_model("bert_base", "seq128").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let t = build_traffic(&dnn, &map, &pl, &cfg);
        let widx = dnn.weight_layers();
        let mut exchanges = 0;
        for (li, lm) in map.per_layer.iter().enumerate() {
            let is_attn = matches!(
                dnn.layers[widx[li]].kind,
                crate::dnn::LayerKind::Attention { .. }
            );
            if !(is_attn && lm.spans_chiplets()) {
                continue;
            }
            let members: Vec<u32> = lm.chiplets.iter().map(|s| s.chiplet as u32).collect();
            // find an all-to-all epoch for this layer: every ordered
            // pair of the layer's chiplets appears as a flow
            let found = t.nop_epochs.iter().any(|e| {
                e.layer == li
                    && members.iter().all(|&a| {
                        members
                            .iter()
                            .filter(|&&b| b != a)
                            .all(|&b| e.flows.iter().any(|f| f.src == a && f.dst == b))
                    })
            });
            assert!(found, "attention layer {li} has no head-exchange epoch");
            exchanges += 1;
        }
        assert!(exchanges > 0, "bert_base must shard attention layers");
        // embedding lookups hit the global buffer
        assert!(t.global_buffer_reads >= 2 * 128 * 768);
        // CNNs are untouched: no embedding reads beyond the classic path
        let cnn_cfg = SiamConfig::paper_default();
        let (cnn_t, _) = setup("resnet110", "cifar10", &cnn_cfg);
        assert!(cnn_t.nop_epochs.iter().all(|e| !e.flows.is_empty()));
    }

    #[test]
    fn monolithic_has_no_nop_traffic() {
        let cfg =
            SiamConfig::paper_default().with_chip_mode(crate::config::ChipMode::Monolithic);
        let (t, _) = setup("resnet110", "cifar10", &cfg);
        assert_eq!(t.inter_chiplet_bits, 0.0);
        assert!(t.nop_epochs.is_empty());
    }

    #[test]
    fn permuted_epochs_share_one_cache_entry() {
        use crate::noc::{EpochCache, Mesh, PacketSim};
        // the same flow set in two different orders must canonicalize to
        // one trace: one cache miss, then a hit
        let f = |src: u32, start: u64| Flow {
            src,
            dst: 5,
            count: 7,
            start,
            stride: 3,
        };
        let mut a = vec![f(2, 2), f(0, 0), f(1, 1)];
        let mut b = vec![a[1], a[2], a[0]];
        canonicalize_flows(&mut a);
        canonicalize_flows(&mut b);
        assert_eq!(a, b, "permutations must canonicalize identically");

        let mesh = Mesh::new(9);
        let sim = PacketSim::new(&mesh);
        let cache = EpochCache::new();
        let ra = sim.run_cached(&a, &cache);
        let rb = sim.run_cached(&b, &cache);
        assert_eq!(ra, rb);
        assert_eq!(cache.misses(), 1, "permuted epochs must alias");
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn emitted_epochs_are_canonical() {
        let cfg = SiamConfig::paper_default();
        let (t, _) = setup("resnet110", "cifar10", &cfg);
        let key = |f: &Flow| (f.start, f.src, f.dst, f.count, f.stride);
        for ep in t.noc_epochs.iter().chain(&t.nop_epochs) {
            assert!(
                ep.flows.windows(2).all(|w| key(&w[0]) <= key(&w[1])),
                "epoch not in canonical order"
            );
        }
    }

    #[test]
    fn flow_counts_match_volume() {
        let cfg = SiamConfig::paper_default();
        let (t, _) = setup("lenet5", "cifar10", &cfg);
        for e in &t.noc_epochs {
            assert!(Flow::total_packets(&e.flows) > 0);
        }
    }
}
