//! Partition & mapping engine (Section 4.2 of the paper).
//!
//! Implements Eq. 1 (layer → crossbar rows/columns), Algorithm 1
//! (layer-wise partitioning onto chiplets — homogeneous, custom and
//! heterogeneous chiplet classes), the crossbar/cell utilization
//! accounting of Fig. 9, interposer placement (row-major snake or
//! dataflow-optimized), the inter-/intra-chiplet traffic volumes, and
//! the global accumulator/buffer access counts that feed the circuit,
//! NoC and NoP engines.

mod partition;
mod placement;
mod traffic;

pub use partition::{eq1_rows_cols, map_dnn, ChipletShare, LayerMapping, MappingError, MappingResult};
pub use placement::{weighted_hop_cost, Placement, TrafficMatrix};
pub use traffic::{build_traffic, canonicalize_flows, Flow, Traffic};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipletStructure, SiamConfig};
    use crate::dnn::build_model;

    fn cfg() -> SiamConfig {
        SiamConfig::paper_default()
    }

    #[test]
    fn resnet110_custom_mapping_is_consistent() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg()).unwrap();
        // every weight layer mapped, shares sum to layer totals
        assert_eq!(map.per_layer.len(), dnn.weight_layers().len());
        for lm in &map.per_layer {
            let sum: usize = lm.chiplets.iter().map(|c| c.xbars).sum();
            assert_eq!(sum, lm.xbars, "layer {} shares", lm.layer_idx);
            assert_eq!(lm.xbars, lm.rows * lm.cols);
        }
        // no chiplet over capacity
        let s = cfg().chiplet_size_xbars();
        for (c, used) in map.chiplet_used_xbars.iter().enumerate() {
            assert!(*used <= s, "chiplet {c} over capacity: {used} > {s}");
        }
        assert!(map.num_chiplets > 0);
        assert!(map.xbar_utilization() > 0.3 && map.xbar_utilization() <= 1.0);
    }

    #[test]
    fn paper_resnet50_tile_count() {
        // Paper Section 1: ResNet-50, 8-bit, 128x128 crossbars, 16
        // crossbars per tile => 802 tiles. Our mapping must land close
        // (exact packing differs slightly from [34]'s).
        let dnn = build_model("resnet50", "imagenet").unwrap();
        let map = map_dnn(&dnn, &cfg()).unwrap();
        let xbars: usize = map.per_layer.iter().map(|l| l.xbars).sum();
        let tiles = xbars.div_ceil(16);
        assert!(
            (700..=900).contains(&tiles),
            "ResNet-50 tiles {tiles} not near the paper's 802"
        );
    }

    #[test]
    fn homogeneous_rejects_overflow() {
        let dnn = build_model("resnet50", "imagenet").unwrap();
        let cfg = cfg()
            .with_chiplet_structure(ChipletStructure::Homogeneous)
            .with_total_chiplets(4);
        match map_dnn(&dnn, &cfg) {
            Err(MappingError::ExceedsChiplets { required, available }) => {
                assert!(required > available);
                assert_eq!(available, 4);
            }
            other => panic!("expected overflow error, got {other:?}"),
        }
    }

    #[test]
    fn homogeneous_spreads_across_all_chiplets() {
        // Fig. 4 left: the generic architecture distributes the DNN over
        // the whole fixed array (more chiplets in use than custom needs).
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let custom = map_dnn(&dnn, &cfg()).unwrap();
        let homog = map_dnn(
            &dnn,
            &cfg().with_total_chiplets(custom.num_chiplets_required + 10),
        )
        .unwrap();
        assert_eq!(homog.num_chiplets, custom.num_chiplets_required + 10);
        assert!(
            homog.num_chiplets_required > custom.num_chiplets_required,
            "homogeneous should spread: {} vs {}",
            homog.num_chiplets_required,
            custom.num_chiplets_required
        );
    }

    #[test]
    fn utilization_improves_with_smaller_chiplets() {
        // Fig. 9 trend: fewer tiles per chiplet -> finer allocation
        // granularity -> utilization can only stay equal or improve.
        let dnn = build_model("vgg16", "imagenet").unwrap();
        let u4 = map_dnn(&dnn, &cfg().with_tiles_per_chiplet(4))
            .unwrap()
            .xbar_utilization();
        let u36 = map_dnn(&dnn, &cfg().with_tiles_per_chiplet(36))
            .unwrap()
            .xbar_utilization();
        assert!(u4 >= u36 - 0.05, "u4={u4} u36={u36}");
    }
}
