//! Hardware performance metric types shared by every SIAM engine.
//!
//! All engines report their results as [`Metrics`] (area / energy /
//! latency / leakage) which compose additively across components and
//! provide the paper's derived figures of merit: energy-delay product
//! (EDP), energy-delay-area product (EDAP), power, and TOPS/W style
//! energy efficiency.

use std::iter::Sum;
use std::ops::{Add, AddAssign, Mul};

/// Area/energy/latency/leakage bundle for a hardware component or system.
///
/// Units are fixed across the whole simulator:
/// * area — µm²
/// * energy — pJ (dynamic, per inference unless stated otherwise)
/// * latency — ns
/// * leakage — µW
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Metrics {
    /// Silicon area, µm².
    pub area_um2: f64,
    /// Dynamic energy, pJ.
    pub energy_pj: f64,
    /// Latency, ns.
    pub latency_ns: f64,
    /// Leakage power, µW.
    pub leakage_uw: f64,
}

impl Metrics {
    /// The additive identity.
    pub const ZERO: Metrics = Metrics {
        area_um2: 0.0,
        energy_pj: 0.0,
        latency_ns: 0.0,
        leakage_uw: 0.0,
    };

    /// Bundle area/energy/latency with zero leakage.
    pub fn new(area_um2: f64, energy_pj: f64, latency_ns: f64) -> Self {
        Metrics {
            area_um2,
            energy_pj,
            latency_ns,
            leakage_uw: 0.0,
        }
    }

    /// Attach a leakage figure.
    pub fn with_leakage(mut self, leakage_uw: f64) -> Self {
        self.leakage_uw = leakage_uw;
        self
    }

    /// Energy-delay product in pJ·ns.
    pub fn edp(&self) -> f64 {
        self.energy_pj * self.latency_ns
    }

    /// Energy-delay-area product in pJ·ns·mm² (area converted to mm² so the
    /// magnitudes stay comparable with the paper's plots).
    pub fn edap(&self) -> f64 {
        self.edp() * self.area_mm2()
    }

    /// Area in mm².
    pub fn area_mm2(&self) -> f64 {
        self.area_um2 / 1.0e6
    }

    /// Energy in µJ.
    pub fn energy_uj(&self) -> f64 {
        self.energy_pj / 1.0e6
    }

    /// Energy in mJ.
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj / 1.0e9
    }

    /// Latency in ms.
    pub fn latency_ms(&self) -> f64 {
        self.latency_ns / 1.0e6
    }

    /// Average dynamic power in mW over the latency window.
    pub fn avg_power_mw(&self) -> f64 {
        if self.latency_ns == 0.0 {
            0.0
        } else {
            self.energy_pj / self.latency_ns // pJ/ns == mW
        }
    }

    /// Leakage energy accumulated over the latency window, in pJ.
    pub fn leakage_energy_pj(&self) -> f64 {
        // µW * ns = femto-J ⇒ /1000 to pJ
        self.leakage_uw * self.latency_ns / 1.0e3
    }

    /// Serial composition: areas and energies add, latencies add.
    pub fn then(&self, other: &Metrics) -> Metrics {
        *self + *other
    }

    /// Parallel composition: areas and energies add, latency is the max.
    pub fn alongside(&self, other: &Metrics) -> Metrics {
        Metrics {
            area_um2: self.area_um2 + other.area_um2,
            energy_pj: self.energy_pj + other.energy_pj,
            latency_ns: self.latency_ns.max(other.latency_ns),
            leakage_uw: self.leakage_uw + other.leakage_uw,
        }
    }

    /// Replicate a component `n` times operating in parallel (area and
    /// energy scale, latency unchanged).
    pub fn replicate(&self, n: usize) -> Metrics {
        Metrics {
            area_um2: self.area_um2 * n as f64,
            energy_pj: self.energy_pj * n as f64,
            latency_ns: self.latency_ns,
            leakage_uw: self.leakage_uw * n as f64,
        }
    }

    /// Repeat an operation `n` times serially on the same hardware (energy
    /// and latency scale, area unchanged).
    pub fn repeat(&self, n: usize) -> Metrics {
        Metrics {
            area_um2: self.area_um2,
            energy_pj: self.energy_pj * n as f64,
            latency_ns: self.latency_ns * n as f64,
            leakage_uw: self.leakage_uw,
        }
    }
}

impl Add for Metrics {
    type Output = Metrics;
    fn add(self, o: Metrics) -> Metrics {
        Metrics {
            area_um2: self.area_um2 + o.area_um2,
            energy_pj: self.energy_pj + o.energy_pj,
            latency_ns: self.latency_ns + o.latency_ns,
            leakage_uw: self.leakage_uw + o.leakage_uw,
        }
    }
}

impl AddAssign for Metrics {
    fn add_assign(&mut self, o: Metrics) {
        *self = *self + o;
    }
}

impl Mul<f64> for Metrics {
    type Output = Metrics;
    fn mul(self, s: f64) -> Metrics {
        Metrics {
            area_um2: self.area_um2 * s,
            energy_pj: self.energy_pj * s,
            latency_ns: self.latency_ns * s,
            leakage_uw: self.leakage_uw * s,
        }
    }
}

impl Sum for Metrics {
    fn sum<I: Iterator<Item = Metrics>>(iter: I) -> Metrics {
        iter.fold(Metrics::ZERO, |a, b| a + b)
    }
}

/// Named breakdown of a system metric into components (Fig. 10 of the
/// paper: IMC circuit vs NoC vs NoP).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    /// `(component name, metrics)` pairs in insertion order.
    pub components: Vec<(String, Metrics)>,
}

impl Breakdown {
    /// Append a named component.
    pub fn push(&mut self, name: impl Into<String>, m: Metrics) {
        self.components.push((name.into(), m));
    }

    /// Sum of all components.
    pub fn total(&self) -> Metrics {
        self.components.iter().map(|(_, m)| *m).sum()
    }

    /// Look up a component by name.
    pub fn get(&self, name: &str) -> Option<Metrics> {
        self.components
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, m)| *m)
    }

    /// Percentage share of each component for a metric selector.
    pub fn shares(&self, select: impl Fn(&Metrics) -> f64) -> Vec<(String, f64)> {
        let total: f64 = self.components.iter().map(|(_, m)| select(m)).sum();
        self.components
            .iter()
            .map(|(n, m)| {
                let share = if total > 0.0 {
                    100.0 * select(m) / total
                } else {
                    0.0
                };
                (n.clone(), share)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edp_edap() {
        let m = Metrics::new(2.0e6, 10.0, 5.0); // 2 mm², 10 pJ, 5 ns
        assert_eq!(m.edp(), 50.0);
        assert!((m.edap() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn serial_vs_parallel_composition() {
        let a = Metrics::new(1.0, 2.0, 3.0);
        let b = Metrics::new(10.0, 20.0, 30.0);
        let s = a.then(&b);
        assert_eq!(s.latency_ns, 33.0);
        let p = a.alongside(&b);
        assert_eq!(p.latency_ns, 30.0);
        assert_eq!(p.energy_pj, 22.0);
    }

    #[test]
    fn replicate_and_repeat() {
        let a = Metrics::new(1.0, 2.0, 3.0);
        let r = a.replicate(4);
        assert_eq!(r.area_um2, 4.0);
        assert_eq!(r.latency_ns, 3.0);
        let q = a.repeat(4);
        assert_eq!(q.area_um2, 1.0);
        assert_eq!(q.latency_ns, 12.0);
    }

    #[test]
    fn power_units() {
        // 1000 pJ over 10 ns = 100 mW
        let m = Metrics::new(0.0, 1000.0, 10.0);
        assert!((m.avg_power_mw() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn breakdown_shares_sum_to_100() {
        let mut b = Breakdown::default();
        b.push("imc", Metrics::new(10.0, 1.0, 1.0));
        b.push("noc", Metrics::new(30.0, 1.0, 1.0));
        b.push("nop", Metrics::new(60.0, 1.0, 1.0));
        let shares = b.shares(|m| m.area_um2);
        let total: f64 = shares.iter().map(|(_, s)| s).sum();
        assert!((total - 100.0).abs() < 1e-9);
        assert!((shares[2].1 - 60.0).abs() < 1e-9);
    }

    #[test]
    fn leakage_energy() {
        let m = Metrics::new(0.0, 0.0, 1000.0).with_leakage(5.0);
        // 5 µW over 1 µs = 5 pJ
        assert!((m.leakage_energy_pj() - 5.0).abs() < 1e-12);
    }
}
