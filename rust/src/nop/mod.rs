//! NoP engine (Section 4.4): chiplet-to-chiplet communication over the
//! passive interposer — trace generation reuses Algorithm 2 (done by the
//! mapping engine), latency comes from the same three-tier mesh engine
//! hierarchy as the NoC (flow-level [`FlowSim`] over the interposer
//! mesh, per-packet and flit-level tiers beneath it), and area/power
//! come from the PTM wire model + measured TX/RX driver figures
//! (Algorithm 3).

pub mod driver;
pub mod wire;

pub use driver::{DriverModel, SIGNALING_SURVEY};
pub use wire::WireModel;

use crate::config::{ChipMode, SiamConfig};
use crate::mapping::{MappingResult, Placement, Traffic};
use crate::metrics::Metrics;
use crate::noc::{EpochCache, EpochObs, EpochObserver, FlowSim, Mesh, TierCounts};

/// Aggregated NoP evaluation.
#[derive(Debug, Clone, Default)]
pub struct NopReport {
    /// Total NoP metrics (drivers, routers, interposer wiring).
    pub metrics: Metrics,
    /// Serialized NoP cycles across the layer sequence.
    pub cycles: u64,
    /// Packets delivered over the interposer.
    pub packets: u64,
    /// Flit-link traversals over the interposer mesh.
    pub flit_hops: u64,
    /// Effective signaling frequency after the wire timing check, MHz.
    pub eff_freq_mhz: f64,
    /// Bits that crossed the interposer (drives Algorithm-3 energy).
    pub bits: f64,
    /// On-chiplet silicon (TX/RX + clocking macros + NoP routers), µm².
    pub die_area_um2: f64,
    /// Passive interposer wiring tracks (not yielded silicon), µm².
    pub interposer_area_um2: f64,
    /// Per-weight-layer serialized cycles as `(layer position, cycles)`
    /// in layer order (epochs of one layer summed — the interposer is a
    /// single shared network; layers with no NoP traffic are absent).
    /// Sums to `cycles`; the serving simulator turns these into
    /// per-stage service times.
    pub per_layer_cycles: Vec<(usize, u64)>,
    /// Engine-tier tally over all interposer epochs (see
    /// [`TierCounts`]); replayed from epoch-cache tags on hits, so the
    /// tally is identical for cached/uncached evaluation.
    pub tiers: TierCounts,
}

/// Evaluate the NoP for a mapped DNN: cycle-accurate latency over the
/// chiplet mesh + driver/wire energy and area.
pub fn evaluate(cfg: &SiamConfig, traffic: &Traffic, placement: &Placement) -> NopReport {
    evaluate_cached(cfg, traffic, placement, None)
}

/// [`evaluate`] with an optional shared [`EpochCache`]: identical
/// interposer epochs across sweep points are replayed from the cache.
/// Passing `None` is equivalent to [`evaluate`]; results are
/// bit-identical either way.
pub fn evaluate_cached(
    cfg: &SiamConfig,
    traffic: &Traffic,
    placement: &Placement,
    cache: Option<&EpochCache>,
) -> NopReport {
    evaluate_cached_obs(cfg, traffic, placement, cache, None)
}

/// [`evaluate_cached`] with an optional per-epoch observer — the tracing
/// hook behind `siam simulate --trace` (see
/// [`crate::noc::evaluate_cached_obs`]). NoP epochs are package-level,
/// so observers see `chiplet: None`; results are bit-identical with and
/// without an observer.
pub fn evaluate_cached_obs(
    cfg: &SiamConfig,
    traffic: &Traffic,
    placement: &Placement,
    cache: Option<&EpochCache>,
    mut obs: Option<EpochObserver<'_>>,
) -> NopReport {
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let wire = WireModel::new(&cfg.system.nop);
    let drv = DriverModel::new(&cfg.system.nop);
    let mesh = Mesh::from_placement(placement);
    // flow-level engine (top tier of the NoC/NoP hierarchy); one arena
    // reused across all interposer epochs of this evaluation
    let mut fsim = FlowSim::new(&mesh);

    // Layer-parallel / cross-layer-serial composition as for the NoC —
    // but the interposer is one shared network, so all epochs of one
    // layer share it and we *sum* within a layer too.
    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut tiers = TierCounts::default();
    for ep in &traffic.nop_epochs {
        let (r, t, hit) = match cache {
            Some(c) => fsim.run_cached_tagged(&ep.flows, c),
            None => {
                let (r, t) = fsim.run_counted(&ep.flows);
                (r, t, false)
            }
        };
        tiers.accumulate(&t);
        if let Some(o) = obs.as_deref_mut() {
            o(&EpochObs {
                layer: ep.layer,
                chiplet: None,
                hit,
                tiers: t,
            });
        }
        *per_layer.entry(ep.layer).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- energy: Algorithm 3 (bits × E_bit) for every link traversal;
    // each hop re-drives the wire through a TX/RX pair.
    let bits_per_flit = cfg.system.nop.bits_per_cycle() as f64;
    let bits = flit_hops as f64 * bits_per_flit;
    let router_e = crate::noc::power::router(
        cfg.system.nop.channel_width,
        4,
        cfg.system.nop.router_ports,
        &tech,
    );
    let energy_pj = drv.energy_pj(bits) + flit_hops as f64 * router_e.flit_energy_pj;

    // ---- area: per-chiplet NoP router + TX/RX + clocking macros (one
    // macro set per mesh port — every neighbour link is independently
    // driven), plus the interposer wiring tracks.
    let nodes = placement.nodes() as f64;
    let ports_per_node = 4.0_f64.min(cfg.system.nop.router_ports as f64 - 1.0);
    let die_area = nodes * (ports_per_node * drv.area_per_chiplet_um2 + router_e.area_um2);
    let interposer_area = placement.links() as f64 * wire.link_area_um2;
    let area = die_area + interposer_area;

    let clk_ns = 1.0e3 / wire.eff_freq_mhz;
    NopReport {
        metrics: Metrics {
            area_um2: area,
            energy_pj,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: nodes * (ports_per_node * drv.leakage_uw + router_e.leakage_uw),
        },
        cycles,
        packets,
        flit_hops,
        eff_freq_mhz: wire.eff_freq_mhz,
        bits,
        die_area_um2: die_area,
        interposer_area_um2: interposer_area,
        per_layer_cycles,
        tiers,
    }
}

/// Analytic lower-bound NoP evaluation — the cheap scoring tier behind
/// `sweep --search pareto|halving` (see `coordinator::dse`).
///
/// As for [`crate::noc::evaluate_bound`]: `packets`, `flit_hops`,
/// `bits` and every energy/area/leakage figure are **bit-identical** to
/// [`evaluate`] (flit-hop counts are trace-determined); `cycles` and
/// `metrics.latency_ns` are provable lower bounds. `tiers` stays zero.
pub fn evaluate_bound(cfg: &SiamConfig, traffic: &Traffic, placement: &Placement) -> NopReport {
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let wire = WireModel::new(&cfg.system.nop);
    let drv = DriverModel::new(&cfg.system.nop);
    let mesh = Mesh::from_placement(placement);
    let defaults = FlowSim::new(&mesh); // engine defaults only

    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    for ep in &traffic.nop_epochs {
        let r = crate::noc::flow::epoch_bound(
            &mesh,
            defaults.router_delay,
            defaults.flits_per_packet,
            &ep.flows,
        );
        *per_layer.entry(ep.layer).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- energy & area: identical to `evaluate_cached_obs`
    let bits_per_flit = cfg.system.nop.bits_per_cycle() as f64;
    let bits = flit_hops as f64 * bits_per_flit;
    let router_e = crate::noc::power::router(
        cfg.system.nop.channel_width,
        4,
        cfg.system.nop.router_ports,
        &tech,
    );
    let energy_pj = drv.energy_pj(bits) + flit_hops as f64 * router_e.flit_energy_pj;
    let nodes = placement.nodes() as f64;
    let ports_per_node = 4.0_f64.min(cfg.system.nop.router_ports as f64 - 1.0);
    let die_area = nodes * (ports_per_node * drv.area_per_chiplet_um2 + router_e.area_um2);
    let interposer_area = placement.links() as f64 * wire.link_area_um2;

    let clk_ns = 1.0e3 / wire.eff_freq_mhz;
    NopReport {
        metrics: Metrics {
            area_um2: die_area + interposer_area,
            energy_pj,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: nodes * (ports_per_node * drv.leakage_uw + router_e.leakage_uw),
        },
        cycles,
        packets,
        flit_hops,
        eff_freq_mhz: wire.eff_freq_mhz,
        bits,
        die_area_um2: die_area,
        interposer_area_um2: interposer_area,
        per_layer_cycles,
        tiers: TierCounts::default(),
    }
}

/// Class-aware variant of [`evaluate_bound`], mirroring
/// [`evaluate_mapped`]: per-class TX/RX driver energy, area and leakage
/// are bit-identical to the full evaluator (they are pure functions of
/// the trace), timing is a provable lower bound. Single-kind systems
/// take [`evaluate_bound`].
pub fn evaluate_mapped_bound(
    cfg: &SiamConfig,
    traffic: &Traffic,
    placement: &Placement,
    map: &MappingResult,
) -> NopReport {
    if !cfg.has_hetero_classes() || cfg.system.chip_mode == ChipMode::Monolithic {
        return evaluate_bound(cfg, traffic, placement);
    }
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let wire = WireModel::new(&cfg.system.nop);
    let classes = cfg.resolved_chiplet_classes();
    let drvs: Vec<DriverModel> = classes
        .iter()
        .map(|c| DriverModel::new(&c.nop_effective(&cfg.system.nop)))
        .collect();
    let base_drv = DriverModel::new(&cfg.system.nop);
    let drv_of = |node: usize| -> &DriverModel {
        if node < map.num_chiplets {
            &drvs[map.chiplet_class[node]]
        } else {
            &base_drv
        }
    };
    let mesh = Mesh::from_placement(placement);
    let defaults = FlowSim::new(&mesh); // engine defaults only

    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    for ep in &traffic.nop_epochs {
        let r = crate::noc::flow::epoch_bound(
            &mesh,
            defaults.router_delay,
            defaults.flits_per_packet,
            &ep.flows,
        );
        *per_layer.entry(ep.layer).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- energy & area: identical to `evaluate_mapped_obs`
    let bits_per_flit = cfg.system.nop.bits_per_cycle() as f64;
    let bits = flit_hops as f64 * bits_per_flit;
    let router_e = crate::noc::power::router(
        cfg.system.nop.channel_width,
        4,
        cfg.system.nop.router_ports,
        &tech,
    );
    let mut drv_energy = 0.0;
    for ep in &traffic.nop_epochs {
        for f in &ep.flows {
            let flow_bits = (f.count * mesh.hops(f.src, f.dst) as u64) as f64 * bits_per_flit;
            drv_energy += flow_bits * drv_of(f.src as usize).ebit_pj;
        }
    }
    let energy_pj = drv_energy + flit_hops as f64 * router_e.flit_energy_pj;
    let ports_per_node = 4.0_f64.min(cfg.system.nop.router_ports as f64 - 1.0);
    let (mut die_area, mut leakage) = (0.0f64, 0.0f64);
    for node in 0..placement.nodes() {
        let d = drv_of(node);
        die_area += ports_per_node * d.area_per_chiplet_um2 + router_e.area_um2;
        leakage += ports_per_node * d.leakage_uw + router_e.leakage_uw;
    }
    let interposer_area = placement.links() as f64 * wire.link_area_um2;

    let clk_ns = 1.0e3 / wire.eff_freq_mhz;
    NopReport {
        metrics: Metrics {
            area_um2: die_area + interposer_area,
            energy_pj,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        eff_freq_mhz: wire.eff_freq_mhz,
        bits,
        die_area_um2: die_area,
        interposer_area_um2: interposer_area,
        per_layer_cycles,
        tiers: TierCounts::default(),
    }
}

/// Class-aware NoP evaluation: like [`evaluate_cached`], but every
/// chiplet carries its own class's TX/RX driver macro — each link
/// traversal is re-driven at the *source chiplet's* per-bit energy, and
/// per-node driver area/leakage follow the class. Timing (packet clock,
/// channel width, wire model) stays package-wide, so cycle counts match
/// the classic engine; special nodes (accumulator, DRAM) use the base
/// `[system.nop]` driver. Single-kind systems — including the
/// degenerate single-class identity — take the classic path and are
/// bit-identical to [`evaluate_cached`].
pub fn evaluate_mapped(
    cfg: &SiamConfig,
    traffic: &Traffic,
    placement: &Placement,
    map: &MappingResult,
    cache: Option<&EpochCache>,
) -> NopReport {
    evaluate_mapped_obs(cfg, traffic, placement, map, cache, None)
}

/// [`evaluate_mapped`] with an optional per-epoch observer (see
/// [`evaluate_cached_obs`]).
pub fn evaluate_mapped_obs(
    cfg: &SiamConfig,
    traffic: &Traffic,
    placement: &Placement,
    map: &MappingResult,
    cache: Option<&EpochCache>,
    mut obs: Option<EpochObserver<'_>>,
) -> NopReport {
    if !cfg.has_hetero_classes() || cfg.system.chip_mode == ChipMode::Monolithic {
        return evaluate_cached_obs(cfg, traffic, placement, cache, obs);
    }
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let wire = WireModel::new(&cfg.system.nop);
    let classes = cfg.resolved_chiplet_classes();
    let drvs: Vec<DriverModel> = classes
        .iter()
        .map(|c| DriverModel::new(&c.nop_effective(&cfg.system.nop)))
        .collect();
    let base_drv = DriverModel::new(&cfg.system.nop);
    let drv_of = |node: usize| -> &DriverModel {
        if node < map.num_chiplets {
            &drvs[map.chiplet_class[node]]
        } else {
            &base_drv
        }
    };
    let mesh = Mesh::from_placement(placement);
    let mut fsim = FlowSim::new(&mesh);

    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut tiers = TierCounts::default();
    for ep in &traffic.nop_epochs {
        let (r, t, hit) = match cache {
            Some(c) => fsim.run_cached_tagged(&ep.flows, c),
            None => {
                let (r, t) = fsim.run_counted(&ep.flows);
                (r, t, false)
            }
        };
        tiers.accumulate(&t);
        if let Some(o) = obs.as_deref_mut() {
            o(&EpochObs {
                layer: ep.layer,
                chiplet: None,
                hit,
                tiers: t,
            });
        }
        *per_layer.entry(ep.layer).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- energy: Algorithm 3 with per-class driver macros — every
    // link traversal of a flow re-drives the wire at the source
    // chiplet's E_bit (X–Y routes keep per-flow hop counts analytic:
    // count × Manhattan distance on the placement).
    let bits_per_flit = cfg.system.nop.bits_per_cycle() as f64;
    let bits = flit_hops as f64 * bits_per_flit;
    let router_e = crate::noc::power::router(
        cfg.system.nop.channel_width,
        4,
        cfg.system.nop.router_ports,
        &tech,
    );
    let mut drv_energy = 0.0;
    for ep in &traffic.nop_epochs {
        for f in &ep.flows {
            let flow_bits = (f.count * mesh.hops(f.src, f.dst) as u64) as f64 * bits_per_flit;
            drv_energy += flow_bits * drv_of(f.src as usize).ebit_pj;
        }
    }
    let energy_pj = drv_energy + flit_hops as f64 * router_e.flit_energy_pj;

    // ---- area & leakage: per node, with the node's class macro
    let ports_per_node = 4.0_f64.min(cfg.system.nop.router_ports as f64 - 1.0);
    let (mut die_area, mut leakage) = (0.0f64, 0.0f64);
    for node in 0..placement.nodes() {
        let d = drv_of(node);
        die_area += ports_per_node * d.area_per_chiplet_um2 + router_e.area_um2;
        leakage += ports_per_node * d.leakage_uw + router_e.leakage_uw;
    }
    let interposer_area = placement.links() as f64 * wire.link_area_um2;

    let clk_ns = 1.0e3 / wire.eff_freq_mhz;
    NopReport {
        metrics: Metrics {
            area_um2: die_area + interposer_area,
            energy_pj,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        eff_freq_mhz: wire.eff_freq_mhz,
        bits,
        die_area_um2: die_area,
        interposer_area_um2: interposer_area,
        per_layer_cycles,
        tiers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipMode, SiamConfig};
    use crate::dnn::build_model;
    use crate::mapping::{build_traffic, map_dnn};

    fn report(model: &str, ds: &str, cfg: &SiamConfig) -> NopReport {
        let dnn = build_model(model, ds).unwrap();
        let map = map_dnn(&dnn, cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, cfg);
        evaluate(cfg, &traffic, &pl)
    }

    #[test]
    fn resnet110_nop_active() {
        let cfg = SiamConfig::paper_default();
        let rep = report("resnet110", "cifar10", &cfg);
        assert!(rep.cycles > 0);
        assert!(rep.bits > 0.0);
        assert!(rep.metrics.area_um2 > 0.0);
        assert!((rep.eff_freq_mhz - 250.0).abs() < 1e-9);
        let sum: u64 = rep.per_layer_cycles.iter().map(|&(_, c)| c).sum();
        assert_eq!(sum, rep.cycles, "per-layer cycles partition the total");
    }

    #[test]
    fn tier_tally_and_observer_see_every_nop_epoch() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let mut seen = 0usize;
        let mut observed = TierCounts::default();
        let mut cb = |o: &EpochObs| {
            seen += 1;
            observed.accumulate(&o.tiers);
            assert!(o.chiplet.is_none(), "NoP epochs are package-level");
        };
        let rep = evaluate_cached_obs(&cfg, &traffic, &pl, None, Some(&mut cb));
        assert_eq!(seen, traffic.nop_epochs.len());
        assert_eq!(observed, rep.tiers);
        assert!(rep.tiers.total() > 0);
        let plain = evaluate(&cfg, &traffic, &pl);
        assert_eq!(plain.cycles, rep.cycles);
        assert_eq!(plain.tiers, rep.tiers);
        assert_eq!(plain.metrics.energy_pj.to_bits(), rep.metrics.energy_pj.to_bits());
    }

    #[test]
    fn monolithic_nop_is_empty() {
        let cfg = SiamConfig::paper_default().with_chip_mode(ChipMode::Monolithic);
        let rep = report("resnet110", "cifar10", &cfg);
        assert_eq!(rep.cycles, 0);
        assert_eq!(rep.packets, 0);
    }

    #[test]
    fn nop_dominates_area_on_chiplet_arch() {
        // Fig. 10: NoP ≈ 85% of ResNet-110 custom-architecture area —
        // driver + clocking macros and 56×-pitch wires are huge.
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let nop = evaluate(&cfg, &traffic, &pl);
        let noc = crate::noc::evaluate(&cfg, &traffic, map.num_chiplets);
        assert!(
            nop.metrics.area_um2 > noc.metrics.area_um2,
            "NoP area {} should exceed NoC area {}",
            nop.metrics.area_um2,
            noc.metrics.area_um2
        );
    }

    #[test]
    fn evaluate_mapped_single_kind_is_bit_identical() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let a = evaluate(&cfg, &traffic, &pl);
        let b = evaluate_mapped(&cfg, &traffic, &pl, &map, None);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.flit_hops, b.flit_hops);
        assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
        assert_eq!(a.metrics.area_um2.to_bits(), b.metrics.area_um2.to_bits());
        assert_eq!(a.metrics.leakage_uw.to_bits(), b.metrics.leakage_uw.to_bits());
    }

    #[test]
    fn cheaper_class_driver_cuts_hetero_energy() {
        use crate::config::{ChipletClassConfig, MemCell};
        let base = SiamConfig::paper_default();
        let mk = |ebit: f64| {
            let big = ChipletClassConfig::from_base(&base, "big");
            let mut little = ChipletClassConfig::from_base(&base, "little");
            little.count = Some(2);
            little.cell = MemCell::Sram;
            little.xbar_rows = 64;
            little.xbar_cols = 64;
            little.adc_bits = 3;
            little.nop_ebit_pj = ebit;
            base.clone().with_chiplet_classes(vec![big, little])
        };
        let dnn = build_model("resnet110", "cifar10").unwrap();
        // identical classes except the little driver E_bit: identical
        // mapping/traffic, so energy must drop strictly and timing must
        // not move
        let (cheap_cfg, dear_cfg) = (mk(0.2), mk(0.54));
        let map = map_dnn(&dnn, &cheap_cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cheap_cfg);
        let cheap = evaluate_mapped(&cheap_cfg, &traffic, &pl, &map, None);
        let dear = evaluate_mapped(&dear_cfg, &traffic, &pl, &map, None);
        assert_eq!(cheap.cycles, dear.cycles, "E_bit must not change timing");
        assert!(
            cheap.metrics.energy_pj < dear.metrics.energy_pj,
            "cheaper little driver must cut NoP energy: {} vs {}",
            cheap.metrics.energy_pj,
            dear.metrics.energy_pj
        );
        // both classes host chiplets, so some traffic pays each rate
        assert!(map.chiplets_per_class().iter().all(|&c| c > 0));
    }

    #[test]
    fn bound_is_exact_on_energy_area_and_a_lower_bound_on_time() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let full = evaluate_mapped(&cfg, &traffic, &pl, &map, None);
        let lb = evaluate_mapped_bound(&cfg, &traffic, &pl, &map);
        assert_eq!(lb.packets, full.packets);
        assert_eq!(lb.flit_hops, full.flit_hops);
        assert_eq!(lb.bits.to_bits(), full.bits.to_bits());
        assert_eq!(lb.metrics.energy_pj.to_bits(), full.metrics.energy_pj.to_bits());
        assert_eq!(lb.metrics.area_um2.to_bits(), full.metrics.area_um2.to_bits());
        assert_eq!(lb.metrics.leakage_uw.to_bits(), full.metrics.leakage_uw.to_bits());
        assert!(lb.cycles <= full.cycles, "{} > {}", lb.cycles, full.cycles);
        assert!(lb.metrics.latency_ns <= full.metrics.latency_ns);
    }

    #[test]
    fn faster_nop_reduces_latency() {
        // Fig. 14d trend: NoP bandwidth speed-up cuts NoP stall time
        let cfg1 = SiamConfig::paper_default();
        let cfg4 = SiamConfig::paper_default().with_nop_speedup(4.0);
        let r1 = report("resnet110", "cifar10", &cfg1);
        let r4 = report("resnet110", "cifar10", &cfg4);
        assert!(
            r4.metrics.latency_ns < r1.metrics.latency_ns,
            "{} vs {}",
            r4.metrics.latency_ns,
            r1.metrics.latency_ns
        );
    }

    #[test]
    fn ebit_scales_energy() {
        let mut cfg = SiamConfig::paper_default();
        let base = report("resnet110", "cifar10", &cfg);
        cfg.system.nop.ebit_pj = 1.08; // 2×
        let hot = report("resnet110", "cifar10", &cfg);
        assert!(hot.metrics.energy_pj > 1.4 * base.metrics.energy_pj);
    }
}
