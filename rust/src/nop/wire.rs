//! NoP interconnect wire model (Section 4.4): PTM-style RC parameters for
//! the interposer wires, timing closure against the requested bandwidth,
//! and wiring area from the shielded-GRS pitch.

use crate::config::NopConfig;

/// RC timing and wiring-area figures of one interposer link.
#[derive(Debug, Clone, Copy)]
pub struct WireModel {
    /// Total resistance of one chiplet-to-chiplet wire, Ω.
    pub r_ohm: f64,
    /// Total capacitance of one wire, fF.
    pub c_ff: f64,
    /// 50% distributed-RC delay (0.38·R·C), ns.
    pub delay_ns: f64,
    /// Maximum signaling frequency the wire supports, MHz.
    pub max_freq_mhz: f64,
    /// Frequency actually used: min(requested, max) — "if the timing
    /// parameters do not satisfy the bandwidth, the NoP engine chooses
    /// the maximum allowable bandwidth".
    pub eff_freq_mhz: f64,
    /// Wiring area of one link (all channels + shielding), µm².
    pub link_area_um2: f64,
    /// Energy of one wire transition, pJ (CV², used as a cross-check on
    /// the measured E_bit, not added on top of it).
    pub wire_energy_pj: f64,
}

impl WireModel {
    /// Evaluate the PTM-style RC model for a NoP configuration.
    pub fn new(nop: &NopConfig) -> WireModel {
        let l = nop.wire_length_mm;
        let r_ohm = nop.wire_r_ohm_per_mm * l;
        let c_ff = nop.wire_c_ff_per_mm * l;
        // Elmore 50% point of a distributed RC line
        let delay_ns = 0.38 * r_ohm * (c_ff * 1e-15) * 1e9;
        // one bit per cycle; require half-period >= delay
        let max_freq_mhz = if delay_ns > 0.0 {
            1.0e3 / (2.0 * delay_ns)
        } else {
            f64::INFINITY
        };
        let eff_freq_mhz = nop.frequency_mhz.min(max_freq_mhz);
        // shielded differential pair: signal + shield per lane
        let track_um = nop.wire_pitch_um * 2.0;
        let link_area_um2 = track_um * (l * 1000.0) * nop.channel_width as f64;
        // CV² with 0.4 V GRS swing
        let v = 0.4;
        let wire_energy_pj = (c_ff * 1e-15) * v * v * 1e12;
        WireModel {
            r_ohm,
            c_ff,
            delay_ns,
            max_freq_mhz,
            eff_freq_mhz,
            link_area_um2,
            wire_energy_pj,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NopConfig;

    #[test]
    fn default_wire_meets_250mhz() {
        let w = WireModel::new(&NopConfig::default());
        // 2.5 mm interposer wire: RC delay well under the 2 ns half-period
        assert!(w.delay_ns < 2.0, "delay {} ns", w.delay_ns);
        assert!((w.eff_freq_mhz - 250.0).abs() < 1e-9);
    }

    #[test]
    fn slow_wire_clamps_bandwidth() {
        let mut nop = NopConfig::default();
        nop.wire_r_ohm_per_mm = 2000.0;
        nop.wire_c_ff_per_mm = 4000.0;
        nop.wire_length_mm = 10.0;
        let w = WireModel::new(&nop);
        assert!(w.eff_freq_mhz < nop.frequency_mhz);
        assert!((w.eff_freq_mhz - w.max_freq_mhz).abs() < 1e-9);
    }

    #[test]
    fn area_scales_with_channels() {
        let mut nop = NopConfig::default();
        let w32 = WireModel::new(&nop);
        nop.channel_width = 64;
        let w64 = WireModel::new(&nop);
        assert!((w64.link_area_um2 / w32.link_area_um2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn wire_energy_below_measured_ebit() {
        // the 0.54 pJ/bit GRS measurement includes the driver; the bare
        // wire CV² must come out lower
        let nop = NopConfig::default();
        let w = WireModel::new(&nop);
        assert!(w.wire_energy_pj < nop.ebit_pj, "{}", w.wire_energy_pj);
    }
}
