//! NoP TX/RX driver + clocking model (Algorithm 3 and the Fig.-6 survey
//! of published signaling circuits).

use crate::config::NopConfig;

/// Published NoP signaling options (Fig. 6 right): name, E_bit (pJ/bit),
/// per-channel TX/RX area (µm²). Users can pick any via the config; the
/// default is the paper's choice, Poulton et al. [30] ground-referenced
/// signaling (also used for the SIMBA calibration).
pub const SIGNALING_SURVEY: &[(&str, f64, f64)] = &[
    ("poulton_grs_28nm [30]", 0.54, 5304.0),
    ("simba_grs_16nm [35]", 0.82, 6000.0),
    ("lin_cowos_7nm [22]", 0.56, 4600.0),
    ("zeppelin_ifop [3]", 2.0, 9000.0),
    ("erett_serdes_16nm [7]", 2.25, 12000.0),
    ("turner_grs_intra [40]", 1.17, 7000.0),
];

/// TX/RX driver + clocking figures for one NoP configuration.
#[derive(Debug, Clone, Copy)]
pub struct DriverModel {
    /// Energy per transferred bit, pJ (TX + RX + clocking).
    pub ebit_pj: f64,
    /// TX/RX + clocking area per chiplet, µm².
    pub area_per_chiplet_um2: f64,
    /// Static power of the always-on clocking circuit per chiplet, µW.
    pub leakage_uw: f64,
}

impl DriverModel {
    /// Driver figures for a NoP configuration (channel count × macro
    /// areas, shared clocking lanes).
    pub fn new(nop: &NopConfig) -> DriverModel {
        let channels = nop.channel_width as f64;
        let clocks = (nop.channel_width as f64 / nop.lanes_per_clock as f64).ceil();
        DriverModel {
            ebit_pj: nop.ebit_pj,
            area_per_chiplet_um2: channels * nop.txrx_area_um2 + clocks * nop.clocking_area_um2,
            // clock-distribution bias only: the measured E_bit already
            // amortizes active clocking power (Fig. 6 methodology)
            leakage_uw: clocks * 50.0,
        }
    }

    /// Algorithm 3: total driver energy for `bits` crossing the NoP.
    pub fn energy_pj(&self, bits: f64) -> f64 {
        bits * self.ebit_pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NopConfig;

    #[test]
    fn default_matches_paper_areas() {
        // 32 channels × 5304 µm² + 8 clocks × 10609 µm²
        let d = DriverModel::new(&NopConfig::default());
        let expect = 32.0 * 5304.0 + 8.0 * 10609.0;
        assert!((d.area_per_chiplet_um2 - expect).abs() < 1.0);
    }

    #[test]
    fn alg3_energy_is_bits_times_ebit() {
        let d = DriverModel::new(&NopConfig::default());
        assert!((d.energy_pj(1000.0) - 540.0).abs() < 1e-9);
    }

    #[test]
    fn survey_contains_the_paper_default() {
        let (name, ebit, area) = SIGNALING_SURVEY[0];
        assert!(name.contains("poulton"));
        assert!((ebit - NopConfig::default().ebit_pj).abs() < 1e-12);
        assert!((area - NopConfig::default().txrx_area_um2).abs() < 1e-12);
    }
}
