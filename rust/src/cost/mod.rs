//! Fabrication cost model (Appendix A): dies per wafer, Poisson yield,
//! normalized cost, verified at 98 % against a commercial processor
//! (SkyLake-SP [39]) in the paper.

/// Wafer/process assumptions of Appendix A's verification experiment.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Wafer diameter, mm (300 mm wafers ⇒ the paper uses D = 152.4 mm
    /// in its verification; both supported).
    pub wafer_diameter_mm: f64,
    /// Defect density D0, defects/mm².
    pub defect_density_per_mm2: f64,
    /// Reference die area for normalization, mm².
    pub reference_area_mm2: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Appendix A verification point: A_ref = 296 mm², D0 = 0.012/mm²,
        // D = 152.4 mm.
        CostModel {
            wafer_diameter_mm: 152.4,
            defect_density_per_mm2: 0.012,
            reference_area_mm2: 296.0,
        }
    }
}

impl CostModel {
    /// Equation 3: dies per wafer.
    pub fn dies_per_wafer(&self, area_mm2: f64) -> f64 {
        let d = self.wafer_diameter_mm;
        d * std::f64::consts::PI * (d / (4.0 * area_mm2) - 1.0 / (2.0 * area_mm2).sqrt())
    }

    /// Poisson yield: η = e^(−D0·A).
    pub fn yield_of(&self, area_mm2: f64) -> f64 {
        (-self.defect_density_per_mm2 * area_mm2).exp()
    }

    /// Equation 5: cost of a die of `area_mm2`, normalized to the
    /// reference die.
    pub fn normalized_die_cost(&self, area_mm2: f64) -> f64 {
        let n_ref = self.dies_per_wafer(self.reference_area_mm2);
        let n_tgt = self.dies_per_wafer(area_mm2);
        (n_ref * self.yield_of(self.reference_area_mm2)) / (n_tgt * self.yield_of(area_mm2))
    }

    /// System cost of a chiplet architecture: `n` chiplets of equal area
    /// (normalized units). Known-good-die assembly: each chiplet yields
    /// independently — the win over one monolithic die.
    pub fn chiplet_system_cost(&self, n: usize, chiplet_area_mm2: f64) -> f64 {
        n as f64 * self.normalized_die_cost(chiplet_area_mm2)
    }

    /// Fig. 13 metric: relative improvement (%) of a chiplet system over
    /// a monolithic die of `mono_area_mm2`.
    pub fn improvement_pct(&self, mono_area_mm2: f64, n: usize, chiplet_area_mm2: f64) -> f64 {
        let mono = self.normalized_die_cost(mono_area_mm2);
        let chip = self.chiplet_system_cost(n, chiplet_area_mm2);
        100.0 * (mono - chip) / mono
    }

    /// Probability that a package of `n` required chiplets plus
    /// `spares` spare chiplets (each an independent die of
    /// `chiplet_area_mm2`, Poisson yield) still has at least `n` live
    /// dies: Σ_{k=0..spares} C(n+spares, k) · (1−η)^k · η^(n+spares−k).
    ///
    /// With no spares this is the classic known-good-die survival η^n;
    /// each spare buys one tolerable die loss. Drives the yield-aware
    /// DSE ranking ([`crate::coordinator::dse::FigureOfMerit::YieldCost`])
    /// and the expected-cost math in `docs/RELIABILITY.md`.
    pub fn system_survival(&self, n: usize, spares: usize, chiplet_area_mm2: f64) -> f64 {
        let y = self.yield_of(chiplet_area_mm2);
        let total = n + spares;
        let mut sum = 0.0;
        let mut binom = 1.0f64; // C(total, 0)
        for k in 0..=spares {
            sum += binom * (1.0 - y).powi(k as i32) * y.powi((total - k) as i32);
            binom *= (total - k) as f64 / (k + 1) as f64;
        }
        sum
    }

    /// Yield-adjusted system cost: the fabrication cost of the `n +
    /// spares` dies divided by the survival probability — the expected
    /// number of packages fabricated per working system, in normalized
    /// cost units. Lower is better; this is the `YieldCost`
    /// figure of merit's score.
    pub fn yield_adjusted_cost(&self, n: usize, spares: usize, chiplet_area_mm2: f64) -> f64 {
        self.chiplet_system_cost(n + spares, chiplet_area_mm2)
            / self.system_survival(n, spares, chiplet_area_mm2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appendix_a_verification_point() {
        // Reference die must normalize to exactly 1.0
        let m = CostModel::default();
        assert!((m.normalized_die_cost(296.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dies_per_wafer_formula() {
        let m = CostModel::default();
        // hand-evaluated Eq. 3 at A = 296 mm², D = 152.4 mm
        let d = 152.4_f64;
        let expect = d * std::f64::consts::PI * (d / (4.0 * 296.0) - 1.0 / (2.0_f64 * 296.0).sqrt());
        assert!((m.dies_per_wafer(296.0) - expect).abs() < 1e-9);
        assert!(expect > 0.0);
    }

    #[test]
    fn cost_grows_superlinearly_with_area() {
        // Fig. 1a: exponential cost growth (yield term) — doubling area
        // must more than double cost.
        let m = CostModel::default();
        let c1 = m.normalized_die_cost(200.0);
        let c2 = m.normalized_die_cost(400.0);
        assert!(c2 > 2.0 * c1, "{c1} vs {c2}");
    }

    #[test]
    fn chiplets_cheaper_for_large_systems() {
        // 16 × 25 mm² chiplets vs one 400 mm² die
        let m = CostModel::default();
        let imp = m.improvement_pct(400.0, 16, 25.0);
        assert!(imp > 0.0, "improvement {imp}%");
    }

    #[test]
    fn tiny_systems_gain_little() {
        // Fig. 13: ResNet-110-class (small area) improvement ≈ 0
        let m = CostModel::default();
        let imp = m.improvement_pct(12.0, 2, 6.0);
        assert!(imp.abs() < 10.0, "improvement {imp}%");
    }

    #[test]
    fn survival_reduces_to_kgd_without_spares() {
        let m = CostModel::default();
        // no spares: survival = η^n exactly
        let y = m.yield_of(25.0);
        for n in [1usize, 4, 16] {
            let s = m.system_survival(n, 0, 25.0);
            assert!((s - y.powi(n as i32)).abs() < 1e-15, "n={n}: {s}");
        }
    }

    #[test]
    fn survival_golden_values_at_paper_defect_density() {
        // Hand-computed at D0 = 0.012/mm², 25 mm² chiplets:
        //   η = e^(−0.3) = 0.7408182206817179
        //   survival(4, 0) = η⁴ = e^(−1.2)      = 0.3011942119122021
        //   survival(4, 1) = η⁵ + 5(1−η)η⁴      = 0.6134504…
        let m = CostModel::default();
        let y = m.yield_of(25.0);
        assert!((y - 0.7408182206817179).abs() < 1e-15);
        assert!((m.system_survival(4, 0, 25.0) - 0.3011942119122021).abs() < 1e-12);
        let s1 = m.system_survival(4, 1, 25.0);
        let expect = (-1.5f64).exp() + 5.0 * (1.0 - (-0.3f64).exp()) * (-1.2f64).exp();
        assert!((s1 - expect).abs() < 1e-15, "{s1} vs {expect}");
        assert!((s1 - 0.6134504).abs() < 1e-6, "{s1}");
    }

    #[test]
    fn spares_raise_survival_monotonically() {
        let m = CostModel::default();
        let mut prev = 0.0;
        for spares in 0..5 {
            let s = m.system_survival(16, spares, 25.0);
            assert!(s > prev, "spares={spares}: {s} <= {prev}");
            assert!(s < 1.0);
            prev = s;
        }
    }

    #[test]
    fn yield_adjusted_cost_has_an_optimum_spare_count() {
        // 4 × 25 mm² chiplets at the paper's D0 (η ≈ 0.74): the first
        // two spares pay for themselves, the fourth overshoots — the
        // expected cost per working system has an interior optimum
        let m = CostModel::default();
        let c: Vec<f64> = (0..5).map(|s| m.yield_adjusted_cost(4, s, 25.0)).collect();
        assert!(c[1] < c[0], "one spare must pay for itself: {c:?}");
        assert!(c[2] < c[1], "the second spare still pays: {c:?}");
        assert!(c[4] > c[2], "four spares must overshoot: {c:?}");
    }

    #[test]
    fn yield_is_poisson() {
        let m = CostModel::default();
        assert!((m.yield_of(0.0) - 1.0).abs() < 1e-12);
        let y = m.yield_of(296.0);
        assert!(((-0.012_f64 * 296.0).exp() - y).abs() < 1e-12);
    }
}
