//! Published GPU baselines for the Section-6.5 comparison. All numbers
//! are adopted from the SIMBA paper [35], exactly as SIAM does: batch-1
//! ResNet-50 inference on Nvidia V100 and T4.

/// One GPU datapoint (batch-1 ResNet-50 ImageNet inference).
#[derive(Debug, Clone, Copy)]
pub struct GpuBaseline {
    /// Marketing name.
    pub name: &'static str,
    /// Die area, mm².
    pub area_mm2: f64,
    /// Board power while inferencing, W.
    pub power_w: f64,
    /// Inference throughput at batch 1, images/s.
    pub throughput_ips: f64,
}

impl GpuBaseline {
    /// Energy per inference, mJ.
    pub fn energy_per_inference_mj(&self) -> f64 {
        self.power_w / self.throughput_ips * 1e3
    }

    /// Energy efficiency, inferences/J.
    pub fn inferences_per_joule(&self) -> f64 {
        self.throughput_ips / self.power_w
    }
}

/// Nvidia V100 (SXM2): 815 mm², 300 W, ≈3.6 inf/J at batch 1 [35].
pub const V100: GpuBaseline = GpuBaseline {
    name: "V100",
    area_mm2: 815.0,
    power_w: 300.0,
    throughput_ips: 1080.0,
};

/// Nvidia T4: 525 mm² (SIAM quotes the board-normalized figure), 70 W,
/// ≈6.4 inf/J at batch 1 [35].
pub const T4: GpuBaseline = GpuBaseline {
    name: "T4",
    area_mm2: 525.0,
    power_w: 70.0,
    throughput_ips: 450.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_energy_per_inference() {
        // 300 W / 1080 ips ≈ 278 mJ
        let e = V100.energy_per_inference_mj();
        assert!((250.0..320.0).contains(&e), "{e}");
    }

    #[test]
    fn t4_more_efficient_than_v100() {
        assert!(T4.inferences_per_joule() > V100.inferences_per_joule());
    }

    #[test]
    fn ratio_between_gpus_matches_paper() {
        // paper: IMC is 130× vs V100 and 72× vs T4 ⇒ V100/T4 energy
        // ratio ≈ 130/72 ≈ 1.8
        let r = V100.energy_per_inference_mj() / T4.energy_per_inference_mj();
        assert!((1.4..2.3).contains(&r), "V100/T4 ratio {r}");
    }
}
