//! Analog device-variation engine: seeded Monte-Carlo modeling of the
//! non-idealities the digital `[fault]` subsystem abstracts away.
//!
//! The `fault` module removes capacity at die/crossbar granularity;
//! this module perturbs the *surviving* cells. Four IMAC-Sim-grounded
//! noise sources (PAPERS.md, arxiv 2304.09252) feed one analytic
//! error-propagation chain per layer — never retraining:
//!
//! 1. **Programming noise** — lognormal dispersion of the programmed
//!    conductance, `sigma_program` in ln-G units. Each write-verify
//!    cycle shrinks the surviving sigma by [`SIGMA_SHRINK_PER_VERIFY`]
//!    and charges program energy/latency.
//! 2. **Conductance drift** — the power law `G(t) = G0·(t/t0)^(-ν)`:
//!    a systematic ln-G shift of `ν·ln(t/t0)` for `t > t0`, with the
//!    exponent itself dispersed across Monte-Carlo samples
//!    ([`NU_DISPERSION`]). Drift also scales the read current, so the
//!    IMC read energy moves with it ([`VariationReport::read_energy_delta_pj`]).
//! 3. **Stuck-at cells** — fractions pinned at Gon/Goff contribute a
//!    bounded weight error; redundant columns repair a proportional
//!    share ([`VariationReport::repair_coverage`]).
//! 4. **ADC offset** — a static input-referred offset in LSB at the
//!    configured ADC resolution, added after the partial-sum averaging.
//!
//! Per layer, the cell-level error sigma averages down over the
//! crossbar rows feeding one ADC conversion, picks up the ADC offset,
//! and the per-layer output sigmas accumulate in quadrature across the
//! network into the accuracy-loss proxy
//! `exp(-ACC_SENSITIVITY · σ_net)` — a monotone, calibration-free
//! stand-in for post-variation inference accuracy.
//!
//! **Determinism discipline** (mirrors [`crate::fault::inject`]): one
//! [`SplitMix64`] stream seeded by `[variation] seed`, fixed draw
//! order — per Monte-Carlo sample: one drift-dispersion normal (only
//! when drift is active), then one programming-noise normal per weight
//! layer in execution order (only when `sigma_program > 0`). Inactive
//! sources consume zero draws, so the stream is independent of the
//! `[fault]` and `[serve]` streams and stable under partial configs
//! (pinned by `tests/proptests.rs`).

use crate::config::SiamConfig;
use crate::mapping::MappingResult;
use crate::serve::traffic::SplitMix64;
use crate::util::json::Json;

/// Multiplicative sigma shrink per write-verify cycle (each verify
/// re-programs outliers back toward the target level).
pub const SIGMA_SHRINK_PER_VERIFY: f64 = 0.7;

/// Lognormal dispersion of the drift exponent ν across Monte-Carlo
/// samples (device-to-device drift variability).
pub const NU_DISPERSION: f64 = 0.3;

/// Lognormal dispersion of a layer's realized programming-noise RMS
/// around its population sigma (finite-population sampling).
pub const CHI_DISPERSION: f64 = 0.25;

/// Normalized weight-error magnitude of a stuck-at-Gon/Goff cell.
pub const STUCK_AT_ERROR: f64 = 0.5;

/// Sensitivity of the accuracy proxy to the network output-error
/// sigma: `proxy = exp(-ACC_SENSITIVITY · σ_net)`.
pub const ACC_SENSITIVITY: f64 = 4.0;

/// Duration of one program (or verify) pulse, ns.
pub const PROGRAM_PULSE_NS: f64 = 100.0;

/// Energy of one program (or verify) pulse per cell, pJ.
pub const PROGRAM_ENERGY_PJ_PER_CELL: f64 = 1.0;

/// One standard normal draw (Box–Muller, cosine branch): consumes
/// exactly two `f64_open` draws from the stream.
fn normal(rng: &mut SplitMix64) -> f64 {
    let u1 = rng.f64_open();
    let u2 = rng.f64_open();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// What the device-variation model predicts for one design point —
/// attached to [`crate::coordinator::SimReport`] /
/// [`crate::coordinator::ServeReport`] and rendered into their JSON as
/// the `"variation"` object (absent on variation-free runs).
#[derive(Debug, Clone, PartialEq)]
pub struct VariationReport {
    /// The `[variation] seed` the Monte-Carlo stream drew from.
    pub seed: u64,
    /// Monte-Carlo samples averaged into the proxy statistics.
    pub mc_samples: usize,
    /// Weight layers the propagation chain covered.
    pub layers: usize,
    /// Programming-noise sigma after write-verify shrink
    /// (`sigma_program · SIGMA_SHRINK_PER_VERIFY^cycles`).
    pub sigma_program_effective: f64,
    /// Retention read time `t` this evaluation aged the cells to, s
    /// (serving runs cap it at the refresh interval).
    pub drift_time_s: f64,
    /// Mean systematic ln-G drift shift `ν·ln(t/t0)` across samples.
    pub drift_shift_ln_mean: f64,
    /// Mean conductance retention factor `exp(-shift)` across samples
    /// (1 = no drift; scales the IMC read current).
    pub drift_energy_factor: f64,
    /// Stuck-at fraction surviving column repair.
    pub stuck_fraction_effective: f64,
    /// Fraction of the raw stuck-at population the redundant columns
    /// repair (`min(1, redundant_cols / xbar_cols)`).
    pub repair_coverage: f64,
    /// Input-referred ADC offset as a fraction of full scale
    /// (`adc_offset_lsb / 2^adc_bits`).
    pub adc_offset_sigma: f64,
    /// Monte-Carlo mean of the accuracy-loss proxy (1 = ideal).
    pub accuracy_proxy_mean: f64,
    /// 95 % confidence half-width of the proxy mean.
    pub accuracy_proxy_ci95: f64,
    /// The `[variation] accuracy_floor` this point is judged against.
    pub accuracy_floor: f64,
    /// Does the proxy mean clear the configured floor?
    pub meets_floor: bool,
    /// Signed IMC read-energy perturbation, pJ: drifted conductances
    /// draw less current, redundant columns draw proportionally more.
    /// Folded into the report's circuit/total energy.
    pub read_energy_delta_pj: f64,
    /// One-time extra write-verify program energy, pJ (reported
    /// separately like the DRAM weight load — not a per-inference
    /// cost).
    pub program_energy_pj: f64,
    /// One-time extra write-verify program latency, ns (row-serial per
    /// crossbar, crossbars in parallel).
    pub program_latency_ns: f64,
    /// Drift-refresh period, s (0 = never refreshed).
    pub refresh_interval_s: f64,
    /// Fraction of serving time the periodic drift refresh steals from
    /// the stages (0 for single-shot evaluations).
    pub refresh_duty: f64,
}

impl VariationReport {
    /// Stage-service-time inflation factor a serving run applies for
    /// the periodic drift refresh: `1 / (1 - refresh_duty)`.
    pub fn service_scale(&self) -> f64 {
        1.0 / (1.0 - self.refresh_duty)
    }

    /// Machine-readable fragment (stable keys; validated in CI's
    /// schema checks).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seed", self.seed)
            .set("mc_samples", self.mc_samples)
            .set("layers", self.layers)
            .set("sigma_program_effective", self.sigma_program_effective)
            .set("drift_time_s", self.drift_time_s)
            .set("drift_shift_ln_mean", self.drift_shift_ln_mean)
            .set("drift_energy_factor", self.drift_energy_factor)
            .set("stuck_fraction_effective", self.stuck_fraction_effective)
            .set("repair_coverage", self.repair_coverage)
            .set("adc_offset_sigma", self.adc_offset_sigma)
            .set("accuracy_proxy_mean", self.accuracy_proxy_mean)
            .set("accuracy_proxy_ci95", self.accuracy_proxy_ci95)
            .set("accuracy_floor", self.accuracy_floor)
            .set("meets_floor", self.meets_floor)
            .set("read_energy_delta_pj", self.read_energy_delta_pj)
            .set("program_energy_pj", self.program_energy_pj)
            .set("program_latency_ns", self.program_latency_ns)
            .set("refresh_interval_s", self.refresh_interval_s)
            .set("refresh_duty", self.refresh_duty);
        o
    }
}

/// Single-shot evaluation for a mapped design point: cells age to the
/// full `[variation] drift_time_s` and no refresh duty applies.
/// `imc_energy_pj` is the point's IMC compute (read) energy, the base
/// the read-energy perturbation scales.
pub fn evaluate(cfg: &SiamConfig, map: &MappingResult, imc_energy_pj: f64) -> VariationReport {
    let xbars: Vec<usize> = map.per_layer.iter().map(|lm| lm.xbars).collect();
    evaluate_layers(cfg, &xbars, imc_energy_pj, cfg.variation.drift_time_s, 0.0)
}

/// Serving-time evaluation: a positive `refresh_interval_s` caps the
/// retention age at the interval (cells never age past a refresh) and
/// charges the refresh duty the maintenance events steal from stage
/// service time.
pub fn evaluate_serving(
    cfg: &SiamConfig,
    map: &MappingResult,
    imc_energy_pj: f64,
) -> VariationReport {
    let v = &cfg.variation;
    let (t_eff, duty) = if v.refresh_interval_s > 0.0 {
        (v.drift_time_s.min(v.refresh_interval_s), refresh_duty(cfg))
    } else {
        (v.drift_time_s, 0.0)
    };
    let xbars: Vec<usize> = map.per_layer.iter().map(|lm| lm.xbars).collect();
    evaluate_layers(cfg, &xbars, imc_energy_pj, t_eff, duty)
}

/// Serving-time fraction the periodic drift refresh steals: one full
/// array reprogram (`1 + write_verify_cycles` row-serial pulse sweeps,
/// crossbars in parallel) every `refresh_interval_s`, capped at 90 %.
fn refresh_duty(cfg: &SiamConfig) -> f64 {
    let v = &cfg.variation;
    let reprogram_ns =
        cfg.chiplet.xbar_rows as f64 * (1.0 + v.write_verify_cycles as f64) * PROGRAM_PULSE_NS;
    (reprogram_ns / (v.refresh_interval_s * 1.0e9)).min(0.9)
}

/// Core Monte-Carlo evaluation over explicit per-layer crossbar counts
/// (the wrappers extract them from a [`MappingResult`]). Deterministic
/// in `(cfg.variation, layer_xbars, drift_time_s)`: one splitmix64
/// stream, fixed draw order (per sample: drift normal when drift is
/// active, then one normal per layer when programming noise is
/// active).
pub fn evaluate_layers(
    cfg: &SiamConfig,
    layer_xbars: &[usize],
    imc_energy_pj: f64,
    drift_time_s: f64,
    refresh_duty: f64,
) -> VariationReport {
    let v = &cfg.variation;
    let rows = cfg.chiplet.xbar_rows as f64;
    let cols = cfg.chiplet.xbar_cols as f64;

    let sigma_eff = v.sigma_program * SIGMA_SHRINK_PER_VERIFY.powi(v.write_verify_cycles as i32);
    let repair_coverage = (v.redundant_cols as f64 / cols).min(1.0);
    let stuck_raw = v.stuck_at_on + v.stuck_at_off;
    let stuck_eff = stuck_raw * (1.0 - repair_coverage);
    let sa_var = stuck_eff * STUCK_AT_ERROR * STUCK_AT_ERROR;
    let adc_sigma = v.adc_offset_lsb / (1u64 << cfg.chiplet.adc_bits) as f64;

    let drift_active = v.drift_nu > 0.0 && drift_time_s > v.drift_t0_s;
    let ln_age = if drift_active {
        (drift_time_s / v.drift_t0_s).ln()
    } else {
        0.0
    };
    let noise_active = sigma_eff > 0.0;

    let mut rng = SplitMix64::new(v.seed);
    let n = v.mc_samples;
    let (mut acc_sum, mut acc_sq) = (0.0f64, 0.0f64);
    let (mut shift_sum, mut factor_sum) = (0.0f64, 0.0f64);
    for _ in 0..n {
        // draw order is part of the report contract: drift first, then
        // one programming-noise draw per layer; inactive sources
        // consume nothing so partial configs keep stable positions
        let shift = if drift_active {
            let z = normal(&mut rng);
            let nu_s = v.drift_nu * (NU_DISPERSION * z - 0.5 * NU_DISPERSION * NU_DISPERSION).exp();
            nu_s * ln_age
        } else {
            0.0
        };
        let mut net_var = 0.0f64;
        for _ in layer_xbars {
            let chi = if noise_active {
                let z = normal(&mut rng);
                (CHI_DISPERSION * z - 0.5 * CHI_DISPERSION * CHI_DISPERSION).exp()
            } else {
                1.0
            };
            let sigma_l = sigma_eff * chi;
            // cell-level error variance → averaged over the rows one
            // ADC conversion accumulates → plus the static ADC offset
            let cell_var = sigma_l * sigma_l + shift * shift + sa_var;
            let out_var = cell_var / rows + adc_sigma * adc_sigma;
            net_var += out_var;
        }
        let acc = (-ACC_SENSITIVITY * net_var.sqrt()).exp();
        acc_sum += acc;
        acc_sq += acc * acc;
        shift_sum += shift;
        factor_sum += (-shift).exp();
    }
    let mean = acc_sum / n as f64;
    let var = (acc_sq / n as f64 - mean * mean).max(0.0);
    let ci95 = if n > 1 {
        1.96 * (var / n as f64).sqrt()
    } else {
        0.0
    };
    let drift_energy_factor = factor_sum / n as f64;

    // deterministic mitigation accounting: extra write-verify pulses
    // over every allocated cell (one-time), and the read-energy
    // perturbation (drift draws less current, redundant columns more)
    let cells: f64 = layer_xbars.iter().map(|&x| x as f64).sum::<f64>() * rows * cols;
    let wv = v.write_verify_cycles as f64;
    let program_energy_pj = cells * wv * PROGRAM_ENERGY_PJ_PER_CELL;
    let program_latency_ns = rows * wv * PROGRAM_PULSE_NS;
    let read_energy_delta_pj =
        imc_energy_pj * ((cols + v.redundant_cols as f64) / cols * drift_energy_factor - 1.0);

    VariationReport {
        seed: v.seed,
        mc_samples: n,
        layers: layer_xbars.len(),
        sigma_program_effective: sigma_eff,
        drift_time_s,
        drift_shift_ln_mean: shift_sum / n as f64,
        drift_energy_factor,
        stuck_fraction_effective: stuck_eff,
        repair_coverage,
        adc_offset_sigma: adc_sigma,
        accuracy_proxy_mean: mean,
        accuracy_proxy_ci95: ci95,
        accuracy_floor: v.accuracy_floor,
        meets_floor: mean >= v.accuracy_floor,
        read_energy_delta_pj,
        program_energy_pj,
        program_latency_ns,
        refresh_interval_s: v.refresh_interval_s,
        refresh_duty,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;

    /// IMAC-Sim-style defaults over a small synthetic layer stack.
    fn noisy_cfg() -> SiamConfig {
        let mut cfg = SiamConfig::paper_default();
        cfg.variation.sigma_program = 0.05;
        cfg.variation.drift_nu = 0.02;
        cfg.variation.drift_time_s = 1.0e4;
        cfg.variation.stuck_at_on = 0.002;
        cfg.variation.stuck_at_off = 0.005;
        cfg.variation.adc_offset_lsb = 0.25;
        cfg.variation.mc_samples = 64;
        cfg.variation.seed = 11;
        cfg
    }

    const XBARS: [usize; 4] = [4, 8, 16, 8];

    fn eval(cfg: &SiamConfig) -> VariationReport {
        evaluate_layers(cfg, &XBARS, 1.0e6, cfg.variation.drift_time_s, 0.0)
    }

    #[test]
    fn evaluation_is_bit_deterministic() {
        let cfg = noisy_cfg();
        let a = eval(&cfg);
        let b = eval(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.accuracy_proxy_mean.to_bits(), b.accuracy_proxy_mean.to_bits());
        let mut other = cfg.clone();
        other.variation.seed = 12;
        let c = eval(&other);
        assert_ne!(
            a.accuracy_proxy_mean.to_bits(),
            c.accuracy_proxy_mean.to_bits(),
            "different seeds must draw different samples"
        );
    }

    #[test]
    fn accuracy_proxy_degrades_monotonically_with_drift_time() {
        let cfg = noisy_cfg();
        let mut last = f64::INFINITY;
        for t in [1.0e2, 1.0e3, 1.0e4, 1.0e5, 1.0e6] {
            let rep = evaluate_layers(&cfg, &XBARS, 1.0e6, t, 0.0);
            assert!(
                rep.accuracy_proxy_mean < last,
                "aging to {t} s must strictly degrade the proxy ({} !< {last})",
                rep.accuracy_proxy_mean
            );
            assert!(rep.accuracy_proxy_mean > 0.0 && rep.accuracy_proxy_mean < 1.0);
            last = rep.accuracy_proxy_mean;
        }
    }

    #[test]
    fn write_verify_recovers_accuracy_at_positive_energy_cost() {
        let cfg = noisy_cfg();
        let base = eval(&cfg);
        let mut wv = cfg.clone();
        wv.variation.write_verify_cycles = 3;
        let verified = eval(&wv);
        // strictly positive recovery...
        assert!(
            verified.accuracy_proxy_mean > base.accuracy_proxy_mean,
            "verify {} !> base {}",
            verified.accuracy_proxy_mean,
            base.accuracy_proxy_mean
        );
        assert!(verified.sigma_program_effective < base.sigma_program_effective);
        // ...at strictly positive energy and latency cost
        assert_eq!(base.program_energy_pj, 0.0);
        assert!(verified.program_energy_pj > 0.0);
        assert!(verified.program_latency_ns > 0.0);
    }

    #[test]
    fn redundant_columns_repair_stuck_cells() {
        let mut cfg = noisy_cfg();
        cfg.variation.stuck_at_on = 0.02;
        cfg.variation.stuck_at_off = 0.02;
        let base = eval(&cfg);
        cfg.variation.redundant_cols = cfg.chiplet.xbar_cols / 2;
        let repaired = eval(&cfg);
        assert!(repaired.repair_coverage > 0.0);
        assert!(repaired.stuck_fraction_effective < base.stuck_fraction_effective);
        assert!(repaired.accuracy_proxy_mean > base.accuracy_proxy_mean);
        // the spare columns draw proportionally more read energy
        assert!(repaired.read_energy_delta_pj > base.read_energy_delta_pj);
    }

    #[test]
    fn drift_refresh_caps_aging_and_charges_duty() {
        let mut cfg = noisy_cfg();
        let aged = evaluate_layers(&cfg, &XBARS, 1.0e6, cfg.variation.drift_time_s, 0.0);
        cfg.variation.refresh_interval_s = 10.0;
        let t_eff = cfg.variation.drift_time_s.min(cfg.variation.refresh_interval_s);
        let duty = super::refresh_duty(&cfg);
        assert!(duty > 0.0 && duty < 0.9);
        let refreshed = evaluate_layers(&cfg, &XBARS, 1.0e6, t_eff, duty);
        assert!(
            refreshed.accuracy_proxy_mean > aged.accuracy_proxy_mean,
            "refresh must cap retention aging"
        );
        assert!(refreshed.service_scale() > 1.0);
        assert_eq!(aged.service_scale(), 1.0);
    }

    #[test]
    fn drift_reduces_read_energy() {
        let cfg = noisy_cfg();
        let rep = eval(&cfg);
        assert!(rep.drift_energy_factor < 1.0);
        assert!(rep.read_energy_delta_pj < 0.0, "drifted conductances draw less read current");
        let mut fresh = cfg.clone();
        fresh.variation.drift_nu = 0.0;
        let f = eval(&fresh);
        assert_eq!(f.drift_energy_factor, 1.0);
        assert_eq!(f.read_energy_delta_pj, 0.0);
    }

    #[test]
    fn inactive_sources_consume_no_draws() {
        // adding an inert source must not shift the stream position of
        // the active ones (the fault module's stream-position invariant)
        let mut cfg = noisy_cfg();
        cfg.variation.drift_nu = 0.0;
        let noise_only = eval(&cfg);
        cfg.variation.adc_offset_lsb = 0.0; // deterministic source: no draws
        let still_noise_only = eval(&cfg);
        assert_eq!(
            noise_only.drift_shift_ln_mean.to_bits(),
            still_noise_only.drift_shift_ln_mean.to_bits()
        );
        // and the per-sample noise draws landed identically
        assert!(noise_only.accuracy_proxy_mean <= still_noise_only.accuracy_proxy_mean);
    }

    #[test]
    fn report_json_has_stable_keys() {
        let s = eval(&noisy_cfg()).to_json().to_string_pretty();
        for key in [
            "seed",
            "mc_samples",
            "layers",
            "sigma_program_effective",
            "drift_time_s",
            "drift_shift_ln_mean",
            "drift_energy_factor",
            "stuck_fraction_effective",
            "repair_coverage",
            "adc_offset_sigma",
            "accuracy_proxy_mean",
            "accuracy_proxy_ci95",
            "accuracy_floor",
            "meets_floor",
            "read_energy_delta_pj",
            "program_energy_pj",
            "program_latency_ns",
            "refresh_interval_s",
            "refresh_duty",
        ] {
            assert!(s.contains(&format!("\"{key}\"")), "missing {key} in {s}");
        }
    }
}
