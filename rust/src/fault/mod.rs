//! Yield-aware fault injection and spare-chiplet failover remap.
//!
//! SIAM's fabrication-cost model (Appendix A) already prices known-good-die
//! yield into the chiplet count/size trade-off; this module extends the
//! simulator itself to the same regime: dies fail (at manufacturing per
//! the yield model, or in the field per an explicit kill list), crossbars
//! degrade, and a package provisioned with `[system] spare_chiplets`
//! survives by remapping the affected layers onto its remaining capacity.
//!
//! The flow has three pieces, all deterministic in the `[fault] seed`:
//!
//! 1. **Injection** ([`inject`]) — draw the fault state from one
//!    splitmix64 stream: explicit kills first, then one survival draw
//!    per chiplet against `die_yield`, then one draw per crossbar of
//!    each surviving chiplet against `xbar_fault_fraction`.
//! 2. **Remap** ([`map_dnn_with_faults`]) — run the classic partition
//!    (Algorithm 1), extend the architecture with the spare chiplets,
//!    and — when any capacity was lost — repack every layer first-fit
//!    onto the surviving per-chiplet capacities (whole-layer placement
//!    preferred, id-order spill when a layer no longer fits anywhere).
//!    Zero injected faults leave the extended mapping untouched (the
//!    identity remap), and the packer errors with
//!    [`MappingError::InsufficientSurvivingCapacity`] rather than
//!    silently dropping layers.
//! 3. **Reporting** ([`FaultReport`]) — what died, what capacity
//!    survived, and whether a remap ran; attached to
//!    [`crate::coordinator::SimReport`] and rendered into its JSON.
//!
//! Serving-time failover (a chiplet dying mid-run, in-flight requests
//! shed, the remapped stage graph hot-swapped after a remap latency)
//! builds on this module from [`crate::serve`].

use crate::config::{FaultConfig, SiamConfig};
use crate::dnn::Dnn;
use crate::mapping::{map_dnn, ChipletShare, MappingError, MappingResult};
use crate::serve::traffic::SplitMix64;
use crate::util::json::Json;

/// Which chiplets and crossbars the injected faults took out.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultState {
    /// Dead chiplet ids, ascending (explicit kills ∪ yield losses).
    pub dead_chiplets: Vec<usize>,
    /// Faulty crossbars per chiplet (length = chiplet count, spares
    /// included; dead chiplets report their full capacity as faulty).
    pub faulty_xbars: Vec<usize>,
}

impl FaultState {
    /// Did the injection take out anything at all?
    pub fn is_clean(&self) -> bool {
        self.dead_chiplets.is_empty() && self.faulty_xbars.iter().all(|&f| f == 0)
    }

    /// Crossbars chiplet `c` can still program.
    pub fn effective_capacity(&self, c: usize, capacity: usize) -> usize {
        capacity.saturating_sub(self.faulty_xbars[c])
    }
}

/// Draw the fault state for an architecture of `capacities.len()`
/// chiplets (spares included) from `fc`'s seed. Deterministic: one
/// splitmix64 stream, fixed draw order (survival draws for chiplets
/// 0..n, then crossbar draws per surviving chiplet in id order).
///
/// Errors with [`MappingError::FaultTargetOutOfRange`] when the kill
/// list names a chiplet the architecture does not have.
pub fn inject(fc: &FaultConfig, capacities: &[usize]) -> Result<FaultState, MappingError> {
    let n = capacities.len();
    let mut dead = vec![false; n];
    for &c in &fc.kill_chiplets {
        if c >= n {
            return Err(MappingError::FaultTargetOutOfRange {
                chiplet: c,
                num_chiplets: n,
            });
        }
        dead[c] = true;
    }
    let mut rng = SplitMix64::new(fc.seed);
    if fc.die_yield < 1.0 {
        // every chiplet gets a draw (kills included) so the stream
        // position — and therefore the crossbar draws below — does not
        // depend on the kill list
        for d in dead.iter_mut() {
            if rng.f64_open() > fc.die_yield {
                *d = true;
            }
        }
    }
    let mut faulty = vec![0usize; n];
    for c in 0..n {
        if dead[c] {
            faulty[c] = capacities[c];
        } else if fc.xbar_fault_fraction > 0.0 {
            for _ in 0..capacities[c] {
                if rng.f64_open() <= fc.xbar_fault_fraction {
                    faulty[c] += 1;
                }
            }
        }
    }
    Ok(FaultState {
        dead_chiplets: (0..n).filter(|&c| dead[c]).collect(),
        faulty_xbars: faulty,
    })
}

/// Partition & mapping under injected faults with spare chiplets:
/// the classic [`map_dnn`] extended by `[system] spare_chiplets` empty
/// chiplets (charged in area/leakage/fabcost, carrying no weights), then
/// repacked onto the surviving capacity when the injection took
/// anything out.
///
/// The repack visits layers in execution order and chiplets in id
/// order: a layer goes whole onto the first chiplet with room for it,
/// or — when no single chiplet fits it — spills across the remaining
/// capacity id-first. Layer geometry (Eq.-1 rows/cols/crossbars and
/// cell utilization) is preserved from the baseline mapping; only the
/// chiplet shares move. With nothing injected the extended baseline is
/// returned untouched (remap is the identity).
pub fn map_dnn_with_faults(
    dnn: &Dnn,
    cfg: &SiamConfig,
) -> Result<(MappingResult, FaultReport), MappingError> {
    let mut map = map_dnn(dnn, cfg)?;
    let spares = cfg.system.spare_chiplets;
    let s = cfg.chiplet_size_xbars();
    map.num_chiplets += spares;
    map.chiplet_used_xbars.resize(map.num_chiplets, 0);
    map.chiplet_class.resize(map.num_chiplets, 0);
    map.chiplet_capacities.resize(map.num_chiplets, s);

    let state = inject(&cfg.fault, &map.chiplet_capacities)?;
    let lost: usize = state.faulty_xbars.iter().sum();
    let surviving: usize = map
        .chiplet_capacities
        .iter()
        .enumerate()
        .map(|(c, &cap)| state.effective_capacity(c, cap))
        .sum();
    let report = FaultReport {
        seed: cfg.fault.seed,
        dead_chiplets: state.dead_chiplets.clone(),
        faulty_xbars: lost,
        spare_chiplets: spares,
        total_chiplets: map.num_chiplets,
        lost_capacity_xbars: lost,
        surviving_capacity_xbars: surviving,
        remapped: !state.is_clean(),
    };
    if state.is_clean() {
        return Ok((map, report));
    }

    // ---- repack every layer onto the surviving capacity
    let mut remaining: Vec<usize> = map
        .chiplet_capacities
        .iter()
        .enumerate()
        .map(|(c, &cap)| state.effective_capacity(c, cap))
        .collect();
    let needed: usize = map.per_layer.iter().map(|lm| lm.xbars).sum();
    if needed > surviving {
        return Err(MappingError::InsufficientSurvivingCapacity {
            needed_xbars: needed,
            available_xbars: surviving,
        });
    }
    let mut used = vec![0usize; map.num_chiplets];
    for lm in &mut map.per_layer {
        let need = lm.xbars;
        let mut shares = Vec::new();
        if let Some(c) = (0..remaining.len()).find(|&c| remaining[c] >= need) {
            remaining[c] -= need;
            used[c] += need;
            shares.push(ChipletShare {
                chiplet: c,
                xbars: need,
            });
        } else {
            let mut left = need;
            for (c, rem) in remaining.iter_mut().enumerate() {
                if *rem == 0 {
                    continue;
                }
                let take = left.min(*rem);
                *rem -= take;
                used[c] += take;
                shares.push(ChipletShare {
                    chiplet: c,
                    xbars: take,
                });
                left -= take;
                if left == 0 {
                    break;
                }
            }
            debug_assert_eq!(left, 0, "surviving-capacity precheck must cover the spill");
        }
        lm.chiplets = shares;
    }
    map.chiplet_used_xbars = used;
    map.num_chiplets_required = map.chiplet_used_xbars.iter().filter(|&&u| u > 0).count();
    Ok((map, report))
}

/// What the fault injection did to one design point — attached to
/// [`crate::coordinator::SimReport`] and rendered into its JSON as the
/// `"fault"` object (absent on fault-free runs).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultReport {
    /// The `[fault] seed` the injection drew from.
    pub seed: u64,
    /// Dead chiplet ids, ascending (explicit kills ∪ yield losses).
    pub dead_chiplets: Vec<usize>,
    /// Faulty crossbars across the system (dead chiplets' full
    /// capacity included).
    pub faulty_xbars: usize,
    /// Spare chiplets the architecture provisioned.
    pub spare_chiplets: usize,
    /// Chiplets the architecture contains, spares included.
    pub total_chiplets: usize,
    /// Crossbar capacity the faults removed.
    pub lost_capacity_xbars: usize,
    /// Crossbar capacity left across surviving chiplets.
    pub surviving_capacity_xbars: usize,
    /// Did the injection force a repack (false = identity remap)?
    pub remapped: bool,
}

impl FaultReport {
    /// Machine-readable fragment (stable keys; validated in CI's
    /// schema checks).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seed", self.seed)
            .set(
                "dead_chiplets",
                Json::Arr(self.dead_chiplets.iter().map(|&c| Json::Num(c as f64)).collect()),
            )
            .set("faulty_xbars", self.faulty_xbars)
            .set("spare_chiplets", self.spare_chiplets)
            .set("total_chiplets", self.total_chiplets)
            .set("lost_capacity_xbars", self.lost_capacity_xbars)
            .set("surviving_capacity_xbars", self.surviving_capacity_xbars)
            .set("remapped", self.remapped);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;

    fn cfg_with(kills: Vec<usize>, spares: usize) -> SiamConfig {
        SiamConfig::paper_default()
            .with_total_chiplets(25)
            .with_spare_chiplets(spares)
            .with_kill_chiplets(kills)
    }

    #[test]
    fn injection_is_bit_deterministic() {
        let caps = vec![256usize; 30];
        let mut fc = crate::config::FaultConfig {
            die_yield: 0.9,
            xbar_fault_fraction: 0.03,
            seed: 7,
            ..Default::default()
        };
        let a = inject(&fc, &caps).unwrap();
        let b = inject(&fc, &caps).unwrap();
        assert_eq!(a, b);
        fc.seed = 8;
        let c = inject(&fc, &caps).unwrap();
        assert_ne!(a, c, "different seeds must draw different faults");
    }

    #[test]
    fn kill_list_out_of_range_errors() {
        let fc = crate::config::FaultConfig {
            kill_chiplets: vec![99],
            ..Default::default()
        };
        match inject(&fc, &vec![256; 10]) {
            Err(MappingError::FaultTargetOutOfRange { chiplet: 99, num_chiplets: 10 }) => {}
            other => panic!("expected out-of-range error, got {other:?}"),
        }
    }

    #[test]
    fn stream_position_independent_of_kill_list() {
        // the same seed must draw the same yield/crossbar faults whether
        // or not a chiplet was explicitly killed
        let caps = vec![256usize; 20];
        let mut fc = crate::config::FaultConfig {
            die_yield: 0.8,
            seed: 5,
            ..Default::default()
        };
        let base = inject(&fc, &caps).unwrap();
        fc.kill_chiplets = vec![3];
        let killed = inject(&fc, &caps).unwrap();
        let expect: Vec<usize> = {
            let mut d = base.dead_chiplets.clone();
            if !d.contains(&3) {
                d.push(3);
                d.sort_unstable();
            }
            d
        };
        assert_eq!(killed.dead_chiplets, expect);
    }

    #[test]
    fn zero_fault_remap_is_identity() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let cfg = cfg_with(vec![], 2);
        let baseline = map_dnn(&dnn, &cfg).unwrap();
        let (map, rep) = map_dnn_with_faults(&dnn, &cfg).unwrap();
        assert!(!rep.remapped);
        assert_eq!(map.num_chiplets, baseline.num_chiplets + 2);
        assert_eq!(map.num_chiplets_required, baseline.num_chiplets_required);
        for (a, b) in map.per_layer.iter().zip(&baseline.per_layer) {
            assert_eq!(a.chiplets, b.chiplets, "identity remap must not move layers");
        }
        // the spares carry nothing
        assert!(map.chiplet_used_xbars[baseline.num_chiplets..].iter().all(|&u| u == 0));
    }

    #[test]
    fn killed_chiplet_spills_onto_spare() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let cfg = cfg_with(vec![3], 1);
        let (map, rep) = map_dnn_with_faults(&dnn, &cfg).unwrap();
        assert!(rep.remapped);
        assert_eq!(rep.dead_chiplets, vec![3]);
        assert_eq!(map.chiplet_used_xbars[3], 0, "dead chiplet must carry nothing");
        // full layer coverage on live chiplets
        for lm in &map.per_layer {
            let total: usize = lm.chiplets.iter().map(|s| s.xbars).sum();
            assert_eq!(total, lm.xbars, "layer must keep all its crossbars");
            assert!(lm.chiplets.iter().all(|s| s.chiplet != 3));
        }
        // capacity respected everywhere
        for (c, (&u, &cap)) in map
            .chiplet_used_xbars
            .iter()
            .zip(&map.chiplet_capacities)
            .enumerate()
        {
            assert!(u <= cap, "chiplet {c} over capacity");
        }
    }

    #[test]
    fn no_spare_total_kill_overflow_errors() {
        // killing chiplets with no spares on a tightly-packed custom
        // architecture must error cleanly, not drop layers
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let cfg = SiamConfig::paper_default().with_kill_chiplets(vec![0, 1, 2]);
        match map_dnn_with_faults(&dnn, &cfg) {
            Err(MappingError::InsufficientSurvivingCapacity {
                needed_xbars,
                available_xbars,
            }) => assert!(available_xbars < needed_xbars),
            other => panic!("expected capacity error, got {other:?}"),
        }
    }

    #[test]
    fn crossbar_faults_degrade_capacity() {
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let mut cfg = cfg_with(vec![], 2);
        cfg.fault.xbar_fault_fraction = 0.05;
        let (map, rep) = map_dnn_with_faults(&dnn, &cfg).unwrap();
        assert!(rep.remapped);
        assert!(rep.faulty_xbars > 0);
        for (c, (&u, &cap)) in map
            .chiplet_used_xbars
            .iter()
            .zip(&map.chiplet_capacities)
            .enumerate()
        {
            let eff = cap - map_faulty(&cfg, &map, c);
            assert!(u <= eff, "chiplet {c} exceeds surviving capacity");
        }
    }

    /// Re-derive chiplet `c`'s faulty-crossbar count from the config's
    /// seed (injection is deterministic, so the test can replay it).
    fn map_faulty(cfg: &SiamConfig, map: &MappingResult, c: usize) -> usize {
        inject(&cfg.fault, &map.chiplet_capacities).unwrap().faulty_xbars[c]
    }

    #[test]
    fn fault_report_json_has_stable_keys() {
        let rep = FaultReport {
            seed: 42,
            dead_chiplets: vec![3],
            faulty_xbars: 256,
            spare_chiplets: 1,
            total_chiplets: 26,
            lost_capacity_xbars: 256,
            surviving_capacity_xbars: 6144,
            remapped: true,
        };
        let s = rep.to_json().to_string_pretty();
        for key in [
            "seed",
            "dead_chiplets",
            "faulty_xbars",
            "spare_chiplets",
            "total_chiplets",
            "lost_capacity_xbars",
            "surviving_capacity_xbars",
            "remapped",
        ] {
            assert!(s.contains(&format!("\"{key}\"")), "missing {key} in {s}");
        }
    }
}
