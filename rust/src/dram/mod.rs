//! DRAM engine (Section 4.5): request generation from the model size,
//! a RAMULATOR-style bank-state timing simulation, a VAMPIRE-style
//! event-based power model, and the instruction-subset fast estimator of
//! Fig. 7a (simulate a fraction, extrapolate, <2 % EDP error at 50 %).

pub mod timing;

pub use timing::{params, DramEnergy, DramTiming};

use crate::config::{DramConfig, SiamConfig};
use crate::dnn::DnnStats;
use crate::metrics::Metrics;

/// One DRAM read request (64 B cache-line granularity).
#[derive(Debug, Clone, Copy)]
pub struct Request {
    /// Byte address of the 64 B line.
    pub addr: u64,
}

/// Result of the DRAM access estimation.
#[derive(Debug, Clone, Copy, Default)]
pub struct DramReport {
    /// Total transfer latency, ns.
    pub latency_ns: f64,
    /// Total energy (array + IO + background), pJ.
    pub energy_pj: f64,
    /// Requests issued (after subset extrapolation).
    pub requests: u64,
    /// Row-buffer hit rate of the simulated stream.
    pub row_hit_rate: f64,
    /// Fraction of requests actually simulated.
    pub simulated_fraction: f64,
}

impl DramReport {
    /// Energy-delay product of the weight load, pJ·ns.
    pub fn edp(&self) -> f64 {
        self.energy_pj * self.latency_ns
    }

    /// As a [`Metrics`] bundle (area 0: commodity DRAM die excluded).
    pub fn metrics(&self) -> Metrics {
        Metrics {
            area_um2: 0.0, // commodity DRAM chiplet: excluded from die cost
            energy_pj: self.energy_pj,
            latency_ns: self.latency_ns,
            leakage_uw: 0.0,
        }
    }
}

/// Generate the weight-load request stream: `model_bytes` sequential
/// reads at 64 B granularity, striped across banks the way a DIMM maps
/// consecutive addresses (row-interleaved within a bank after the
/// column bits).
pub fn generate_requests(model_bytes: usize, n: Option<usize>) -> Vec<Request> {
    let lines = model_bytes.div_ceil(64).max(1);
    let take = n.unwrap_or(lines).min(lines);
    (0..take)
        .map(|i| Request {
            addr: (i as u64) * 64,
        })
        .collect()
}

/// Bank-state timing simulation of an in-order read stream.
///
/// Address mapping: column bits (within a row) → bank → row, so a
/// sequential stream sweeps a full row in one bank, then moves to the
/// next bank (bank-interleaved rows hide tRP+tRCD behind transfers).
pub fn simulate(requests: &[Request], t: &DramTiming, e: &DramEnergy, bus_bits: usize) -> DramReport {
    if requests.is_empty() {
        return DramReport::default();
    }
    let bytes_per_burst = bus_bits / 8 * t.burst_beats; // x64 BL8 = 64 B
    let bursts_per_row = (t.row_bytes * 8) / (bus_bits * t.burst_beats); // per x-width row slice

    let mut bank_row: Vec<Option<u64>> = vec![None; t.banks];
    let mut bank_ready: Vec<u64> = vec![0; t.banks]; // cycle bank can ACT
    let mut bus_free: u64 = 0;
    let mut act_times: std::collections::VecDeque<u64> = Default::default();

    let (mut acts, mut hits, mut bursts) = (0u64, 0u64, 0u64);
    let mut now: u64 = 0;

    for r in requests {
        let line = r.addr / bytes_per_burst as u64;
        let bank = (line / bursts_per_row as u64) as usize % t.banks;
        let row = line / (bursts_per_row as u64 * t.banks as u64);

        let mut issue = now;
        if bank_row[bank] != Some(row) {
            // precharge + activate
            let mut act_at = issue.max(bank_ready[bank]);
            // tFAW: at most 4 ACTs in any tFAW window
            if act_times.len() == 4 {
                let oldest = *act_times.front().unwrap();
                act_at = act_at.max(oldest + t.tfaw);
                act_times.pop_front();
            }
            act_times.push_back(act_at);
            let prp = if bank_row[bank].is_some() { t.trp } else { 0 };
            issue = act_at + prp + t.trcd;
            bank_row[bank] = Some(row);
            bank_ready[bank] = act_at + prp + t.tras;
            acts += 1;
        } else {
            hits += 1;
        }
        // CAS latency is pipelined; the bus is occupied tCCD per burst
        let data_at = (issue + t.cl).max(bus_free);
        bus_free = data_at + t.tccd;
        bursts += 1;
        now = issue; // next command no earlier than this request's issue
    }
    let completion = bus_free + t.tccd;
    let latency_ns = completion as f64 * t.tck_ns;

    let io_bytes = (bursts as usize * bytes_per_burst) as f64;
    let energy_pj = acts as f64 * e.act_pre_pj
        + bursts as f64 * e.rd_burst_pj
        + io_bytes * e.io_pj_per_byte
        + e.background_mw * latency_ns / 1.0e3; // mW·ns = pJ/1000… (mW=pJ/ns)

    DramReport {
        latency_ns,
        energy_pj,
        requests: requests.len() as u64,
        row_hit_rate: hits as f64 / requests.len() as f64,
        simulated_fraction: 1.0,
    }
}

/// Full engine entry point: generate requests for the DNN's weights,
/// simulate `cfg.dram.subset_fraction` of them, extrapolate (Fig. 7a's
/// speed/accuracy trade).
pub fn estimate(stats: &DnnStats, cfg: &SiamConfig) -> DramReport {
    estimate_with(stats.model_bytes(cfg.dnn.weight_precision), &cfg.dram)
}

/// [`estimate`] from an explicit model size (testing / sweeps).
pub fn estimate_with(model_bytes: usize, dc: &DramConfig) -> DramReport {
    let (t, e) = params(dc.kind);
    let total_lines = model_bytes.div_ceil(64).max(1);
    let sim_lines = ((total_lines as f64 * dc.subset_fraction).ceil() as usize).max(1);
    let reqs = generate_requests(model_bytes, Some(sim_lines));
    let mut rep = simulate(&reqs, &t, &e, dc.bus_bits);
    let scale = total_lines as f64 / sim_lines as f64;
    rep.latency_ns *= scale;
    rep.energy_pj *= scale;
    rep.requests = total_lines as u64;
    rep.simulated_fraction = 1.0 / scale;
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramConfig, DramKind};

    fn dc(kind: DramKind, frac: f64) -> DramConfig {
        DramConfig {
            kind,
            bus_bits: 64,
            subset_fraction: frac,
        }
    }

    #[test]
    fn sequential_stream_hits_rows() {
        let (t, e) = timing::ddr4();
        let reqs = generate_requests(1 << 20, None); // 1 MB
        let rep = simulate(&reqs, &t, &e, 64);
        assert!(rep.row_hit_rate > 0.85, "hit rate {}", rep.row_hit_rate);
    }

    #[test]
    fn bandwidth_bounded_by_bus() {
        // sequential reads approach the x64 DDR4-2400 peak (19.2 GB/s);
        // tCCD_L (6 cycles per 64 B) caps us at ~12.8 GB/s
        let (t, e) = timing::ddr4();
        let bytes = 8 << 20;
        let rep = simulate(&generate_requests(bytes, None), &t, &e, 64);
        let gbs = bytes as f64 / rep.latency_ns; // B/ns = GB/s
        assert!((6.0..20.0).contains(&gbs), "throughput {gbs} GB/s");
    }

    #[test]
    fn subset_extrapolation_accurate() {
        // Fig. 7a: 50% of instructions => <2% EDP error
        let bytes = 3000 * 64; // "3000 DRAM instructions"
        let full = estimate_with(bytes, &dc(DramKind::Ddr4, 1.0));
        let half = estimate_with(bytes, &dc(DramKind::Ddr4, 0.5));
        let err = (half.edp() - full.edp()).abs() / full.edp();
        assert!(err < 0.02, "EDP error {err}");
    }

    #[test]
    fn subset_runs_fewer_requests() {
        let bytes = 1 << 22;
        let half = estimate_with(bytes, &dc(DramKind::Ddr4, 0.5));
        assert!((half.simulated_fraction - 0.5).abs() < 0.01);
        assert_eq!(half.requests as usize, bytes / 64);
    }

    #[test]
    fn ddr3_higher_energy_than_ddr4() {
        let bytes = 1 << 22;
        let e3 = estimate_with(bytes, &dc(DramKind::Ddr3, 1.0));
        let e4 = estimate_with(bytes, &dc(DramKind::Ddr4, 1.0));
        assert!(e3.energy_pj > e4.energy_pj);
    }

    #[test]
    fn edp_grows_superlinearly_with_model_size() {
        // Fig. 7b: exponential EDP growth with DNN size (E and T both
        // grow ~linearly => EDP ~quadratically)
        let small = estimate_with(1 << 20, &dc(DramKind::Ddr4, 1.0));
        let big = estimate_with(16 << 20, &dc(DramKind::Ddr4, 1.0));
        let ratio = big.edp() / small.edp();
        assert!(ratio > 100.0, "EDP ratio {ratio} for 16x model size");
    }

    #[test]
    fn empty_model_safe() {
        let rep = estimate_with(0, &dc(DramKind::Ddr4, 0.5));
        assert!(rep.latency_ns >= 0.0);
    }
}
