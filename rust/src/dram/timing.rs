//! DDR3/DDR4 device timing + per-event energy parameters (Micron
//! datasheets [26][27], the same sources the paper's customized
//! RAMULATOR/VAMPIRE use).

use crate::config::DramKind;

/// Device timing parameters in command-clock cycles.
#[derive(Debug, Clone, Copy)]
pub struct DramTiming {
    /// Clock period, ns (command clock).
    pub tck_ns: f64,
    /// CAS latency, cycles.
    pub cl: u64,
    /// RAS-to-CAS delay, cycles.
    pub trcd: u64,
    /// Row precharge, cycles.
    pub trp: u64,
    /// Row active minimum, cycles.
    pub tras: u64,
    /// Column-to-column delay (burst occupancy on the data bus), cycles.
    pub tccd: u64,
    /// Four-activate window, cycles.
    pub tfaw: u64,
    /// Banks (DDR4: bank groups × banks/group).
    pub banks: usize,
    /// Row (page) size, bytes.
    pub row_bytes: usize,
    /// Burst length in beats (BL8).
    pub burst_beats: usize,
}

/// Per-event energy parameters (VAMPIRE-style).
#[derive(Debug, Clone, Copy)]
pub struct DramEnergy {
    /// One ACT+PRE pair, pJ.
    pub act_pre_pj: f64,
    /// One read burst (core array + peripheral), pJ.
    pub rd_burst_pj: f64,
    /// IO energy per byte driven on the bus, pJ/B.
    pub io_pj_per_byte: f64,
    /// Background (standby) power, mW.
    pub background_mw: f64,
}

/// DDR3-1600 (MT41K256M8, 2 Gb, x8 ranks on a x64 DIMM).
pub fn ddr3() -> (DramTiming, DramEnergy) {
    (
        DramTiming {
            tck_ns: 1.25,
            cl: 11,
            trcd: 11,
            trp: 11,
            tras: 28,
            tccd: 4,
            tfaw: 32,
            banks: 8,
            row_bytes: 2048,
            burst_beats: 8,
        },
        DramEnergy {
            // IDD0=95 mA, IDD3N=45 mA @1.5 V over tRC≈49 ns
            act_pre_pj: 2500.0,
            // (IDD4R−IDD3N)≈110 mA @1.5 V over 5 ns burst
            rd_burst_pj: 1200.0,
            io_pj_per_byte: 15.0,
            background_mw: 60.0,
        },
    )
}

/// DDR4-2400 (MT40A1G4, 4 Gb, x4/x8 on a x64 DIMM).
pub fn ddr4() -> (DramTiming, DramEnergy) {
    (
        DramTiming {
            tck_ns: 0.833,
            cl: 17,
            trcd: 17,
            trp: 17,
            tras: 39,
            tccd: 6, // tCCD_L
            tfaw: 26,
            banks: 16,
            row_bytes: 1024,
            burst_beats: 8,
        },
        DramEnergy {
            // IDD0=55 mA, IDD3N=42 mA @1.2 V over tRC≈47 ns
            act_pre_pj: 1500.0,
            // (IDD4R−IDD3N)≈98 mA @1.2 V over 5 ns burst
            rd_burst_pj: 800.0,
            io_pj_per_byte: 10.0,
            background_mw: 45.0,
        },
    )
}

/// Timing + energy parameters for a DRAM standard.
pub fn params(kind: DramKind) -> (DramTiming, DramEnergy) {
    match kind {
        DramKind::Ddr3 => ddr3(),
        DramKind::Ddr4 => ddr4(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddr4_is_faster_but_lower_energy() {
        let (t3, e3) = ddr3();
        let (t4, e4) = ddr4();
        assert!(t4.tck_ns < t3.tck_ns);
        assert!(e4.act_pre_pj < e3.act_pre_pj);
        assert!(e4.io_pj_per_byte < e3.io_pj_per_byte);
        assert!(t4.banks > t3.banks);
    }

    #[test]
    fn timing_sanity() {
        for (t, _) in [ddr3(), ddr4()] {
            assert!(t.tras >= t.trcd);
            assert!(t.row_bytes.is_power_of_two());
        }
    }
}
