//! Aggregate workload statistics consumed by the cost / DRAM engines and
//! the report writer.

use super::graph::Dnn;
use super::layer::LayerKind;

/// Aggregate workload statistics of a [`Dnn`].
#[derive(Debug, Clone, Copy, Default)]
pub struct DnnStats {
    /// Total weights + biases.
    pub params: usize,
    /// Total MACs per inference.
    pub macs: usize,
    /// MACs executed on the digital side (dynamic attention/matmul
    /// products; a subset of `macs`). Zero for pure CNNs.
    pub digital_macs: usize,
    /// Total activation elements produced per inference.
    pub activations: usize,
    /// Weight-bearing layers.
    pub weight_layers: usize,
    /// All layers (incl. pool/relu/add/concat).
    pub total_layers: usize,
    /// Residual / concat skip edges (drives extra buffer provisioning —
    /// the paper's "branched structure" cost).
    pub skip_edges: usize,
    /// Peak activation elements that must be held for a future skip edge.
    pub peak_skip_buffer: usize,
}

impl DnnStats {
    /// Walk the graph and aggregate.
    pub fn of(dnn: &Dnn) -> DnnStats {
        let mut s = DnnStats {
            total_layers: dnn.layers.len(),
            ..Default::default()
        };
        // live skip-edge buffer tracking: for each layer with a later
        // skip consumer, its ofm stays buffered until consumed.
        let mut consumers: Vec<Option<usize>> = vec![None; dnn.layers.len()];
        for (i, l) in dnn.layers.iter().enumerate() {
            if let LayerKind::ResidualAdd { from } | LayerKind::Concat { from } = l.kind {
                consumers[from] = Some(i);
                s.skip_edges += 1;
            }
        }
        let mut live: usize = 0;
        let mut expiry: Vec<(usize, usize)> = Vec::new(); // (consumer, elems)
        for (i, l) in dnn.layers.iter().enumerate() {
            s.params += l.params();
            s.macs += l.macs();
            s.digital_macs += l.digital_macs();
            s.activations += l.ofm.elems();
            if l.is_weight_layer() {
                s.weight_layers += 1;
            }
            expiry.retain(|&(at, elems)| {
                if at == i {
                    live -= elems;
                    false
                } else {
                    true
                }
            });
            if let Some(at) = consumers[i] {
                live += l.ofm.elems();
                expiry.push((at, l.ofm.elems()));
            }
            s.peak_skip_buffer = s.peak_skip_buffer.max(live);
        }
        s
    }

    /// Model size in bytes at the given weight precision.
    pub fn model_bytes(&self, weight_bits: u8) -> usize {
        (self.params * weight_bits as usize).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use crate::dnn::graph::DnnBuilder;

    #[test]
    fn stats_add_up() {
        let mut b = DnnBuilder::new("t", "cifar10", (8, 8, 3));
        b.conv("c1", 3, 1, 1, 4);
        b.relu("r1");
        let c1 = 1; // relu output index
        b.conv("c2", 3, 1, 1, 4);
        b.residual_add("res", c1);
        b.fc("fc", 10);
        let s = b.build().stats();
        assert_eq!(s.weight_layers, 3);
        assert_eq!(s.skip_edges, 1);
        let conv1 = 3 * 3 * 3 * 4 + 4;
        let conv2 = 3 * 3 * 4 * 4 + 4;
        let fc = 8 * 8 * 4 * 10 + 10;
        assert_eq!(s.params, conv1 + conv2 + fc);
        assert_eq!(s.peak_skip_buffer, 8 * 8 * 4);
    }

    #[test]
    fn model_bytes_rounding() {
        let mut b = DnnBuilder::new("t", "cifar10", (4, 4, 1));
        b.fc("f", 3); // 16*3+3 = 51 params
        let s = b.build().stats();
        assert_eq!(s.model_bytes(8), 51);
        assert_eq!(s.model_bytes(4), 26); // ceil(51*4/8)
    }
}
