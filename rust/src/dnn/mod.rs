//! DNN workload representation: layer graph, shape inference and the
//! model zoo the paper evaluates (LeNet-5, ResNet-20/56/110, ResNet-50,
//! VGG-16/19, DenseNet, NiN, DriveNet).
//!
//! The partition & mapping engine consumes only layer *shapes* — kernel
//! geometry, feature-map sizes, branch structure — so the zoo builds
//! weight-free graphs. Parameter counts are exposed for the cost and DRAM
//! engines and are asserted against the paper's reported sizes in tests.

pub mod graph;
pub mod layer;
pub mod models;
pub mod stats;

pub use graph::Dnn;
pub use layer::{Layer, LayerKind, TensorShape};
pub use stats::DnnStats;

use anyhow::{bail, Result};

/// Resolve a model-zoo entry by name. Dataset selects the input
/// resolution / class count variant.
pub fn build_model(name: &str, dataset: &str) -> Result<Dnn> {
    let ds = dataset.to_ascii_lowercase();
    let (input, classes) = match ds.as_str() {
        "cifar10" => ((32, 32, 3), 10),
        "cifar100" => ((32, 32, 3), 100),
        "imagenet" => ((224, 224, 3), 1000),
        "drivenet" | "driving" => ((66, 200, 3), 10),
        other => bail!("unknown dataset '{other}' (cifar10|cifar100|imagenet|drivenet)"),
    };
    match name.to_ascii_lowercase().as_str() {
        "lenet5" => Ok(models::lenet::lenet5(input, classes)),
        "nin" => Ok(models::nin::nin(input, classes)),
        "resnet20" => Ok(models::resnet::resnet_cifar(3, input, classes)),
        "resnet56" => Ok(models::resnet::resnet_cifar(9, input, classes)),
        "resnet110" => Ok(models::resnet::resnet_cifar(18, input, classes)),
        "resnet50" => Ok(models::resnet::resnet50(input, classes)),
        "vgg16" => Ok(models::vgg::vgg(&models::vgg::VGG16_PLAN, input, classes)),
        "vgg19" => Ok(models::vgg::vgg(&models::vgg::VGG19_PLAN, input, classes)),
        "densenet40" => Ok(models::densenet::densenet(40, 12, input, classes)),
        "densenet110" => Ok(models::densenet::densenet(100, 24, input, classes)),
        "drivenet" => Ok(models::drivenet::drivenet(classes)),
        other => bail!(
            "unknown model '{other}' (lenet5|nin|resnet20|resnet56|resnet110|resnet50|vgg16|vgg19|densenet40|densenet110|drivenet)"
        ),
    }
}

/// All model names the zoo supports (for the CLI `models` subcommand).
pub fn zoo_names() -> &'static [&'static str] {
    &[
        "lenet5",
        "nin",
        "resnet20",
        "resnet56",
        "resnet110",
        "resnet50",
        "vgg16",
        "vgg19",
        "densenet40",
        "densenet110",
        "drivenet",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_builds_all() {
        for name in zoo_names() {
            let ds = match *name {
                "resnet50" | "vgg16" => "imagenet",
                "vgg19" => "cifar100",
                "drivenet" => "drivenet",
                _ => "cifar10",
            };
            let dnn = build_model(name, ds).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!dnn.layers.is_empty(), "{name} has layers");
            assert!(dnn.stats().params > 0, "{name} has params");
        }
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(build_model("alexnet", "cifar10").is_err());
        assert!(build_model("resnet110", "svhn").is_err());
    }

    /// Parameter counts vs the paper (Section 6.1): ResNet-110 1.7M,
    /// ResNet-50 23M (conv+fc = 25.5M actual; paper quotes conv-dominated
    /// 23M), VGG-16 138M. Allow the documented tolerance.
    #[test]
    fn param_counts_match_paper() {
        let close = |got: usize, want: f64, tol: f64| {
            let got = got as f64;
            assert!(
                (got - want).abs() / want < tol,
                "params {got} vs paper {want}"
            );
        };
        close(
            build_model("resnet110", "cifar10").unwrap().stats().params,
            1.7e6,
            0.15,
        );
        close(
            build_model("resnet50", "imagenet").unwrap().stats().params,
            25.5e6,
            0.15,
        );
        close(
            build_model("vgg16", "imagenet").unwrap().stats().params,
            138.0e6,
            0.10,
        );
        // VGG-19/CIFAR-100 with the full 4096-wide classifier ≈ 39.4M;
        // paper rounds up to 45.6M — accept the structural value.
        close(
            build_model("vgg19", "cifar100").unwrap().stats().params,
            39.4e6,
            0.15,
        );
        // DenseNet(L=100, k=24) ≈ 27.2M vs paper's "DenseNet-110, 28.1M".
        close(
            build_model("densenet110", "cifar10").unwrap().stats().params,
            27.2e6,
            0.20,
        );
    }
}
