//! DNN workload representation: layer graph, shape inference, the model
//! zoo the paper evaluates (LeNet-5, ResNet-20/56/110, ResNet-50,
//! VGG-16/19, DenseNet, NiN, DriveNet) plus transformer workloads
//! (ViT-Tiny/Small, a BERT-base-class encoder, a GPT-2-class decoder),
//! and the file-based network frontend (`model = "file:net.toml"`, see
//! [`file`]).
//!
//! The partition & mapping engine consumes only layer *shapes* — kernel
//! geometry, feature-map sizes, branch structure — so the zoo builds
//! weight-free graphs. Parameter counts are exposed for the cost and DRAM
//! engines and are asserted against the paper's reported sizes in tests.

pub mod file;
pub mod graph;
pub mod layer;
pub mod models;
pub mod stats;

pub use file::{load_model_file, parse_model_str, to_model_toml};
pub use graph::{Dnn, ModelSource};
pub use layer::{Layer, LayerKind, TensorShape};
pub use stats::DnnStats;

use anyhow::{bail, Result};

/// Resolve a `[dnn] model` value: a `file:` prefix loads a network
/// description through [`load_model_file`] (the file declares its own
/// input shape and dataset — `dataset` is ignored); anything else is a
/// zoo name handed to [`build_model`].
pub fn resolve_model(model: &str, dataset: &str) -> Result<Dnn> {
    match model.strip_prefix("file:") {
        Some(path) => load_model_file(path),
        None => build_model(model, dataset),
    }
}

/// `(input shape, classes)` of a dataset name — the single vocabulary
/// shared by [`build_model`] and [`check_model_name`], so the two can
/// never drift. `None` for unknown datasets.
pub fn dataset_spec(dataset: &str) -> Option<((usize, usize, usize), usize)> {
    match dataset.to_ascii_lowercase().as_str() {
        "cifar10" => Some(((32, 32, 3), 10)),
        "cifar100" => Some(((32, 32, 3), 100)),
        "imagenet" => Some(((224, 224, 3), 1000)),
        "drivenet" | "driving" => Some(((66, 200, 3), 10)),
        // `seq<N>`: an N-token id sequence (binary classification head
        // for BERT-class encoders; decoder graphs ignore the class
        // count). `seq128` is the canonical published-figure length;
        // `seq1` is the autoregressive decode-step graph.
        other => {
            let n: usize = other.strip_prefix("seq")?.parse().ok()?;
            if n == 0 {
                return None;
            }
            Some(((1, n, 1), 2))
        }
    }
}

/// The token-id `seq<N>` inputs are 1×N×1 — convolutional stems would
/// underflow on them, so they only pair with token models. Shared by
/// [`build_model`] and [`check_model_name`] so the crashing combination
/// is rejected at validate time, never mid-run.
fn dataset_supports_model(name: &str, ds: &str) -> Result<(), String> {
    let token_model = matches!(name, "bert_base" | "gpt2_small");
    if ds.starts_with("seq") && !token_model {
        return Err(format!(
            "dataset '{ds}' is a token-id sequence; model '{name}' needs an image \
             dataset (seq<N> pairs with bert_base|gpt2_small)"
        ));
    }
    Ok(())
}

/// Resolve a model-zoo entry by name. Dataset selects the input
/// resolution / class count variant; the returned graph carries the
/// resolved (lowercased) dataset name, not any builder-internal family
/// tag, so exports and file-model reports stay in the documented
/// dataset vocabulary.
pub fn build_model(name: &str, dataset: &str) -> Result<Dnn> {
    let ds = dataset.to_ascii_lowercase();
    let Some((input, classes)) = dataset_spec(&ds) else {
        bail!("unknown dataset '{ds}' (cifar10|cifar100|imagenet|drivenet|seq<N>)");
    };
    let name_lc = name.to_ascii_lowercase();
    if let Err(e) = dataset_supports_model(&name_lc, &ds) {
        bail!("{e}");
    }
    let mut dnn = build_zoo_entry(&name_lc, input, classes)?;
    dnn.dataset = ds;
    Ok(dnn)
}

fn build_zoo_entry(name: &str, input: (usize, usize, usize), classes: usize) -> Result<Dnn> {
    match name.to_ascii_lowercase().as_str() {
        "lenet5" => Ok(models::lenet::lenet5(input, classes)),
        "nin" => Ok(models::nin::nin(input, classes)),
        "resnet20" => Ok(models::resnet::resnet_cifar(3, input, classes)),
        "resnet56" => Ok(models::resnet::resnet_cifar(9, input, classes)),
        "resnet110" => Ok(models::resnet::resnet_cifar(18, input, classes)),
        "resnet50" => Ok(models::resnet::resnet50(input, classes)),
        "vgg16" => Ok(models::vgg::vgg(&models::vgg::VGG16_PLAN, input, classes)),
        "vgg19" => Ok(models::vgg::vgg(&models::vgg::VGG19_PLAN, input, classes)),
        "densenet40" => Ok(models::densenet::densenet(40, 12, input, classes)),
        "densenet110" => Ok(models::densenet::densenet(100, 24, input, classes)),
        "drivenet" => Ok(models::drivenet::drivenet(classes)),
        "vit_tiny" => Ok(models::transformer::vit("vit_tiny", 12, 192, 3, 16, input, classes)),
        "vit_small" => Ok(models::transformer::vit("vit_small", 12, 384, 6, 16, input, classes)),
        "bert_base" => Ok(models::transformer::bert_encoder(
            "bert_base",
            12,
            768,
            12,
            30522,
            512,
            input,
            classes,
        )),
        // decoder: no classifier head — `classes` does not apply
        "gpt2_small" => Ok(models::transformer::gpt2(
            "gpt2_small",
            12,
            768,
            12,
            50257,
            1024,
            input,
        )),
        other => bail!(
            "unknown model '{other}' (lenet5|nin|resnet20|resnet56|resnet110|resnet50|vgg16|\
             vgg19|densenet40|densenet110|drivenet|vit_tiny|vit_small|bert_base|gpt2_small)"
        ),
    }
}

/// All model names the zoo supports (for the CLI `models` subcommand).
pub fn zoo_names() -> &'static [&'static str] {
    &[
        "lenet5",
        "nin",
        "resnet20",
        "resnet56",
        "resnet110",
        "resnet50",
        "vgg16",
        "vgg19",
        "densenet40",
        "densenet110",
        "drivenet",
        "vit_tiny",
        "vit_small",
        "bert_base",
        "gpt2_small",
    ]
}

/// The canonical dataset of a zoo entry (the one its published figures
/// are quoted for) — used by the CLI `models` listing and the tests.
pub fn default_dataset(name: &str) -> &'static str {
    match name {
        "resnet50" | "vgg16" | "vit_tiny" | "vit_small" => "imagenet",
        "vgg19" => "cifar100",
        "drivenet" => "drivenet",
        "bert_base" | "gpt2_small" => "seq128",
        _ => "cifar10",
    }
}

/// Split a `[serve] workloads` entry into `(model, dataset)`. Entries
/// are `"model"`, `"model:dataset"`, or a whole `"file:path"` reference
/// — file models carry their own dataset, so the colon after `file` is
/// part of the reference, not a dataset separator.
pub fn split_workload<'a>(entry: &'a str, default_dataset: &'a str) -> (&'a str, &'a str) {
    if entry.starts_with("file:") {
        return (entry, default_dataset);
    }
    match entry.split_once(':') {
        Some((m, d)) => (m, d),
        None => (entry, default_dataset),
    }
}

/// Check a `[dnn]`/`[serve]` model reference without building it, for
/// config-validate-time errors: a `file:` path must exist on disk, and
/// a zoo name must be in the registry with a known dataset. Returns the
/// actionable message validation surfaces.
pub fn check_model_name(model: &str, dataset: &str) -> Result<(), String> {
    if let Some(path) = model.strip_prefix("file:") {
        if path.is_empty() {
            return Err("model 'file:' needs a path (file:path/to/net.toml)".into());
        }
        if !std::path::Path::new(path).exists() {
            return Err(format!("model file '{path}' does not exist"));
        }
        return Ok(());
    }
    let name = model.to_ascii_lowercase();
    if !zoo_names().contains(&name.as_str()) {
        return Err(format!(
            "unknown model '{model}' (zoo: {}; or file:path/to/net.toml)",
            zoo_names().join("|")
        ));
    }
    if dataset_spec(dataset).is_none() {
        return Err(format!(
            "unknown dataset '{dataset}' (cifar10|cifar100|imagenet|drivenet|seq<N>)"
        ));
    }
    dataset_supports_model(&name, &dataset.to_ascii_lowercase())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_builds_all() {
        for name in zoo_names() {
            let dnn = build_model(name, default_dataset(name))
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!dnn.layers.is_empty(), "{name} has layers");
            assert!(dnn.stats().params > 0, "{name} has params");
            assert_eq!(dnn.source, ModelSource::Builtin);
        }
    }

    #[test]
    fn unknown_model_is_an_error() {
        assert!(build_model("alexnet", "cifar10").is_err());
        assert!(build_model("resnet110", "svhn").is_err());
        assert!(check_model_name("alexnet", "cifar10").is_err());
        assert!(check_model_name("resnet110", "svhn").is_err());
        assert!(check_model_name("resnet110", "cifar10").is_ok());
        assert!(check_model_name("file:", "cifar10").is_err());
        assert!(check_model_name("file:/nonexistent/net.toml", "cifar10").is_err());
    }

    #[test]
    fn seq128_requires_a_token_model() {
        // conv stems underflow on the 1x128x1 token input — rejected at
        // validate/build time, never a mid-run panic
        assert!(build_model("lenet5", "seq128").is_err());
        assert!(build_model("vit_tiny", "seq128").is_err());
        assert!(check_model_name("lenet5", "seq128").is_err());
        assert!(check_model_name("bert_base", "seq128").is_ok());
        assert!(build_model("bert_base", "seq128").is_ok());
        assert!(check_model_name("gpt2_small", "seq128").is_ok());
        assert!(build_model("gpt2_small", "seq128").is_ok());
        assert!(build_model("resnet110", "seq64").is_err());
    }

    #[test]
    fn seq_datasets_are_length_parameterized() {
        // seq<N> resolves for any positive N; the graph's sequence
        // length follows the dataset, weight geometry does not
        assert_eq!(dataset_spec("seq128"), Some(((1, 128, 1), 2)));
        assert_eq!(dataset_spec("seq1"), Some(((1, 1, 1), 2)));
        assert_eq!(dataset_spec("seq256"), Some(((1, 256, 1), 2)));
        assert_eq!(dataset_spec("seq0"), None);
        assert_eq!(dataset_spec("seq"), None);
        assert_eq!(dataset_spec("seqx"), None);
        assert_eq!(dataset_spec("sequence"), None);
        let long = build_model("gpt2_small", "seq256").unwrap();
        let step = build_model("gpt2_small", "seq1").unwrap();
        assert_eq!(long.dataset, "seq256");
        assert_eq!(step.dataset, "seq1");
        assert_eq!(long.stats().params, step.stats().params);
        assert!(step.stats().macs < long.stats().macs);
        assert!(check_model_name("bert_base", "seq64").is_ok());
        assert!(check_model_name("gpt2_small", "seqx").is_err());
    }

    #[test]
    fn build_model_stamps_resolved_dataset() {
        // builder-internal family tags ("any", "cifar") never leak into
        // the graph — exports and file-model reports stay in the
        // documented dataset vocabulary
        assert_eq!(build_model("vgg16", "imagenet").unwrap().dataset, "imagenet");
        assert_eq!(build_model("resnet110", "CIFAR10").unwrap().dataset, "cifar10");
        assert_eq!(build_model("bert_base", "seq128").unwrap().dataset, "seq128");
        assert_eq!(dataset_spec("cifar100"), Some(((32, 32, 3), 100)));
        assert_eq!(dataset_spec("svhn"), None);
    }

    #[test]
    fn workload_entries_split() {
        assert_eq!(split_workload("resnet110", "cifar10"), ("resnet110", "cifar10"));
        assert_eq!(split_workload("vgg19:cifar100", "cifar10"), ("vgg19", "cifar100"));
        // file references keep their colon — the file declares its dataset
        assert_eq!(
            split_workload("file:configs/models/vit_tiny.toml", "cifar10"),
            ("file:configs/models/vit_tiny.toml", "cifar10")
        );
    }

    #[test]
    fn resolve_model_dispatches() {
        assert_eq!(resolve_model("lenet5", "cifar10").unwrap().name, "lenet5");
        assert!(resolve_model("file:/nonexistent/net.toml", "cifar10").is_err());
    }

    /// Parameter counts vs the paper (Section 6.1): ResNet-110 1.7M,
    /// ResNet-50 23M (conv+fc = 25.5M actual; paper quotes conv-dominated
    /// 23M), VGG-16 138M. Allow the documented tolerance.
    #[test]
    fn param_counts_match_paper() {
        let close = |got: usize, want: f64, tol: f64| {
            let got = got as f64;
            assert!(
                (got - want).abs() / want < tol,
                "params {got} vs paper {want}"
            );
        };
        close(
            build_model("resnet110", "cifar10").unwrap().stats().params,
            1.7e6,
            0.15,
        );
        close(
            build_model("resnet50", "imagenet").unwrap().stats().params,
            25.5e6,
            0.15,
        );
        close(
            build_model("vgg16", "imagenet").unwrap().stats().params,
            138.0e6,
            0.10,
        );
        // VGG-19/CIFAR-100 with the full 4096-wide classifier ≈ 39.4M;
        // paper rounds up to 45.6M — accept the structural value.
        close(
            build_model("vgg19", "cifar100").unwrap().stats().params,
            39.4e6,
            0.15,
        );
        // DenseNet(L=100, k=24) ≈ 27.2M vs paper's "DenseNet-110, 28.1M".
        close(
            build_model("densenet110", "cifar10").unwrap().stats().params,
            27.2e6,
            0.20,
        );
    }

    /// Transformer golden figures (tighter than the paper CNNs: these
    /// are pinned against the published reference implementations —
    /// timm ViTs, huggingface BERT-base; the documented omissions are
    /// < 1 % of parameters).
    #[test]
    fn transformer_goldens_match_published() {
        let close = |got: usize, want: f64, tol: f64, what: &str| {
            let got = got as f64;
            assert!(
                (got - want).abs() / want < tol,
                "{what}: {got} vs published {want}"
            );
        };
        let vt = build_model("vit_tiny", "imagenet").unwrap().stats();
        close(vt.params, 5.72e6, 0.02, "vit_tiny params");
        close(vt.macs, 1.26e9, 0.05, "vit_tiny MACs");
        let vs = build_model("vit_small", "imagenet").unwrap().stats();
        close(vs.params, 22.05e6, 0.02, "vit_small params");
        close(vs.macs, 4.6e9, 0.05, "vit_small MACs");
        let bb = build_model("bert_base", "seq128").unwrap().stats();
        close(bb.params, 109.5e6, 0.02, "bert_base params");
        close(bb.macs, 11.2e9, 0.05, "bert_base MACs");
        // gpt2_small is pinned *exactly* (tied unembedding makes the
        // count land on the published 124.4M to the digit)
        let g = build_model("gpt2_small", "seq128").unwrap().stats();
        assert_eq!(g.params, 124_439_808, "gpt2_small params");
        assert_eq!(g.macs, 15_964_274_688, "gpt2_small MACs at seq128");
    }
}
