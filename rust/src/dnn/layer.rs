//! Layer types and shape inference.


/// (height, width, channels) of a feature map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Channels.
    pub c: usize,
}

impl TensorShape {
    /// Build an (h, w, c) shape.
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        TensorShape { h, w, c }
    }

    /// Total elements (h × w × c).
    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Layer operator. Only `Conv` and `Fc` carry weights and map onto IMC
/// crossbars; the rest contribute activations traffic and digital-unit
/// work (pooling / activation / elementwise add / concat).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv {
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Spatial stride.
        stride: usize,
        /// Zero padding on each border.
        padding: usize,
        /// Output channels.
        out_ch: usize,
    },
    /// Fully-connected layer.
    Fc {
        /// Output features.
        out_features: usize,
    },
    /// Max pooling.
    MaxPool {
        /// Window size.
        k: usize,
        /// Spatial stride.
        stride: usize,
        /// Zero padding on each border.
        padding: usize,
    },
    /// Average pooling.
    AvgPool {
        /// Window size.
        k: usize,
        /// Spatial stride.
        stride: usize,
        /// Zero padding on each border.
        padding: usize,
    },
    /// Global average pool to 1×1.
    GlobalAvgPool,
    /// Rectified linear activation.
    Relu,
    /// Sigmoid activation (LUT-based in hardware).
    Sigmoid,
    /// Residual addition with the output of layer `from` (index into the
    /// DNN layer list). Requires buffering that layer's activations.
    ResidualAdd {
        /// Index of the skip-edge source layer.
        from: usize,
    },
    /// Channel concatenation with the output of layer `from` (DenseNet).
    Concat {
        /// Index of the skip-edge source layer.
        from: usize,
    },
}

/// One node of the DNN graph with inferred input/output shapes.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Layer name (Caffe-style for ResNet-50, so calibration
    /// experiments can address specific layers).
    pub name: String,
    /// Operator and its parameters.
    pub kind: LayerKind,
    /// Input feature-map shape.
    pub ifm: TensorShape,
    /// Output feature-map shape.
    pub ofm: TensorShape,
}

impl Layer {
    /// Weight parameters (zero for non-weight layers). Biases included.
    pub fn params(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kh, kw, out_ch, .. } => kh * kw * self.ifm.c * out_ch + out_ch,
            LayerKind::Fc { out_features } => self.ifm.elems() * out_features + out_features,
            _ => 0,
        }
    }

    /// Multiply-accumulate operations for one inference.
    pub fn macs(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kh, kw, .. } => self.ofm.elems() * kh * kw * self.ifm.c,
            LayerKind::Fc { out_features } => self.ifm.elems() * out_features,
            _ => 0,
        }
    }

    /// Does this layer own IMC crossbars?
    pub fn is_weight_layer(&self) -> bool {
        matches!(self.kind, LayerKind::Conv { .. } | LayerKind::Fc { .. })
    }

    /// Rows of the unrolled weight matrix (Kx·Ky·Nif for conv, K for fc) —
    /// the numerator of N_r in Eq. 1.
    pub fn weight_rows(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kh, kw, .. } => kh * kw * self.ifm.c,
            LayerKind::Fc { .. } => self.ifm.elems(),
            _ => 0,
        }
    }

    /// Columns of the unrolled weight matrix (Nof) — the numerator of N_c
    /// in Eq. 1 before the ×N_bits bit-slicing.
    pub fn weight_cols(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => out_ch,
            LayerKind::Fc { out_features } => out_features,
            _ => 0,
        }
    }

    /// Number of input vectors pushed through the crossbars per inference
    /// (spatial positions for conv, 1 for fc).
    pub fn input_vectors(&self) -> usize {
        match self.kind {
            LayerKind::Conv { .. } => self.ofm.h * self.ofm.w,
            LayerKind::Fc { .. } => 1,
            _ => 0,
        }
    }
}

/// Shape inference for a layer kind applied to an input shape.
pub fn infer_ofm(kind: &LayerKind, ifm: TensorShape) -> TensorShape {
    match *kind {
        LayerKind::Conv {
            kh,
            kw,
            stride,
            padding,
            out_ch,
        } => TensorShape::new(
            (ifm.h + 2 * padding - kh) / stride + 1,
            (ifm.w + 2 * padding - kw) / stride + 1,
            out_ch,
        ),
        LayerKind::Fc { out_features } => TensorShape::new(1, 1, out_features),
        LayerKind::MaxPool { k, stride, padding } | LayerKind::AvgPool { k, stride, padding } => {
            TensorShape::new(
                (ifm.h + 2 * padding - k) / stride + 1,
                (ifm.w + 2 * padding - k) / stride + 1,
                ifm.c,
            )
        }
        LayerKind::GlobalAvgPool => TensorShape::new(1, 1, ifm.c),
        LayerKind::Relu | LayerKind::Sigmoid | LayerKind::ResidualAdd { .. } => ifm,
        LayerKind::Concat { .. } => ifm, // channel count fixed by the builder
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(kh: usize, stride: usize, padding: usize, out_ch: usize) -> LayerKind {
        LayerKind::Conv {
            kh,
            kw: kh,
            stride,
            padding,
            out_ch,
        }
    }

    #[test]
    fn conv_shape_inference() {
        let ifm = TensorShape::new(32, 32, 3);
        let ofm = infer_ofm(&conv(3, 1, 1, 16), ifm);
        assert_eq!(ofm, TensorShape::new(32, 32, 16));
        let ofm2 = infer_ofm(&conv(3, 2, 1, 32), ifm);
        assert_eq!(ofm2, TensorShape::new(16, 16, 32));
        let ofm7 = infer_ofm(&conv(7, 2, 3, 64), TensorShape::new(224, 224, 3));
        assert_eq!(ofm7, TensorShape::new(112, 112, 64));
    }

    #[test]
    fn pool_shape_inference() {
        let ifm = TensorShape::new(32, 32, 16);
        let ofm = infer_ofm(&LayerKind::MaxPool { k: 2, stride: 2, padding: 0 }, ifm);
        assert_eq!(ofm, TensorShape::new(16, 16, 16));
    }

    #[test]
    fn params_and_macs() {
        let l = Layer {
            name: "conv1".into(),
            kind: conv(3, 1, 1, 16),
            ifm: TensorShape::new(32, 32, 3),
            ofm: TensorShape::new(32, 32, 16),
        };
        assert_eq!(l.params(), 3 * 3 * 3 * 16 + 16);
        assert_eq!(l.macs(), 32 * 32 * 16 * 27);
        assert_eq!(l.weight_rows(), 27);
        assert_eq!(l.weight_cols(), 16);
        assert_eq!(l.input_vectors(), 1024);
    }

    #[test]
    fn fc_params() {
        let l = Layer {
            name: "fc".into(),
            kind: LayerKind::Fc { out_features: 10 },
            ifm: TensorShape::new(1, 1, 64),
            ofm: TensorShape::new(1, 1, 10),
        };
        assert_eq!(l.params(), 64 * 10 + 10);
        assert_eq!(l.input_vectors(), 1);
    }
}
