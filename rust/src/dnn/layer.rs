//! Layer types and shape inference.


/// (height, width, channels) of a feature map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    /// Height.
    pub h: usize,
    /// Width.
    pub w: usize,
    /// Channels.
    pub c: usize,
}

impl TensorShape {
    /// Build an (h, w, c) shape.
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        TensorShape { h, w, c }
    }

    /// Total elements (h × w × c).
    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Layer operator. `Conv`, `Fc` and the projection half of `Attention`
/// carry weights and map onto IMC crossbars; the rest contribute
/// activations traffic and digital-unit work (pooling / activation /
/// elementwise add / concat / normalization / dynamic matmuls).
///
/// Transformer workloads are expressed over the same `(h, w, c)` tensor
/// shapes as CNNs: a sequence of `L` tokens with hidden size `D` is any
/// shape with `h·w = L` and `c = D` (e.g. the `14×14×192` patch grid a
/// ViT patch-embedding convolution produces, or `1×128×768` for a BERT
/// encoder). Per-token linears (the transformer MLP) are 1×1
/// convolutions, which unroll to exactly the same crossbar geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv {
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Spatial stride.
        stride: usize,
        /// Zero padding on each border.
        padding: usize,
        /// Output channels.
        out_ch: usize,
    },
    /// Fully-connected layer.
    Fc {
        /// Output features.
        out_features: usize,
    },
    /// Max pooling.
    MaxPool {
        /// Window size.
        k: usize,
        /// Spatial stride.
        stride: usize,
        /// Zero padding on each border.
        padding: usize,
    },
    /// Average pooling.
    AvgPool {
        /// Window size.
        k: usize,
        /// Spatial stride.
        stride: usize,
        /// Zero padding on each border.
        padding: usize,
    },
    /// Global average pool to 1×1.
    GlobalAvgPool,
    /// Rectified linear activation.
    Relu,
    /// Sigmoid activation (LUT-based in hardware).
    Sigmoid,
    /// Residual addition with the output of layer `from` (index into the
    /// DNN layer list). Requires buffering that layer's activations.
    ResidualAdd {
        /// Index of the skip-edge source layer.
        from: usize,
    },
    /// Channel concatenation with the output of layer `from` (DenseNet).
    Concat {
        /// Index of the skip-edge source layer.
        from: usize,
    },
    /// Multi-head self-attention over the `ifm.h · ifm.w` token
    /// sequence. The Q/K/V/O projections unroll to one `dim × 4·dim`
    /// weight matrix mapped onto crossbars exactly like [`LayerKind::Fc`]
    /// (one input vector per token); the `Q·Kᵀ` score and `softmax(S)·V`
    /// matmuls are dynamic activation×activation products executed on
    /// the digital side (see [`Layer::digital_macs`]). Requires
    /// `ifm.c == dim` and `heads | dim` (checked by `Dnn::check`).
    Attention {
        /// Number of attention heads (must divide `dim`).
        heads: usize,
        /// Model (hidden) dimension; must equal the input channel count.
        dim: usize,
    },
    /// Dynamic activation×activation matrix multiply: the `(L × c)`
    /// token matrix times a runtime `(c × out_features)` operand.
    /// Carries no weights — all `ifm.elems() × out_features` MACs run
    /// on the digital side (standalone score/value products outside an
    /// [`LayerKind::Attention`] block).
    Matmul {
        /// Columns of the dynamic right-hand operand.
        out_features: usize,
    },
    /// Layer normalization over the channel axis (learnable per-channel
    /// scale and shift; 2·c parameters, digital-unit work).
    LayerNorm,
    /// Gaussian-error linear unit activation (LUT-based digital unit,
    /// like [`LayerKind::Sigmoid`]).
    Gelu,
    /// Embedding-table lookup / positional-embedding add: a learnable
    /// `vocab × dim` table read per token. With `ifm.c == dim` it is a
    /// positional add (shape-preserving); otherwise a token lookup that
    /// rewrites the channel count to `dim`. The table lives in the
    /// global buffer / DRAM, not on crossbars.
    Embedding {
        /// Table rows (vocabulary size or sequence positions).
        vocab: usize,
        /// Embedding width; becomes the output channel count.
        dim: usize,
    },
    /// Causally-masked multi-head self-attention (decoder blocks): the
    /// Q/K/V/O projections are identical to [`LayerKind::Attention`]
    /// (one fused `dim × 4·dim` weight matrix on crossbars, one input
    /// vector per token), but the dynamic score/value matmuls only see
    /// the lower-triangular mask — token `i` attends to `i + 1` keys,
    /// so the digital work is `L·(L+1)·D` instead of `2·L²·D`. During
    /// autoregressive decode the K/V rows of earlier tokens are the KV
    /// cache ([`crate::serve::decode`] charges its residency).
    CausalAttention {
        /// Number of attention heads (must divide `dim`).
        heads: usize,
        /// Model (hidden) dimension; must equal the input channel count.
        dim: usize,
    },
    /// Output projection onto the vocabulary with the *transposed token
    /// embedding* as its weight matrix (GPT-2 weight tying). Owns
    /// crossbars like [`LayerKind::Fc`] — the tied table must still be
    /// programmed somewhere to run in a weight-stationary IMC — but
    /// contributes **zero** parameters: they are already counted by the
    /// tied [`LayerKind::Embedding`].
    TiedUnembed {
        /// Vocabulary size; becomes the output channel count.
        vocab: usize,
    },
}

/// One node of the DNN graph with inferred input/output shapes.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Layer name (Caffe-style for ResNet-50, so calibration
    /// experiments can address specific layers).
    pub name: String,
    /// Operator and its parameters.
    pub kind: LayerKind,
    /// Input feature-map shape.
    pub ifm: TensorShape,
    /// Output feature-map shape.
    pub ofm: TensorShape,
}

impl Layer {
    /// Sequence length when the layer is read as a token sequence
    /// (`ifm.h · ifm.w`).
    pub fn seq_len(&self) -> usize {
        self.ifm.h * self.ifm.w
    }

    /// Weight parameters (zero for non-weight layers). Biases included.
    /// `LayerNorm` (scale+shift) and `Embedding` (the lookup table)
    /// carry parameters without owning crossbars.
    pub fn params(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kh, kw, out_ch, .. } => kh * kw * self.ifm.c * out_ch + out_ch,
            LayerKind::Fc { out_features } => self.ifm.elems() * out_features + out_features,
            LayerKind::Attention { dim, .. } | LayerKind::CausalAttention { dim, .. } => {
                4 * dim * dim + 4 * dim
            }
            LayerKind::LayerNorm => 2 * self.ifm.c,
            LayerKind::Embedding { vocab, dim } => vocab * dim,
            // weight-tied with the token embedding: counted there
            LayerKind::TiedUnembed { .. } => 0,
            _ => 0,
        }
    }

    /// Multiply-accumulate operations for one inference (crossbar-mapped
    /// and digital MACs combined; see [`Layer::digital_macs`] for the
    /// digital-only share).
    pub fn macs(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kh, kw, .. } => self.ofm.elems() * kh * kw * self.ifm.c,
            LayerKind::Fc { out_features } => self.ifm.elems() * out_features,
            // Q/K/V/O projections (L·4·D²) + score/value matmuls (2·L²·D
            // bidirectional, L·(L+1)·D causal)
            LayerKind::Attention { dim, .. } | LayerKind::CausalAttention { dim, .. } => {
                self.seq_len() * 4 * dim * dim + self.digital_macs()
            }
            LayerKind::TiedUnembed { vocab } => self.seq_len() * self.ifm.c * vocab,
            LayerKind::Matmul { .. } => self.digital_macs(),
            _ => 0,
        }
    }

    /// MACs executed on the digital side (accumulator/SIMD lanes)
    /// because one operand is a runtime activation: the score and value
    /// matmuls of [`LayerKind::Attention`] (`2·L²·D`) and the whole of
    /// [`LayerKind::Matmul`]. Zero for every weight-stationary kind.
    pub fn digital_macs(&self) -> usize {
        match self.kind {
            LayerKind::Attention { dim, .. } => {
                let l = self.seq_len();
                2 * l * l * dim
            }
            // causal mask: token i sees i+1 keys — Σ 2·(i+1)·D = L·(L+1)·D
            LayerKind::CausalAttention { dim, .. } => {
                let l = self.seq_len();
                l * (l + 1) * dim
            }
            LayerKind::Matmul { out_features } => self.ifm.elems() * out_features,
            _ => 0,
        }
    }

    /// Does this layer own IMC crossbars?
    pub fn is_weight_layer(&self) -> bool {
        matches!(
            self.kind,
            LayerKind::Conv { .. }
                | LayerKind::Fc { .. }
                | LayerKind::Attention { .. }
                | LayerKind::CausalAttention { .. }
                | LayerKind::TiedUnembed { .. }
        )
    }

    /// Rows of the unrolled weight matrix (Kx·Ky·Nif for conv, K for fc,
    /// D for attention) — the numerator of N_r in Eq. 1.
    pub fn weight_rows(&self) -> usize {
        match self.kind {
            LayerKind::Conv { kh, kw, .. } => kh * kw * self.ifm.c,
            LayerKind::Fc { .. } => self.ifm.elems(),
            LayerKind::Attention { dim, .. } | LayerKind::CausalAttention { dim, .. } => dim,
            LayerKind::TiedUnembed { .. } => self.ifm.c,
            _ => 0,
        }
    }

    /// Columns of the unrolled weight matrix (Nof for conv/fc, the fused
    /// 4·D Q/K/V/O projection block for attention) — the numerator of
    /// N_c in Eq. 1 before the ×N_bits bit-slicing.
    pub fn weight_cols(&self) -> usize {
        match self.kind {
            LayerKind::Conv { out_ch, .. } => out_ch,
            LayerKind::Fc { out_features } => out_features,
            LayerKind::Attention { dim, .. } | LayerKind::CausalAttention { dim, .. } => 4 * dim,
            LayerKind::TiedUnembed { vocab } => vocab,
            _ => 0,
        }
    }

    /// Number of input vectors pushed through the crossbars per inference
    /// (spatial positions for conv, 1 for fc, one per token for
    /// attention projections).
    pub fn input_vectors(&self) -> usize {
        match self.kind {
            LayerKind::Conv { .. } => self.ofm.h * self.ofm.w,
            LayerKind::Fc { .. } => 1,
            LayerKind::Attention { .. }
            | LayerKind::CausalAttention { .. }
            | LayerKind::TiedUnembed { .. } => self.seq_len(),
            _ => 0,
        }
    }
}

/// Shape inference for a layer kind applied to an input shape.
pub fn infer_ofm(kind: &LayerKind, ifm: TensorShape) -> TensorShape {
    match *kind {
        LayerKind::Conv {
            kh,
            kw,
            stride,
            padding,
            out_ch,
        } => TensorShape::new(
            (ifm.h + 2 * padding - kh) / stride + 1,
            (ifm.w + 2 * padding - kw) / stride + 1,
            out_ch,
        ),
        LayerKind::Fc { out_features } => TensorShape::new(1, 1, out_features),
        LayerKind::MaxPool { k, stride, padding } | LayerKind::AvgPool { k, stride, padding } => {
            TensorShape::new(
                (ifm.h + 2 * padding - k) / stride + 1,
                (ifm.w + 2 * padding - k) / stride + 1,
                ifm.c,
            )
        }
        LayerKind::GlobalAvgPool => TensorShape::new(1, 1, ifm.c),
        LayerKind::Relu
        | LayerKind::Sigmoid
        | LayerKind::Gelu
        | LayerKind::LayerNorm
        | LayerKind::Attention { .. }
        | LayerKind::CausalAttention { .. }
        | LayerKind::ResidualAdd { .. } => ifm,
        LayerKind::Concat { .. } => ifm, // channel count fixed by the builder
        LayerKind::Matmul { out_features } => TensorShape::new(ifm.h, ifm.w, out_features),
        LayerKind::Embedding { dim, .. } => TensorShape::new(ifm.h, ifm.w, dim),
        LayerKind::TiedUnembed { vocab } => TensorShape::new(ifm.h, ifm.w, vocab),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(kh: usize, stride: usize, padding: usize, out_ch: usize) -> LayerKind {
        LayerKind::Conv {
            kh,
            kw: kh,
            stride,
            padding,
            out_ch,
        }
    }

    #[test]
    fn conv_shape_inference() {
        let ifm = TensorShape::new(32, 32, 3);
        let ofm = infer_ofm(&conv(3, 1, 1, 16), ifm);
        assert_eq!(ofm, TensorShape::new(32, 32, 16));
        let ofm2 = infer_ofm(&conv(3, 2, 1, 32), ifm);
        assert_eq!(ofm2, TensorShape::new(16, 16, 32));
        let ofm7 = infer_ofm(&conv(7, 2, 3, 64), TensorShape::new(224, 224, 3));
        assert_eq!(ofm7, TensorShape::new(112, 112, 64));
    }

    #[test]
    fn pool_shape_inference() {
        let ifm = TensorShape::new(32, 32, 16);
        let ofm = infer_ofm(&LayerKind::MaxPool { k: 2, stride: 2, padding: 0 }, ifm);
        assert_eq!(ofm, TensorShape::new(16, 16, 16));
    }

    #[test]
    fn params_and_macs() {
        let l = Layer {
            name: "conv1".into(),
            kind: conv(3, 1, 1, 16),
            ifm: TensorShape::new(32, 32, 3),
            ofm: TensorShape::new(32, 32, 16),
        };
        assert_eq!(l.params(), 3 * 3 * 3 * 16 + 16);
        assert_eq!(l.macs(), 32 * 32 * 16 * 27);
        assert_eq!(l.weight_rows(), 27);
        assert_eq!(l.weight_cols(), 16);
        assert_eq!(l.input_vectors(), 1024);
    }

    #[test]
    fn attention_geometry_and_macs() {
        // 196 tokens × 192 channels (a ViT-Tiny block)
        let ifm = TensorShape::new(14, 14, 192);
        let kind = LayerKind::Attention { heads: 3, dim: 192 };
        assert_eq!(infer_ofm(&kind, ifm), ifm);
        let l = Layer {
            name: "attn".into(),
            kind,
            ifm,
            ofm: ifm,
        };
        assert!(l.is_weight_layer());
        assert_eq!(l.seq_len(), 196);
        assert_eq!(l.params(), 4 * 192 * 192 + 4 * 192);
        assert_eq!(l.weight_rows(), 192);
        assert_eq!(l.weight_cols(), 4 * 192);
        assert_eq!(l.input_vectors(), 196);
        assert_eq!(l.digital_macs(), 2 * 196 * 196 * 192);
        assert_eq!(l.macs(), 196 * 4 * 192 * 192 + 2 * 196 * 196 * 192);
    }

    #[test]
    fn transformer_digital_kinds() {
        let ifm = TensorShape::new(1, 8, 16);
        // matmul: dynamic product, no weights, all MACs digital
        let mm = LayerKind::Matmul { out_features: 4 };
        assert_eq!(infer_ofm(&mm, ifm), TensorShape::new(1, 8, 4));
        let l = Layer { name: "mm".into(), kind: mm, ifm, ofm: infer_ofm(&mm, ifm) };
        assert!(!l.is_weight_layer());
        assert_eq!(l.params(), 0);
        assert_eq!(l.digital_macs(), 8 * 16 * 4);
        assert_eq!(l.macs(), l.digital_macs());
        // layernorm: shape-preserving, 2c params
        let ln = Layer {
            name: "ln".into(),
            kind: LayerKind::LayerNorm,
            ifm,
            ofm: infer_ofm(&LayerKind::LayerNorm, ifm),
        };
        assert_eq!(ln.ofm, ifm);
        assert_eq!(ln.params(), 32);
        assert!(!ln.is_weight_layer());
        // gelu: shape-preserving, no params
        assert_eq!(infer_ofm(&LayerKind::Gelu, ifm), ifm);
        // embedding: rewrites channels to dim, vocab·dim params
        let em = LayerKind::Embedding { vocab: 100, dim: 24 };
        assert_eq!(infer_ofm(&em, ifm), TensorShape::new(1, 8, 24));
        let l = Layer { name: "em".into(), kind: em, ifm, ofm: infer_ofm(&em, ifm) };
        assert_eq!(l.params(), 2400);
        assert!(!l.is_weight_layer());
    }

    #[test]
    fn causal_attention_geometry_and_macs() {
        // 128 tokens × 768 channels (a GPT-2-small block)
        let ifm = TensorShape::new(1, 128, 768);
        let kind = LayerKind::CausalAttention { heads: 12, dim: 768 };
        assert_eq!(infer_ofm(&kind, ifm), ifm);
        let l = Layer { name: "cattn".into(), kind, ifm, ofm: ifm };
        assert!(l.is_weight_layer());
        assert_eq!(l.seq_len(), 128);
        // projections identical to bidirectional attention...
        assert_eq!(l.params(), 4 * 768 * 768 + 4 * 768);
        assert_eq!(l.weight_rows(), 768);
        assert_eq!(l.weight_cols(), 4 * 768);
        assert_eq!(l.input_vectors(), 128);
        // ...but the masked score/value matmuls halve (L+1 vs 2L)
        assert_eq!(l.digital_macs(), 128 * 129 * 768);
        assert_eq!(l.macs(), 128 * 4 * 768 * 768 + 128 * 129 * 768);
        let bidi = Layer {
            name: "attn".into(),
            kind: LayerKind::Attention { heads: 12, dim: 768 },
            ifm,
            ofm: ifm,
        };
        assert!(l.digital_macs() < bidi.digital_macs());
    }

    #[test]
    fn tied_unembed_geometry() {
        let ifm = TensorShape::new(1, 128, 768);
        let kind = LayerKind::TiedUnembed { vocab: 50257 };
        assert_eq!(infer_ofm(&kind, ifm), TensorShape::new(1, 128, 50257));
        let l = Layer { name: "unembed".into(), kind, ifm, ofm: infer_ofm(&kind, ifm) };
        // owns crossbars (the tied table must be programmed) but the
        // parameters are counted by the tied embedding, not here
        assert!(l.is_weight_layer());
        assert_eq!(l.params(), 0);
        assert_eq!(l.weight_rows(), 768);
        assert_eq!(l.weight_cols(), 50257);
        assert_eq!(l.input_vectors(), 128);
        assert_eq!(l.macs(), 128 * 768 * 50257);
        assert_eq!(l.digital_macs(), 0);
    }

    #[test]
    fn fc_params() {
        let l = Layer {
            name: "fc".into(),
            kind: LayerKind::Fc { out_features: 10 },
            ifm: TensorShape::new(1, 1, 64),
            ofm: TensorShape::new(1, 1, 10),
        };
        assert_eq!(l.params(), 64 * 10 + 10);
        assert_eq!(l.input_vectors(), 1);
    }
}
