//! File-based network descriptions: author any supported topology as a
//! TOML file and run it through the full pipeline with
//! `[dnn] model = "file:path/to/net.toml"`.
//!
//! The format is parsed by the same in-tree TOML-subset parser as the
//! configuration files, so errors carry line numbers. A model file is a
//! `[model]` header plus one `[[layer]]` block per layer:
//!
//! ```toml
//! [model]
//! name = "tiny_vit"
//! dataset = "cifar10"
//! input = [32, 32, 3]        # h, w, c
//!
//! [[layer]]
//! type = "conv"              # patch embedding
//! k = 8
//! stride = 8
//! out_channels = 64
//!
//! [[layer]]
//! type = "attention"
//! heads = 4
//!
//! [[layer]]
//! type = "gap"
//!
//! [[layer]]
//! type = "fc"
//! out_features = 10
//! ```
//!
//! Shape inference runs over the existing [`LayerKind`] rules exactly as
//! the built-in zoo builders use them, and the finished graph passes the
//! same `Dnn::check` consistency pass. [`to_model_toml`] serializes any
//! chain-with-skips graph (every zoo builtin included) back to the
//! format, and the round trip reproduces the graph layer-for-layer —
//! the self-hosting property the `configs/models/` zoo files and their
//! bit-identity tests rely on.
//!
//! Layer reference: see `docs/MODELS.md` for the full authoring guide
//! (every `type`, its keys, defaults and shape rule).

use super::graph::{Dnn, ModelSource};
use super::layer::{infer_ofm, Layer, LayerKind, TensorShape};
use crate::config::{parse_flat, Value};
use anyhow::{Context, Result};
use std::path::Path;

/// Load and validate a network-description file.
///
/// The returned graph carries a [`ModelSource::File`] provenance tag
/// with an FNV-1a fingerprint of the file content, which reports and
/// sweep artifacts surface so results stay reproducible.
///
/// # Examples
///
/// ```
/// let text = r#"
/// [model]
/// name = "mini"
/// input = [8, 8, 3]
///
/// [[layer]]
/// type = "conv"
/// k = 3
/// padding = 1
/// out_channels = 8
///
/// [[layer]]
/// type = "relu"
///
/// [[layer]]
/// type = "fc"
/// out_features = 10
/// "#;
/// let dir = std::env::temp_dir().join("siam_doctest_models");
/// std::fs::create_dir_all(&dir).unwrap();
/// let path = dir.join("mini.toml");
/// std::fs::write(&path, text).unwrap();
/// let dnn = siam::dnn::load_model_file(&path).unwrap();
/// assert_eq!(dnn.name, "mini");
/// assert_eq!(dnn.layers.len(), 3);
/// assert_eq!(dnn.weight_layers(), vec![0, 2]);
/// ```
pub fn load_model_file(path: impl AsRef<Path>) -> Result<Dnn> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading model file {path:?}"))?;
    let mut dnn = parse_model_str(&text)
        .map_err(|e| anyhow::anyhow!("model file {}: {e}", path.display()))?;
    dnn.source = ModelSource::File {
        path: path.display().to_string(),
        fingerprint: content_fingerprint(&text),
    };
    Ok(dnn)
}

/// FNV-1a fold of the file content — the fingerprint reports carry.
fn content_fingerprint(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn as_str(v: &Value) -> Option<String> {
    match v {
        Value::Str(s) => Some(s.clone()),
        _ => None,
    }
}

fn as_count(v: &Value) -> Option<usize> {
    match v {
        Value::Int(i) if *i >= 0 => Some(*i as usize),
        _ => None,
    }
}

/// Parse a network description from TOML text (line-numbered errors).
/// The graph's `source` is left as `Builtin`; [`load_model_file`] stamps
/// the file provenance.
pub fn parse_model_str(text: &str) -> Result<Dnn, String> {
    let mut m = parse_flat(text)?;

    // ---- [model] header
    let Some((name_v, name_line)) = m.remove("model.name") else {
        return Err("missing required key model.name".into());
    };
    let name =
        as_str(&name_v).ok_or_else(|| format!("line {name_line}: model.name must be a string"))?;
    let dataset = match m.remove("model.dataset") {
        Some((v, line)) => {
            as_str(&v).ok_or_else(|| format!("line {line}: model.dataset must be a string"))?
        }
        None => "custom".into(),
    };
    let Some((input_v, input_line)) = m.remove("model.input") else {
        return Err("missing required key model.input (= [h, w, c])".into());
    };
    let input = match &input_v {
        Value::Array(a) if a.len() == 3 => {
            let dim = |x: f64| -> Result<usize, String> {
                if x.fract() == 0.0 && (1.0..=1e9).contains(&x) {
                    Ok(x as usize)
                } else {
                    Err(format!(
                        "line {input_line}: model.input entries must be positive integers"
                    ))
                }
            };
            TensorShape::new(dim(a[0])?, dim(a[1])?, dim(a[2])?)
        }
        _ => {
            return Err(format!(
                "line {input_line}: model.input must be a 3-element array [h, w, c]"
            ))
        }
    };

    // ---- [[layer]] blocks, in file order (indices are zero-padded by
    // the flattening parser, so lexicographic id order is file order)
    const PREFIX: &str = "layer.";
    let mut ids: Vec<String> = m
        .keys()
        .filter_map(|k| k.strip_prefix(PREFIX))
        .filter_map(|rest| rest.split_once('.').map(|(idx, _)| idx.to_string()))
        .collect();
    ids.sort();
    ids.dedup();
    if ids.is_empty() {
        return Err("model file declares no [[layer]] blocks".into());
    }

    let mut layers: Vec<Layer> = Vec::with_capacity(ids.len());
    let mut cur = input;
    for (i, idx) in ids.iter().enumerate() {
        let p = |field: &str| format!("{PREFIX}{idx}.{field}");
        let block_line = m
            .remove(&p("__block__"))
            .map(|(_, line)| line)
            .unwrap_or(0);
        let at = |line: usize| if line > 0 { line } else { block_line };

        // a string key with a default
        macro_rules! str_key {
            ($field:expr, $default:expr) => {
                match m.remove(&p($field)) {
                    Some((v, line)) => as_str(&v).ok_or_else(|| {
                        format!("line {}: layer {i} key '{}' must be a string", at(line), $field)
                    })?,
                    None => $default,
                }
            };
        }
        // a non-negative integer key with a default
        macro_rules! int_key {
            ($field:expr, $default:expr) => {
                match m.remove(&p($field)) {
                    Some((v, line)) => as_count(&v).ok_or_else(|| {
                        format!(
                            "line {}: layer {i} key '{}' must be a non-negative integer",
                            at(line),
                            $field
                        )
                    })?,
                    None => $default,
                }
            };
        }
        // an optional key that must be >= 1 when present
        macro_rules! pos_key {
            ($field:expr, $default:expr) => {{
                match m.remove(&p($field)) {
                    Some((v, line)) => match as_count(&v) {
                        Some(0) | None => {
                            return Err(format!(
                                "line {}: layer {i} key '{}' must be an integer >= 1",
                                at(line),
                                $field
                            ))
                        }
                        Some(v) => v,
                    },
                    None => $default,
                }
            }};
        }
        // a required positive integer key
        macro_rules! req_key {
            ($field:expr) => {{
                match m.remove(&p($field)) {
                    Some((v, line)) => match as_count(&v) {
                        Some(0) | None => {
                            return Err(format!(
                                "line {}: layer {i} key '{}' must be an integer >= 1",
                                at(line),
                                $field
                            ))
                        }
                        Some(v) => v,
                    },
                    None => {
                        return Err(format!(
                            "line {block_line}: layer {i} is missing required key '{}'",
                            $field
                        ))
                    }
                }
            }};
        }

        let ty = match m.remove(&p("type")) {
            Some((v, line)) => as_str(&v)
                .ok_or_else(|| format!("line {}: layer {i} 'type' must be a string", at(line)))?,
            None => return Err(format!("line {block_line}: layer {i} is missing 'type'")),
        };

        // branch restart: read an earlier layer's output shape (or the
        // network input) instead of the previous layer's — how
        // projection shortcuts are expressed in a chain format
        if let Some((v, line)) = m.remove(&p("from_shape_of")) {
            cur = match &v {
                Value::Str(s) if s == "input" => input,
                _ => {
                    let j = resolve_ref(&v, &layers)
                        .map_err(|e| format!("line {line}: layer {i} from_shape_of {e}"))?;
                    layers[j].ofm
                }
            };
        }

        // skip-edge reference for residual/concat
        macro_rules! from_ref {
            () => {
                match m.remove(&p("from")) {
                    Some((v, line)) => resolve_ref(&v, &layers)
                        .map_err(|e| format!("line {line}: layer {i} from {e}"))?,
                    None => {
                        return Err(format!(
                            "line {block_line}: layer {i} ('{ty}') is missing required key 'from'"
                        ))
                    }
                }
            };
        }

        let kind = match ty.as_str() {
            "conv" => {
                let (kh, kw) = match int_key!("k", 0) {
                    0 => (req_key!("kh"), req_key!("kw")),
                    k => (k, k),
                };
                let stride = pos_key!("stride", 1);
                let padding = int_key!("padding", 0);
                let out_ch = req_key!("out_channels");
                if cur.h + 2 * padding < kh || cur.w + 2 * padding < kw {
                    return Err(format!(
                        "line {block_line}: layer {i} conv kernel {kh}x{kw} exceeds padded \
                         input {}x{}",
                        cur.h + 2 * padding,
                        cur.w + 2 * padding
                    ));
                }
                LayerKind::Conv { kh, kw, stride, padding, out_ch }
            }
            "fc" => LayerKind::Fc { out_features: req_key!("out_features") },
            "maxpool" | "avgpool" => {
                let k = req_key!("k");
                let stride = pos_key!("stride", k);
                let padding = int_key!("padding", 0);
                if cur.h + 2 * padding < k || cur.w + 2 * padding < k {
                    return Err(format!(
                        "line {block_line}: layer {i} pool window {k} exceeds padded input \
                         {}x{}",
                        cur.h + 2 * padding,
                        cur.w + 2 * padding
                    ));
                }
                if ty == "maxpool" {
                    LayerKind::MaxPool { k, stride, padding }
                } else {
                    LayerKind::AvgPool { k, stride, padding }
                }
            }
            "gap" => LayerKind::GlobalAvgPool,
            "relu" => LayerKind::Relu,
            "sigmoid" => LayerKind::Sigmoid,
            "gelu" => LayerKind::Gelu,
            "layernorm" => LayerKind::LayerNorm,
            "attention" | "causal_attention" => {
                let heads = req_key!("heads");
                let dim = int_key!("dim", cur.c);
                if dim != cur.c {
                    return Err(format!(
                        "line {block_line}: layer {i} attention dim {dim} != input channels {}",
                        cur.c
                    ));
                }
                if dim % heads != 0 {
                    return Err(format!(
                        "line {block_line}: layer {i} attention heads {heads} must divide \
                         dim {dim}"
                    ));
                }
                if ty == "causal_attention" {
                    LayerKind::CausalAttention { heads, dim }
                } else {
                    LayerKind::Attention { heads, dim }
                }
            }
            "matmul" => LayerKind::Matmul { out_features: req_key!("out_features") },
            "embedding" => LayerKind::Embedding { vocab: req_key!("vocab"), dim: req_key!("dim") },
            "tied_unembed" => LayerKind::TiedUnembed { vocab: req_key!("vocab") },
            "residual" => LayerKind::ResidualAdd { from: from_ref!() },
            "concat" => LayerKind::Concat { from: from_ref!() },
            other => {
                return Err(format!(
                    "line {block_line}: layer {i} has unknown type '{other}' \
                     (conv|fc|maxpool|avgpool|gap|relu|sigmoid|gelu|layernorm|attention|\
                     causal_attention|matmul|embedding|tied_unembed|residual|concat)"
                ))
            }
        };
        let lname = str_key!("name", format!("{ty}{i}"));
        if lname == "input" {
            return Err(format!(
                "line {block_line}: layer {i} may not be named 'input' — the name is \
                 reserved for `from_shape_of = \"input\"` (the network input)"
            ));
        }

        let ifm = cur;
        let mut ofm = infer_ofm(&kind, ifm);
        if let LayerKind::Concat { from } = kind {
            ofm.c = ifm.c + layers[from].ofm.c;
        }
        layers.push(Layer { name: lname, kind, ifm, ofm });
        cur = ofm;
    }

    // any key not consumed above is a typo — report it with its line
    if let Some((k, (_, line))) = m.iter().next() {
        return Err(format!("line {line}: unknown key '{k}' in model file"));
    }

    let dnn = Dnn { name, dataset, input, layers, source: ModelSource::Builtin };
    dnn.check().map_err(|e| format!("inconsistent network: {e}"))?;
    Ok(dnn)
}

/// Resolve a layer reference: an integer index or the name of an
/// earlier layer (the last layer with that name wins, matching how
/// builders shadow names).
fn resolve_ref(v: &Value, layers: &[Layer]) -> Result<usize, String> {
    match v {
        Value::Int(i) if *i >= 0 && (*i as usize) < layers.len() => Ok(*i as usize),
        Value::Int(i) => Err(format!(
            "index {i} out of range (must reference one of the {} earlier layers)",
            layers.len()
        )),
        Value::Str(s) => layers
            .iter()
            .rposition(|l| l.name == *s)
            .ok_or_else(|| format!("references '{s}', which names no earlier layer")),
        _ => Err("must be an integer index or an earlier layer's name".into()),
    }
}

/// Serialize a graph to the network-file format. Works for every graph
/// whose branches are expressible as `from_shape_of` restarts — all zoo
/// builtins included; errors if a layer's input shape matches neither
/// the running chain, the network input, nor any earlier layer's
/// output, or if a name contains a character the quote-verbatim TOML
/// subset cannot carry (`"` or a newline).
///
/// # Examples
///
/// The export/parse round trip reproduces any builtin layer-for-layer:
///
/// ```
/// let dnn = siam::dnn::build_model("vit_tiny", "imagenet").unwrap();
/// let text = siam::dnn::to_model_toml(&dnn).unwrap();
/// let back = siam::dnn::parse_model_str(&text).unwrap();
/// assert!(dnn.same_graph(&back));
/// ```
pub fn to_model_toml(dnn: &Dnn) -> Result<String, String> {
    use std::fmt::Write;
    // the TOML subset carries strings verbatim between double quotes
    // (no escapes), so names containing a quote or a newline have no
    // serialization — refuse rather than emit text that cannot re-parse
    let quotable = |what: &str, s: &str| -> Result<(), String> {
        if s.contains('"') || s.contains('\n') {
            Err(format!("{what} {s:?} contains a quote or newline and cannot serialize"))
        } else {
            Ok(())
        }
    };
    quotable("model name", &dnn.name)?;
    quotable("dataset", &dnn.dataset)?;
    for l in &dnn.layers {
        quotable("layer name", &l.name)?;
        if l.name == "input" {
            return Err(
                "layer name 'input' is reserved by the file format (from_shape_of)".into(),
            );
        }
    }
    let mut s = String::new();
    writeln!(s, "[model]").unwrap();
    writeln!(s, "name = \"{}\"", dnn.name).unwrap();
    writeln!(s, "dataset = \"{}\"", dnn.dataset).unwrap();
    writeln!(s, "input = [{}, {}, {}]", dnn.input.h, dnn.input.w, dnn.input.c).unwrap();
    let mut cur = dnn.input;
    for (i, l) in dnn.layers.iter().enumerate() {
        writeln!(s, "\n[[layer]]").unwrap();
        let ty = match l.kind {
            LayerKind::Conv { .. } => "conv",
            LayerKind::Fc { .. } => "fc",
            LayerKind::MaxPool { .. } => "maxpool",
            LayerKind::AvgPool { .. } => "avgpool",
            LayerKind::GlobalAvgPool => "gap",
            LayerKind::Relu => "relu",
            LayerKind::Sigmoid => "sigmoid",
            LayerKind::Gelu => "gelu",
            LayerKind::LayerNorm => "layernorm",
            LayerKind::Attention { .. } => "attention",
            LayerKind::CausalAttention { .. } => "causal_attention",
            LayerKind::Matmul { .. } => "matmul",
            LayerKind::Embedding { .. } => "embedding",
            LayerKind::TiedUnembed { .. } => "tied_unembed",
            LayerKind::ResidualAdd { .. } => "residual",
            LayerKind::Concat { .. } => "concat",
        };
        writeln!(s, "type = \"{ty}\"").unwrap();
        writeln!(s, "name = \"{}\"", l.name).unwrap();
        if l.ifm != cur {
            if l.ifm == dnn.input {
                writeln!(s, "from_shape_of = \"input\"").unwrap();
            } else {
                let j = dnn.layers[..i]
                    .iter()
                    .rposition(|e| e.ofm == l.ifm)
                    .ok_or_else(|| {
                        format!(
                            "layer {i} ({}) input {:?} matches no earlier output",
                            l.name, l.ifm
                        )
                    })?;
                writeln!(s, "from_shape_of = {j}").unwrap();
            }
        }
        match l.kind {
            LayerKind::Conv { kh, kw, stride, padding, out_ch } => {
                if kh == kw {
                    writeln!(s, "k = {kh}").unwrap();
                } else {
                    writeln!(s, "kh = {kh}").unwrap();
                    writeln!(s, "kw = {kw}").unwrap();
                }
                writeln!(s, "stride = {stride}").unwrap();
                writeln!(s, "padding = {padding}").unwrap();
                writeln!(s, "out_channels = {out_ch}").unwrap();
            }
            LayerKind::Fc { out_features } | LayerKind::Matmul { out_features } => {
                writeln!(s, "out_features = {out_features}").unwrap();
            }
            LayerKind::MaxPool { k, stride, padding }
            | LayerKind::AvgPool { k, stride, padding } => {
                writeln!(s, "k = {k}").unwrap();
                writeln!(s, "stride = {stride}").unwrap();
                writeln!(s, "padding = {padding}").unwrap();
            }
            LayerKind::Attention { heads, .. } | LayerKind::CausalAttention { heads, .. } => {
                writeln!(s, "heads = {heads}").unwrap()
            }
            LayerKind::Embedding { vocab, dim } => {
                writeln!(s, "vocab = {vocab}").unwrap();
                writeln!(s, "dim = {dim}").unwrap();
            }
            LayerKind::TiedUnembed { vocab } => writeln!(s, "vocab = {vocab}").unwrap(),
            LayerKind::ResidualAdd { from } | LayerKind::Concat { from } => {
                writeln!(s, "from = {from}").unwrap();
            }
            _ => {}
        }
        cur = l.ofm;
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::build_model;

    const MINI: &str = r#"
# a hand-written hybrid network
[model]
name = "mini_hybrid"
dataset = "cifar10"
input = [32, 32, 3]

[[layer]]
type = "conv"
name = "patch"
k = 8
stride = 8
out_channels = 32          # -> 4x4x32, a 16-token sequence

[[layer]]
type = "layernorm"

[[layer]]
type = "attention"
heads = 4

[[layer]]
type = "residual"
from = "patch"

[[layer]]
type = "conv"
name = "mlp"
k = 1
out_channels = 64

[[layer]]
type = "gelu"

[[layer]]
type = "gap"

[[layer]]
type = "fc"
out_features = 10
"#;

    #[test]
    fn parses_shapes_and_defaults() {
        let dnn = parse_model_str(MINI).unwrap();
        assert_eq!(dnn.name, "mini_hybrid");
        assert_eq!(dnn.dataset, "cifar10");
        assert_eq!(dnn.layers.len(), 8);
        assert_eq!(dnn.layers[0].ofm, TensorShape::new(4, 4, 32));
        // default names carry the type + ordinal
        assert_eq!(dnn.layers[1].name, "layernorm1");
        // attention picked up dim from the running channel count
        assert_eq!(dnn.layers[2].kind, LayerKind::Attention { heads: 4, dim: 32 });
        // residual resolved by name
        assert_eq!(dnn.layers[3].kind, LayerKind::ResidualAdd { from: 0 });
        assert_eq!(dnn.layers[7].ofm, TensorShape::new(1, 1, 10));
        assert!(dnn.check().is_ok());
        assert!(dnn.stats().params > 0);
    }

    #[test]
    fn errors_carry_line_numbers() {
        // unknown key inside a layer block
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"conv\"\nk = 3\nout_chans = 4\n",
        )
        .unwrap_err();
        assert!(err.contains("line 7"), "{err}");
        // missing required key names the block's header line
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"conv\"\nk = 3\n",
        )
        .unwrap_err();
        assert!(err.contains("out_channels"), "{err}");
        assert!(err.contains("line 4"), "{err}");
        // unknown type
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"blur\"\n",
        )
        .unwrap_err();
        assert!(err.contains("unknown type 'blur'"), "{err}");
        // bad skip reference
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"residual\"\nfrom = \"nope\"\n",
        )
        .unwrap_err();
        assert!(err.contains("'nope'"), "{err}");
        // attention heads must divide channels
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [4, 4, 10]\n[[layer]]\ntype = \"attention\"\nheads = 3\n",
        )
        .unwrap_err();
        assert!(err.contains("must divide"), "{err}");
        // oversized kernel caught before shape inference underflows
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [4, 4, 3]\n[[layer]]\ntype = \"conv\"\nk = 7\nout_channels = 4\n",
        )
        .unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
        // missing header keys
        let err = parse_model_str("[[layer]]\ntype = \"relu\"\n").unwrap_err();
        assert!(err.contains("model.name"), "{err}");
    }

    #[test]
    fn zero_values_rejected_with_their_own_line() {
        // an explicit stride = 0 is an error, not a silent clamp, and
        // the message points at the key's line, not the block header
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"conv\"\nk = 3\nout_channels = 4\nstride = 0\n",
        )
        .unwrap_err();
        assert!(err.contains("stride"), "{err}");
        assert!(err.contains("line 8"), "{err}");
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"conv\"\nk = 3\nout_channels = 0\n",
        )
        .unwrap_err();
        assert!(err.contains("line 7"), "{err}");
    }

    #[test]
    fn unserializable_names_refused() {
        let mut dnn = parse_model_str(MINI).unwrap();
        dnn.layers[0].name = "pa\"tch".into();
        let err = to_model_toml(&dnn).unwrap_err();
        assert!(err.contains("quote"), "{err}");
        // "input" is reserved for from_shape_of, both ways
        let mut dnn = parse_model_str(MINI).unwrap();
        dnn.layers[0].name = "input".into();
        assert!(to_model_toml(&dnn).unwrap_err().contains("reserved"));
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [8, 8, 3]\n[[layer]]\ntype = \"relu\"\nname = \"input\"\n",
        )
        .unwrap_err();
        assert!(err.contains("reserved"), "{err}");
    }

    #[test]
    fn mismatched_residual_in_file_rejected() {
        // a pool between a layer and its residual source changes the
        // shape — the frontend reports it instead of simulating garbage
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [16, 16, 8]\n\
             [[layer]]\ntype = \"conv\"\nname = \"c\"\nk = 3\npadding = 1\nout_channels = 8\n\
             [[layer]]\ntype = \"maxpool\"\nk = 2\n\
             [[layer]]\ntype = \"residual\"\nfrom = \"c\"\n",
        )
        .unwrap_err();
        assert!(err.contains("incompatible"), "{err}");
    }

    #[test]
    fn decoder_kinds_parse_and_roundtrip() {
        let text = "[model]\nname = \"mini_dec\"\ninput = [1, 16, 1]\n\
             [[layer]]\ntype = \"embedding\"\nname = \"wte\"\nvocab = 100\ndim = 32\n\
             [[layer]]\ntype = \"causal_attention\"\nheads = 4\n\
             [[layer]]\ntype = \"tied_unembed\"\nvocab = 100\n";
        let dnn = parse_model_str(text).unwrap();
        assert_eq!(
            dnn.layers[1].kind,
            LayerKind::CausalAttention { heads: 4, dim: 32 }
        );
        assert_eq!(dnn.layers[2].kind, LayerKind::TiedUnembed { vocab: 100 });
        assert_eq!(dnn.layers[2].ofm.c, 100);
        // tied: only the embedding table counts parameters
        assert_eq!(dnn.stats().params, 100 * 32);
        let back = parse_model_str(&to_model_toml(&dnn).unwrap()).unwrap();
        assert!(dnn.same_graph(&back));
        // causal attention enforces the same head/dim rules
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [1, 4, 10]\n[[layer]]\ntype = \"causal_attention\"\nheads = 3\n",
        )
        .unwrap_err();
        assert!(err.contains("must divide"), "{err}");
        // tied_unembed requires vocab
        let err = parse_model_str(
            "[model]\nname = \"m\"\ninput = [1, 4, 8]\n[[layer]]\ntype = \"tied_unembed\"\n",
        )
        .unwrap_err();
        assert!(err.contains("vocab"), "{err}");
    }

    #[test]
    fn round_trips_itself() {
        let a = parse_model_str(MINI).unwrap();
        let text = to_model_toml(&a).unwrap();
        let b = parse_model_str(&text).unwrap();
        assert!(a.same_graph(&b), "round trip changed the graph");
    }

    #[test]
    fn round_trips_every_zoo_builtin() {
        // self-hosting: any builtin exports to the file format and
        // parses back layer-for-layer (projection shortcuts ride on
        // from_shape_of restarts)
        for name in crate::dnn::zoo_names() {
            let ds = crate::dnn::default_dataset(name);
            let a = build_model(name, ds).unwrap();
            let text = to_model_toml(&a)
                .unwrap_or_else(|e| panic!("{name} does not serialize: {e}"));
            let b = parse_model_str(&text)
                .unwrap_or_else(|e| panic!("{name} round trip failed: {e}"));
            assert!(a.same_graph(&b), "{name} round trip changed the graph");
        }
    }

    #[test]
    fn load_model_file_stamps_provenance() {
        let dir = std::env::temp_dir().join("siam_file_model_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mini_hybrid.toml");
        std::fs::write(&path, MINI).unwrap();
        let dnn = load_model_file(&path).unwrap();
        match &dnn.source {
            ModelSource::File { path: p, fingerprint } => {
                assert!(p.ends_with("mini_hybrid.toml"));
                assert_eq!(*fingerprint, super::content_fingerprint(MINI));
                assert!(dnn.source.describe().starts_with("file:"));
            }
            other => panic!("expected file provenance, got {other:?}"),
        }
        assert!(load_model_file(dir.join("missing.toml")).is_err());
    }

    #[test]
    fn fingerprint_tracks_content() {
        assert_ne!(content_fingerprint("a"), content_fingerprint("b"));
        assert_eq!(content_fingerprint(MINI), content_fingerprint(MINI));
    }
}
