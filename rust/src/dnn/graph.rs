//! The DNN graph: an ordered layer list with skip-edges (residual /
//! concat `from` indices), plus a builder that performs shape inference.

use super::layer::{infer_ofm, Layer, LayerKind, TensorShape};
use super::stats::DnnStats;

/// A DNN workload: layers in topological (execution) order. Branches are
/// encoded as `ResidualAdd { from }` / `Concat { from }` layers referring
/// back to earlier layer indices, which is sufficient for the chain-with-
/// skips topologies of the evaluated networks and keeps the mapping
/// engine's sequential-packing semantics identical to the paper's.
#[derive(Debug, Clone)]
pub struct Dnn {
    /// Model name (zoo key).
    pub name: String,
    /// Dataset variant the shapes were built for.
    pub dataset: String,
    /// Network input shape.
    pub input: TensorShape,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl Dnn {
    /// Aggregate parameter/MAC/buffer statistics.
    pub fn stats(&self) -> DnnStats {
        DnnStats::of(self)
    }

    /// Indices of weight-bearing layers (the ones mapped to crossbars).
    pub fn weight_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_weight_layer())
            .map(|(i, _)| i)
            .collect()
    }

    /// Check internal consistency: shape chain and skip-edge targets.
    ///
    /// Branch layers (e.g. projection shortcuts) may read an *earlier*
    /// layer's output instead of the immediately preceding one, so a
    /// layer's ifm must match either the previous ofm or some earlier
    /// layer's ofm (or the network input).
    pub fn check(&self) -> Result<(), String> {
        let mut prev = self.input;
        for (i, l) in self.layers.iter().enumerate() {
            if l.ifm != prev {
                let feeds = self.input == l.ifm
                    || self.layers[..i].iter().any(|e| e.ofm == l.ifm);
                if !feeds {
                    return Err(format!(
                        "layer {i} ({}) ifm {:?} matches neither previous ofm {:?} nor any earlier layer",
                        l.name, l.ifm, prev
                    ));
                }
            }
            match l.kind {
                LayerKind::ResidualAdd { from } | LayerKind::Concat { from } => {
                    if from >= i {
                        return Err(format!(
                            "layer {i} ({}) skip-edge from {from} is not earlier",
                            l.name
                        ));
                    }
                }
                _ => {}
            }
            prev = l.ofm;
        }
        Ok(())
    }
}

/// Builder with running shape inference.
pub struct DnnBuilder {
    name: String,
    dataset: String,
    input: TensorShape,
    cur: TensorShape,
    pub(crate) layers: Vec<Layer>,
}

impl DnnBuilder {
    /// Start a graph with the given input shape.
    pub fn new(name: &str, dataset: &str, input: (usize, usize, usize)) -> Self {
        let input = TensorShape::new(input.0, input.1, input.2);
        DnnBuilder {
            name: name.into(),
            dataset: dataset.into(),
            input,
            cur: input,
            layers: Vec::new(),
        }
    }

    /// Current output shape (for builders that need to branch).
    pub fn shape(&self) -> TensorShape {
        self.cur
    }

    /// Index of the most recently added layer.
    pub fn last_index(&self) -> usize {
        self.layers.len() - 1
    }

    /// Append a layer, inferring its output shape; returns its index.
    pub fn push(&mut self, name: impl Into<String>, kind: LayerKind) -> usize {
        let ifm = self.cur;
        let mut ofm = infer_ofm(&kind, ifm);
        if let LayerKind::Concat { from } = kind {
            ofm.c = ifm.c + self.layers[from].ofm.c;
        }
        self.layers.push(Layer {
            name: name.into(),
            kind,
            ifm,
            ofm,
        });
        self.cur = ofm;
        self.layers.len() - 1
    }

    /// Append a square convolution.
    pub fn conv(
        &mut self,
        name: impl Into<String>,
        k: usize,
        stride: usize,
        padding: usize,
        out_ch: usize,
    ) -> usize {
        self.push(
            name,
            LayerKind::Conv {
                kh: k,
                kw: k,
                stride,
                padding,
                out_ch,
            },
        )
    }

    /// Append a ReLU.
    pub fn relu(&mut self, name: impl Into<String>) -> usize {
        self.push(name, LayerKind::Relu)
    }

    /// Append an unpadded max pool.
    pub fn maxpool(&mut self, name: impl Into<String>, k: usize, stride: usize) -> usize {
        self.push(name, LayerKind::MaxPool { k, stride, padding: 0 })
    }

    /// Append a padded max pool.
    pub fn maxpool_pad(
        &mut self,
        name: impl Into<String>,
        k: usize,
        stride: usize,
        padding: usize,
    ) -> usize {
        self.push(name, LayerKind::MaxPool { k, stride, padding })
    }

    /// Append an average pool.
    pub fn avgpool(&mut self, name: impl Into<String>, k: usize, stride: usize) -> usize {
        self.push(name, LayerKind::AvgPool { k, stride, padding: 0 })
    }

    /// Append a global average pool.
    pub fn global_avgpool(&mut self, name: impl Into<String>) -> usize {
        self.push(name, LayerKind::GlobalAvgPool)
    }

    /// Append a fully-connected layer.
    pub fn fc(&mut self, name: impl Into<String>, out_features: usize) -> usize {
        self.push(name, LayerKind::Fc { out_features })
    }

    /// Append a residual add reading layer `from`.
    pub fn residual_add(&mut self, name: impl Into<String>, from: usize) -> usize {
        self.push(name, LayerKind::ResidualAdd { from })
    }

    /// Append a channel concat reading layer `from`.
    pub fn concat(&mut self, name: impl Into<String>, from: usize) -> usize {
        self.push(name, LayerKind::Concat { from })
    }

    /// Force the current shape (used for projection-shortcut bookkeeping
    /// where the skip path is itself a conv recorded earlier).
    pub fn set_shape(&mut self, s: TensorShape) {
        self.cur = s;
    }

    /// Finish and consistency-check the graph (panics on builder bugs).
    pub fn build(self) -> Dnn {
        let dnn = Dnn {
            name: self.name,
            dataset: self.dataset,
            input: self.input,
            layers: self.layers,
        };
        if let Err(e) = dnn.check() {
            panic!("DnnBuilder produced an inconsistent graph: {e}");
        }
        dnn
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_shapes() {
        let mut b = DnnBuilder::new("tiny", "cifar10", (32, 32, 3));
        b.conv("c1", 3, 1, 1, 16);
        b.relu("r1");
        b.maxpool("p1", 2, 2);
        b.fc("fc", 10);
        let dnn = b.build();
        assert_eq!(dnn.layers.len(), 4);
        assert_eq!(dnn.layers[2].ofm, TensorShape::new(16, 16, 16));
        assert_eq!(dnn.layers[3].ofm, TensorShape::new(1, 1, 10));
        assert!(dnn.check().is_ok());
    }

    #[test]
    fn concat_adds_channels() {
        let mut b = DnnBuilder::new("d", "cifar10", (8, 8, 4));
        let a = b.conv("c1", 3, 1, 1, 4); // ofm c=4
        b.conv("c2", 3, 1, 1, 6);
        b.concat("cat", a);
        let dnn = b.build();
        assert_eq!(dnn.layers[2].ofm.c, 10);
    }

    #[test]
    fn weight_layers_listed() {
        let mut b = DnnBuilder::new("t", "cifar10", (32, 32, 3));
        b.conv("c", 3, 1, 1, 8);
        b.relu("r");
        b.fc("f", 10);
        let dnn = b.build();
        assert_eq!(dnn.weight_layers(), vec![0, 2]);
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn bad_skip_edge_panics() {
        let mut b = DnnBuilder::new("bad", "cifar10", (8, 8, 3));
        b.conv("c", 3, 1, 1, 3);
        // Manually corrupt: residual from a future layer
        b.layers.push(Layer {
            name: "res".into(),
            kind: LayerKind::ResidualAdd { from: 99 },
            ifm: b.cur,
            ofm: b.cur,
        });
        b.build();
    }
}
