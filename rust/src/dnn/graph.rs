//! The DNN graph: an ordered layer list with skip-edges (residual /
//! concat `from` indices), plus a builder that performs shape inference.

use super::layer::{infer_ofm, Layer, LayerKind, TensorShape};
use super::stats::DnnStats;

/// Where a [`Dnn`] graph came from: a built-in zoo builder or a
/// user-authored network file (see [`crate::dnn::load_model_file`]).
/// Reports and sweep artifacts carry this so results stay reproducible —
/// a file model is identified by its path *and* a fingerprint of its
/// content at load time.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ModelSource {
    /// A zoo builder (`build_model` registry entry).
    #[default]
    Builtin,
    /// A `file:` model description.
    File {
        /// Path the file was loaded from.
        path: String,
        /// FNV-1a fingerprint of the file content at load time.
        fingerprint: u64,
    },
}

impl ModelSource {
    /// Stable one-token description for reports and JSON artifacts:
    /// `"builtin"`, or `"file:<path>#<fingerprint as 16 hex digits>"`.
    pub fn describe(&self) -> String {
        match self {
            ModelSource::Builtin => "builtin".into(),
            ModelSource::File { path, fingerprint } => format!("file:{path}#{fingerprint:016x}"),
        }
    }
}

/// A DNN workload: layers in topological (execution) order. Branches are
/// encoded as `ResidualAdd { from }` / `Concat { from }` layers referring
/// back to earlier layer indices, which is sufficient for the chain-with-
/// skips topologies of the evaluated networks and keeps the mapping
/// engine's sequential-packing semantics identical to the paper's.
#[derive(Debug, Clone)]
pub struct Dnn {
    /// Model name (zoo key or the file's `[model] name`).
    pub name: String,
    /// Dataset variant the shapes were built for.
    pub dataset: String,
    /// Network input shape.
    pub input: TensorShape,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
    /// Provenance of the graph (builtin builder vs network file).
    pub source: ModelSource,
}

impl Dnn {
    /// Aggregate parameter/MAC/buffer statistics.
    pub fn stats(&self) -> DnnStats {
        DnnStats::of(self)
    }

    /// Structural equality — same name, dataset, input and
    /// layer-for-layer identical (name, kind, shapes) — ignoring the
    /// provenance tag. Two graphs that are `same_graph` produce
    /// bit-identical results through the whole pipeline under one
    /// configuration; this is what the builtin-vs-file bit-identity
    /// tests assert on.
    pub fn same_graph(&self, other: &Dnn) -> bool {
        self.name == other.name
            && self.dataset == other.dataset
            && self.input == other.input
            && self.layers.len() == other.layers.len()
            && self
                .layers
                .iter()
                .zip(&other.layers)
                .all(|(a, b)| {
                    a.name == b.name && a.kind == b.kind && a.ifm == b.ifm && a.ofm == b.ofm
                })
    }

    /// Indices of weight-bearing layers (the ones mapped to crossbars).
    pub fn weight_layers(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_weight_layer())
            .map(|(i, _)| i)
            .collect()
    }

    /// Check internal consistency: shape chain and skip-edge targets.
    ///
    /// Branch layers (e.g. projection shortcuts) may read an *earlier*
    /// layer's output instead of the immediately preceding one, so a
    /// layer's ifm must match either the previous ofm or some earlier
    /// layer's ofm (or the network input).
    pub fn check(&self) -> Result<(), String> {
        let mut prev = self.input;
        for (i, l) in self.layers.iter().enumerate() {
            if l.ifm != prev {
                let feeds = self.input == l.ifm
                    || self.layers[..i].iter().any(|e| e.ofm == l.ifm);
                if !feeds {
                    return Err(format!(
                        "layer {i} ({}) ifm {:?} matches neither previous ofm {:?} nor any earlier layer",
                        l.name, l.ifm, prev
                    ));
                }
            }
            match l.kind {
                LayerKind::ResidualAdd { from } | LayerKind::Concat { from } => {
                    if from >= i {
                        return Err(format!(
                            "layer {i} ({}) skip-edge from {from} is not earlier",
                            l.name
                        ));
                    }
                    let src = self.layers[from].ofm;
                    let shape_ok = match l.kind {
                        // elementwise add needs the full shape to agree
                        LayerKind::ResidualAdd { .. } => src == l.ifm,
                        // channel concat needs matching spatial dims
                        _ => src.h == l.ifm.h && src.w == l.ifm.w,
                    };
                    if !shape_ok {
                        return Err(format!(
                            "layer {i} ({}) skip-edge source {from} has shape {src:?}, \
                             incompatible with input {:?}",
                            l.name, l.ifm
                        ));
                    }
                }
                LayerKind::Attention { heads, dim }
                | LayerKind::CausalAttention { heads, dim } => {
                    if dim != l.ifm.c {
                        return Err(format!(
                            "layer {i} ({}) attention dim {dim} != input channels {}",
                            l.name, l.ifm.c
                        ));
                    }
                    if heads == 0 || dim % heads != 0 {
                        return Err(format!(
                            "layer {i} ({}) attention heads {heads} must divide dim {dim}",
                            l.name
                        ));
                    }
                }
                LayerKind::TiedUnembed { vocab } => {
                    if vocab == 0 {
                        return Err(format!(
                            "layer {i} ({}) tied_unembed vocab must be >= 1",
                            l.name
                        ));
                    }
                }
                LayerKind::Matmul { out_features } => {
                    if out_features == 0 {
                        return Err(format!(
                            "layer {i} ({}) matmul out_features must be >= 1",
                            l.name
                        ));
                    }
                }
                LayerKind::Embedding { vocab, dim } => {
                    if vocab == 0 || dim == 0 {
                        return Err(format!(
                            "layer {i} ({}) embedding vocab and dim must be >= 1",
                            l.name
                        ));
                    }
                }
                _ => {}
            }
            prev = l.ofm;
        }
        Ok(())
    }
}

/// Builder with running shape inference.
pub struct DnnBuilder {
    name: String,
    dataset: String,
    input: TensorShape,
    cur: TensorShape,
    pub(crate) layers: Vec<Layer>,
}

impl DnnBuilder {
    /// Start a graph with the given input shape.
    pub fn new(name: &str, dataset: &str, input: (usize, usize, usize)) -> Self {
        let input = TensorShape::new(input.0, input.1, input.2);
        DnnBuilder {
            name: name.into(),
            dataset: dataset.into(),
            input,
            cur: input,
            layers: Vec::new(),
        }
    }

    /// Current output shape (for builders that need to branch).
    pub fn shape(&self) -> TensorShape {
        self.cur
    }

    /// Index of the most recently added layer.
    pub fn last_index(&self) -> usize {
        self.layers.len() - 1
    }

    /// Append a layer, inferring its output shape; returns its index.
    pub fn push(&mut self, name: impl Into<String>, kind: LayerKind) -> usize {
        let ifm = self.cur;
        let mut ofm = infer_ofm(&kind, ifm);
        if let LayerKind::Concat { from } = kind {
            ofm.c = ifm.c + self.layers[from].ofm.c;
        }
        self.layers.push(Layer {
            name: name.into(),
            kind,
            ifm,
            ofm,
        });
        self.cur = ofm;
        self.layers.len() - 1
    }

    /// Append a square convolution.
    pub fn conv(
        &mut self,
        name: impl Into<String>,
        k: usize,
        stride: usize,
        padding: usize,
        out_ch: usize,
    ) -> usize {
        self.push(
            name,
            LayerKind::Conv {
                kh: k,
                kw: k,
                stride,
                padding,
                out_ch,
            },
        )
    }

    /// Append a ReLU.
    pub fn relu(&mut self, name: impl Into<String>) -> usize {
        self.push(name, LayerKind::Relu)
    }

    /// Append an unpadded max pool.
    pub fn maxpool(&mut self, name: impl Into<String>, k: usize, stride: usize) -> usize {
        self.push(name, LayerKind::MaxPool { k, stride, padding: 0 })
    }

    /// Append a padded max pool.
    pub fn maxpool_pad(
        &mut self,
        name: impl Into<String>,
        k: usize,
        stride: usize,
        padding: usize,
    ) -> usize {
        self.push(name, LayerKind::MaxPool { k, stride, padding })
    }

    /// Append an average pool.
    pub fn avgpool(&mut self, name: impl Into<String>, k: usize, stride: usize) -> usize {
        self.push(name, LayerKind::AvgPool { k, stride, padding: 0 })
    }

    /// Append a global average pool.
    pub fn global_avgpool(&mut self, name: impl Into<String>) -> usize {
        self.push(name, LayerKind::GlobalAvgPool)
    }

    /// Append a fully-connected layer.
    pub fn fc(&mut self, name: impl Into<String>, out_features: usize) -> usize {
        self.push(name, LayerKind::Fc { out_features })
    }

    /// Append a multi-head self-attention block over the current
    /// sequence (`dim` = current channel count).
    pub fn attention(&mut self, name: impl Into<String>, heads: usize) -> usize {
        let dim = self.cur.c;
        self.push(name, LayerKind::Attention { heads, dim })
    }

    /// Append a causally-masked self-attention block over the current
    /// sequence (`dim` = current channel count) — decoder blocks.
    pub fn causal_attention(&mut self, name: impl Into<String>, heads: usize) -> usize {
        let dim = self.cur.c;
        self.push(name, LayerKind::CausalAttention { heads, dim })
    }

    /// Append a weight-tied unembedding projection onto `vocab` logits.
    pub fn tied_unembed(&mut self, name: impl Into<String>, vocab: usize) -> usize {
        self.push(name, LayerKind::TiedUnembed { vocab })
    }

    /// Append a layer normalization.
    pub fn layer_norm(&mut self, name: impl Into<String>) -> usize {
        self.push(name, LayerKind::LayerNorm)
    }

    /// Append a GELU activation.
    pub fn gelu(&mut self, name: impl Into<String>) -> usize {
        self.push(name, LayerKind::Gelu)
    }

    /// Append a dynamic activation×activation matmul.
    pub fn matmul(&mut self, name: impl Into<String>, out_features: usize) -> usize {
        self.push(name, LayerKind::Matmul { out_features })
    }

    /// Append an embedding lookup / positional-embedding add.
    pub fn embedding(&mut self, name: impl Into<String>, vocab: usize, dim: usize) -> usize {
        self.push(name, LayerKind::Embedding { vocab, dim })
    }

    /// Append a residual add reading layer `from`.
    pub fn residual_add(&mut self, name: impl Into<String>, from: usize) -> usize {
        self.push(name, LayerKind::ResidualAdd { from })
    }

    /// Append a channel concat reading layer `from`.
    pub fn concat(&mut self, name: impl Into<String>, from: usize) -> usize {
        self.push(name, LayerKind::Concat { from })
    }

    /// Force the current shape (used for projection-shortcut bookkeeping
    /// where the skip path is itself a conv recorded earlier).
    pub fn set_shape(&mut self, s: TensorShape) {
        self.cur = s;
    }

    /// Finish and consistency-check the graph (panics on builder bugs).
    pub fn build(self) -> Dnn {
        let dnn = Dnn {
            name: self.name,
            dataset: self.dataset,
            input: self.input,
            layers: self.layers,
            source: ModelSource::Builtin,
        };
        if let Err(e) = dnn.check() {
            panic!("DnnBuilder produced an inconsistent graph: {e}");
        }
        dnn
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_shapes() {
        let mut b = DnnBuilder::new("tiny", "cifar10", (32, 32, 3));
        b.conv("c1", 3, 1, 1, 16);
        b.relu("r1");
        b.maxpool("p1", 2, 2);
        b.fc("fc", 10);
        let dnn = b.build();
        assert_eq!(dnn.layers.len(), 4);
        assert_eq!(dnn.layers[2].ofm, TensorShape::new(16, 16, 16));
        assert_eq!(dnn.layers[3].ofm, TensorShape::new(1, 1, 10));
        assert!(dnn.check().is_ok());
    }

    #[test]
    fn concat_adds_channels() {
        let mut b = DnnBuilder::new("d", "cifar10", (8, 8, 4));
        let a = b.conv("c1", 3, 1, 1, 4); // ofm c=4
        b.conv("c2", 3, 1, 1, 6);
        b.concat("cat", a);
        let dnn = b.build();
        assert_eq!(dnn.layers[2].ofm.c, 10);
    }

    #[test]
    fn weight_layers_listed() {
        let mut b = DnnBuilder::new("t", "cifar10", (32, 32, 3));
        b.conv("c", 3, 1, 1, 8);
        b.relu("r");
        b.fc("f", 10);
        let dnn = b.build();
        assert_eq!(dnn.weight_layers(), vec![0, 2]);
    }

    #[test]
    fn transformer_block_chains_shapes() {
        // one pre-norm encoder block on a 2x2 patch grid
        let mut b = DnnBuilder::new("xf", "custom", (2, 2, 16));
        let block_in = b.embedding("pos", 4, 16);
        b.layer_norm("ln1");
        b.attention("attn", 4);
        let a = b.residual_add("add1", block_in);
        b.layer_norm("ln2");
        b.conv("mlp_fc1", 1, 1, 0, 64);
        b.gelu("gelu");
        b.conv("mlp_fc2", 1, 1, 0, 16);
        b.residual_add("add2", a);
        let dnn = b.build();
        assert!(dnn.check().is_ok());
        assert_eq!(dnn.layers.last().unwrap().ofm, TensorShape::new(2, 2, 16));
        // attention + the two 1x1 MLP convs own crossbars
        assert_eq!(dnn.weight_layers().len(), 3);
        assert_eq!(dnn.source, super::ModelSource::Builtin);
    }

    #[test]
    fn attention_dim_mismatch_rejected() {
        let mut b = DnnBuilder::new("bad", "custom", (2, 2, 16));
        b.layers.push(Layer {
            name: "attn".into(),
            kind: LayerKind::Attention { heads: 2, dim: 32 },
            ifm: b.cur,
            ofm: b.cur,
        });
        let dnn = Dnn {
            name: "bad".into(),
            dataset: "custom".into(),
            input: TensorShape::new(2, 2, 16),
            layers: b.layers,
            source: super::ModelSource::Builtin,
        };
        let err = dnn.check().unwrap_err();
        assert!(err.contains("attention dim"), "{err}");
    }

    #[test]
    fn mismatched_skip_edge_shapes_rejected() {
        // an elementwise add whose source shape differs from its input
        // is inconsistent even when the index is legal
        let mut b = DnnBuilder::new("bad", "custom", (16, 16, 8));
        b.conv("c", 3, 1, 1, 8); // (16,16,8)
        b.maxpool("p", 2, 2); // (8,8,8)
        b.layers.push(Layer {
            name: "res".into(),
            kind: LayerKind::ResidualAdd { from: 0 },
            ifm: b.cur,
            ofm: b.cur,
        });
        let dnn = Dnn {
            name: "bad".into(),
            dataset: "custom".into(),
            input: TensorShape::new(16, 16, 8),
            layers: b.layers,
            source: super::ModelSource::Builtin,
        };
        let err = dnn.check().unwrap_err();
        assert!(err.contains("incompatible"), "{err}");
    }

    #[test]
    fn model_source_describes() {
        assert_eq!(super::ModelSource::Builtin.describe(), "builtin");
        let f = super::ModelSource::File { path: "m.toml".into(), fingerprint: 0xabc };
        assert_eq!(f.describe(), "file:m.toml#0000000000000abc");
    }

    #[test]
    #[should_panic(expected = "inconsistent")]
    fn bad_skip_edge_panics() {
        let mut b = DnnBuilder::new("bad", "cifar10", (8, 8, 3));
        b.conv("c", 3, 1, 1, 3);
        // Manually corrupt: residual from a future layer
        b.layers.push(Layer {
            name: "res".into(),
            kind: LayerKind::ResidualAdd { from: 99 },
            ifm: b.cur,
            ofm: b.cur,
        });
        b.build();
    }
}
