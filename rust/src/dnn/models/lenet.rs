//! LeNet-5 (the paper's smallest Fig. 1a workload).

use crate::dnn::graph::{Dnn, DnnBuilder};

/// LeNet-5: two 5×5 conv stages plus a 120-84-`classes` classifier.
pub fn lenet5(input: (usize, usize, usize), classes: usize) -> Dnn {
    let mut b = DnnBuilder::new("lenet5", "cifar10", input);
    b.conv("conv1", 5, 1, 0, 6);
    b.relu("relu1");
    b.avgpool("pool1", 2, 2);
    b.conv("conv2", 5, 1, 0, 16);
    b.relu("relu2");
    b.avgpool("pool2", 2, 2);
    b.fc("fc1", 120);
    b.relu("relu3");
    b.fc("fc2", 84);
    b.relu("relu4");
    b.fc("fc3", classes);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_shapes() {
        let d = lenet5((32, 32, 3), 10);
        // 32 -> 28 -> 14 -> 10 -> 5
        assert_eq!(d.layers[3].ofm.h, 10);
        assert_eq!(d.layers[5].ofm.h, 5);
        assert_eq!(d.layers[6].ifm.elems(), 400);
        assert_eq!(d.stats().weight_layers, 5);
    }
}
