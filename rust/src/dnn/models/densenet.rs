//! DenseNet (Huang et al.). Plain (non-BC) construction: 3 dense blocks
//! of (depth-4)/3 3×3 conv layers with growth rate `k`, transitions with
//! a 1×1 conv + 2×2 average pool, global average pool and FC classifier.
//!
//! The paper's "DenseNet-110 (28.1M)" corresponds to the L=100, k=24
//! configuration of the DenseNet paper (27.2M); we expose it as
//! `densenet110` and note the naming in DESIGN.md.

use crate::dnn::graph::{Dnn, DnnBuilder};

/// Plain DenseNet with `(depth-4)/3` conv layers per dense block and
/// growth rate `growth`.
pub fn densenet(depth: usize, growth: usize, input: (usize, usize, usize), classes: usize) -> Dnn {
    assert!((depth - 4) % 3 == 0, "densenet depth must be 3n+4");
    let per_block = (depth - 4) / 3;
    let mut b = DnnBuilder::new(&format!("densenet{depth}"), "cifar", input);
    b.conv("conv0", 3, 1, 1, 16);
    for blk in 0..3 {
        for i in 0..per_block {
            let stack = b.last_index();
            b.conv(format!("d{blk}_{i}_conv"), 3, 1, 1, growth);
            b.relu(format!("d{blk}_{i}_relu"));
            b.concat(format!("d{blk}_{i}_cat"), stack);
        }
        if blk < 2 {
            let ch = b.shape().c;
            b.conv(format!("t{blk}_conv"), 1, 1, 0, ch);
            b.relu(format!("t{blk}_relu"));
            b.avgpool(format!("t{blk}_pool"), 2, 2);
        }
    }
    b.global_avgpool("gap");
    b.fc("fc", classes);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densenet_growth() {
        let d = densenet(40, 12, (32, 32, 3), 10);
        // after block 0: 16 + 12*12 = 160 channels
        let t0 = d.layers.iter().find(|l| l.name == "t0_conv").unwrap();
        assert_eq!(t0.ifm.c, 160);
        // spatial: 32 -> 16 -> 8
        let gap = d.layers.iter().find(|l| l.name == "gap").unwrap();
        assert_eq!(gap.ifm.h, 8);
        assert_eq!(gap.ifm.c, 160 + 12 * 12 + 12 * 12);
    }

    #[test]
    fn densenet40_params_match_paper() {
        // DenseNet paper: L=40, k=12 => 1.0M params
        let p = densenet(40, 12, (32, 32, 3), 10).stats().params as f64;
        assert!((p - 1.0e6).abs() / 1.0e6 < 0.15, "params {p}");
    }

    #[test]
    fn densenet100_k24_params_match_paper() {
        // DenseNet paper: L=100, k=24 => 27.2M params
        let p = densenet(100, 24, (32, 32, 3), 10).stats().params as f64;
        assert!((p - 27.2e6).abs() / 27.2e6 < 0.15, "params {p}");
    }
}
