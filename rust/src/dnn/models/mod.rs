//! Model zoo: builders for every DNN the paper evaluates.

pub mod densenet;
pub mod drivenet;
pub mod lenet;
pub mod nin;
pub mod resnet;
pub mod vgg;
