//! Model zoo: builders for every DNN the paper evaluates, plus the
//! transformer workloads (ViT image encoders, BERT-class text encoder).

pub mod densenet;
pub mod drivenet;
pub mod lenet;
pub mod nin;
pub mod resnet;
pub mod transformer;
pub mod vgg;
