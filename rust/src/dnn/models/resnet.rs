//! ResNets: the CIFAR family (6n+2: ResNet-20/56/110) and ResNet-50
//! (ImageNet, bottleneck blocks, Caffe layer naming so the SIMBA
//! calibration experiment can address `res3a_branch1` and
//! `res5[a-c]_branch2b` — Fig. 14c/d of the paper).

use crate::dnn::graph::{Dnn, DnnBuilder};


/// CIFAR ResNet with `2n` conv layers per stage over 3 stages
/// (16/32/64 channels) plus stem and classifier: depth = 6n+2.
/// n=3 → ResNet-20, n=9 → ResNet-56, n=18 → ResNet-110.
pub fn resnet_cifar(n: usize, input: (usize, usize, usize), classes: usize) -> Dnn {
    let depth = 6 * n + 2;
    let mut b = DnnBuilder::new(&format!("resnet{depth}"), "cifar", input);
    b.conv("conv1", 3, 1, 1, 16);
    b.relu("relu1");
    let mut skip = b.last_index();
    for (stage, ch) in [(2usize, 16usize), (3, 32), (4, 64)] {
        for blk in 0..n {
            let first = blk == 0 && stage != 2;
            let stride = if first { 2 } else { 1 };
            let tag = format!("res{stage}_{blk}");
            b.conv(format!("{tag}_conv1"), 3, stride, 1, ch);
            b.relu(format!("{tag}_relu1"));
            b.conv(format!("{tag}_conv2"), 3, 1, 1, ch);
            if first {
                // projection shortcut: 1x1/2 conv from the skip point.
                // Builder is a chain, so record the projection as a layer
                // reading the *block input* shape. We emulate the branch by
                // inserting it before the add and wiring the add to it.
                let main_out = b.shape();
                let block_in = b.layers[skip].ofm;
                b.set_shape(block_in);
                let proj = b.conv(format!("res{stage}a_branch1"), 1, 2, 0, ch);
                b.set_shape(main_out);
                b.residual_add(format!("{tag}_add"), proj);
            } else {
                b.residual_add(format!("{tag}_add"), skip);
            }
            b.relu(format!("{tag}_relu2"));
            skip = b.last_index();
        }
    }
    b.global_avgpool("gap");
    b.fc("fc", classes);
    b.build()
}

/// ResNet-50 (ImageNet): stem 7×7/2 + 3×3/2 max-pool, bottleneck stages
/// [3,4,6,3] with widths (64,128,256,512)×4, global average pool, FC-1000.
pub fn resnet50(input: (usize, usize, usize), classes: usize) -> Dnn {
    let mut b = DnnBuilder::new("resnet50", "imagenet", input);
    b.conv("conv1", 7, 2, 3, 64);
    b.relu("conv1_relu");
    b.maxpool_pad("pool1", 3, 2, 1);
    let mut skip = b.last_index();
    let stages: [(usize, usize, usize); 4] =
        [(2, 64, 3), (3, 128, 4), (4, 256, 6), (5, 512, 3)];
    for (stage, width, blocks) in stages {
        for blk in 0..blocks {
            let letter = (b'a' + blk as u8) as char;
            let tag = format!("res{stage}{letter}");
            let first = blk == 0;
            // conv4_x (caffe res3..res5) downsample at the first block of
            // stages 3..5; stage 2 keeps stride 1 after the max-pool.
            let stride = if first && stage != 2 { 2 } else { 1 };
            let out = width * 4;
            b.conv(format!("{tag}_branch2a"), 1, stride, 0, width);
            b.relu(format!("{tag}_branch2a_relu"));
            b.conv(format!("{tag}_branch2b"), 3, 1, 1, width);
            b.relu(format!("{tag}_branch2b_relu"));
            b.conv(format!("{tag}_branch2c"), 1, 1, 0, out);
            if first {
                let main_out = b.shape();
                let block_in = b.layers[skip].ofm;
                b.set_shape(block_in);
                let proj = b.conv(format!("res{stage}a_branch1"), 1, stride, 0, out);
                b.set_shape(main_out);
                b.residual_add(format!("{tag}_add"), proj);
            } else {
                b.residual_add(format!("{tag}_add"), skip);
            }
            b.relu(format!("{tag}_relu"));
            skip = b.last_index();
        }
    }
    b.global_avgpool("gap");
    b.fc("fc1000", classes);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::TensorShape;

    #[test]
    fn resnet110_shape_and_params() {
        let d = resnet_cifar(18, (32, 32, 3), 10);
        let s = d.stats();
        // 1 stem + 108 block convs + 2 projections + 1 fc = 112 weight layers
        assert_eq!(s.weight_layers, 112);
        let p = s.params as f64;
        assert!((p - 1.73e6).abs() / 1.73e6 < 0.05, "params {p}");
        assert!(d.check().is_ok());
    }

    #[test]
    fn resnet20_params() {
        let d = resnet_cifar(3, (32, 32, 3), 10);
        let p = d.stats().params as f64;
        assert!((p - 0.27e6).abs() / 0.27e6 < 0.1, "params {p}");
    }

    #[test]
    fn resnet50_params_and_names() {
        let d = resnet50((224, 224, 3), 1000);
        let p = d.stats().params as f64;
        // torchvision resnet50: 25.56M
        assert!((p - 25.5e6).abs() / 25.5e6 < 0.03, "params {p}");
        assert!(d.layers.iter().any(|l| l.name == "res3a_branch1"));
        assert!(d.layers.iter().any(|l| l.name == "res5a_branch2b"));
        assert!(d.layers.iter().any(|l| l.name == "res5c_branch2b"));
        // res3a_branch1 downsamples 56 -> 28
        let l = d.layers.iter().find(|l| l.name == "res3a_branch1").unwrap();
        assert_eq!(l.ofm.h, 28);
        assert_eq!(l.ofm.c, 512);
    }

    #[test]
    fn stage_spatial_sizes() {
        let d = resnet50((224, 224, 3), 1000);
        let at = |n: &str| d.layers.iter().find(|l| l.name == n).unwrap().ofm;
        assert_eq!(at("res2a_branch2b").h, 56);
        assert_eq!(at("res3a_branch2b").h, 28);
        assert_eq!(at("res4a_branch2b").h, 14);
        assert_eq!(at("res5a_branch2b").h, 7);
        assert_eq!(at("gap"), TensorShape::new(1, 1, 2048));
    }
}
