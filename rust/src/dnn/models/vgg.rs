//! VGG-16 / VGG-19 (Simonyan & Zisserman). Plan entries: `C(n)` conv3×3
//! with n output channels, `M` 2×2/2 max-pool. The classifier follows the
//! original 4096-4096-classes head; for CIFAR inputs the first FC sees a
//! 1×1×512 map.

use crate::dnn::graph::{Dnn, DnnBuilder};

/// One step of a VGG plan.
#[derive(Clone, Copy)]
pub enum P {
    /// 3×3 convolution with the given output channels.
    C(usize),
    /// 2×2 stride-2 max pool.
    M,
}

/// The 13-conv / 5-pool body of VGG-16.
pub const VGG16_PLAN: [P; 18] = [
    P::C(64),
    P::C(64),
    P::M,
    P::C(128),
    P::C(128),
    P::M,
    P::C(256),
    P::C(256),
    P::C(256),
    P::M,
    P::C(512),
    P::C(512),
    P::C(512),
    P::M,
    P::C(512),
    P::C(512),
    P::C(512),
    P::M,
];

/// The 16-conv / 5-pool body of VGG-19.
pub const VGG19_PLAN: [P; 21] = [
    P::C(64),
    P::C(64),
    P::M,
    P::C(128),
    P::C(128),
    P::M,
    P::C(256),
    P::C(256),
    P::C(256),
    P::C(256),
    P::M,
    P::C(512),
    P::C(512),
    P::C(512),
    P::C(512),
    P::M,
    P::C(512),
    P::C(512),
    P::C(512),
    P::C(512),
    P::M,
];

/// Build a VGG network from a plan plus the 4096-4096-`classes` head.
pub fn vgg(plan: &[P], input: (usize, usize, usize), classes: usize) -> Dnn {
    let name = if plan.len() == 18 { "vgg16" } else { "vgg19" };
    let mut b = DnnBuilder::new(name, "any", input);
    let (mut ci, mut pi) = (0usize, 0usize);
    for step in plan {
        match step {
            P::C(ch) => {
                ci += 1;
                b.conv(format!("conv{ci}"), 3, 1, 1, *ch);
                b.relu(format!("relu{ci}"));
            }
            P::M => {
                pi += 1;
                b.maxpool(format!("pool{pi}"), 2, 2);
            }
        }
    }
    b.fc("fc6", 4096);
    b.relu("relu_fc6");
    b.fc("fc7", 4096);
    b.relu("relu_fc7");
    b.fc("fc8", classes);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_imagenet_params() {
        let d = vgg(&VGG16_PLAN, (224, 224, 3), 1000);
        let p = d.stats().params as f64;
        // torchvision vgg16: 138.36M
        assert!((p - 138.36e6).abs() / 138.36e6 < 0.01, "params {p}");
    }

    #[test]
    fn vgg19_cifar_shapes() {
        let d = vgg(&VGG19_PLAN, (32, 32, 3), 100);
        // five pools: 32 -> 1
        let last_conv = d
            .layers
            .iter()
            .rev()
            .find(|l| l.name.starts_with("pool"))
            .unwrap();
        assert_eq!(last_conv.ofm.h, 1);
        assert_eq!(d.layers.last().unwrap().ofm.c, 100);
        assert_eq!(d.stats().weight_layers, 16 + 3);
    }
}
