//! Transformer builders: ViT image encoders (patch-embedding
//! convolution + pre-norm encoder blocks), a BERT-class text encoder
//! (token/positional embeddings + the same block structure), and a
//! GPT-2-class decoder (causal attention, weight-tied unembedding).
//!
//! Both express a token sequence of length `L` with hidden size `D` as
//! an `(h, w, c)` tensor with `h·w = L`, `c = D` (the patch grid for
//! ViT, `1×L` for BERT), so the entire mapping / circuit / interconnect
//! stack consumes them unchanged: attention projections and the 1×1-conv
//! MLP linears map onto crossbars like any conv/fc layer, while the
//! score matmuls, softmax, GELU and LayerNorm run on the digital side.
//!
//! Omitted relative to the reference implementations (documented so the
//! golden param tests read honestly): the ViT class token (we pool with
//! a global average instead, as DeiT-style models do), BERT's
//! token-type embeddings and pooler head. Both are < 1 % of parameters.
//! The GPT-2 decoder ties the unembedding projection to the token
//! embedding exactly as the reference does, so its [`crate::dnn::LayerKind::TiedUnembed`]
//! layer owns crossbars but contributes zero parameters — the 124.4M
//! golden count matches the published figure without adjustment.

use crate::dnn::graph::{Dnn, DnnBuilder};

/// A ViT-style encoder: `patch×patch`/`patch` embedding convolution,
/// learned positional embeddings, `depth` pre-norm encoder blocks of
/// width `dim` with `heads` attention heads and a 4× MLP, final
/// LayerNorm, global average pool and a linear classifier head.
pub fn vit(
    name: &str,
    depth: usize,
    dim: usize,
    heads: usize,
    patch: usize,
    input: (usize, usize, usize),
    classes: usize,
) -> Dnn {
    let mut b = DnnBuilder::new(name, "imagenet", input);
    b.conv("patch_embed", patch, patch, 0, dim);
    let grid = b.shape();
    b.embedding("pos_embed", grid.h * grid.w, dim);
    encoder_blocks(&mut b, depth, heads, dim);
    b.layer_norm("ln_final");
    b.global_avgpool("gap");
    b.fc("head", classes);
    b.build()
}

/// A BERT-class text encoder: token embedding (`vocab × dim`), learned
/// positional embeddings over `max_pos` positions, `depth` pre-norm
/// encoder blocks, final LayerNorm, mean pooling and a classifier head.
/// The input is a `1 × seq × 1` token-id sequence.
#[allow(clippy::too_many_arguments)]
pub fn bert_encoder(
    name: &str,
    depth: usize,
    dim: usize,
    heads: usize,
    vocab: usize,
    max_pos: usize,
    input: (usize, usize, usize),
    classes: usize,
) -> Dnn {
    let mut b = DnnBuilder::new(name, "seq128", input);
    b.embedding("tok_embed", vocab, dim);
    b.embedding("pos_embed", max_pos, dim);
    encoder_blocks(&mut b, depth, heads, dim);
    b.layer_norm("ln_final");
    b.global_avgpool("gap");
    b.fc("head", classes);
    b.build()
}

/// A GPT-2-class decoder: token embedding (`vocab × dim`), learned
/// positional embeddings over `max_pos` positions, `depth` pre-norm
/// decoder blocks (causal attention, 4× MLP), final LayerNorm and a
/// weight-tied unembedding onto the vocabulary. The input is a
/// `1 × seq × 1` token-id sequence; the sequence length comes from the
/// dataset (`seq<N>`), so the same builder serves full-context prefill
/// graphs and the `seq1` decode-step graph.
pub fn gpt2(
    name: &str,
    depth: usize,
    dim: usize,
    heads: usize,
    vocab: usize,
    max_pos: usize,
    input: (usize, usize, usize),
) -> Dnn {
    let mut b = DnnBuilder::new(name, "seq128", input);
    b.embedding("wte", vocab, dim);
    b.embedding("wpe", max_pos, dim);
    decoder_blocks(&mut b, depth, heads, dim);
    b.layer_norm("ln_f");
    b.tied_unembed("unembed", vocab);
    b.build()
}

/// `depth` pre-norm encoder blocks: LN → MHSA → add, LN → 1×1-conv MLP
/// (4× expansion, GELU) → add.
fn encoder_blocks(b: &mut DnnBuilder, depth: usize, heads: usize, dim: usize) {
    for blk in 0..depth {
        let block_in = b.last_index();
        b.layer_norm(format!("blk{blk}_ln1"));
        b.attention(format!("blk{blk}_attn"), heads);
        let attn_out = b.residual_add(format!("blk{blk}_add1"), block_in);
        b.layer_norm(format!("blk{blk}_ln2"));
        b.conv(format!("blk{blk}_mlp_fc1"), 1, 1, 0, 4 * dim);
        b.gelu(format!("blk{blk}_gelu"));
        b.conv(format!("blk{blk}_mlp_fc2"), 1, 1, 0, dim);
        b.residual_add(format!("blk{blk}_add2"), attn_out);
    }
}

/// `depth` pre-norm decoder blocks: identical to [`encoder_blocks`]
/// except the attention carries the causal mask.
fn decoder_blocks(b: &mut DnnBuilder, depth: usize, heads: usize, dim: usize) {
    for blk in 0..depth {
        let block_in = b.last_index();
        b.layer_norm(format!("blk{blk}_ln1"));
        b.causal_attention(format!("blk{blk}_attn"), heads);
        let attn_out = b.residual_add(format!("blk{blk}_add1"), block_in);
        b.layer_norm(format!("blk{blk}_ln2"));
        b.conv(format!("blk{blk}_mlp_fc1"), 1, 1, 0, 4 * dim);
        b.gelu(format!("blk{blk}_gelu"));
        b.conv(format!("blk{blk}_mlp_fc2"), 1, 1, 0, dim);
        b.residual_add(format!("blk{blk}_add2"), attn_out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(got: usize, want: f64, tol: f64, what: &str) {
        let got = got as f64;
        assert!(
            (got - want).abs() / want < tol,
            "{what}: {got} vs published {want}"
        );
    }

    #[test]
    fn vit_tiny_matches_published_figures() {
        // timm vit_tiny_patch16_224: 5.72M params, ~1.26 GMACs
        let d = vit("vit_tiny", 12, 192, 3, 16, (224, 224, 3), 1000);
        let s = d.stats();
        close(s.params, 5.72e6, 0.02, "vit_tiny params");
        close(s.macs, 1.26e9, 0.05, "vit_tiny macs");
        assert!(d.check().is_ok());
        // 1 patch conv + 12 × (attn + 2 mlp convs) + head = 38 weight layers
        assert_eq!(s.weight_layers, 38);
        assert!(s.digital_macs > 0 && s.digital_macs < s.macs);
    }

    #[test]
    fn vit_small_matches_published_figures() {
        // timm vit_small_patch16_224: 22.05M params, ~4.6 GMACs
        let d = vit("vit_small", 12, 384, 6, 16, (224, 224, 3), 1000);
        let s = d.stats();
        close(s.params, 22.05e6, 0.02, "vit_small params");
        close(s.macs, 4.6e9, 0.05, "vit_small macs");
    }

    #[test]
    fn bert_base_matches_published_figures() {
        // huggingface bert-base-uncased encoder: 109.5M params (incl.
        // 23.8M embeddings); ~11.2 GMACs at sequence length 128
        let d = bert_encoder("bert_base", 12, 768, 12, 30522, 512, (1, 128, 1), 2);
        let s = d.stats();
        close(s.params, 109.5e6, 0.02, "bert_base params");
        close(s.macs, 11.2e9, 0.05, "bert_base macs");
        // token lookup rewrites channels: 1×128×1 -> 1×128×768
        assert_eq!(d.layers[0].ofm.c, 768);
        assert_eq!(d.layers[0].ofm.w, 128);
    }

    #[test]
    fn gpt2_small_matches_published_figures_exactly() {
        // huggingface gpt2 (decoder, tied unembedding): 124,439,808
        // parameters — wte 50257×768 + wpe 1024×768 + 12 blocks ×
        // 7,087,872 + ln_f 1536, unembed tied (0)
        let d = gpt2("gpt2_small", 12, 768, 12, 50257, 1024, (1, 128, 1));
        let s = d.stats();
        assert_eq!(s.params, 124_439_808, "gpt2_small params");
        close(s.params, 124.4e6, 0.001, "gpt2_small params vs published");
        // MACs at seq 128, exact closed form: 12 blocks ×
        // (128·4·768² QKVO + 128·129·768 causal scores + 2 × 128·768·3072
        // MLP halves) + 128·768·50257 unembed
        let block = 128 * 4 * 768 * 768 + 128 * 129 * 768 + 2 * (128 * 3072 * 768);
        assert_eq!(block, 918_650_880);
        assert_eq!(s.macs, 12 * block + 128 * 768 * 50257, "gpt2_small macs");
        assert_eq!(s.macs, 15_964_274_688usize);
        // causal scores are the only digital MACs
        assert_eq!(s.digital_macs, 12 * 128 * 129 * 768);
        // 12 × (attn + 2 mlp convs) + tied unembed own crossbars
        assert_eq!(s.weight_layers, 37);
        assert!(d.check().is_ok());
        // token lookup rewrites channels: 1×128×1 -> 1×128×768
        assert_eq!(d.layers[0].ofm.c, 768);
        // unembed projects onto the vocabulary
        assert_eq!(d.layers.last().unwrap().ofm.c, 50257);
    }

    #[test]
    fn gpt2_decode_step_graph_shrinks_with_seq() {
        // the same builder at seq 1 is the decode-step graph: weight
        // geometry identical, dynamic work collapses to one token
        let full = gpt2("gpt2_small", 12, 768, 12, 50257, 1024, (1, 128, 1));
        let step = gpt2("gpt2_small", 12, 768, 12, 50257, 1024, (1, 1, 1));
        assert_eq!(full.stats().params, step.stats().params);
        assert_eq!(full.weight_layers().len(), step.weight_layers().len());
        assert!(step.stats().macs < full.stats().macs / 100);
        // per-layer crossbar geometry (rows/cols) is seq-independent
        for (&a, &b) in full.weight_layers().iter().zip(&step.weight_layers()) {
            assert_eq!(
                full.layers[a].weight_rows(),
                step.layers[b].weight_rows()
            );
            assert_eq!(
                full.layers[a].weight_cols(),
                step.layers[b].weight_cols()
            );
        }
    }

    #[test]
    fn blocks_are_residual_chains() {
        let d = vit("vit_tiny", 2, 64, 2, 16, (32, 32, 3), 10);
        assert!(d.check().is_ok());
        let s = d.stats();
        assert_eq!(s.skip_edges, 4, "two adds per block");
        assert!(s.peak_skip_buffer > 0);
    }
}
