//! DriveNet / PilotNet (Bojarski et al.) — the small DNN SIMBA uses for
//! its chiplet-scaling study; we use it for the Fig. 14b analogue.

use crate::dnn::graph::{Dnn, DnnBuilder};

/// DriveNet / PilotNet: five convs and a 100-50-`outputs` head over a
/// 66×200 camera input.
pub fn drivenet(outputs: usize) -> Dnn {
    let mut b = DnnBuilder::new("drivenet", "driving", (66, 200, 3));
    b.conv("conv1", 5, 2, 0, 24);
    b.relu("relu1");
    b.conv("conv2", 5, 2, 0, 36);
    b.relu("relu2");
    b.conv("conv3", 5, 2, 0, 48);
    b.relu("relu3");
    b.conv("conv4", 3, 1, 0, 64);
    b.relu("relu4");
    b.conv("conv5", 3, 1, 0, 64);
    b.relu("relu5");
    b.fc("fc1", 100);
    b.relu("relu6");
    b.fc("fc2", 50);
    b.relu("relu7");
    b.fc("fc3", outputs);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilotnet_shapes() {
        let d = drivenet(10);
        // 66x200 -> 31x98 -> 14x47 -> 5x22 -> 3x20 -> 1x18
        let conv5 = d.layers.iter().find(|l| l.name == "conv5").unwrap();
        assert_eq!((conv5.ofm.h, conv5.ofm.w, conv5.ofm.c), (1, 18, 64));
        let fc1 = d.layers.iter().find(|l| l.name == "fc1").unwrap();
        assert_eq!(fc1.ifm.elems(), 1152);
    }
}
