//! Network-in-Network (Lin et al.) — a compact all-conv CIFAR model used
//! as an extra small-workload point for design-space sweeps.

use crate::dnn::graph::{Dnn, DnnBuilder};

/// Network-in-Network: three conv+cccp stages with a global pool head.
pub fn nin(input: (usize, usize, usize), classes: usize) -> Dnn {
    let mut b = DnnBuilder::new("nin", "cifar", input);
    b.conv("conv1", 5, 1, 2, 192);
    b.relu("relu1");
    b.conv("cccp1", 1, 1, 0, 160);
    b.relu("relu2");
    b.conv("cccp2", 1, 1, 0, 96);
    b.relu("relu3");
    b.maxpool("pool1", 2, 2);
    b.conv("conv2", 5, 1, 2, 192);
    b.relu("relu4");
    b.conv("cccp3", 1, 1, 0, 192);
    b.relu("relu5");
    b.conv("cccp4", 1, 1, 0, 192);
    b.relu("relu6");
    b.avgpool("pool2", 2, 2);
    b.conv("conv3", 3, 1, 1, 192);
    b.relu("relu7");
    b.conv("cccp5", 1, 1, 0, 192);
    b.relu("relu8");
    b.conv("cccp6", 1, 1, 0, classes);
    b.global_avgpool("gap");
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nin_structure() {
        let d = nin((32, 32, 3), 10);
        assert_eq!(d.stats().weight_layers, 9);
        assert_eq!(d.layers.last().unwrap().ofm.c, 10);
        let p = d.stats().params as f64;
        assert!((p - 0.97e6).abs() / 0.97e6 < 0.1, "params {p}");
    }
}
