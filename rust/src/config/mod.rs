//! User-facing configuration: the Table-2 inputs of the SIAM paper.
//!
//! A [`SiamConfig`] fully describes one architecture point: the DNN
//! workload, the device/technology, the intra-chiplet fabric (crossbars,
//! ADCs, buffers, NoC) and the inter-chiplet system (chiplet structure,
//! NoP, DRAM). Configurations are TOML files (see `configs/`), with
//! programmatic builders for design-space sweeps.

mod parse;
mod types;
mod validate;

pub use parse::parse_flat;
pub use parse::Value;
pub use types::*;
pub use validate::ValidationError;

use anyhow::{Context, Result};
use std::path::Path;

impl SiamConfig {
    /// Paper defaults (Section 6.1): RRAM 1 bit/cell, 128×128 crossbars,
    /// 4-bit flash ADC with 8:1 column mux, parallel read-out, 16 tiles
    /// per chiplet, 32 nm, 1 GHz, mesh NoC, GRS NoP @ 0.54 pJ/bit,
    /// DDR4 DRAM.
    pub fn paper_default() -> Self {
        SiamConfig::default()
    }

    /// Load and validate a TOML configuration file (overrides applied on
    /// top of the paper defaults).
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml_str(&text)
    }

    /// Parse and validate a TOML configuration string.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let cfg = parse::apply(SiamConfig::default(), text)
            .map_err(|e| anyhow::anyhow!("parsing config: {e}"))?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize the configuration back to the TOML subset.
    pub fn to_toml_string(&self) -> Result<String> {
        Ok(parse::write(self))
    }

    /// Total IMC crossbars per chiplet: S = tiles/chiplet × crossbars/tile.
    pub fn chiplet_size_xbars(&self) -> usize {
        self.chiplet.tiles_per_chiplet * self.chiplet.xbars_per_tile
    }

    /// The chiplet classes this configuration describes, always
    /// non-empty: the configured `[[system.chiplet_class]]` array, or —
    /// when none is configured — one synthetic class inheriting the base
    /// `[device]`/`[chiplet]`/`[system.nop]` blocks, with `count` taken
    /// from the legacy `structure`/`total_chiplets` pair. Engines that
    /// need per-chiplet parameters read this instead of branching on
    /// the legacy fields.
    pub fn resolved_chiplet_classes(&self) -> Vec<ChipletClassConfig> {
        if !self.system.chiplet_classes.is_empty() {
            return self.system.chiplet_classes.clone();
        }
        let mut base = ChipletClassConfig::from_base(self, "base");
        if self.system.structure == ChipletStructure::Homogeneous {
            base.count = self.system.total_chiplets;
        }
        vec![base]
    }

    /// True when the configuration is *genuinely* heterogeneous: at
    /// least one `[[system.chiplet_class]]` whose device / geometry /
    /// driver fields differ from the base blocks (a single class that
    /// merely restates the base config is the degenerate identity and
    /// runs through the classic engine paths bit-for-bit).
    pub fn has_hetero_classes(&self) -> bool {
        self.degenerate_class_mode().is_none() && !self.system.chiplet_classes.is_empty()
    }

    /// Detect the degenerate single-class case: exactly one configured
    /// class whose every field (name aside) equals the base-derived
    /// class. Returns `Some(count)` — the class's chiplet budget — so
    /// callers can fall back to the classic custom (`None`) or
    /// homogeneous (`Some(n)`) paths, which the degenerate class must
    /// reproduce bit-for-bit. Returns `None` for zero or several
    /// classes, or a single class that differs from the base.
    pub fn degenerate_class_mode(&self) -> Option<Option<usize>> {
        match self.system.chiplet_classes.as_slice() {
            [only] => {
                let mut base = ChipletClassConfig::from_base(self, &only.name);
                base.count = only.count;
                (*only == base).then_some(only.count)
            }
            _ => None,
        }
    }

    /// The effective single-kind configuration of one chiplet class:
    /// the base config with the class's device, crossbar geometry, ADC
    /// and NoP driver fields substituted (and the class list cleared).
    /// Per-class engine models — circuit costs, NoC meshes, driver
    /// macros — are built from this.
    pub fn class_effective(&self, class: &ChipletClassConfig) -> SiamConfig {
        let mut cfg = self.clone();
        cfg.device.cell = class.cell;
        cfg.device.bits_per_cell = class.bits_per_cell;
        cfg.chiplet.xbar_rows = class.xbar_rows;
        cfg.chiplet.xbar_cols = class.xbar_cols;
        cfg.chiplet.tiles_per_chiplet = class.tiles_per_chiplet;
        cfg.chiplet.xbars_per_tile = class.xbars_per_tile;
        cfg.chiplet.adc_bits = class.adc_bits;
        cfg.chiplet.cols_per_adc = class.cols_per_adc;
        cfg.chiplet.frequency_mhz = class.frequency_mhz;
        cfg.system.nop.ebit_pj = class.nop_ebit_pj;
        cfg.system.nop.txrx_area_um2 = class.nop_txrx_area_um2;
        cfg.system.chiplet_classes = Vec::new();
        cfg.system.structure = ChipletStructure::Custom;
        cfg.system.total_chiplets = None;
        cfg
    }

    /// Clock period of the intra-chiplet logic, ns.
    pub fn clock_period_ns(&self) -> f64 {
        1.0e3 / self.chiplet.frequency_mhz
    }

    /// Builder-style override: set the DNN workload.
    pub fn with_model(mut self, model: &str, dataset: &str) -> Self {
        self.dnn.model = model.to_string();
        self.dnn.dataset = dataset.to_string();
        self
    }

    /// Builder-style override: set the chiplet size in tiles (the
    /// Figs. 9/11/12 sweep axis).
    pub fn with_tiles_per_chiplet(mut self, tiles: usize) -> Self {
        self.chiplet.tiles_per_chiplet = tiles;
        self
    }

    /// Builder-style override: set the chiplet allocation policy.
    pub fn with_chiplet_structure(mut self, structure: ChipletStructure) -> Self {
        self.system.structure = structure;
        self
    }

    /// Builder-style override: fix a homogeneous architecture with
    /// `count` chiplets.
    pub fn with_total_chiplets(mut self, count: usize) -> Self {
        self.system.structure = ChipletStructure::Homogeneous;
        self.system.total_chiplets = Some(count);
        self
    }

    /// Builder-style override: monolithic vs chiplet integration.
    pub fn with_chip_mode(mut self, mode: ChipMode) -> Self {
        self.system.chip_mode = mode;
        self
    }

    /// Builder-style override: install heterogeneous chiplet classes
    /// (clears the legacy `structure`/`total_chiplets` pair, which
    /// classes supersede).
    pub fn with_chiplet_classes(mut self, classes: Vec<ChipletClassConfig>) -> Self {
        self.system.chiplet_classes = classes;
        self.system.structure = ChipletStructure::Custom;
        self.system.total_chiplets = None;
        self
    }

    /// Builder-style override: set the chiplet placement policy.
    pub fn with_placement(mut self, placement: PlacementPolicy) -> Self {
        self.system.placement = placement;
        self
    }

    /// Builder-style override: set the NoP packet clock.
    pub fn with_nop_frequency_mhz(mut self, f: f64) -> Self {
        self.system.nop.frequency_mhz = f;
        self
    }

    /// Scale NoP link bandwidth (the Fig. 14d "NoP speed-up" axis).
    pub fn with_nop_speedup(mut self, factor: f64) -> Self {
        self.system.nop.gbps_per_lane *= factor;
        self
    }

    /// Builder-style override: open-loop serving at `rate_qps`
    /// inferences/s (0 = auto: 80 % of the bottleneck-stage rate).
    pub fn with_serve_open(mut self, rate_qps: f64) -> Self {
        self.serve.mode = ServeMode::Open;
        self.serve.rate_qps = rate_qps;
        self
    }

    /// Builder-style override: closed-loop serving with `concurrency`
    /// outstanding requests.
    pub fn with_serve_closed(mut self, concurrency: usize) -> Self {
        self.serve.mode = ServeMode::Closed;
        self.serve.concurrency = concurrency;
        self
    }

    /// Builder-style override: number of requests the serving simulator
    /// streams through the pipeline.
    pub fn with_serve_requests(mut self, requests: usize) -> Self {
        self.serve.requests = requests;
        self
    }

    /// Builder-style override: provision `n` spare chiplets. Spares are
    /// charged in area / leakage / fabrication cost but carry no weights
    /// until a failover remap spills work onto them.
    pub fn with_spare_chiplets(mut self, n: usize) -> Self {
        self.system.spare_chiplets = n;
        self
    }

    /// Builder-style override: deterministically kill the listed
    /// chiplet ids before mapping (the `[fault] kill_chiplets` list).
    pub fn with_kill_chiplets(mut self, ids: Vec<usize>) -> Self {
        self.fault.kill_chiplets = ids;
        self
    }

    /// Builder-style override: schedule a mid-run chiplet death for the
    /// serving failover scenario — `chiplet` dies when open-loop arrival
    /// number `at_request` reaches the system, and the remapped stage
    /// graph comes online `remap_latency_us` later.
    pub fn with_failover(mut self, at_request: usize, chiplet: usize, remap_latency_us: f64) -> Self {
        self.serve.fail_at_request = Some(at_request);
        self.serve.fail_chiplet = chiplet;
        self.serve.remap_latency_us = remap_latency_us;
        self
    }

    /// Builder-style override: autoregressive decode scenario for
    /// `siam serve --decode` (`[decode]` block) — tokens generated per
    /// request, KV-cache precision and the continuous-batching cap.
    pub fn with_decode(
        mut self,
        max_new_tokens: usize,
        kv_precision_bits: usize,
        batch_cap: usize,
    ) -> Self {
        self.decode.max_new_tokens = max_new_tokens;
        self.decode.kv_precision_bits = kv_precision_bits;
        self.decode.batch_cap = batch_cap;
        self
    }

    /// Builder-style override: chunked prefill — the prompt is processed
    /// in `ceil(seq / chunk)` sequential passes (`[decode] prefill_chunk`).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.decode.prefill_chunk = chunk;
        self
    }

    /// Builder-style override: lognormal programming-noise sigma of the
    /// analog variation model (`[variation] sigma_program`).
    pub fn with_variation_noise(mut self, sigma: f64) -> Self {
        self.variation.sigma_program = sigma;
        self
    }

    /// Builder-style override: extra write-verify cycles per programmed
    /// cell — each shrinks the effective programming sigma and charges
    /// program energy/latency.
    pub fn with_write_verify(mut self, cycles: u32) -> Self {
        self.variation.write_verify_cycles = cycles;
        self
    }

    /// Builder-style override: conductance drift — power-law exponent
    /// `nu` evaluated at retention age `time_s` seconds.
    pub fn with_drift(mut self, nu: f64, time_s: f64) -> Self {
        self.variation.drift_nu = nu;
        self.variation.drift_time_s = time_s;
        self
    }

    /// Builder-style override: periodic drift-refresh interval for the
    /// serving simulator, seconds (0 = never refresh).
    pub fn with_refresh_interval(mut self, seconds: f64) -> Self {
        self.variation.refresh_interval_s = seconds;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_toml() {
        let cfg = SiamConfig::paper_default();
        let text = cfg.to_toml_string().unwrap();
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.chiplet.xbar_rows, cfg.chiplet.xbar_rows);
        assert_eq!(back.dnn.model, cfg.dnn.model);
        assert_eq!(back.system.nop.ebit_pj, cfg.system.nop.ebit_pj);
        assert_eq!(back.serve.mode, cfg.serve.mode);
        assert_eq!(back.serve.requests, cfg.serve.requests);
    }

    #[test]
    fn serve_workload_mix_roundtrips() {
        let mut cfg = SiamConfig::paper_default().with_serve_open(2000.0);
        cfg.serve.workloads = vec!["resnet110".into(), "vgg19:cifar100".into()];
        let text = cfg.to_toml_string().unwrap();
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.serve.workloads, cfg.serve.workloads);
        assert_eq!(back.serve.rate_qps, 2000.0);
        // and the re-serialization is byte-identical (bit-exact round trip)
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn fault_and_spares_roundtrip_through_toml() {
        let mut cfg = SiamConfig::paper_default()
            .with_total_chiplets(25)
            .with_spare_chiplets(2)
            .with_kill_chiplets(vec![3, 7]);
        cfg.fault.xbar_fault_fraction = 0.05;
        cfg.fault.seed = 99;
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml_string().unwrap();
        assert!(text.contains("spare_chiplets = 2"), "{text}");
        assert!(text.contains("[fault]"), "{text}");
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.system.spare_chiplets, 2);
        assert_eq!(back.fault, cfg.fault);
        // bit-exact fixed point
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn failover_serve_keys_roundtrip() {
        let cfg = SiamConfig::paper_default()
            .with_total_chiplets(25)
            .with_spare_chiplets(1)
            .with_serve_open(1000.0)
            .with_failover(50, 3, 250.0);
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml_string().unwrap();
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.serve.fail_at_request, Some(50));
        assert_eq!(back.serve.fail_chiplet, 3);
        assert_eq!(back.serve.remap_latency_us, 250.0);
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn zero_fault_config_writes_no_fault_block() {
        // the default config must serialize byte-identically to pre-fault
        // output: no [fault] block, no spare_chiplets, no failover keys
        let text = SiamConfig::paper_default().to_toml_string().unwrap();
        assert!(!text.contains("fault"), "{text}");
        assert!(!text.contains("spare"), "{text}");
    }

    #[test]
    fn variation_roundtrips_through_toml() {
        let mut cfg = SiamConfig::paper_default()
            .with_variation_noise(0.05)
            .with_write_verify(2)
            .with_drift(0.02, 1.0e4)
            .with_refresh_interval(3600.0);
        cfg.variation.stuck_at_on = 0.002;
        cfg.variation.stuck_at_off = 0.005;
        cfg.variation.adc_offset_lsb = 0.25;
        cfg.variation.redundant_cols = 8;
        cfg.variation.mc_samples = 64;
        cfg.variation.accuracy_floor = 0.7;
        cfg.variation.seed = 11;
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml_string().unwrap();
        assert!(text.contains("[variation]"), "{text}");
        assert!(text.contains("write_verify_cycles = 2"), "{text}");
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.variation, cfg.variation);
        // bit-exact fixed point
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn zero_variation_config_writes_no_variation_block() {
        // the default config must serialize byte-identically to
        // pre-variation output: no [variation] block at all
        let text = SiamConfig::paper_default().to_toml_string().unwrap();
        assert!(!text.contains("variation"), "{text}");
        assert!(SiamConfig::paper_default().variation.is_none());
    }

    #[test]
    fn sweep_block_roundtrips_through_toml() {
        let mut cfg = SiamConfig::paper_default();
        cfg.sweep.cache_file = Some("epochs.cache".into());
        cfg.sweep.search = SearchMode::Pareto;
        cfg.sweep.halving_keep = 0.25;
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml_string().unwrap();
        assert!(text.contains("[sweep]"), "{text}");
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.sweep, cfg.sweep);
        // bit-exact fixed point
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn default_sweep_config_writes_no_sweep_block() {
        // the default config must serialize byte-identically to
        // pre-cache output: no [sweep] block at all
        let text = SiamConfig::paper_default().to_toml_string().unwrap();
        assert!(!text.contains("sweep"), "{text}");
        assert!(SiamConfig::paper_default().sweep.is_default());
    }

    #[test]
    fn decode_roundtrips_through_toml() {
        let cfg = SiamConfig::paper_default()
            .with_decode(64, 16, 4)
            .with_prefill_chunk(32);
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml_string().unwrap();
        assert!(text.contains("[decode]"), "{text}");
        assert!(text.contains("max_new_tokens = 64"), "{text}");
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.decode, cfg.decode);
        // bit-exact fixed point
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn default_decode_config_writes_no_decode_block() {
        // the default config must serialize byte-identically to
        // pre-decode output: no [decode] block at all
        let text = SiamConfig::paper_default().to_toml_string().unwrap();
        assert!(!text.contains("decode"), "{text}");
        assert!(SiamConfig::paper_default().decode.is_default());
    }

    #[test]
    fn fault_validation_bounds() {
        let base = SiamConfig::paper_default().with_total_chiplets(25);
        let mut cfg = base.clone();
        cfg.fault.die_yield = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = base.clone();
        cfg.fault.die_yield = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = base.clone();
        cfg.fault.xbar_fault_fraction = 1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = base.clone();
        cfg.fault.kill_chiplets = vec![2, 2];
        assert!(cfg.validate().is_err());
        // fault / spares need chiplet mode
        let mut cfg = base.clone().with_chip_mode(ChipMode::Monolithic);
        cfg.system.total_chiplets = None;
        cfg.system.structure = ChipletStructure::Custom;
        cfg.fault.kill_chiplets = vec![0];
        assert!(cfg.validate().is_err());
        // fail_at requires open-loop serving
        let mut cfg = base.clone().with_serve_closed(4).with_spare_chiplets(1);
        cfg.serve.fail_at_request = Some(10);
        assert!(cfg.validate().is_err());
        // hetero classes are out of scope for faults
        let hetero = big_little().with_spare_chiplets(1);
        assert!(hetero.validate().is_err());
    }

    #[test]
    fn default_matches_paper_section_6_1() {
        let cfg = SiamConfig::paper_default();
        assert_eq!(cfg.chiplet.xbar_rows, 128);
        assert_eq!(cfg.chiplet.xbar_cols, 128);
        assert_eq!(cfg.chiplet.adc_bits, 4);
        assert_eq!(cfg.chiplet.cols_per_adc, 8);
        assert_eq!(cfg.chiplet.tiles_per_chiplet, 16);
        assert_eq!(cfg.chiplet.xbars_per_tile, 16);
        assert_eq!(cfg.device.tech_node_nm, 32);
        assert_eq!(cfg.device.bits_per_cell, 1);
        assert_eq!(cfg.dnn.weight_precision, 8);
        assert!((cfg.chiplet.frequency_mhz - 1000.0).abs() < 1e-9);
        assert!((cfg.system.nop.ebit_pj - 0.54).abs() < 1e-9);
        assert_eq!(cfg.system.nop.channel_width, 32);
        assert!((cfg.system.nop.frequency_mhz - 250.0).abs() < 1e-9);
    }

    #[test]
    fn chiplet_size() {
        let cfg = SiamConfig::paper_default();
        assert_eq!(cfg.chiplet_size_xbars(), 256);
    }

    #[test]
    fn builders() {
        let cfg = SiamConfig::paper_default()
            .with_model("vgg16", "imagenet")
            .with_tiles_per_chiplet(36)
            .with_total_chiplets(64);
        assert_eq!(cfg.dnn.model, "vgg16");
        assert_eq!(cfg.chiplet.tiles_per_chiplet, 36);
        assert_eq!(cfg.system.total_chiplets, Some(64));
        assert_eq!(cfg.system.structure, ChipletStructure::Homogeneous);
    }

    #[test]
    fn rejects_bad_config() {
        let mut cfg = SiamConfig::paper_default();
        cfg.chiplet.xbar_rows = 0;
        assert!(cfg.validate().is_err());
    }

    fn big_little() -> SiamConfig {
        let base = SiamConfig::paper_default();
        let big = ChipletClassConfig::from_base(&base, "big");
        let mut little = ChipletClassConfig::from_base(&base, "little");
        little.cell = MemCell::Sram;
        little.xbar_rows = 64;
        little.xbar_cols = 64;
        little.tiles_per_chiplet = 8;
        little.xbars_per_tile = 8;
        little.adc_bits = 3;
        little.nop_ebit_pj = 0.3;
        base.with_chiplet_classes(vec![big, little])
    }

    #[test]
    fn classes_roundtrip_through_toml() {
        let mut cfg = big_little();
        cfg.system.placement = PlacementPolicy::Dataflow;
        cfg.system.chiplet_classes[0].count = Some(4);
        assert!(cfg.validate().is_ok());
        let text = cfg.to_toml_string().unwrap();
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.system.chiplet_classes, cfg.system.chiplet_classes);
        assert_eq!(back.system.placement, PlacementPolicy::Dataflow);
        // bit-exact fixed point
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn degenerate_single_class_detected() {
        let base = SiamConfig::paper_default();
        // no classes: not degenerate-class mode, not hetero
        assert_eq!(base.degenerate_class_mode(), None);
        assert!(!base.has_hetero_classes());
        // one base-identical class: degenerate custom
        let one = base
            .clone()
            .with_chiplet_classes(vec![ChipletClassConfig::from_base(&base, "only")]);
        assert_eq!(one.degenerate_class_mode(), Some(None));
        assert!(!one.has_hetero_classes());
        // with a budget: degenerate homogeneous
        let mut bounded = one.clone();
        bounded.system.chiplet_classes[0].count = Some(36);
        assert_eq!(bounded.degenerate_class_mode(), Some(Some(36)));
        assert!(!bounded.has_hetero_classes());
        // a field deviation makes it genuinely heterogeneous
        let mut hetero = one.clone();
        hetero.system.chiplet_classes[0].xbar_rows = 64;
        assert_eq!(hetero.degenerate_class_mode(), None);
        assert!(hetero.has_hetero_classes());
        assert!(big_little().has_hetero_classes());
    }

    #[test]
    fn resolved_classes_cover_legacy_modes() {
        let custom = SiamConfig::paper_default();
        let r = custom.resolved_chiplet_classes();
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].count, None);
        assert_eq!(r[0].xbar_rows, custom.chiplet.xbar_rows);
        let homog = SiamConfig::paper_default().with_total_chiplets(36);
        assert_eq!(homog.resolved_chiplet_classes()[0].count, Some(36));
        let classes = big_little().resolved_chiplet_classes();
        assert_eq!(classes.len(), 2);
        assert_eq!(classes[1].name, "little");
    }

    #[test]
    fn class_effective_substitutes_fields() {
        let cfg = big_little();
        let eff = cfg.class_effective(&cfg.system.chiplet_classes[1]);
        assert_eq!(eff.device.cell, MemCell::Sram);
        assert_eq!(eff.chiplet.xbar_rows, 64);
        assert_eq!(eff.chiplet.adc_bits, 3);
        assert_eq!(eff.system.nop.ebit_pj, 0.3);
        assert!(eff.system.chiplet_classes.is_empty());
        assert!(eff.validate().is_ok());
        // untouched blocks ride along
        assert_eq!(eff.dnn.model, cfg.dnn.model);
        assert_eq!(eff.system.nop.channel_width, cfg.system.nop.channel_width);
    }

    #[test]
    fn class_validation_rejects_conflicts() {
        // classes + total_chiplets conflict
        let mut cfg = big_little();
        cfg.system.total_chiplets = Some(16);
        assert!(cfg.validate().is_err());
        // monolithic + classes conflict
        let mut cfg = big_little();
        cfg.system.chip_mode = ChipMode::Monolithic;
        assert!(cfg.validate().is_err());
        // duplicate names
        let mut cfg = big_little();
        cfg.system.chiplet_classes[1].name = "big".into();
        assert!(cfg.validate().is_err());
        // mux must divide class columns
        let mut cfg = big_little();
        cfg.system.chiplet_classes[1].cols_per_adc = 48;
        assert!(cfg.validate().is_err());
        // zero-budget class
        let mut cfg = big_little();
        cfg.system.chiplet_classes[0].count = Some(0);
        assert!(cfg.validate().is_err());
        assert!(big_little().validate().is_ok());
    }
}
