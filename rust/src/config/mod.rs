//! User-facing configuration: the Table-2 inputs of the SIAM paper.
//!
//! A [`SiamConfig`] fully describes one architecture point: the DNN
//! workload, the device/technology, the intra-chiplet fabric (crossbars,
//! ADCs, buffers, NoC) and the inter-chiplet system (chiplet structure,
//! NoP, DRAM). Configurations are TOML files (see `configs/`), with
//! programmatic builders for design-space sweeps.

mod parse;
mod types;
mod validate;

pub use parse::Value;
pub use types::*;
pub use validate::ValidationError;

use anyhow::{Context, Result};
use std::path::Path;

impl SiamConfig {
    /// Paper defaults (Section 6.1): RRAM 1 bit/cell, 128×128 crossbars,
    /// 4-bit flash ADC with 8:1 column mux, parallel read-out, 16 tiles
    /// per chiplet, 32 nm, 1 GHz, mesh NoC, GRS NoP @ 0.54 pJ/bit,
    /// DDR4 DRAM.
    pub fn paper_default() -> Self {
        SiamConfig::default()
    }

    /// Load and validate a TOML configuration file (overrides applied on
    /// top of the paper defaults).
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_toml_str(&text)
    }

    /// Parse and validate a TOML configuration string.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let cfg = parse::apply(SiamConfig::default(), text)
            .map_err(|e| anyhow::anyhow!("parsing config: {e}"))?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize the configuration back to the TOML subset.
    pub fn to_toml_string(&self) -> Result<String> {
        Ok(parse::write(self))
    }

    /// Total IMC crossbars per chiplet: S = tiles/chiplet × crossbars/tile.
    pub fn chiplet_size_xbars(&self) -> usize {
        self.chiplet.tiles_per_chiplet * self.chiplet.xbars_per_tile
    }

    /// Clock period of the intra-chiplet logic, ns.
    pub fn clock_period_ns(&self) -> f64 {
        1.0e3 / self.chiplet.frequency_mhz
    }

    /// Builder-style override: set the DNN workload.
    pub fn with_model(mut self, model: &str, dataset: &str) -> Self {
        self.dnn.model = model.to_string();
        self.dnn.dataset = dataset.to_string();
        self
    }

    /// Builder-style override: set the chiplet size in tiles (the
    /// Figs. 9/11/12 sweep axis).
    pub fn with_tiles_per_chiplet(mut self, tiles: usize) -> Self {
        self.chiplet.tiles_per_chiplet = tiles;
        self
    }

    /// Builder-style override: set the chiplet allocation policy.
    pub fn with_chiplet_structure(mut self, structure: ChipletStructure) -> Self {
        self.system.structure = structure;
        self
    }

    /// Builder-style override: fix a homogeneous architecture with
    /// `count` chiplets.
    pub fn with_total_chiplets(mut self, count: usize) -> Self {
        self.system.structure = ChipletStructure::Homogeneous;
        self.system.total_chiplets = Some(count);
        self
    }

    /// Builder-style override: monolithic vs chiplet integration.
    pub fn with_chip_mode(mut self, mode: ChipMode) -> Self {
        self.system.chip_mode = mode;
        self
    }

    /// Builder-style override: set the NoP packet clock.
    pub fn with_nop_frequency_mhz(mut self, f: f64) -> Self {
        self.system.nop.frequency_mhz = f;
        self
    }

    /// Scale NoP link bandwidth (the Fig. 14d "NoP speed-up" axis).
    pub fn with_nop_speedup(mut self, factor: f64) -> Self {
        self.system.nop.gbps_per_lane *= factor;
        self
    }

    /// Builder-style override: open-loop serving at `rate_qps`
    /// inferences/s (0 = auto: 80 % of the bottleneck-stage rate).
    pub fn with_serve_open(mut self, rate_qps: f64) -> Self {
        self.serve.mode = ServeMode::Open;
        self.serve.rate_qps = rate_qps;
        self
    }

    /// Builder-style override: closed-loop serving with `concurrency`
    /// outstanding requests.
    pub fn with_serve_closed(mut self, concurrency: usize) -> Self {
        self.serve.mode = ServeMode::Closed;
        self.serve.concurrency = concurrency;
        self
    }

    /// Builder-style override: number of requests the serving simulator
    /// streams through the pipeline.
    pub fn with_serve_requests(mut self, requests: usize) -> Self {
        self.serve.requests = requests;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_through_toml() {
        let cfg = SiamConfig::paper_default();
        let text = cfg.to_toml_string().unwrap();
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.chiplet.xbar_rows, cfg.chiplet.xbar_rows);
        assert_eq!(back.dnn.model, cfg.dnn.model);
        assert_eq!(back.system.nop.ebit_pj, cfg.system.nop.ebit_pj);
        assert_eq!(back.serve.mode, cfg.serve.mode);
        assert_eq!(back.serve.requests, cfg.serve.requests);
    }

    #[test]
    fn serve_workload_mix_roundtrips() {
        let mut cfg = SiamConfig::paper_default().with_serve_open(2000.0);
        cfg.serve.workloads = vec!["resnet110".into(), "vgg19:cifar100".into()];
        let text = cfg.to_toml_string().unwrap();
        let back = SiamConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.serve.workloads, cfg.serve.workloads);
        assert_eq!(back.serve.rate_qps, 2000.0);
        // and the re-serialization is byte-identical (bit-exact round trip)
        assert_eq!(back.to_toml_string().unwrap(), text);
    }

    #[test]
    fn default_matches_paper_section_6_1() {
        let cfg = SiamConfig::paper_default();
        assert_eq!(cfg.chiplet.xbar_rows, 128);
        assert_eq!(cfg.chiplet.xbar_cols, 128);
        assert_eq!(cfg.chiplet.adc_bits, 4);
        assert_eq!(cfg.chiplet.cols_per_adc, 8);
        assert_eq!(cfg.chiplet.tiles_per_chiplet, 16);
        assert_eq!(cfg.chiplet.xbars_per_tile, 16);
        assert_eq!(cfg.device.tech_node_nm, 32);
        assert_eq!(cfg.device.bits_per_cell, 1);
        assert_eq!(cfg.dnn.weight_precision, 8);
        assert!((cfg.chiplet.frequency_mhz - 1000.0).abs() < 1e-9);
        assert!((cfg.system.nop.ebit_pj - 0.54).abs() < 1e-9);
        assert_eq!(cfg.system.nop.channel_width, 32);
        assert!((cfg.system.nop.frequency_mhz - 250.0).abs() < 1e-9);
    }

    #[test]
    fn chiplet_size() {
        let cfg = SiamConfig::paper_default();
        assert_eq!(cfg.chiplet_size_xbars(), 256);
    }

    #[test]
    fn builders() {
        let cfg = SiamConfig::paper_default()
            .with_model("vgg16", "imagenet")
            .with_tiles_per_chiplet(36)
            .with_total_chiplets(64);
        assert_eq!(cfg.dnn.model, "vgg16");
        assert_eq!(cfg.chiplet.tiles_per_chiplet, 36);
        assert_eq!(cfg.system.total_chiplets, Some(64));
        assert_eq!(cfg.system.structure, ChipletStructure::Homogeneous);
    }

    #[test]
    fn rejects_bad_config() {
        let mut cfg = SiamConfig::paper_default();
        cfg.chiplet.xbar_rows = 0;
        assert!(cfg.validate().is_err());
    }
}
