//! Configuration validation: reject physically meaningless inputs early,
//! with actionable messages (the paper's engine "throws an error and
//! requests an increase in chiplets" — we extend that spirit to every
//! input).

use super::types::*;

/// Error raised when a [`SiamConfig`] is inconsistent or out of the
/// modeled range.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidationError(pub String);

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid SIAM config: {}", self.0)
    }
}

impl std::error::Error for ValidationError {}

impl SiamConfig {
    /// Reject physically meaningless or inconsistent inputs with an
    /// actionable message; every engine assumes a validated config.
    pub fn validate(&self) -> Result<(), ValidationError> {
        let err = |msg: String| Err(ValidationError(msg));

        if self.chiplet.xbar_rows == 0 || self.chiplet.xbar_cols == 0 {
            return err("crossbar dimensions must be non-zero".into());
        }
        if !self.chiplet.xbar_rows.is_power_of_two() || !self.chiplet.xbar_cols.is_power_of_two() {
            return err(format!(
                "crossbar dims must be powers of two, got {}x{}",
                self.chiplet.xbar_rows, self.chiplet.xbar_cols
            ));
        }
        if self.chiplet.tiles_per_chiplet == 0 || self.chiplet.xbars_per_tile == 0 {
            return err("chiplet must contain at least one tile and one crossbar".into());
        }
        if self.chiplet.adc_bits == 0 || self.chiplet.adc_bits > 12 {
            return err(format!(
                "ADC resolution {} out of supported range 1..=12",
                self.chiplet.adc_bits
            ));
        }
        if self.chiplet.cols_per_adc == 0 || self.chiplet.xbar_cols % self.chiplet.cols_per_adc != 0
        {
            return err(format!(
                "cols_per_adc {} must divide crossbar columns {}",
                self.chiplet.cols_per_adc, self.chiplet.xbar_cols
            ));
        }
        if self.chiplet.frequency_mhz <= 0.0 {
            return err("chiplet frequency must be positive".into());
        }
        if self.chiplet.noc_width == 0 {
            return err("NoC width must be non-zero".into());
        }
        if self.chiplet.noc_buffer_depth == 0 {
            return err("NoC buffer depth must be non-zero".into());
        }
        if self.dnn.weight_precision == 0 || self.dnn.weight_precision > 32 {
            return err(format!(
                "weight precision {} out of range 1..=32",
                self.dnn.weight_precision
            ));
        }
        if self.dnn.activation_precision == 0 || self.dnn.activation_precision > 32 {
            return err(format!(
                "activation precision {} out of range 1..=32",
                self.dnn.activation_precision
            ));
        }
        if self.dnn.batch == 0 {
            return err("batch must be >= 1".into());
        }
        // model references resolve now, not mid-run: zoo names against
        // the registry, `file:` models against the filesystem
        if let Err(e) = crate::dnn::check_model_name(&self.dnn.model, &self.dnn.dataset) {
            return err(format!("dnn.model: {e}"));
        }
        for w in &self.serve.workloads {
            if w.is_empty() {
                continue; // reported below with the dedicated message
            }
            let (model, dataset) = crate::dnn::split_workload(w, &self.dnn.dataset);
            if let Err(e) = crate::dnn::check_model_name(model, dataset) {
                return err(format!("serve.workloads entry '{w}': {e}"));
            }
        }
        if let Some(sp) = &self.dnn.sparsity {
            if sp.iter().any(|&s| !(0.0..1.0).contains(&s)) {
                return err("sparsity values must lie in [0, 1)".into());
            }
        }
        if self.device.bits_per_cell == 0 || self.device.bits_per_cell > 4 {
            return err(format!(
                "bits per cell {} out of supported range 1..=4",
                self.device.bits_per_cell
            ));
        }
        if self.device.tech_node_nm < 7 || self.device.tech_node_nm > 130 {
            return err(format!(
                "tech node {} nm outside modeled range 7..=130",
                self.device.tech_node_nm
            ));
        }
        if self.device.r_on <= 0.0 || self.device.r_off_ratio <= 1.0 {
            return err("RRAM resistances must satisfy r_on > 0, r_off/r_on > 1".into());
        }
        if self.system.structure == ChipletStructure::Homogeneous
            && self.system.total_chiplets.is_none()
        {
            return err("homogeneous structure requires total_chiplets".into());
        }
        if let Some(c) = self.system.total_chiplets {
            if c == 0 {
                return err("total_chiplets must be >= 1".into());
            }
        }
        if !self.system.chiplet_classes.is_empty() {
            if self.system.chip_mode == ChipMode::Monolithic {
                return err("monolithic chip mode cannot use chiplet classes".into());
            }
            if self.system.structure == ChipletStructure::Homogeneous
                || self.system.total_chiplets.is_some()
            {
                return err(
                    "chiplet classes supersede structure/total_chiplets; \
                     remove those keys (per-class budgets go in count)"
                        .into(),
                );
            }
            let mut names = std::collections::BTreeSet::new();
            for class in &self.system.chiplet_classes {
                let c = &class.name;
                if c.is_empty() {
                    return err("chiplet class names must be non-empty".into());
                }
                if !names.insert(c) {
                    return err(format!("duplicate chiplet class name '{c}'"));
                }
                if class.count == Some(0) {
                    return err(format!("chiplet class '{c}' count must be >= 1"));
                }
                if class.xbar_rows == 0 || class.xbar_cols == 0 {
                    return err(format!("chiplet class '{c}' crossbar dims must be non-zero"));
                }
                if !class.xbar_rows.is_power_of_two() || !class.xbar_cols.is_power_of_two() {
                    return err(format!(
                        "chiplet class '{c}' crossbar dims must be powers of two, got {}x{}",
                        class.xbar_rows, class.xbar_cols
                    ));
                }
                if class.tiles_per_chiplet == 0 || class.xbars_per_tile == 0 {
                    return err(format!(
                        "chiplet class '{c}' must contain at least one tile and one crossbar"
                    ));
                }
                if class.adc_bits == 0 || class.adc_bits > 12 {
                    return err(format!(
                        "chiplet class '{c}' ADC resolution {} out of supported range 1..=12",
                        class.adc_bits
                    ));
                }
                if class.cols_per_adc == 0 || class.xbar_cols % class.cols_per_adc != 0 {
                    return err(format!(
                        "chiplet class '{c}' cols_per_adc {} must divide crossbar columns {}",
                        class.cols_per_adc, class.xbar_cols
                    ));
                }
                if class.bits_per_cell == 0 || class.bits_per_cell > 4 {
                    return err(format!(
                        "chiplet class '{c}' bits per cell {} out of supported range 1..=4",
                        class.bits_per_cell
                    ));
                }
                if class.frequency_mhz <= 0.0 {
                    return err(format!("chiplet class '{c}' frequency must be positive"));
                }
                if class.nop_ebit_pj <= 0.0 || class.nop_txrx_area_um2 <= 0.0 {
                    return err(format!(
                        "chiplet class '{c}' NoP driver figures must be positive"
                    ));
                }
            }
        }
        if self.system.accumulator_size == 0 {
            return err("accumulator size must be >= 1".into());
        }
        if self.system.nop.frequency_mhz <= 0.0 || self.system.nop.channel_width == 0 {
            return err("NoP frequency and channel width must be positive".into());
        }
        if self.system.nop.ebit_pj <= 0.0 {
            return err("NoP energy-per-bit must be positive".into());
        }
        if self.system.nop.gbps_per_lane <= 0.0 {
            return err("NoP lane rate must be positive".into());
        }
        if self.system.nop.lanes_per_clock == 0 || self.system.nop.router_ports < 2 {
            return err("NoP lanes_per_clock >= 1 and router_ports >= 2 required".into());
        }
        if !(0.0 < self.dram.subset_fraction && self.dram.subset_fraction <= 1.0) {
            return err(format!(
                "DRAM subset fraction {} must be in (0, 1]",
                self.dram.subset_fraction
            ));
        }
        if self.dram.bus_bits == 0 || self.dram.bus_bits % 8 != 0 {
            return err("DRAM bus width must be a positive multiple of 8".into());
        }
        if !(self.serve.rate_qps >= 0.0 && self.serve.rate_qps.is_finite()) {
            return err(format!(
                "serve rate {} must be finite and >= 0 (0 = auto)",
                self.serve.rate_qps
            ));
        }
        if self.serve.requests == 0 {
            return err("serve requests must be >= 1".into());
        }
        if self.serve.concurrency == 0 {
            return err("serve concurrency must be >= 1".into());
        }
        if self.serve.queue_depth == 0 {
            return err("serve queue depth must be >= 1 (back-pressure needs a slot)".into());
        }
        if self.serve.qos_p99_ms <= 0.0 {
            return err("serve QoS p99 target must be positive".into());
        }
        if self.serve.workloads.iter().any(|w| w.is_empty()) {
            return err("serve workload names must be non-empty".into());
        }
        if !(0.0 < self.fault.die_yield && self.fault.die_yield <= 1.0) {
            return err(format!(
                "fault die_yield {} must be in (0, 1]",
                self.fault.die_yield
            ));
        }
        if !(0.0..1.0).contains(&self.fault.xbar_fault_fraction) {
            return err(format!(
                "fault xbar_fault_fraction {} must be in [0, 1)",
                self.fault.xbar_fault_fraction
            ));
        }
        {
            let mut seen = std::collections::BTreeSet::new();
            for &c in &self.fault.kill_chiplets {
                if !seen.insert(c) {
                    return err(format!("fault kill_chiplets repeats chiplet {c}"));
                }
            }
        }
        if (!self.fault.is_none() || self.system.spare_chiplets > 0)
            && self.system.chip_mode == ChipMode::Monolithic
        {
            return err("fault injection and spare chiplets require chiplet mode".into());
        }
        if (!self.fault.is_none() || self.system.spare_chiplets > 0)
            && self.has_hetero_classes()
        {
            return err(
                "fault injection and spare chiplets are not yet supported with \
                 heterogeneous chiplet classes"
                    .into(),
            );
        }
        let v = &self.variation;
        if !(v.sigma_program >= 0.0 && v.sigma_program.is_finite()) {
            return err(format!(
                "variation sigma_program {} must be finite and >= 0",
                v.sigma_program
            ));
        }
        if !(0.0..1.0).contains(&v.drift_nu) {
            return err(format!(
                "variation drift_nu {} must be in [0, 1) (power-law exponent)",
                v.drift_nu
            ));
        }
        if !(v.drift_time_s > 0.0 && v.drift_time_s.is_finite()) {
            return err(format!(
                "variation drift_time_s {} must be finite and > 0",
                v.drift_time_s
            ));
        }
        if !(v.drift_t0_s > 0.0 && v.drift_t0_s.is_finite()) {
            return err(format!(
                "variation drift_t0_s {} must be finite and > 0",
                v.drift_t0_s
            ));
        }
        if !(0.0..1.0).contains(&v.stuck_at_on) {
            return err(format!(
                "variation stuck_at_on {} must be in [0, 1)",
                v.stuck_at_on
            ));
        }
        if !(0.0..1.0).contains(&v.stuck_at_off) {
            return err(format!(
                "variation stuck_at_off {} must be in [0, 1)",
                v.stuck_at_off
            ));
        }
        if !(v.adc_offset_lsb >= 0.0 && v.adc_offset_lsb.is_finite()) {
            return err(format!(
                "variation adc_offset_lsb {} must be finite and >= 0",
                v.adc_offset_lsb
            ));
        }
        if v.redundant_cols >= self.chiplet.xbar_cols {
            return err(format!(
                "variation redundant_cols {} must be < crossbar columns {}",
                v.redundant_cols, self.chiplet.xbar_cols
            ));
        }
        if v.mc_samples == 0 {
            return err("variation mc_samples must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&v.accuracy_floor) {
            return err(format!(
                "variation accuracy_floor {} must be in [0, 1]",
                v.accuracy_floor
            ));
        }
        if !(v.refresh_interval_s >= 0.0 && v.refresh_interval_s.is_finite()) {
            return err(format!(
                "variation refresh_interval_s {} must be finite and >= 0 (0 = never)",
                v.refresh_interval_s
            ));
        }
        if !v.is_none() && self.has_hetero_classes() {
            return err(
                "analog variation modeling is not yet supported with \
                 heterogeneous chiplet classes"
                    .into(),
            );
        }
        if !(self.sweep.halving_keep.is_finite()
            && self.sweep.halving_keep > 0.0
            && self.sweep.halving_keep <= 1.0)
        {
            return err(format!(
                "sweep halving_keep {} must be finite and in (0, 1]",
                self.sweep.halving_keep
            ));
        }
        if self.sweep.cache_file.as_deref() == Some("") {
            return err("sweep cache_file must be a non-empty path".into());
        }
        if self.serve.fail_at_request.is_some() {
            if self.serve.mode != ServeMode::Open {
                return err("serve fail_at_request requires mode = \"open\"".into());
            }
            if !(self.serve.remap_latency_us >= 0.0 && self.serve.remap_latency_us.is_finite()) {
                return err(format!(
                    "serve remap_latency_us {} must be finite and >= 0",
                    self.serve.remap_latency_us
                ));
            }
        }
        if !self.decode.is_default() {
            if self.decode.max_new_tokens == 0 {
                return err("decode max_new_tokens must be >= 1".into());
            }
            if !(1..=32).contains(&self.decode.kv_precision_bits) {
                return err(format!(
                    "decode kv_precision_bits {} must be in 1..=32",
                    self.decode.kv_precision_bits
                ));
            }
            if self.decode.batch_cap == 0 {
                return err("decode batch_cap must be >= 1".into());
            }
            if self.serve.mode == ServeMode::Closed
                && self.decode.batch_cap < self.serve.concurrency
            {
                return err(format!(
                    "decode batch_cap {} must be >= serve concurrency {} \
                     in closed-loop mode (every client needs a batch slot)",
                    self.decode.batch_cap, self.serve.concurrency
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        assert!(SiamConfig::default().validate().is_ok());
    }

    #[test]
    fn homogeneous_requires_count() {
        let mut cfg = SiamConfig::default();
        cfg.system.structure = ChipletStructure::Homogeneous;
        cfg.system.total_chiplets = None;
        assert!(cfg.validate().is_err());
        cfg.system.total_chiplets = Some(36);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn mux_must_divide_columns() {
        let mut cfg = SiamConfig::default();
        cfg.chiplet.cols_per_adc = 7;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn sparsity_range_checked() {
        let mut cfg = SiamConfig::default();
        cfg.dnn.sparsity = Some(vec![0.5, 1.5]);
        assert!(cfg.validate().is_err());
        cfg.dnn.sparsity = Some(vec![0.0, 0.9]);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn subset_fraction_bounds() {
        let mut cfg = SiamConfig::default();
        cfg.dram.subset_fraction = 0.0;
        assert!(cfg.validate().is_err());
        cfg.dram.subset_fraction = 1.0;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn error_message_is_actionable() {
        let mut cfg = SiamConfig::default();
        cfg.chiplet.adc_bits = 0;
        let e = cfg.validate().unwrap_err();
        assert!(e.to_string().contains("ADC"));
    }

    #[test]
    fn model_names_resolve_at_validate_time() {
        // a typo'd model fails validation, not mid-run
        let mut cfg = SiamConfig::default();
        cfg.dnn.model = "resent110".into();
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("dnn.model"), "{e}");
        // a missing file: model fails validation with the path
        let mut cfg = SiamConfig::default();
        cfg.dnn.model = "file:/definitely/not/here.toml".into();
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("does not exist"), "{e}");
        // workload mixes resolve too (model and model:dataset forms)
        let mut cfg = SiamConfig::default();
        cfg.serve.workloads = vec!["vgg19:cifar100".into(), "alexnet".into()];
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("alexnet"), "{e}");
        cfg.serve.workloads = vec!["vgg19:cifar100".into(), "lenet5".into()];
        assert!(cfg.validate().is_ok());
        // bad dataset half of a workload entry
        cfg.serve.workloads = vec!["vgg19:svhn".into()];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn variation_block_checked() {
        let mut cfg = SiamConfig::default();
        cfg.variation.sigma_program = -0.1;
        assert!(cfg.validate().is_err());
        cfg.variation.sigma_program = 0.1;
        assert!(cfg.validate().is_ok());
        cfg.variation.drift_nu = 1.0; // exponent >= 1 rejected
        assert!(cfg.validate().is_err());
        cfg.variation.drift_nu = 0.1;
        cfg.variation.stuck_at_on = 1.0;
        assert!(cfg.validate().is_err());
        cfg.variation.stuck_at_on = 0.01;
        cfg.variation.mc_samples = 0;
        assert!(cfg.validate().is_err());
        cfg.variation.mc_samples = 16;
        cfg.variation.redundant_cols = cfg.chiplet.xbar_cols;
        assert!(cfg.validate().is_err());
        cfg.variation.redundant_cols = 4;
        cfg.variation.drift_time_s = 0.0;
        assert!(cfg.validate().is_err());
        cfg.variation.drift_time_s = 3600.0;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn sweep_block_checked() {
        let mut cfg = SiamConfig::default();
        cfg.sweep.halving_keep = 0.0;
        assert!(cfg.validate().is_err());
        cfg.sweep.halving_keep = 1.5;
        assert!(cfg.validate().is_err());
        cfg.sweep.halving_keep = f64::NAN;
        assert!(cfg.validate().is_err());
        cfg.sweep.halving_keep = 1.0;
        assert!(cfg.validate().is_ok());
        cfg.sweep.cache_file = Some("".into());
        assert!(cfg.validate().is_err());
        cfg.sweep.cache_file = Some("epochs.cache".into());
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn serve_block_checked() {
        let mut cfg = SiamConfig::default();
        cfg.serve.rate_qps = -1.0;
        assert!(cfg.validate().is_err());
        cfg.serve.rate_qps = 0.0; // auto is allowed
        assert!(cfg.validate().is_ok());
        cfg.serve.queue_depth = 0;
        assert!(cfg.validate().is_err());
        cfg.serve.queue_depth = 4;
        cfg.serve.requests = 0;
        assert!(cfg.validate().is_err());
        cfg.serve.requests = 16;
        cfg.serve.workloads = vec!["resnet110".into(), "".into()];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn decode_block_checked() {
        let mut cfg = SiamConfig::default();
        cfg.decode.max_new_tokens = 0;
        assert!(cfg.validate().is_err());
        cfg.decode.max_new_tokens = 16;
        assert!(cfg.validate().is_ok());
        cfg.decode.kv_precision_bits = 0;
        assert!(cfg.validate().is_err());
        cfg.decode.kv_precision_bits = 33;
        assert!(cfg.validate().is_err());
        cfg.decode.kv_precision_bits = 16;
        cfg.decode.batch_cap = 0;
        assert!(cfg.validate().is_err());
        // closed loop: every client needs a batch slot
        cfg.decode.batch_cap = 2;
        cfg.serve.mode = ServeMode::Closed;
        cfg.serve.concurrency = 4;
        assert!(cfg.validate().is_err());
        cfg.decode.batch_cap = 4;
        assert!(cfg.validate().is_ok());
        // open loop has no concurrency floor
        cfg.serve.mode = ServeMode::Open;
        cfg.decode.batch_cap = 2;
        assert!(cfg.validate().is_ok());
    }
}
