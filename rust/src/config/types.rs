//! Configuration data types — one struct per block of Table 2.


/// Memory cell technology of the IMC crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemCell {
    /// Resistive RAM crosspoint cell.
    Rram,
    /// 6T SRAM bitcell used as an IMC cell.
    Sram,
}

/// Crossbar read-out: one row at a time (sequential) or all rows in
/// parallel with analog summation on the bitline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReadOut {
    /// One row at a time (digital-friendly, slow).
    Sequential,
    /// All rows at once with analog bitline summation.
    Parallel,
}

/// On-chip buffer implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufferType {
    /// SRAM banks (dense, slower).
    Sram,
    /// Register file (fast, area/energy hungry).
    RegisterFile,
}

/// Intra-chiplet interconnect topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NocTopology {
    /// 2-D mesh with X-Y wormhole routing (the paper's default).
    Mesh,
    /// Binary tree (modeled analytically like the H-tree).
    Tree,
    /// NeuroSim-style H-tree.
    HTree,
}

/// Whole-system integration style.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChipMode {
    /// One large die, no NoP (the Fig. 1/13 baseline).
    Monolithic,
    /// 2.5-D chiplet system on a passive interposer.
    Chiplet,
}

/// Chiplet allocation policy (Section 4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChipletStructure {
    /// Fixed, user-supplied chiplet count; error if the DNN does not fit.
    Homogeneous,
    /// Exactly as many chiplets as the DNN needs.
    Custom,
}

/// DRAM standard for the external-memory chiplet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DramKind {
    /// DDR3-1600 timing/energy (Micron [26]).
    Ddr3,
    /// DDR4-2400 timing/energy (Micron [27]).
    Ddr4,
}

/// DNN algorithm block of Table 2.
#[derive(Debug, Clone)]
pub struct DnnConfig {
    /// Model-zoo name: lenet5, resnet20/56/110, resnet50, vgg16, vgg19,
    /// densenet110, drivenet, nin.
    pub model: String,
    /// cifar10 | cifar100 | imagenet (sets input resolution / classes).
    pub dataset: String,
    /// Weight precision N_bits (Eq. 1).
    pub weight_precision: u8,
    /// Activation precision (bit-serial input cycles).
    pub activation_precision: u8,
    /// Optional layer-wise weight sparsity in [0,1); scales mapped cells.
    pub sparsity: Option<Vec<f64>>,
    /// Inference batch size (the paper evaluates batch 1).
    pub batch: usize,
}

impl Default for DnnConfig {
    fn default() -> Self {
        DnnConfig {
            model: "resnet110".into(),
            dataset: "cifar10".into(),
            weight_precision: 8,
            activation_precision: 8,
            sparsity: None,
            batch: 1,
        }
    }
}

/// Device & technology block of Table 2.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// CMOS technology node, nm (the paper evaluates 32 nm).
    pub tech_node_nm: u32,
    /// IMC memory-cell technology.
    pub cell: MemCell,
    /// Levels per RRAM cell as bits (1 => binary cell).
    pub bits_per_cell: u8,
    /// RRAM on-resistance, ohms.
    pub r_on: f64,
    /// Off/on resistance ratio (paper: 100).
    pub r_off_ratio: f64,
    /// Read voltage, volts.
    pub v_read: f64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        DeviceConfig {
            tech_node_nm: 32,
            cell: MemCell::Rram,
            bits_per_cell: 1,
            r_on: 1.0e5,
            r_off_ratio: 100.0,
            v_read: 0.15,
        }
    }
}

/// Intra-chiplet architecture block of Table 2.
#[derive(Debug, Clone)]
pub struct ChipletConfig {
    /// IMC crossbar rows (PE_x in Eq. 1).
    pub xbar_rows: usize,
    /// IMC crossbar columns (PE_y in Eq. 1).
    pub xbar_cols: usize,
    /// IMC tiles per chiplet ("Chiplet Size" input).
    pub tiles_per_chiplet: usize,
    /// Crossbar arrays per tile (paper: 16).
    pub xbars_per_tile: usize,
    /// Implementation of the tile/chiplet buffers.
    pub buffer_type: BufferType,
    /// Flash-ADC resolution, bits.
    pub adc_bits: u8,
    /// Columns sharing one ADC via the column mux (paper: 8).
    pub cols_per_adc: usize,
    /// Crossbar read-out scheme.
    pub read_out: ReadOut,
    /// Intra-chiplet interconnect topology.
    pub noc_topology: NocTopology,
    /// NoC channel (flit) width, bits.
    pub noc_width: usize,
    /// NoC router input-buffer depth in flits.
    pub noc_buffer_depth: usize,
    /// Chiplet logic & NoC clock, MHz.
    pub frequency_mhz: f64,
}

impl Default for ChipletConfig {
    fn default() -> Self {
        ChipletConfig {
            xbar_rows: 128,
            xbar_cols: 128,
            tiles_per_chiplet: 16,
            xbars_per_tile: 16,
            buffer_type: BufferType::Sram,
            adc_bits: 4,
            cols_per_adc: 8,
            read_out: ReadOut::Parallel,
            noc_topology: NocTopology::Mesh,
            noc_width: 32,
            noc_buffer_depth: 4,
            frequency_mhz: 1000.0,
        }
    }
}

/// Network-on-package parameters (Section 4.4, defaults from [30] —
/// Poulton et al. ground-referenced signaling).
#[derive(Debug, Clone)]
pub struct NopConfig {
    /// NoP packet/router clock, MHz (paper: 250 MHz bandwidth).
    pub frequency_mhz: f64,
    /// Serial lane rate, Gb/s (GRS lanes are multi-Gb/s serial links —
    /// Poulton et al. run 20 Gb/s; the conservative default of 1 matches the paper's 250 MHz x 32-lane NoP budget with 4:1 serialization).
    pub gbps_per_lane: f64,
    /// Energy per bit of the TX/RX pair, pJ/bit (paper: 0.54).
    pub ebit_pj: f64,
    /// Parallel TX/RX lanes per link ("NoP channel width", paper: 32).
    pub channel_width: usize,
    /// TX+RX macro area per channel, µm² (paper: 5304).
    pub txrx_area_um2: f64,
    /// Clocking circuit (LC-PLL) area, µm² (paper: 10609).
    pub clocking_area_um2: f64,
    /// Data lanes sharing one clocking lane (SIMBA: 4).
    pub lanes_per_clock: usize,
    /// Interposer wire length between adjacent chiplets, mm.
    pub wire_length_mm: f64,
    /// NoP wire pitch, µm (shielded GRS wiring; ~56× on-chip pitch).
    pub wire_pitch_um: f64,
    /// Wire resistance per mm, ohm (PTM interposer global wire).
    pub wire_r_ohm_per_mm: f64,
    /// Wire capacitance per mm, fF (PTM interposer global wire).
    pub wire_c_ff_per_mm: f64,
    /// NoP router ports (paper default: 5).
    pub router_ports: usize,
}

impl NopConfig {
    /// Bits moved per NoP packet-clock cycle over one link:
    /// lanes × (lane rate / packet clock).
    pub fn bits_per_cycle(&self) -> u64 {
        let per_lane = (self.gbps_per_lane * 1000.0 / self.frequency_mhz).max(1.0);
        (self.channel_width as f64 * per_lane).round() as u64
    }
}

impl Default for NopConfig {
    fn default() -> Self {
        NopConfig {
            frequency_mhz: 250.0,
            gbps_per_lane: 1.0,
            ebit_pj: 0.54,
            channel_width: 32,
            txrx_area_um2: 5304.0,
            clocking_area_um2: 10609.0,
            lanes_per_clock: 4,
            wire_length_mm: 2.5,
            wire_pitch_um: 5.6, // 56× the 0.1 µm on-chip intermediate pitch
            wire_r_ohm_per_mm: 25.0,
            wire_c_ff_per_mm: 200.0,
            router_ports: 5,
        }
    }
}

/// DRAM engine parameters (Section 4.5).
#[derive(Debug, Clone)]
pub struct DramConfig {
    /// DRAM standard of the memory chiplet.
    pub kind: DramKind,
    /// Data-bus width, bits (x64 DIMM).
    pub bus_bits: usize,
    /// Instruction-subset fraction used by the fast estimator (Fig. 7a):
    /// 1.0 = simulate everything, 0.5 = simulate half and extrapolate.
    pub subset_fraction: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            kind: DramKind::Ddr4,
            bus_bits: 64,
            subset_fraction: 0.5,
        }
    }
}

/// Chiplet placement policy on the interposer mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PlacementPolicy {
    /// Row-major snake order (the paper's sequential-chain embedding;
    /// bit-identical to every pre-heterogeneity release).
    #[default]
    RowMajor,
    /// Dataflow-aware: order chiplets to minimize the weighted NoP
    /// hop-distance of the inter-layer traffic (greedy construction +
    /// pairwise-swap refinement; see `mapping::Placement::dataflow`).
    Dataflow,
}

/// One heterogeneous chiplet class (`[[system.chiplet_class]]` in TOML).
///
/// A class bundles the device technology, crossbar geometry and NoP
/// driver figures of one *kind* of chiplet; the class-aware packer
/// (`mapping::map_dnn`) assigns every weight layer to the cheapest class
/// that fits. Fields omitted in TOML inherit the base `[device]` /
/// `[chiplet]` / `[system.nop]` values, so a bare
/// `[[system.chiplet_class]]` block reproduces the homogeneous system.
///
/// The monolithic / homogeneous / custom structures are degenerate
/// single-class cases: one class identical to the base config with
/// `count` unset behaves exactly like `structure = "custom"`, and with
/// `count` set like `structure = "homogeneous"` (asserted bit-for-bit
/// by regression tests).
#[derive(Debug, Clone, PartialEq)]
pub struct ChipletClassConfig {
    /// Class name used in reports (e.g. `"big"`, `"little"`).
    pub name: String,
    /// Chiplets of this class the package provides; `None` = build as
    /// many as the packer needs (the custom-structure rule per class).
    pub count: Option<usize>,
    /// IMC memory-cell technology of this class.
    pub cell: MemCell,
    /// Levels per cell as bits (1 => binary cell).
    pub bits_per_cell: u8,
    /// Crossbar rows of this class.
    pub xbar_rows: usize,
    /// Crossbar columns of this class.
    pub xbar_cols: usize,
    /// IMC tiles per chiplet of this class.
    pub tiles_per_chiplet: usize,
    /// Crossbar arrays per tile of this class.
    pub xbars_per_tile: usize,
    /// Flash-ADC resolution of this class, bits (smaller crossbars need
    /// fewer bits to capture the bitline range).
    pub adc_bits: u8,
    /// Columns sharing one ADC in this class (must divide `xbar_cols`).
    pub cols_per_adc: usize,
    /// Chiplet logic & NoC clock of this class, MHz.
    pub frequency_mhz: f64,
    /// NoP TX/RX driver energy of this class, pJ/bit (per-class GRS
    /// macro; hops sourced at a chiplet of this class pay this rate).
    pub nop_ebit_pj: f64,
    /// NoP TX/RX macro area per channel of this class, µm².
    pub nop_txrx_area_um2: f64,
}

impl ChipletClassConfig {
    /// A class inheriting every field from the base `[device]` /
    /// `[chiplet]` / `[system.nop]` blocks of `cfg` (the degenerate
    /// single-class identity).
    pub fn from_base(cfg: &SiamConfig, name: &str) -> ChipletClassConfig {
        ChipletClassConfig {
            name: name.to_string(),
            count: None,
            cell: cfg.device.cell,
            bits_per_cell: cfg.device.bits_per_cell,
            xbar_rows: cfg.chiplet.xbar_rows,
            xbar_cols: cfg.chiplet.xbar_cols,
            tiles_per_chiplet: cfg.chiplet.tiles_per_chiplet,
            xbars_per_tile: cfg.chiplet.xbars_per_tile,
            adc_bits: cfg.chiplet.adc_bits,
            cols_per_adc: cfg.chiplet.cols_per_adc,
            frequency_mhz: cfg.chiplet.frequency_mhz,
            nop_ebit_pj: cfg.system.nop.ebit_pj,
            nop_txrx_area_um2: cfg.system.nop.txrx_area_um2,
        }
    }

    /// Crossbars one chiplet of this class holds.
    pub fn capacity_xbars(&self) -> usize {
        self.tiles_per_chiplet * self.xbars_per_tile
    }

    /// Clock period of this class's chiplet logic, ns.
    pub fn clock_period_ns(&self) -> f64 {
        1.0e3 / self.frequency_mhz
    }

    /// The base NoP block with this class's driver figures substituted
    /// (wire/protocol parameters stay package-wide).
    pub fn nop_effective(&self, base: &NopConfig) -> NopConfig {
        let mut nop = base.clone();
        nop.ebit_pj = self.nop_ebit_pj;
        nop.txrx_area_um2 = self.nop_txrx_area_um2;
        nop
    }
}

/// Seeded fault-injection block (`[fault]`): which dies and devices are
/// broken before the run starts.
///
/// Faults degrade per-chiplet crossbar capacity: a killed chiplet drops
/// to zero, a crossbar fault fraction removes a seeded random subset of
/// every surviving chiplet's crossbars. The mapping pipeline then
/// repacks the DNN onto the surviving capacity (plus any
/// `[system] spare_chiplets`) — see `fault` module docs and
/// docs/RELIABILITY.md. The default block injects nothing and leaves
/// every report bit-identical to a build without the fault subsystem.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Chiplet ids killed outright (known-bad dies). Ids index the
    /// mapped system including spares; out-of-range ids are a runtime
    /// error once the chiplet count is known.
    pub kill_chiplets: Vec<usize>,
    /// Per-chiplet survival probability for seeded random kills, in
    /// (0, 1]. `1.0` = no random kills. Set from the Appendix-A model as
    /// `exp(-D0 · A_chiplet)` (`cost::CostModel::yield_of`) to model
    /// known-good-die escapes at the paper's defect density.
    pub die_yield: f64,
    /// Fraction of each surviving chiplet's crossbars that are faulty,
    /// in [0, 1). Each crossbar fails independently (seeded draw).
    pub xbar_fault_fraction: f64,
    /// Seed of the splitmix64 fault-draw RNG. All draws — random kills
    /// and crossbar faults — come from this one stream, so a `(config,
    /// seed)` pair is bit-reproducible.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            kill_chiplets: Vec::new(),
            die_yield: 1.0,
            xbar_fault_fraction: 0.0,
            seed: 42,
        }
    }
}

impl FaultConfig {
    /// True when the block injects nothing (the default): no kill list,
    /// no random kills, no crossbar faults. The pipeline routes such
    /// configs through the classic fault-free path bit-for-bit.
    pub fn is_none(&self) -> bool {
        self.kill_chiplets.is_empty()
            && self.die_yield >= 1.0
            && self.xbar_fault_fraction <= 0.0
    }
}

/// Analog device-variation block (`[variation]`): non-idealities of the
/// programmed conductances and the read-out chain, plus the mitigation
/// knobs that trade energy for accuracy.
///
/// Where `[fault]` removes digital capacity (dies, crossbars), this
/// block perturbs the *analog* values that survive: lognormal
/// programming noise per cell, power-law retention drift
/// `G(t) = G0·(t/t0)^(-ν)`, stuck-at-Gon/Goff cell fractions and ADC
/// input offset. The variation engine (`crate::variation`) propagates
/// them analytically per layer into an accuracy-loss proxy and a
/// perturbed read energy — never by retraining. Parameter ranges follow
/// IMAC-Sim (arXiv 2304.09252). The default block is inert and leaves
/// every report bit-identical to a build without the subsystem.
#[derive(Debug, Clone, PartialEq)]
pub struct VariationConfig {
    /// Lognormal programming-noise sigma of `ln G` per freshly
    /// programmed cell, ≥ 0. `0` = ideal programming.
    pub sigma_program: f64,
    /// Write-verify iterations per programmed cell. Each cycle shrinks
    /// the effective programming sigma (×0.7 per cycle) and charges
    /// program energy/latency — the costed mitigation knob.
    pub write_verify_cycles: u32,
    /// Drift exponent ν of the power law `G(t) = G0·(t/t0)^(-ν)`, in
    /// [0, 1). `0` = no retention drift.
    pub drift_nu: f64,
    /// Retention time t at which conductances are read, seconds (> 0).
    pub drift_time_s: f64,
    /// Drift reference time t0, seconds (> 0). Drift accrues only for
    /// `t > t0`.
    pub drift_t0_s: f64,
    /// Fraction of cells stuck at G_on, in [0, 1).
    pub stuck_at_on: f64,
    /// Fraction of cells stuck at G_off, in [0, 1).
    pub stuck_at_off: f64,
    /// ADC input-referred offset, in LSB at the configured `adc_bits`,
    /// ≥ 0.
    pub adc_offset_lsb: f64,
    /// Redundant columns per crossbar for stuck-cell repair. Charged as
    /// a proportional read-energy overhead; repairs a matching share of
    /// the stuck-at population.
    pub redundant_cols: usize,
    /// Monte-Carlo samples per evaluation, ≥ 1.
    pub mc_samples: usize,
    /// Accuracy-proxy floor in [0, 1] for the variation-aware sweep
    /// mode: design points whose expected proxy falls below it are
    /// pruned from the ranking.
    pub accuracy_floor: f64,
    /// Serving drift-refresh interval, seconds; `0` = never refresh.
    /// Refresh caps retention aging at the interval and steals stage
    /// service time for the reprogramming pass.
    pub refresh_interval_s: f64,
    /// Seed of the splitmix64 variation-draw RNG — a stream independent
    /// of the `[fault]` and `[serve]` streams, so a `(config, seed)`
    /// pair is bit-reproducible.
    pub seed: u64,
}

impl Default for VariationConfig {
    fn default() -> Self {
        VariationConfig {
            sigma_program: 0.0,
            write_verify_cycles: 0,
            drift_nu: 0.0,
            drift_time_s: 1.0,
            drift_t0_s: 1.0,
            stuck_at_on: 0.0,
            stuck_at_off: 0.0,
            adc_offset_lsb: 0.0,
            redundant_cols: 0,
            mc_samples: 32,
            accuracy_floor: 0.9,
            refresh_interval_s: 0.0,
            seed: 42,
        }
    }
}

impl VariationConfig {
    /// True when the block perturbs nothing (the default): no noise
    /// source and no mitigation knob is active. The pipeline routes
    /// such configs through the classic variation-free path bit-for-bit
    /// (sample count, floor and seed alone activate nothing).
    pub fn is_none(&self) -> bool {
        self.sigma_program <= 0.0
            && self.drift_nu <= 0.0
            && self.stuck_at_on <= 0.0
            && self.stuck_at_off <= 0.0
            && self.adc_offset_lsb <= 0.0
            && self.write_verify_cycles == 0
            && self.redundant_cols == 0
            && self.refresh_interval_s <= 0.0
    }
}

/// Inter-chiplet architecture block of Table 2.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Monolithic die or chiplet system.
    pub chip_mode: ChipMode,
    /// Chiplet allocation policy (custom vs homogeneous). Superseded by
    /// `chiplet_classes` when any class is configured.
    pub structure: ChipletStructure,
    /// Homogeneous mode: fixed chiplet count (must be a perfect square for
    /// the mesh placement). Ignored by custom mode.
    pub total_chiplets: Option<usize>,
    /// Heterogeneous chiplet classes (`[[system.chiplet_class]]`).
    /// Empty = the classic single-kind system described by `structure`.
    pub chiplet_classes: Vec<ChipletClassConfig>,
    /// Chiplet placement policy on the interposer mesh.
    pub placement: PlacementPolicy,
    /// Spare chiplets provisioned for failover. Spares sit on the
    /// interposer mesh and are charged in area, leakage and fabrication
    /// cost, but carry no weights until a fault remap spills work onto
    /// them (see docs/RELIABILITY.md). `0` = the classic system,
    /// bit-identical to pre-fault releases.
    pub spare_chiplets: usize,
    /// Global accumulator width, elements accumulated per cycle.
    pub accumulator_size: usize,
    /// Global buffer capacity, kB.
    pub global_buffer_kb: usize,
    /// Network-on-package parameters.
    pub nop: NopConfig,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            chip_mode: ChipMode::Chiplet,
            structure: ChipletStructure::Custom,
            total_chiplets: None,
            chiplet_classes: Vec::new(),
            placement: PlacementPolicy::default(),
            spare_chiplets: 0,
            accumulator_size: 64,
            global_buffer_kb: 256,
            nop: NopConfig::default(),
        }
    }
}

/// Traffic generator of the inference-serving simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ServeMode {
    /// Open loop: Poisson arrivals at a fixed offered rate; requests
    /// that find the ingress queue full are shed (counted as dropped).
    Open,
    /// Closed loop: a fixed number of concurrent clients, each issuing
    /// its next request the instant the previous one completes.
    Closed,
}

/// Inference-serving simulator block (`[serve]`): the streaming-traffic
/// scenario evaluated by `siam serve` and the QoS sweep mode.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Traffic generator: open loop (Poisson) or closed loop (fixed
    /// concurrency).
    pub mode: ServeMode,
    /// Open-loop offered rate, inferences/s. `0.0` = auto (80 % of the
    /// analytic bottleneck-stage service rate).
    pub rate_qps: f64,
    /// Closed-loop concurrent clients.
    pub concurrency: usize,
    /// Requests to stream through the pipeline.
    pub requests: usize,
    /// Bounded per-stage queue depth (back-pressure blocks the upstream
    /// stage when a queue is full).
    pub queue_depth: usize,
    /// Seed of the splitmix64 arrival-time RNG (open loop).
    pub seed: u64,
    /// Workload mix: model names served in turn by `siam serve`
    /// (`"model"` or `"model:dataset"`). Empty = the `[dnn]` model.
    pub workloads: Vec<String>,
    /// QoS target for p99 latency, ms (the `SweepBuilder` QoS mode
    /// ranks design points by p99 under the target offered rate).
    pub qos_p99_ms: f64,
    /// Failover scenario: kill `fail_chiplet` when the open-loop arrival
    /// with this index reaches the system (`None` = no mid-run failure).
    /// Requires `mode = "open"` — closed-loop traffic has no external
    /// clock to anchor the failure to.
    pub fail_at_request: Option<usize>,
    /// The chiplet that dies in the failover scenario.
    pub fail_chiplet: usize,
    /// Time between the failure and the remapped pipeline taking over,
    /// µs (failure detection + weight reload onto spare capacity).
    pub remap_latency_us: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            mode: ServeMode::Open,
            rate_qps: 0.0,
            concurrency: 4,
            requests: 1024,
            queue_depth: 4,
            seed: 42,
            workloads: Vec::new(),
            qos_p99_ms: 10.0,
            fail_at_request: None,
            fail_chiplet: 0,
            remap_latency_us: 100.0,
        }
    }
}

/// Grid traversal strategy of the design-space sweep (`[sweep] search`).
///
/// The pruned modes evaluate a subset of the grid through the full
/// engines, certified by a cheap closed-form lower-bound pass, and
/// provably return the same best point as exhaustion for the active
/// figure of merit (see `docs/CACHING.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SearchMode {
    /// Evaluate every grid point through the full pipeline (default).
    #[default]
    Exhaustive,
    /// Pareto-front pruning over (latency, energy, area): fully evaluate
    /// the cheap-pass front, then discard only points whose cheap lower
    /// bound is strictly dominated in all three axes by an evaluated
    /// point's true vector.
    Pareto,
    /// Successive halving: rank all points by cheap lower-bound score,
    /// promote the best `halving_keep` fraction to full evaluation, then
    /// promote every survivor whose bound still undercuts the best full
    /// score (the round that makes the argmax exact).
    Halving,
}

impl SearchMode {
    /// The mode's TOML / CLI spelling (`[sweep] search = "..."`).
    pub fn as_str(&self) -> &'static str {
        match self {
            SearchMode::Exhaustive => "exhaustive",
            SearchMode::Pareto => "pareto",
            SearchMode::Halving => "halving",
        }
    }
}

/// Design-space sweep block (`[sweep]`): persistent epoch cache and
/// search strategy of `SweepBuilder`. The defaults are inert — no cache
/// file, exhaustive search — and the block is omitted from serialized
/// configs when untouched, keeping default TOML output byte-stable.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    /// Persistent epoch-cache file (`--cache-file`). Created on first
    /// use; later sweeps hydrate the in-memory cache from it and append
    /// what they computed. `None` = in-memory caching only.
    pub cache_file: Option<String>,
    /// Grid traversal strategy (see [`SearchMode`]).
    pub search: SearchMode,
    /// Fraction of cheap-ranked candidates the halving search promotes
    /// to full evaluation per round, in (0, 1].
    pub halving_keep: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            cache_file: None,
            search: SearchMode::Exhaustive,
            halving_keep: 0.5,
        }
    }
}

impl SweepConfig {
    /// True when every field still holds its default: no cache file,
    /// exhaustive search, the stock promotion fraction. Such a block is
    /// not serialized, so pre-sweep configs round-trip byte-identically.
    pub fn is_default(&self) -> bool {
        self.cache_file.is_none()
            && self.search == SearchMode::Exhaustive
            && self.halving_keep == 0.5
    }
}

/// Autoregressive decode-serving block (`[decode]`): the token-level
/// generation scenario evaluated by `siam serve --decode`.
///
/// Generation is modeled as one prefill pass over the prompt followed by
/// `max_new_tokens` decode steps of one token each. Decode steps reuse
/// the weight-stationary mapping — crossbar geometry is sequence-length
/// independent — with dynamic work collapsed to a single token (the
/// `seq1` graph), and each resident sequence charges a KV cache of
/// `2 · causal_layers · dim · kv_precision_bits / 8` bytes per token
/// against the global buffer, spilling to DRAM when it overflows (see
/// `crate::serve::decode`). The defaults are inert: the block is omitted
/// from serialized configs when untouched and nothing changes for
/// encoder/CNN serving, keeping every pre-decode report byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeConfig {
    /// Tokens generated per request after prefill, ≥ 1.
    pub max_new_tokens: usize,
    /// KV-cache element precision, bits per stored key/value scalar,
    /// in 1..=32 (8 = int8 cache, 16 = fp16).
    pub kv_precision_bits: usize,
    /// Continuous-batching occupancy cap: decode steps serve at most
    /// this many resident sequences, ≥ 1. Closed-loop runs require
    /// `batch_cap >= serve.concurrency` so no client starves.
    pub batch_cap: usize,
    /// Prefill chunk size in tokens; `0` = whole-prompt prefill in one
    /// pass, otherwise the prompt is processed in `ceil(seq / chunk)`
    /// sequential chunks (bounds TTFT memory at the cost of latency).
    pub prefill_chunk: usize,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig {
            max_new_tokens: 32,
            kv_precision_bits: 8,
            batch_cap: 8,
            prefill_chunk: 0,
        }
    }
}

impl DecodeConfig {
    /// True when every field still holds its default. Such a block is
    /// not serialized and decode mode stays opt-in (`--decode` / an
    /// explicit `[decode]` block), so pre-decode configs round-trip
    /// byte-identically.
    pub fn is_default(&self) -> bool {
        *self == DecodeConfig::default()
    }
}

/// Complete SIAM configuration (all Table-2 blocks).
#[derive(Debug, Clone, Default)]
pub struct SiamConfig {
    /// DNN algorithm block.
    pub dnn: DnnConfig,
    /// Device & technology block.
    pub device: DeviceConfig,
    /// Intra-chiplet architecture block.
    pub chiplet: ChipletConfig,
    /// Inter-chiplet system block.
    pub system: SystemConfig,
    /// DRAM engine block.
    pub dram: DramConfig,
    /// Inference-serving simulator block.
    pub serve: ServeConfig,
    /// Seeded fault-injection block (defaults inject nothing).
    pub fault: FaultConfig,
    /// Analog device-variation block (defaults perturb nothing).
    pub variation: VariationConfig,
    /// Design-space sweep block (defaults change nothing).
    pub sweep: SweepConfig,
    /// Autoregressive decode-serving block (defaults change nothing).
    pub decode: DecodeConfig,
}
