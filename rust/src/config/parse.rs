//! Minimal TOML-subset parser/writer for SIAM config files.
//!
//! The offline build environment vendors no TOML crate, so we parse the
//! subset the `configs/` presets need: `[section]` / `[section.sub]`
//! headers, `key = value` pairs with string / bool / integer / float /
//! numeric-array / string-array values, and `#` comments (full-line or
//! trailing after a value; `#` inside a quoted string is literal).
//! Unknown keys are an error reported with their line number — catching
//! config typos is part of the validation story.

use super::types::*;
use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Double-quoted string.
    Str(String),
    /// `true` / `false`.
    Bool(bool),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Numeric array (`[0.1, 0.2]`).
    Array(Vec<f64>),
    /// String array (`["a", "b"]`) — e.g. the `[serve]` workload mix.
    StrArray(Vec<String>),
}

impl Value {
    fn parse(raw: &str, line: usize) -> Result<Value, String> {
        let raw = raw.trim();
        if let Some(s) = raw.strip_prefix('"').and_then(|r| r.strip_suffix('"')) {
            // `"x"y"` is a string followed by junk, not a string with a
            // quote in it — the subset has no escapes
            if s.contains('"') {
                return Err(format!("line {line}: stray '\"' inside string value"));
            }
            return Ok(Value::Str(s.to_string()));
        }
        if raw == "true" {
            return Ok(Value::Bool(true));
        }
        if raw == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(inner) = raw.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            // string array when the first element is quoted (elements
            // may not contain commas — model names never do)
            if inner.trim_start().starts_with('"') {
                let mut out = Vec::new();
                for part in inner.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        continue;
                    }
                    let s = part
                        .strip_prefix('"')
                        .and_then(|r| r.strip_suffix('"'))
                        .filter(|s| !s.contains('"'))
                        .ok_or_else(|| {
                            format!("line {line}: bad string-array element '{part}'")
                        })?;
                    out.push(s.to_string());
                }
                return Ok(Value::StrArray(out));
            }
            let mut out = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                out.push(
                    part.parse::<f64>()
                        .map_err(|_| format!("line {line}: bad array element '{part}'"))?,
                );
            }
            return Ok(Value::Array(out));
        }
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(format!("line {line}: cannot parse value '{raw}'"))
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }
}

/// Cut a line at the first `#` that sits outside a double-quoted
/// string, so trailing comments after values are stripped while string
/// values may contain literal `#` characters.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse `text` into flattened `section.key -> (Value, line number)`
/// pairs; line numbers survive into unknown-key / bad-value errors.
///
/// Array-of-tables headers (`[[system.chiplet_class]]`) flatten to
/// zero-padded indexed sections (`system.chiplet_class.0000.<key>`),
/// so repeated blocks keep both their identity and their file order
/// under the map's lexicographic iteration.
pub fn parse_flat(text: &str) -> Result<BTreeMap<String, (Value, usize)>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    let mut array_counts: BTreeMap<String, usize> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        let line = strip_comment(line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
            let name = h.trim().to_string();
            let idx = array_counts.entry(name.clone()).or_insert(0);
            section = format!("{name}.{idx:04}");
            *idx += 1;
            // a marker entry so a block with no keys of its own (legal:
            // every field inherits the base blocks) is still seen by
            // the consumer instead of silently vanishing
            out.insert(format!("{section}.__block__"), (Value::Bool(true), n));
            continue;
        }
        if let Some(h) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = h.trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(format!("line {n}: expected 'key = value', got '{line}'"));
        };
        let k = k.trim();
        if k.is_empty() {
            return Err(format!("line {n}: missing key before '='"));
        }
        let key = if section.is_empty() {
            k.to_string()
        } else {
            format!("{section}.{k}")
        };
        if out.insert(key.clone(), (Value::parse(v, n)?, n)).is_some() {
            // TOML forbids redefining a key; silently letting the last
            // occurrence win hides config typos
            return Err(format!("line {n}: duplicate key '{key}'"));
        }
    }
    Ok(out)
}

macro_rules! take {
    ($map:expr, $key:expr, $slot:expr, $conv:expr) => {
        if let Some((v, line)) = $map.remove($key) {
            $slot = $conv(&v)
                .ok_or_else(|| format!("line {}: bad value for {}", line, $key))?;
        }
    };
}

fn mem_cell(v: &Value) -> Option<MemCell> {
    match v {
        Value::Str(s) if s == "rram" => Some(MemCell::Rram),
        Value::Str(s) if s == "sram" => Some(MemCell::Sram),
        _ => None,
    }
}

fn read_out(v: &Value) -> Option<ReadOut> {
    match v {
        Value::Str(s) if s == "sequential" => Some(ReadOut::Sequential),
        Value::Str(s) if s == "parallel" => Some(ReadOut::Parallel),
        _ => None,
    }
}

fn buffer_type(v: &Value) -> Option<BufferType> {
    match v {
        Value::Str(s) if s == "sram" => Some(BufferType::Sram),
        Value::Str(s) if s == "registerfile" => Some(BufferType::RegisterFile),
        _ => None,
    }
}

fn noc_topology(v: &Value) -> Option<NocTopology> {
    match v {
        Value::Str(s) if s == "mesh" => Some(NocTopology::Mesh),
        Value::Str(s) if s == "tree" => Some(NocTopology::Tree),
        Value::Str(s) if s == "htree" => Some(NocTopology::HTree),
        _ => None,
    }
}

fn chip_mode(v: &Value) -> Option<ChipMode> {
    match v {
        Value::Str(s) if s == "monolithic" => Some(ChipMode::Monolithic),
        Value::Str(s) if s == "chiplet" => Some(ChipMode::Chiplet),
        _ => None,
    }
}

fn structure(v: &Value) -> Option<ChipletStructure> {
    match v {
        Value::Str(s) if s == "homogeneous" => Some(ChipletStructure::Homogeneous),
        Value::Str(s) if s == "custom" => Some(ChipletStructure::Custom),
        _ => None,
    }
}

fn placement(v: &Value) -> Option<PlacementPolicy> {
    match v {
        Value::Str(s) if s == "rowmajor" => Some(PlacementPolicy::RowMajor),
        Value::Str(s) if s == "dataflow" => Some(PlacementPolicy::Dataflow),
        _ => None,
    }
}

fn dram_kind(v: &Value) -> Option<DramKind> {
    match v {
        Value::Str(s) if s == "ddr3" => Some(DramKind::Ddr3),
        Value::Str(s) if s == "ddr4" => Some(DramKind::Ddr4),
        _ => None,
    }
}

fn serve_mode(v: &Value) -> Option<ServeMode> {
    match v {
        Value::Str(s) if s == "open" => Some(ServeMode::Open),
        Value::Str(s) if s == "closed" => Some(ServeMode::Closed),
        _ => None,
    }
}

fn search_mode(v: &Value) -> Option<SearchMode> {
    match v {
        Value::Str(s) if s == "exhaustive" => Some(SearchMode::Exhaustive),
        Value::Str(s) if s == "pareto" => Some(SearchMode::Pareto),
        Value::Str(s) if s == "halving" => Some(SearchMode::Halving),
        _ => None,
    }
}

fn u64v(v: &Value) -> Option<u64> {
    match v {
        Value::Int(i) if *i >= 0 => Some(*i as u64),
        _ => None,
    }
}

fn string(v: &Value) -> Option<String> {
    match v {
        Value::Str(s) => Some(s.clone()),
        _ => None,
    }
}

fn u8v(v: &Value) -> Option<u8> {
    v.as_usize().and_then(|u| u8::try_from(u).ok())
}

/// Numeric array whose every element is a non-negative integer (the
/// `[fault] kill_chiplets` id list).
fn usize_array(v: &Value) -> Option<Vec<usize>> {
    match v {
        Value::Array(a) => a
            .iter()
            .map(|&x| {
                (x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64).then_some(x as usize)
            })
            .collect(),
        _ => None,
    }
}

fn u32v(v: &Value) -> Option<u32> {
    v.as_usize().and_then(|u| u32::try_from(u).ok())
}

/// Apply flattened pairs on top of a default config.
pub fn apply(mut cfg: SiamConfig, text: &str) -> Result<SiamConfig, String> {
    let mut m = parse_flat(text)?;

    take!(m, "dnn.model", cfg.dnn.model, string);
    take!(m, "dnn.dataset", cfg.dnn.dataset, string);
    take!(m, "dnn.weight_precision", cfg.dnn.weight_precision, u8v);
    take!(
        m,
        "dnn.activation_precision",
        cfg.dnn.activation_precision,
        u8v
    );
    take!(m, "dnn.batch", cfg.dnn.batch, Value::as_usize);
    if let Some((v, line)) = m.remove("dnn.sparsity") {
        match v {
            Value::Array(a) => cfg.dnn.sparsity = Some(a),
            _ => return Err(format!("line {line}: dnn.sparsity must be an array")),
        }
    }

    take!(m, "device.tech_node_nm", cfg.device.tech_node_nm, u32v);
    take!(m, "device.cell", cfg.device.cell, mem_cell);
    take!(m, "device.bits_per_cell", cfg.device.bits_per_cell, u8v);
    take!(m, "device.r_on", cfg.device.r_on, Value::as_f64);
    take!(m, "device.r_off_ratio", cfg.device.r_off_ratio, Value::as_f64);
    take!(m, "device.v_read", cfg.device.v_read, Value::as_f64);

    take!(m, "chiplet.xbar_rows", cfg.chiplet.xbar_rows, Value::as_usize);
    take!(m, "chiplet.xbar_cols", cfg.chiplet.xbar_cols, Value::as_usize);
    take!(
        m,
        "chiplet.tiles_per_chiplet",
        cfg.chiplet.tiles_per_chiplet,
        Value::as_usize
    );
    take!(
        m,
        "chiplet.xbars_per_tile",
        cfg.chiplet.xbars_per_tile,
        Value::as_usize
    );
    take!(m, "chiplet.buffer_type", cfg.chiplet.buffer_type, buffer_type);
    take!(m, "chiplet.adc_bits", cfg.chiplet.adc_bits, u8v);
    take!(
        m,
        "chiplet.cols_per_adc",
        cfg.chiplet.cols_per_adc,
        Value::as_usize
    );
    take!(m, "chiplet.read_out", cfg.chiplet.read_out, read_out);
    take!(m, "chiplet.noc_topology", cfg.chiplet.noc_topology, noc_topology);
    take!(m, "chiplet.noc_width", cfg.chiplet.noc_width, Value::as_usize);
    take!(
        m,
        "chiplet.noc_buffer_depth",
        cfg.chiplet.noc_buffer_depth,
        Value::as_usize
    );
    take!(
        m,
        "chiplet.frequency_mhz",
        cfg.chiplet.frequency_mhz,
        Value::as_f64
    );

    take!(m, "system.chip_mode", cfg.system.chip_mode, chip_mode);
    take!(m, "system.structure", cfg.system.structure, structure);
    if let Some((v, line)) = m.remove("system.total_chiplets") {
        cfg.system.total_chiplets = Some(v.as_usize().ok_or(format!(
            "line {line}: bad value for system.total_chiplets"
        ))?);
    }
    take!(m, "system.placement", cfg.system.placement, placement);
    take!(
        m,
        "system.spare_chiplets",
        cfg.system.spare_chiplets,
        Value::as_usize
    );
    take!(
        m,
        "system.accumulator_size",
        cfg.system.accumulator_size,
        Value::as_usize
    );
    take!(
        m,
        "system.global_buffer_kb",
        cfg.system.global_buffer_kb,
        Value::as_usize
    );

    take!(
        m,
        "system.nop.frequency_mhz",
        cfg.system.nop.frequency_mhz,
        Value::as_f64
    );
    take!(m, "system.nop.ebit_pj", cfg.system.nop.ebit_pj, Value::as_f64);
    take!(
        m,
        "system.nop.gbps_per_lane",
        cfg.system.nop.gbps_per_lane,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.channel_width",
        cfg.system.nop.channel_width,
        Value::as_usize
    );
    take!(
        m,
        "system.nop.txrx_area_um2",
        cfg.system.nop.txrx_area_um2,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.clocking_area_um2",
        cfg.system.nop.clocking_area_um2,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.lanes_per_clock",
        cfg.system.nop.lanes_per_clock,
        Value::as_usize
    );
    take!(
        m,
        "system.nop.wire_length_mm",
        cfg.system.nop.wire_length_mm,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.wire_pitch_um",
        cfg.system.nop.wire_pitch_um,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.wire_r_ohm_per_mm",
        cfg.system.nop.wire_r_ohm_per_mm,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.wire_c_ff_per_mm",
        cfg.system.nop.wire_c_ff_per_mm,
        Value::as_f64
    );
    take!(
        m,
        "system.nop.router_ports",
        cfg.system.nop.router_ports,
        Value::as_usize
    );

    take!(m, "dram.kind", cfg.dram.kind, dram_kind);
    take!(m, "dram.bus_bits", cfg.dram.bus_bits, Value::as_usize);
    take!(
        m,
        "dram.subset_fraction",
        cfg.dram.subset_fraction,
        Value::as_f64
    );

    take!(m, "serve.mode", cfg.serve.mode, serve_mode);
    take!(m, "serve.rate_qps", cfg.serve.rate_qps, Value::as_f64);
    take!(m, "serve.concurrency", cfg.serve.concurrency, Value::as_usize);
    take!(m, "serve.requests", cfg.serve.requests, Value::as_usize);
    take!(m, "serve.queue_depth", cfg.serve.queue_depth, Value::as_usize);
    take!(m, "serve.seed", cfg.serve.seed, u64v);
    take!(m, "serve.qos_p99_ms", cfg.serve.qos_p99_ms, Value::as_f64);
    if let Some((v, line)) = m.remove("serve.fail_at_request") {
        cfg.serve.fail_at_request = Some(v.as_usize().ok_or(format!(
            "line {line}: bad value for serve.fail_at_request"
        ))?);
    }
    take!(m, "serve.fail_chiplet", cfg.serve.fail_chiplet, Value::as_usize);
    take!(
        m,
        "serve.remap_latency_us",
        cfg.serve.remap_latency_us,
        Value::as_f64
    );
    if let Some((v, line)) = m.remove("serve.workloads") {
        match v {
            Value::StrArray(a) => cfg.serve.workloads = a,
            // `[]` parses as an empty numeric array
            Value::Array(a) if a.is_empty() => cfg.serve.workloads = Vec::new(),
            _ => {
                return Err(format!(
                    "line {line}: serve.workloads must be a string array"
                ))
            }
        }
    }

    take!(m, "fault.kill_chiplets", cfg.fault.kill_chiplets, usize_array);
    take!(m, "fault.die_yield", cfg.fault.die_yield, Value::as_f64);
    take!(
        m,
        "fault.xbar_fault_fraction",
        cfg.fault.xbar_fault_fraction,
        Value::as_f64
    );
    take!(m, "fault.seed", cfg.fault.seed, u64v);

    take!(
        m,
        "variation.sigma_program",
        cfg.variation.sigma_program,
        Value::as_f64
    );
    take!(
        m,
        "variation.write_verify_cycles",
        cfg.variation.write_verify_cycles,
        u32v
    );
    take!(m, "variation.drift_nu", cfg.variation.drift_nu, Value::as_f64);
    take!(
        m,
        "variation.drift_time_s",
        cfg.variation.drift_time_s,
        Value::as_f64
    );
    take!(
        m,
        "variation.drift_t0_s",
        cfg.variation.drift_t0_s,
        Value::as_f64
    );
    take!(m, "variation.stuck_at_on", cfg.variation.stuck_at_on, Value::as_f64);
    take!(
        m,
        "variation.stuck_at_off",
        cfg.variation.stuck_at_off,
        Value::as_f64
    );
    take!(
        m,
        "variation.adc_offset_lsb",
        cfg.variation.adc_offset_lsb,
        Value::as_f64
    );
    take!(
        m,
        "variation.redundant_cols",
        cfg.variation.redundant_cols,
        Value::as_usize
    );
    take!(
        m,
        "variation.mc_samples",
        cfg.variation.mc_samples,
        Value::as_usize
    );
    take!(
        m,
        "variation.accuracy_floor",
        cfg.variation.accuracy_floor,
        Value::as_f64
    );
    take!(
        m,
        "variation.refresh_interval_s",
        cfg.variation.refresh_interval_s,
        Value::as_f64
    );
    take!(m, "variation.seed", cfg.variation.seed, u64v);

    if let Some((v, line)) = m.remove("sweep.cache_file") {
        cfg.sweep.cache_file = Some(
            string(&v).ok_or(format!("line {line}: bad value for sweep.cache_file"))?,
        );
    }
    take!(m, "sweep.search", cfg.sweep.search, search_mode);
    take!(m, "sweep.halving_keep", cfg.sweep.halving_keep, Value::as_f64);

    take!(
        m,
        "decode.max_new_tokens",
        cfg.decode.max_new_tokens,
        Value::as_usize
    );
    take!(
        m,
        "decode.kv_precision_bits",
        cfg.decode.kv_precision_bits,
        Value::as_usize
    );
    take!(m, "decode.batch_cap", cfg.decode.batch_cap, Value::as_usize);
    take!(
        m,
        "decode.prefill_chunk",
        cfg.decode.prefill_chunk,
        Value::as_usize
    );

    // ---- [[system.chiplet_class]] blocks: fields omitted in a block
    // inherit the base [device]/[chiplet]/[system.nop] values parsed
    // above, so a bare block is the degenerate identity class.
    const CLASS_PREFIX: &str = "system.chiplet_class.";
    let mut class_ids: Vec<String> = m
        .keys()
        .filter_map(|k| k.strip_prefix(CLASS_PREFIX))
        .filter_map(|rest| rest.split_once('.').map(|(idx, _)| idx.to_string()))
        .collect();
    class_ids.sort();
    class_ids.dedup();
    for idx in class_ids {
        let mut class =
            ChipletClassConfig::from_base(&cfg, &format!("class{}", cfg.system.chiplet_classes.len()));
        let p = |field: &str| format!("{CLASS_PREFIX}{idx}.{field}");
        m.remove(&p("__block__"));
        take!(m, &p("name"), class.name, string);
        if let Some((v, line)) = m.remove(&p("count")) {
            class.count = Some(v.as_usize().ok_or(format!(
                "line {line}: bad value for {}",
                p("count")
            ))?);
        }
        take!(m, &p("cell"), class.cell, mem_cell);
        take!(m, &p("bits_per_cell"), class.bits_per_cell, u8v);
        take!(m, &p("xbar_rows"), class.xbar_rows, Value::as_usize);
        take!(m, &p("xbar_cols"), class.xbar_cols, Value::as_usize);
        take!(m, &p("tiles_per_chiplet"), class.tiles_per_chiplet, Value::as_usize);
        take!(m, &p("xbars_per_tile"), class.xbars_per_tile, Value::as_usize);
        take!(m, &p("adc_bits"), class.adc_bits, u8v);
        take!(m, &p("cols_per_adc"), class.cols_per_adc, Value::as_usize);
        take!(m, &p("frequency_mhz"), class.frequency_mhz, Value::as_f64);
        take!(m, &p("nop_ebit_pj"), class.nop_ebit_pj, Value::as_f64);
        take!(m, &p("nop_txrx_area_um2"), class.nop_txrx_area_um2, Value::as_f64);
        cfg.system.chiplet_classes.push(class);
    }

    if let Some((k, (_, line))) = m.iter().next() {
        return Err(format!("line {line}: unknown config key '{k}'"));
    }
    Ok(cfg)
}

fn fmt_enum(cfg: &SiamConfig) -> [String; 7] {
    [
        match cfg.device.cell {
            MemCell::Rram => "rram",
            MemCell::Sram => "sram",
        }
        .into(),
        match cfg.chiplet.buffer_type {
            BufferType::Sram => "sram",
            BufferType::RegisterFile => "registerfile",
        }
        .into(),
        match cfg.chiplet.read_out {
            ReadOut::Sequential => "sequential",
            ReadOut::Parallel => "parallel",
        }
        .into(),
        match cfg.chiplet.noc_topology {
            NocTopology::Mesh => "mesh",
            NocTopology::Tree => "tree",
            NocTopology::HTree => "htree",
        }
        .into(),
        match cfg.system.chip_mode {
            ChipMode::Monolithic => "monolithic",
            ChipMode::Chiplet => "chiplet",
        }
        .into(),
        match cfg.system.structure {
            ChipletStructure::Homogeneous => "homogeneous",
            ChipletStructure::Custom => "custom",
        }
        .into(),
        match cfg.dram.kind {
            DramKind::Ddr3 => "ddr3",
            DramKind::Ddr4 => "ddr4",
        }
        .into(),
    ]
}

/// Serialize a config back to the TOML subset.
pub fn write(cfg: &SiamConfig) -> String {
    let [cell, buf, ro, noc, mode, structure, dram] = fmt_enum(cfg);
    let mut s = String::new();
    use std::fmt::Write;
    writeln!(s, "[dnn]").unwrap();
    writeln!(s, "model = \"{}\"", cfg.dnn.model).unwrap();
    writeln!(s, "dataset = \"{}\"", cfg.dnn.dataset).unwrap();
    writeln!(s, "weight_precision = {}", cfg.dnn.weight_precision).unwrap();
    writeln!(s, "activation_precision = {}", cfg.dnn.activation_precision).unwrap();
    writeln!(s, "batch = {}", cfg.dnn.batch).unwrap();
    if let Some(sp) = &cfg.dnn.sparsity {
        let parts: Vec<String> = sp.iter().map(|v| format!("{v}")).collect();
        writeln!(s, "sparsity = [{}]", parts.join(", ")).unwrap();
    }
    writeln!(s, "\n[device]").unwrap();
    writeln!(s, "tech_node_nm = {}", cfg.device.tech_node_nm).unwrap();
    writeln!(s, "cell = \"{cell}\"").unwrap();
    writeln!(s, "bits_per_cell = {}", cfg.device.bits_per_cell).unwrap();
    writeln!(s, "r_on = {}", cfg.device.r_on).unwrap();
    writeln!(s, "r_off_ratio = {}", cfg.device.r_off_ratio).unwrap();
    writeln!(s, "v_read = {}", cfg.device.v_read).unwrap();
    writeln!(s, "\n[chiplet]").unwrap();
    writeln!(s, "xbar_rows = {}", cfg.chiplet.xbar_rows).unwrap();
    writeln!(s, "xbar_cols = {}", cfg.chiplet.xbar_cols).unwrap();
    writeln!(s, "tiles_per_chiplet = {}", cfg.chiplet.tiles_per_chiplet).unwrap();
    writeln!(s, "xbars_per_tile = {}", cfg.chiplet.xbars_per_tile).unwrap();
    writeln!(s, "buffer_type = \"{buf}\"").unwrap();
    writeln!(s, "adc_bits = {}", cfg.chiplet.adc_bits).unwrap();
    writeln!(s, "cols_per_adc = {}", cfg.chiplet.cols_per_adc).unwrap();
    writeln!(s, "read_out = \"{ro}\"").unwrap();
    writeln!(s, "noc_topology = \"{noc}\"").unwrap();
    writeln!(s, "noc_width = {}", cfg.chiplet.noc_width).unwrap();
    writeln!(s, "noc_buffer_depth = {}", cfg.chiplet.noc_buffer_depth).unwrap();
    writeln!(s, "frequency_mhz = {}", cfg.chiplet.frequency_mhz).unwrap();
    writeln!(s, "\n[system]").unwrap();
    writeln!(s, "chip_mode = \"{mode}\"").unwrap();
    writeln!(s, "structure = \"{structure}\"").unwrap();
    if let Some(c) = cfg.system.total_chiplets {
        writeln!(s, "total_chiplets = {c}").unwrap();
    }
    let placement = match cfg.system.placement {
        PlacementPolicy::RowMajor => "rowmajor",
        PlacementPolicy::Dataflow => "dataflow",
    };
    writeln!(s, "placement = \"{placement}\"").unwrap();
    if cfg.system.spare_chiplets > 0 {
        writeln!(s, "spare_chiplets = {}", cfg.system.spare_chiplets).unwrap();
    }
    writeln!(s, "accumulator_size = {}", cfg.system.accumulator_size).unwrap();
    writeln!(s, "global_buffer_kb = {}", cfg.system.global_buffer_kb).unwrap();
    writeln!(s, "\n[system.nop]").unwrap();
    writeln!(s, "frequency_mhz = {}", cfg.system.nop.frequency_mhz).unwrap();
    writeln!(s, "ebit_pj = {}", cfg.system.nop.ebit_pj).unwrap();
    writeln!(s, "gbps_per_lane = {}", cfg.system.nop.gbps_per_lane).unwrap();
    writeln!(s, "channel_width = {}", cfg.system.nop.channel_width).unwrap();
    writeln!(s, "txrx_area_um2 = {}", cfg.system.nop.txrx_area_um2).unwrap();
    writeln!(s, "clocking_area_um2 = {}", cfg.system.nop.clocking_area_um2).unwrap();
    writeln!(s, "lanes_per_clock = {}", cfg.system.nop.lanes_per_clock).unwrap();
    writeln!(s, "wire_length_mm = {}", cfg.system.nop.wire_length_mm).unwrap();
    writeln!(s, "wire_pitch_um = {}", cfg.system.nop.wire_pitch_um).unwrap();
    writeln!(s, "wire_r_ohm_per_mm = {}", cfg.system.nop.wire_r_ohm_per_mm).unwrap();
    writeln!(s, "wire_c_ff_per_mm = {}", cfg.system.nop.wire_c_ff_per_mm).unwrap();
    writeln!(s, "router_ports = {}", cfg.system.nop.router_ports).unwrap();
    for class in &cfg.system.chiplet_classes {
        let cell = match class.cell {
            MemCell::Rram => "rram",
            MemCell::Sram => "sram",
        };
        writeln!(s, "\n[[system.chiplet_class]]").unwrap();
        writeln!(s, "name = \"{}\"", class.name).unwrap();
        if let Some(c) = class.count {
            writeln!(s, "count = {c}").unwrap();
        }
        writeln!(s, "cell = \"{cell}\"").unwrap();
        writeln!(s, "bits_per_cell = {}", class.bits_per_cell).unwrap();
        writeln!(s, "xbar_rows = {}", class.xbar_rows).unwrap();
        writeln!(s, "xbar_cols = {}", class.xbar_cols).unwrap();
        writeln!(s, "tiles_per_chiplet = {}", class.tiles_per_chiplet).unwrap();
        writeln!(s, "xbars_per_tile = {}", class.xbars_per_tile).unwrap();
        writeln!(s, "adc_bits = {}", class.adc_bits).unwrap();
        writeln!(s, "cols_per_adc = {}", class.cols_per_adc).unwrap();
        writeln!(s, "frequency_mhz = {}", class.frequency_mhz).unwrap();
        writeln!(s, "nop_ebit_pj = {}", class.nop_ebit_pj).unwrap();
        writeln!(s, "nop_txrx_area_um2 = {}", class.nop_txrx_area_um2).unwrap();
    }
    writeln!(s, "\n[dram]").unwrap();
    writeln!(s, "kind = \"{dram}\"").unwrap();
    writeln!(s, "bus_bits = {}", cfg.dram.bus_bits).unwrap();
    writeln!(s, "subset_fraction = {}", cfg.dram.subset_fraction).unwrap();
    writeln!(s, "\n[serve]").unwrap();
    let mode = match cfg.serve.mode {
        ServeMode::Open => "open",
        ServeMode::Closed => "closed",
    };
    writeln!(s, "mode = \"{mode}\"").unwrap();
    writeln!(s, "rate_qps = {}", cfg.serve.rate_qps).unwrap();
    writeln!(s, "concurrency = {}", cfg.serve.concurrency).unwrap();
    writeln!(s, "requests = {}", cfg.serve.requests).unwrap();
    writeln!(s, "queue_depth = {}", cfg.serve.queue_depth).unwrap();
    writeln!(s, "seed = {}", cfg.serve.seed).unwrap();
    if !cfg.serve.workloads.is_empty() {
        let parts: Vec<String> =
            cfg.serve.workloads.iter().map(|w| format!("\"{w}\"")).collect();
        writeln!(s, "workloads = [{}]", parts.join(", ")).unwrap();
    }
    writeln!(s, "qos_p99_ms = {}", cfg.serve.qos_p99_ms).unwrap();
    if let Some(at) = cfg.serve.fail_at_request {
        writeln!(s, "fail_at_request = {at}").unwrap();
        writeln!(s, "fail_chiplet = {}", cfg.serve.fail_chiplet).unwrap();
        writeln!(s, "remap_latency_us = {}", cfg.serve.remap_latency_us).unwrap();
    }
    if !cfg.fault.is_none() {
        writeln!(s, "\n[fault]").unwrap();
        if !cfg.fault.kill_chiplets.is_empty() {
            let parts: Vec<String> =
                cfg.fault.kill_chiplets.iter().map(|c| format!("{c}")).collect();
            writeln!(s, "kill_chiplets = [{}]", parts.join(", ")).unwrap();
        }
        writeln!(s, "die_yield = {}", cfg.fault.die_yield).unwrap();
        writeln!(s, "xbar_fault_fraction = {}", cfg.fault.xbar_fault_fraction).unwrap();
        writeln!(s, "seed = {}", cfg.fault.seed).unwrap();
    }
    if !cfg.variation.is_none() {
        let v = &cfg.variation;
        writeln!(s, "\n[variation]").unwrap();
        writeln!(s, "sigma_program = {}", v.sigma_program).unwrap();
        writeln!(s, "write_verify_cycles = {}", v.write_verify_cycles).unwrap();
        writeln!(s, "drift_nu = {}", v.drift_nu).unwrap();
        writeln!(s, "drift_time_s = {}", v.drift_time_s).unwrap();
        writeln!(s, "drift_t0_s = {}", v.drift_t0_s).unwrap();
        writeln!(s, "stuck_at_on = {}", v.stuck_at_on).unwrap();
        writeln!(s, "stuck_at_off = {}", v.stuck_at_off).unwrap();
        writeln!(s, "adc_offset_lsb = {}", v.adc_offset_lsb).unwrap();
        writeln!(s, "redundant_cols = {}", v.redundant_cols).unwrap();
        writeln!(s, "mc_samples = {}", v.mc_samples).unwrap();
        writeln!(s, "accuracy_floor = {}", v.accuracy_floor).unwrap();
        writeln!(s, "refresh_interval_s = {}", v.refresh_interval_s).unwrap();
        writeln!(s, "seed = {}", v.seed).unwrap();
    }
    if !cfg.sweep.is_default() {
        writeln!(s, "\n[sweep]").unwrap();
        if let Some(path) = &cfg.sweep.cache_file {
            writeln!(s, "cache_file = \"{path}\"").unwrap();
        }
        writeln!(s, "search = \"{}\"", cfg.sweep.search.as_str()).unwrap();
        writeln!(s, "halving_keep = {}", cfg.sweep.halving_keep).unwrap();
    }
    if !cfg.decode.is_default() {
        writeln!(s, "\n[decode]").unwrap();
        writeln!(s, "max_new_tokens = {}", cfg.decode.max_new_tokens).unwrap();
        writeln!(s, "kv_precision_bits = {}", cfg.decode.kv_precision_bits).unwrap();
        writeln!(s, "batch_cap = {}", cfg.decode.batch_cap).unwrap();
        writeln!(s, "prefill_chunk = {}", cfg.decode.prefill_chunk).unwrap();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let m = parse_flat(
            "# comment\n[dnn]\nmodel = \"vgg16\"\nbatch = 4\n[system.nop]\nebit_pj = 0.54\n",
        )
        .unwrap();
        assert_eq!(m["dnn.model"].0, Value::Str("vgg16".into()));
        assert_eq!(m["dnn.batch"].0, Value::Int(4));
        assert_eq!(m["dnn.batch"].1, 4, "line numbers recorded");
        assert_eq!(m["system.nop.ebit_pj"].0, Value::Float(0.54));
    }

    #[test]
    fn arrays_parse() {
        let m = parse_flat("[dnn]\nsparsity = [0.1, 0.2, 0.3]\n").unwrap();
        assert_eq!(m["dnn.sparsity"].0, Value::Array(vec![0.1, 0.2, 0.3]));
    }

    #[test]
    fn string_arrays_parse() {
        let m = parse_flat("[serve]\nworkloads = [\"resnet110\", \"vgg19:cifar100\"]\n").unwrap();
        assert_eq!(
            m["serve.workloads"].0,
            Value::StrArray(vec!["resnet110".into(), "vgg19:cifar100".into()])
        );
        let cfg = apply(
            SiamConfig::default(),
            "[serve]\nworkloads = [\"resnet110\", \"lenet5\"]\n",
        )
        .unwrap();
        assert_eq!(cfg.serve.workloads, vec!["resnet110", "lenet5"]);
    }

    #[test]
    fn trailing_comments_stripped_quotes_respected() {
        let m = parse_flat("[dnn]\nbatch = 4 # trailing comment\nmodel = \"res#net\"\n").unwrap();
        assert_eq!(m["dnn.batch"].0, Value::Int(4));
        assert_eq!(m["dnn.model"].0, Value::Str("res#net".into()));
        let m = parse_flat("[serve]\nworkloads = [\"a\", \"b\"] # mix\n").unwrap();
        assert_eq!(
            m["serve.workloads"].0,
            Value::StrArray(vec!["a".into(), "b".into()])
        );
    }

    #[test]
    fn array_of_tables_parses_in_order() {
        let cfg = apply(
            SiamConfig::default(),
            "[chiplet]\nxbar_rows = 256\nxbar_cols = 256\n\
             [[system.chiplet_class]]\nname = \"big\"\n\
             [[system.chiplet_class]]\nname = \"little\"\nxbar_rows = 64\nxbar_cols = 64\ncount = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.system.chiplet_classes.len(), 2);
        let (big, little) = (&cfg.system.chiplet_classes[0], &cfg.system.chiplet_classes[1]);
        assert_eq!(big.name, "big");
        // omitted fields inherit the (file-overridden) base blocks
        assert_eq!(big.xbar_rows, 256);
        assert_eq!(big.count, None);
        assert_eq!(little.name, "little");
        assert_eq!(little.xbar_rows, 64);
        assert_eq!(little.count, Some(8));
        assert_eq!(little.tiles_per_chiplet, cfg.chiplet.tiles_per_chiplet);
    }

    #[test]
    fn bare_class_block_still_counts() {
        // a block with zero keys is legal (every field inherits the
        // base blocks) and must not vanish
        let cfg = apply(
            SiamConfig::default(),
            "[[system.chiplet_class]]\n[[system.chiplet_class]]\nname = \"little\"\nxbar_rows = 64\nxbar_cols = 64\n",
        )
        .unwrap();
        assert_eq!(cfg.system.chiplet_classes.len(), 2);
        assert_eq!(cfg.system.chiplet_classes[0].xbar_rows, cfg.chiplet.xbar_rows);
        assert_eq!(cfg.system.chiplet_classes[0].name, "class0");
        assert_eq!(cfg.system.chiplet_classes[1].name, "little");
    }

    #[test]
    fn placement_key_parses() {
        let cfg = apply(SiamConfig::default(), "[system]\nplacement = \"dataflow\"\n").unwrap();
        assert_eq!(cfg.system.placement, PlacementPolicy::Dataflow);
        assert!(apply(SiamConfig::default(), "[system]\nplacement = \"zigzag\"\n").is_err());
    }

    #[test]
    fn unknown_class_key_rejected() {
        let err = apply(
            SiamConfig::default(),
            "[[system.chiplet_class]]\nname = \"big\"\nxbarrows = 64\n",
        )
        .unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn unknown_key_rejected() {
        let cfg = SiamConfig::default();
        let err = apply(cfg, "[dnn]\nmodle = \"oops\"\n").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
        assert!(err.contains("line 2"), "line number kept: {err}");
    }

    #[test]
    fn bad_line_reports_number() {
        let err = parse_flat("[dnn]\nmodel \"x\"\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn apply_overrides_defaults() {
        let cfg = apply(
            SiamConfig::default(),
            "[chiplet]\ntiles_per_chiplet = 36\n[system]\nstructure = \"homogeneous\"\ntotal_chiplets = 64\n",
        )
        .unwrap();
        assert_eq!(cfg.chiplet.tiles_per_chiplet, 36);
        assert_eq!(cfg.system.structure, ChipletStructure::Homogeneous);
        assert_eq!(cfg.system.total_chiplets, Some(64));
    }

    #[test]
    fn sweep_section_applies() {
        let cfg = apply(
            SiamConfig::default(),
            "[sweep]\ncache_file = \"epochs.cache\"\nsearch = \"halving\"\nhalving_keep = 0.25\n",
        )
        .unwrap();
        assert_eq!(cfg.sweep.cache_file.as_deref(), Some("epochs.cache"));
        assert_eq!(cfg.sweep.search, SearchMode::Halving);
        assert_eq!(cfg.sweep.halving_keep, 0.25);
        assert!(apply(SiamConfig::default(), "[sweep]\nsearch = \"random\"\n").is_err());
    }

    #[test]
    fn decode_section_applies() {
        let cfg = apply(
            SiamConfig::default(),
            "[decode]\nmax_new_tokens = 64\nkv_precision_bits = 16\nbatch_cap = 4\nprefill_chunk = 32\n",
        )
        .unwrap();
        assert_eq!(cfg.decode.max_new_tokens, 64);
        assert_eq!(cfg.decode.kv_precision_bits, 16);
        assert_eq!(cfg.decode.batch_cap, 4);
        assert_eq!(cfg.decode.prefill_chunk, 32);
        assert!(!cfg.decode.is_default());
        // negative / non-integer values are rejected with the line number
        let err = apply(SiamConfig::default(), "[decode]\nbatch_cap = -1\n")
            .unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        // unknown decode keys are rejected like every other section
        assert!(apply(SiamConfig::default(), "[decode]\nkv_bits = 8\n").is_err());
    }

    #[test]
    fn serve_section_applies() {
        let cfg = apply(
            SiamConfig::default(),
            "[serve]\nmode = \"closed\"\nrate_qps = 1500.5\nconcurrency = 8\nrequests = 256\nqueue_depth = 2\nseed = 7\nqos_p99_ms = 2.5\n",
        )
        .unwrap();
        assert_eq!(cfg.serve.mode, ServeMode::Closed);
        assert_eq!(cfg.serve.rate_qps, 1500.5);
        assert_eq!(cfg.serve.concurrency, 8);
        assert_eq!(cfg.serve.requests, 256);
        assert_eq!(cfg.serve.queue_depth, 2);
        assert_eq!(cfg.serve.seed, 7);
        assert_eq!(cfg.serve.qos_p99_ms, 2.5);
    }
}
