//! Circuit & intra-chiplet estimator (Section 4.3.1 of the paper) —
//! NeuroSim-style bottom-up area/energy/latency models for the IMC
//! crossbar, peripherals (flash ADC, column mux, shift-add), buffers,
//! accumulators, pooling and activation units, composed device → crossbar
//! → tile → chiplet → system.

pub mod components;
pub mod estimator;
pub mod tech;

pub use estimator::{CircuitEstimator, CircuitReport, LayerCircuit, LayerCostCache};
pub use tech::Tech;
