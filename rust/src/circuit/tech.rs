//! Technology scaling. All component base numbers are calibrated at
//! 32 nm (the paper's node) against published NeuroSim / ISAAC figures;
//! other nodes scale classically: area ∝ F², dynamic energy ∝ F^1.3
//! (capacitance × mildly-scaling V_dd²), leakage density roughly constant
//! per µm² (so leakage ∝ area).

use crate::config::DeviceConfig;

/// Scaling factors relative to the 32 nm calibration point.
#[derive(Debug, Clone, Copy)]
pub struct Tech {
    /// Technology node, nm.
    pub node_nm: u32,
    /// Area multiplier vs 32 nm.
    pub area: f64,
    /// Dynamic-energy multiplier vs 32 nm.
    pub energy: f64,
    /// Leakage multiplier vs 32 nm.
    pub leakage: f64,
}

impl Tech {
    /// Scaling factors for `node_nm` relative to 32 nm.
    pub fn new(node_nm: u32) -> Tech {
        let s = node_nm as f64 / 32.0;
        Tech {
            node_nm,
            area: s * s,
            energy: s.powf(1.3),
            leakage: s * s,
        }
    }

    /// Scaling factors for a device configuration's node.
    pub fn from_device(dev: &DeviceConfig) -> Tech {
        Tech::new(dev.tech_node_nm)
    }

    /// Feature size in µm.
    pub fn f_um(&self) -> f64 {
        self.node_nm as f64 * 1e-3
    }

    /// Area of `n` F² in µm².
    pub fn f2_um2(&self, n: f64) -> f64 {
        let f = self.f_um();
        n * f * f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_node_is_identity() {
        let t = Tech::new(32);
        assert!((t.area - 1.0).abs() < 1e-12);
        assert!((t.energy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn smaller_node_shrinks() {
        let t = Tech::new(16);
        assert!((t.area - 0.25).abs() < 1e-12);
        assert!(t.energy < 1.0 && t.energy > 0.25);
    }

    #[test]
    fn f2_area() {
        let t = Tech::new(32);
        // 4F² RRAM cell at 32nm = 4 * 0.032² = 0.004096 µm²
        assert!((t.f2_um2(4.0) - 0.004096).abs() < 1e-9);
    }
}
