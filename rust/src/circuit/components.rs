//! Per-component circuit models (area / per-op energy / per-op latency /
//! leakage). Base numbers are at 32 nm, calibrated so the composed system
//! reproduces the published anchors:
//!
//! * tile area ≈ 0.5 mm² for 16× 128×128 RRAM crossbars with 4-bit flash
//!   ADCs at 8:1 muxing (matches Fig. 1a: DenseNet-110 → 2184 tiles →
//!   ≈1200 mm² monolithic chip);
//! * system energy ≈ 0.6–1 mJ / ResNet-50 inference (matches the paper's
//!   130×/72× energy-efficiency claim over V100/T4, whose per-inference
//!   energies are taken from SIMBA);
//! * flash-ADC conversion ≈ 0.55 pJ at 4 bits (ISAAC-class peripheral
//!   budgets; flash energy/area grow ≈2× per extra bit).

use super::tech::Tech;
use crate::config::{BufferType, ChipletConfig, DeviceConfig, MemCell};

/// A circuit block: fixed area + leakage, per-operation energy/latency.
#[derive(Debug, Clone, Copy, Default)]
pub struct Component {
    /// Fixed silicon area, µm².
    pub area_um2: f64,
    /// Energy per operation, pJ.
    pub energy_per_op_pj: f64,
    /// Latency per operation, ns.
    pub latency_per_op_ns: f64,
    /// Static leakage, µW.
    pub leakage_uw: f64,
}

/// IMC crossbar array (cells + wordline drivers + decoders).
pub fn xbar_array(dev: &DeviceConfig, ch: &ChipletConfig, tech: &Tech) -> Component {
    let cells = (ch.xbar_rows * ch.xbar_cols) as f64;
    let cell_f2 = match dev.cell {
        MemCell::Rram => 4.0,   // 1T0R-style crosspoint
        MemCell::Sram => 146.0, // 6T bitcell used as IMC cell
    };
    let array_area = tech.f2_um2(cell_f2) * cells;
    // wordline driver + row decoder: ~1.2 µm²/row at 32 nm
    let driver_area = 1.2 * ch.xbar_rows as f64 * tech.area;
    // Read energy for one *column group* conversion cycle with all rows
    // active (parallel read-out): I_cell = V/R_on, E = V·I·t per on-cell.
    // At 0.15 V / 100 kΩ / 1 ns: 0.225 fJ per on-cell·cycle; assume half
    // the cells conduct on average.
    let v = dev.v_read;
    let t_ns = 1.0;
    let e_cell_pj = v * (v / dev.r_on) * (t_ns * 1e-9) * 1e12; // pJ
    let active_cols = ch.xbar_cols as f64 / ch.cols_per_adc as f64;
    let e_col_cycle = 0.5 * e_cell_pj * ch.xbar_rows as f64 * active_cols;
    Component {
        area_um2: array_area + driver_area,
        energy_per_op_pj: e_col_cycle, // per column-group cycle
        latency_per_op_ns: 1.0,        // array settle per cycle (pipelined)
        leakage_uw: 0.02 * tech.leakage * cells / 16384.0,
    }
}

/// Flash ADC: 2^bits − 1 comparators + thermometer encoder.
pub fn flash_adc(bits: u8, tech: &Tech) -> Component {
    let levels = (1u64 << bits) as f64;
    // 4-bit anchor: 1100 µm², 0.55 pJ/conversion (flash comparator bank
    // + reference ladder + encoder at 1 GS/s); both ≈ ∝ 2^bits
    let scale = levels / 16.0;
    Component {
        area_um2: 1100.0 * scale * tech.area,
        energy_per_op_pj: 0.55 * scale * tech.energy,
        latency_per_op_ns: 1.0, // one cycle per conversion at 1 GHz
        leakage_uw: 1.1 * scale * tech.leakage,
    }
}

/// Column multiplexer in front of each ADC.
pub fn column_mux(cols_per_adc: usize, tech: &Tech) -> Component {
    Component {
        area_um2: 12.0 * cols_per_adc as f64 * tech.area,
        energy_per_op_pj: 0.002 * tech.energy,
        latency_per_op_ns: 0.0, // hidden in the conversion cycle
        leakage_uw: 0.01 * tech.leakage,
    }
}

/// Shift-and-add tree combining ADC outputs across bit positions.
pub fn shift_add(tech: &Tech) -> Component {
    Component {
        area_um2: 480.0 * tech.area,
        energy_per_op_pj: 0.05 * tech.energy,
        latency_per_op_ns: 1.0,
        leakage_uw: 0.4 * tech.leakage,
    }
}

/// SRAM / register-file buffer, per-bit figures.
pub fn buffer_bit(kind: BufferType, tech: &Tech) -> Component {
    let (area, energy) = match kind {
        // 6T SRAM + periphery ≈ 0.30 µm²/bit, 22 fJ/bit access at 32 nm
        // (bank periphery + wordline/bitline swing included)
        BufferType::Sram => (0.30, 0.022),
        // register file: faster, bigger, hungrier
        BufferType::RegisterFile => (0.95, 0.038),
    };
    Component {
        area_um2: area * tech.area,
        energy_per_op_pj: energy * tech.energy,
        latency_per_op_ns: 0.0, // pipelined with compute
        leakage_uw: 8.0e-6 * tech.leakage,
    }
}

/// Digital accumulator (partial-sum adder), per 32-bit add.
pub fn accumulator(tech: &Tech) -> Component {
    Component {
        area_um2: 2400.0 * tech.area,
        energy_per_op_pj: 0.10 * tech.energy,
        latency_per_op_ns: 1.0,
        leakage_uw: 2.0 * tech.leakage,
    }
}

/// Chiplet pooling unit (max + average modes).
pub fn pooling_unit(tech: &Tech) -> Component {
    Component {
        area_um2: 5200.0 * tech.area,
        energy_per_op_pj: 0.04, // per pooled element
        latency_per_op_ns: 1.0,
        leakage_uw: 4.0 * tech.leakage,
    }
}

/// Chiplet activation unit (ReLU; sigmoid via LUT costs ~4×).
pub fn activation_unit(tech: &Tech) -> Component {
    Component {
        area_um2: 3100.0 * tech.area,
        energy_per_op_pj: 0.015, // per ReLU element
        latency_per_op_ns: 1.0,
        leakage_uw: 2.5 * tech.leakage,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;

    fn t() -> Tech {
        Tech::new(32)
    }

    #[test]
    fn adc_scales_with_bits() {
        let a4 = flash_adc(4, &t());
        let a8 = flash_adc(8, &t());
        assert!((a8.area_um2 / a4.area_um2 - 16.0).abs() < 1e-9);
        assert!(a8.energy_per_op_pj > a4.energy_per_op_pj);
    }

    #[test]
    fn xbar_array_area_is_small_vs_adc() {
        // IMC truism at 1 bit/cell: ADC area dominates the array
        let cfg = SiamConfig::paper_default();
        let arr = xbar_array(&cfg.device, &cfg.chiplet, &t());
        let adcs = flash_adc(4, &t()).area_um2 * 16.0; // 128/8 ADCs
        assert!(arr.area_um2 < adcs, "{} vs {adcs}", arr.area_um2);
    }

    #[test]
    fn sram_cell_bigger_than_rram() {
        let cfg = SiamConfig::paper_default();
        let mut dev = cfg.device.clone();
        let rram = xbar_array(&dev, &cfg.chiplet, &t());
        dev.cell = MemCell::Sram;
        let sram = xbar_array(&dev, &cfg.chiplet, &t());
        assert!(sram.area_um2 > 10.0 * rram.area_um2);
    }

    #[test]
    fn buffer_types_differ() {
        let s = buffer_bit(BufferType::Sram, &t());
        let r = buffer_bit(BufferType::RegisterFile, &t());
        assert!(r.area_um2 > s.area_um2);
        assert!(r.energy_per_op_pj > s.energy_per_op_pj);
    }

    #[test]
    fn read_energy_tracks_v_and_r() {
        let cfg = SiamConfig::paper_default();
        let mut dev = cfg.device.clone();
        let base = xbar_array(&dev, &cfg.chiplet, &t()).energy_per_op_pj;
        dev.r_on *= 2.0; // higher resistance, less current, less energy
        let hi_r = xbar_array(&dev, &cfg.chiplet, &t()).energy_per_op_pj;
        assert!(hi_r < base);
    }
}
