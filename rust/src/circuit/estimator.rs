//! The circuit estimator (Section 4.3.1): bottom-up composition from
//! device → crossbar → tile → chiplet → system, evaluated layer-wise
//! exactly as the paper describes.

use super::components as comp;
use super::tech::Tech;
use crate::config::{BufferType, ChipMode, MemCell, ReadOut, SiamConfig};
use crate::dnn::{Dnn, LayerKind};
use crate::mapping::{MappingResult, Traffic};
use crate::metrics::{Breakdown, Metrics};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Per-layer compute cost (energy per inference, latency per inference).
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCircuit {
    /// Compute energy of the layer per inference, pJ.
    pub energy_pj: f64,
    /// Compute latency of the layer per inference, ns.
    pub latency_ns: f64,
    /// ADC conversions performed (exposed for ablations).
    pub conversions: u64,
}

/// Every input [`CircuitEstimator::layer_cost`] reads, with floats
/// stored as bit patterns so the key is `Eq + Hash`. Two configurations
/// with equal keys produce identical per-layer cost vectors; design
/// points of a sweep that vary only `tiles_per_chiplet` / chiplet count
/// (the Figs. 9/11/12 axes) therefore share one entry.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct LayerCostKey {
    model: String,
    dataset: String,
    weight_precision: u8,
    activation_precision: u8,
    batch: usize,
    sparsity_bits: Option<Vec<u64>>,
    cell: MemCell,
    bits_per_cell: u8,
    tech_node_nm: u32,
    r_on_bits: u64,
    r_off_ratio_bits: u64,
    v_read_bits: u64,
    xbar_rows: usize,
    xbar_cols: usize,
    adc_bits: u8,
    cols_per_adc: usize,
    read_out: ReadOut,
    buffer_type: BufferType,
    frequency_bits: u64,
    // digital dynamic-matmul work (attention scores) runs on the
    // accumulator lanes, so their width is circuit-relevant
    accumulator_size: usize,
}

impl LayerCostKey {
    fn of(cfg: &SiamConfig) -> LayerCostKey {
        LayerCostKey {
            model: cfg.dnn.model.clone(),
            dataset: cfg.dnn.dataset.clone(),
            weight_precision: cfg.dnn.weight_precision,
            activation_precision: cfg.dnn.activation_precision,
            batch: cfg.dnn.batch,
            sparsity_bits: cfg
                .dnn
                .sparsity
                .as_ref()
                .map(|v| v.iter().map(|s| s.to_bits()).collect()),
            cell: cfg.device.cell,
            bits_per_cell: cfg.device.bits_per_cell,
            tech_node_nm: cfg.device.tech_node_nm,
            r_on_bits: cfg.device.r_on.to_bits(),
            r_off_ratio_bits: cfg.device.r_off_ratio.to_bits(),
            v_read_bits: cfg.device.v_read.to_bits(),
            xbar_rows: cfg.chiplet.xbar_rows,
            xbar_cols: cfg.chiplet.xbar_cols,
            adc_bits: cfg.chiplet.adc_bits,
            cols_per_adc: cfg.chiplet.cols_per_adc,
            read_out: cfg.chiplet.read_out,
            buffer_type: cfg.chiplet.buffer_type,
            frequency_bits: cfg.chiplet.frequency_mhz.to_bits(),
            accumulator_size: cfg.system.accumulator_size,
        }
    }
}

/// Thread-safe cache of per-layer compute-cost vectors, keyed by the
/// complete circuit-relevant configuration (see [`LayerCostKey`] —
/// notably *not* `tiles_per_chiplet` or the chiplet count, which the
/// per-layer costs are independent of).
///
/// Shared across the points of a design-space sweep via
/// [`crate::coordinator::SweepContext`], so the Eq.-1 geometry walk and
/// the bit-serial energy model run once per sweep instead of once per
/// point. A cache hit returns the exact vector the uncached path would
/// compute.
#[derive(Debug, Default)]
pub struct LayerCostCache {
    map: Mutex<HashMap<LayerCostKey, Arc<Vec<LayerCircuit>>>>,
}

impl LayerCostCache {
    /// Create an empty cache.
    pub fn new() -> LayerCostCache {
        LayerCostCache::default()
    }

    /// Number of distinct circuit configurations cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when nothing has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Output of the circuit estimator.
#[derive(Debug, Clone, Default)]
pub struct CircuitReport {
    /// Weight-layer costs, parallel chiplets already folded in.
    pub per_layer: Vec<LayerCircuit>,
    /// IMC compute area: chiplets × (tiles + digital units), µm².
    pub chiplets_area_um2: f64,
    /// Global accumulator + global buffer area, µm².
    pub global_area_um2: f64,
    /// Total compute energy per inference, pJ.
    pub energy_pj: f64,
    /// Total compute latency per inference (layers execute sequentially),
    /// ns.
    pub latency_ns: f64,
    /// All-on (peak) leakage, µW.
    pub leakage_uw: f64,
    /// Leakage energy actually accrued, pJ. Idle chiplets/crossbars are
    /// power-gated (the paper gates the global accumulator and buffer
    /// when unused; we extend gating to idle layers' fabric), so only
    /// the active layer's share of the fabric leaks during its slot.
    pub leakage_energy_pj: f64,
    /// Component-class breakdown of energy.
    pub energy_breakdown: Breakdown,
}

impl CircuitReport {
    /// Compute area/energy/latency/leakage rolled into one [`Metrics`].
    pub fn total_metrics(&self) -> Metrics {
        Metrics {
            area_um2: self.chiplets_area_um2 + self.global_area_um2,
            energy_pj: self.energy_pj,
            latency_ns: self.latency_ns,
            leakage_uw: self.leakage_uw,
        }
    }
}

/// Fixed per-chiplet digital units (pool/act/accumulator/output buffer).
const CHIPLET_OUT_BUFFER_BITS: f64 = 32.0 * 1024.0 * 8.0; // 32 kB

/// Bottom-up circuit estimator for one configuration (Section 4.3.1).
pub struct CircuitEstimator<'a> {
    cfg: &'a SiamConfig,
    tech: Tech,
}

impl<'a> CircuitEstimator<'a> {
    /// Estimator for `cfg`, with technology scaling resolved from the
    /// device block.
    pub fn new(cfg: &'a SiamConfig) -> Self {
        CircuitEstimator {
            cfg,
            tech: Tech::from_device(&cfg.device),
        }
    }

    fn adcs_per_xbar(&self) -> f64 {
        (self.cfg.chiplet.xbar_cols / self.cfg.chiplet.cols_per_adc) as f64
    }

    /// One crossbar + its peripherals (ADCs, muxes, shift-add), µm².
    pub fn xbar_unit_area(&self) -> f64 {
        let ch = &self.cfg.chiplet;
        let arr = comp::xbar_array(&self.cfg.device, ch, &self.tech);
        let adc = comp::flash_adc(ch.adc_bits, &self.tech);
        let mux = comp::column_mux(ch.cols_per_adc, &self.tech);
        let sa = comp::shift_add(&self.tech);
        arr.area_um2 + self.adcs_per_xbar() * (adc.area_um2 + mux.area_um2) + sa.area_um2
    }

    /// One tile: crossbars + tile input/output buffer + tile accumulator.
    pub fn tile_area(&self) -> f64 {
        let ch = &self.cfg.chiplet;
        let buf = comp::buffer_bit(ch.buffer_type, &self.tech);
        let buf_bits = (ch.xbars_per_tile * ch.xbar_rows) as f64
            * self.cfg.dnn.activation_precision as f64
            * 2.0;
        let acc = comp::accumulator(&self.tech);
        ch.xbars_per_tile as f64 * self.xbar_unit_area() + buf_bits * buf.area_um2 + acc.area_um2
    }

    /// One chiplet: tiles + pooling + activation + chiplet accumulator +
    /// output buffer (NoC and NoP interface areas are owned by their
    /// engines).
    pub fn chiplet_area(&self) -> f64 {
        let ch = &self.cfg.chiplet;
        let buf = comp::buffer_bit(ch.buffer_type, &self.tech);
        ch.tiles_per_chiplet as f64 * self.tile_area()
            + comp::pooling_unit(&self.tech).area_um2
            + comp::activation_unit(&self.tech).area_um2
            + comp::accumulator(&self.tech).area_um2
            + CHIPLET_OUT_BUFFER_BITS * buf.area_um2
    }

    /// Compute cost of one weight layer (Eq.-1 geometry, bit-serial
    /// read-out, ADC, shift-add, intra-chiplet accumulation, buffers).
    ///
    /// `wpos` is the layer's position in the weight-layer sequence
    /// (`dnn.weight_layers()` order), used to look up its sparsity; the
    /// Eq.-1 row-crossbar count is derived internally from this
    /// estimator's crossbar geometry, so the cost is well-defined per
    /// `(layer, circuit configuration)` pair independent of how the
    /// layer is partitioned across chiplets — that independence is what
    /// lets [`LayerCostCache`] share one vector per circuit
    /// configuration (and per chiplet class) across all sweep points.
    pub fn layer_cost(&self, layer: &crate::dnn::Layer, wpos: usize) -> LayerCircuit {
        let ch = &self.cfg.chiplet;
        let dev = &self.cfg.device;
        let act_bits = self.cfg.dnn.activation_precision as f64;
        let vectors = (layer.input_vectors() * self.cfg.dnn.batch) as f64;

        let cols_per_weight = (self.cfg.dnn.weight_precision as f64
            / dev.bits_per_cell as f64)
            .ceil();
        let cols_used = layer.weight_cols() as f64 * cols_per_weight;
        let rows_used = layer.weight_rows() as f64;

        // --- latency: bit-serial cycles × mux groups (× rows if
        // sequential read-out), crossbars fully parallel, vectors
        // streamed through the pipeline.
        let seq_factor = match ch.read_out {
            ReadOut::Parallel => 1.0,
            ReadOut::Sequential => ch.xbar_rows as f64,
        };
        let cycles_per_vec = act_bits * ch.cols_per_adc as f64 * seq_factor;
        let pipeline_depth = 20.0;
        let mut latency_ns = (vectors * cycles_per_vec + pipeline_depth) * self.clk_ns();

        // --- energy
        let arr = comp::xbar_array(dev, ch, &self.tech);
        let adc = comp::flash_adc(ch.adc_bits, &self.tech);
        let mux = comp::column_mux(ch.cols_per_adc, &self.tech);
        let sa = comp::shift_add(&self.tech);
        let acc = comp::accumulator(&self.tech);
        let buf = comp::buffer_bit(ch.buffer_type, &self.tech);

        // ADC conversions: every used column, every input bit, every vector
        let conversions = vectors * cols_used * act_bits;
        // array column-group cycles across the used crossbars
        let xbar_cycles = vectors
            * act_bits
            * ch.cols_per_adc as f64
            * seq_factor
            * (cols_used / ch.xbar_cols as f64).max(1.0)
            * (rows_used / ch.xbar_rows as f64).max(1.0);
        // digital accumulation across row-crossbars (N_r-1 adds per col)
        let sparsity = self
            .cfg
            .dnn
            .sparsity
            .as_ref()
            .and_then(|v| v.get(wpos))
            .copied()
            .unwrap_or(0.0);
        let (n_r, _, _) = crate::mapping::eq1_rows_cols(
            layer.weight_rows(),
            layer.weight_cols(),
            self.cfg.dnn.weight_precision,
            dev.bits_per_cell,
            ch.xbar_rows,
            ch.xbar_cols,
            sparsity,
        );
        let row_xbars = n_r as f64;
        let acc_adds = vectors * layer.weight_cols() as f64 * (row_xbars - 1.0).max(0.0);
        // buffers: read each input vector act_bits-wide per row, write out
        let buf_bits = vectors * (rows_used * act_bits + layer.weight_cols() as f64 * act_bits);

        let mut energy_pj = conversions * (adc.energy_per_op_pj + mux.energy_per_op_pj)
            + xbar_cycles * arr.energy_per_op_pj
            + conversions * sa.energy_per_op_pj
            + acc_adds * acc.energy_per_op_pj
            + buf_bits * buf.energy_per_op_pj;

        // Dynamic activation×activation work of the layer (attention
        // score/value matmuls): both operands are runtime values, so it
        // cannot live on weight-stationary crossbars — it runs on the
        // digital accumulator/SIMD lanes (`system.accumulator_size` MACs
        // per cycle; one multiply + one add per MAC). Zero for every
        // weight-stationary kind, leaving CNN costs bit-identical.
        let dmacs = layer.digital_macs() as f64 * self.cfg.dnn.batch as f64;
        if dmacs > 0.0 {
            energy_pj += 2.0 * dmacs * acc.energy_per_op_pj;
            latency_ns += dmacs / self.cfg.system.accumulator_size as f64 * self.clk_ns();
        }

        LayerCircuit {
            energy_pj,
            latency_ns,
            conversions: conversions as u64,
        }
    }

    fn clk_ns(&self) -> f64 {
        self.cfg.clock_period_ns()
    }

    /// The per-weight-layer cost vector of the whole model under *this*
    /// estimator's circuit configuration, through the cache when one is
    /// supplied. Heterogeneous estimation calls this once per chiplet
    /// class (on the class's effective configuration), and the cache key
    /// covers every class-varying circuit field, so per-class vectors
    /// stay cached across all points of a sweep.
    fn layer_costs(&self, dnn: &Dnn, cache: Option<&LayerCostCache>) -> Arc<Vec<LayerCircuit>> {
        let compute = || {
            Arc::new(
                dnn.weight_layers()
                    .iter()
                    .enumerate()
                    .map(|(wpos, &idx)| self.layer_cost(&dnn.layers[idx], wpos))
                    .collect::<Vec<_>>(),
            )
        };
        match cache {
            Some(c) => {
                let key = LayerCostKey::of(self.cfg);
                c.map.lock().unwrap().entry(key).or_insert_with(compute).clone()
            }
            None => compute(),
        }
    }

    /// Full circuit estimation for a mapped DNN.
    pub fn estimate(&self, dnn: &Dnn, map: &MappingResult, traffic: &Traffic) -> CircuitReport {
        self.estimate_cached(dnn, map, traffic, None)
    }

    /// [`estimate`](CircuitEstimator::estimate) with an optional
    /// [`LayerCostCache`] shared across sweep points. Per-layer compute
    /// costs are independent of the chiplet partitioning, so a sweep
    /// computes them once; results are bit-identical to the uncached
    /// path.
    pub fn estimate_cached(
        &self,
        dnn: &Dnn,
        map: &MappingResult,
        traffic: &Traffic,
        cache: Option<&LayerCostCache>,
    ) -> CircuitReport {
        let monolithic = self.cfg.system.chip_mode == ChipMode::Monolithic;
        if !monolithic && self.cfg.has_hetero_classes() {
            return self.estimate_hetero(dnn, map, traffic, cache);
        }
        let mut rep = CircuitReport::default();
        let ch = &self.cfg.chiplet;
        let tech = &self.tech;

        // ---- areas
        rep.chiplets_area_um2 = if monolithic {
            // one big chip with exactly the used tiles + one set of units
            map.total_tiles(ch.xbars_per_tile) as f64 * self.tile_area()
                + comp::pooling_unit(tech).area_um2
                + comp::activation_unit(tech).area_um2
                + comp::accumulator(tech).area_um2
        } else {
            map.num_chiplets as f64 * self.chiplet_area()
        };

        // ---- per weight-layer compute (vector shared via the cache)
        let costs = self.layer_costs(dnn, cache);
        let mut e_imc = 0.0;
        let total_xbars = map.total_xbars().max(1) as f64;
        let mut active_share_time_ns = 0.0; // Σ share × layer latency
        for (lm, &lc) in map.per_layer.iter().zip(costs.iter()) {
            e_imc += lc.energy_pj;
            rep.latency_ns += lc.latency_ns;
            rep.energy_pj += lc.energy_pj;
            active_share_time_ns += lc.latency_ns * lm.xbars as f64 / total_xbars;
            rep.per_layer.push(lc);
        }
        rep.energy_breakdown.push("imc_compute", Metrics {
            energy_pj: e_imc,
            ..Metrics::ZERO
        });

        let adc = comp::flash_adc(ch.adc_bits, tech);
        let adc_leakage_uw = map.total_xbars() as f64 * self.adcs_per_xbar() * adc.leakage_uw;
        self.estimate_tail(&mut rep, dnn, traffic, active_share_time_ns, adc_leakage_uw);
        rep
    }

    /// Heterogeneous-class estimation: per-layer compute costs come from
    /// the owning class's effective configuration (one cached vector per
    /// class), chiplet areas sum per class, and ADC leakage follows each
    /// class's ADC count over its mapped crossbars. Shared units
    /// (pooling/activation, global accumulator + buffer) stay on the
    /// base configuration.
    fn estimate_hetero(
        &self,
        dnn: &Dnn,
        map: &MappingResult,
        traffic: &Traffic,
        cache: Option<&LayerCostCache>,
    ) -> CircuitReport {
        let classes = self.cfg.resolved_chiplet_classes();
        let effs: Vec<crate::config::SiamConfig> =
            classes.iter().map(|c| self.cfg.class_effective(c)).collect();
        let ests: Vec<CircuitEstimator> = effs.iter().map(CircuitEstimator::new).collect();
        let costs: Vec<Arc<Vec<LayerCircuit>>> =
            ests.iter().map(|e| e.layer_costs(dnn, cache)).collect();
        let mut counts = vec![0usize; classes.len()];
        for &k in &map.chiplet_class {
            counts[k] += 1;
        }

        let mut rep = CircuitReport::default();

        // ---- areas: Σ per class (chiplet area from the class's
        // effective configuration)
        rep.chiplets_area_um2 = counts
            .iter()
            .zip(&ests)
            .map(|(&n, e)| n as f64 * e.chiplet_area())
            .sum();

        // ---- per weight-layer compute from the owning class. The
        // active-fabric share weights latency by crossbar count — a
        // crossbar-unit approximation across classes of unequal
        // crossbar sizes (exact for single-kind systems, which never
        // reach this path).
        let total_xbars = map.total_xbars().max(1) as f64;
        let mut e_imc = 0.0;
        let mut active_share_time_ns = 0.0;
        let mut xbars_of_class = vec![0usize; classes.len()];
        for (li, lm) in map.per_layer.iter().enumerate() {
            let lc = costs[lm.class][li];
            e_imc += lc.energy_pj;
            rep.latency_ns += lc.latency_ns;
            rep.energy_pj += lc.energy_pj;
            active_share_time_ns += lc.latency_ns * lm.xbars as f64 / total_xbars;
            xbars_of_class[lm.class] += lm.xbars;
            rep.per_layer.push(lc);
        }
        rep.energy_breakdown.push("imc_compute", Metrics {
            energy_pj: e_imc,
            ..Metrics::ZERO
        });

        let adc_leakage_uw: f64 = ests
            .iter()
            .enumerate()
            .map(|(k, e)| {
                let adc = comp::flash_adc(effs[k].chiplet.adc_bits, &e.tech);
                xbars_of_class[k] as f64 * e.adcs_per_xbar() * adc.leakage_uw
            })
            .sum();
        self.estimate_tail(&mut rep, dnn, traffic, active_share_time_ns, adc_leakage_uw);
        rep
    }

    /// The configuration-shared back half of an estimation: global
    /// accumulator/buffer area, pooling/activation and global-reduction
    /// energy, and the power-gated leakage accounting. Identical
    /// operation order for the classic and heterogeneous paths.
    fn estimate_tail(
        &self,
        rep: &mut CircuitReport,
        dnn: &Dnn,
        traffic: &Traffic,
        active_share_time_ns: f64,
        adc_leakage_uw: f64,
    ) {
        let ch = &self.cfg.chiplet;
        let tech = &self.tech;
        let gbuf_bits = self.cfg.system.global_buffer_kb as f64 * 1024.0 * 8.0;
        let buf = comp::buffer_bit(ch.buffer_type, tech);
        let gacc = comp::accumulator(tech);
        rep.global_area_um2 =
            gbuf_bits * buf.area_um2 + self.cfg.system.accumulator_size as f64 * gacc.area_um2;

        // ---- pooling / activation units over the non-weight layers,
        // plus the digital transformer ops that fall outside the
        // weight-layer cost rows: standalone dynamic matmuls,
        // LayerNorm's normalize+scale passes, and embedding-table reads
        // (attention's own score matmuls are charged in `layer_cost`).
        let (mut pool_elems, mut act_elems) = (0.0, 0.0);
        let (mut xf_macs, mut xf_elems) = (0.0, 0.0);
        for l in &dnn.layers {
            match l.kind {
                LayerKind::MaxPool { .. } | LayerKind::AvgPool { .. } | LayerKind::GlobalAvgPool => {
                    pool_elems += l.ifm.elems() as f64
                }
                LayerKind::Relu | LayerKind::Sigmoid | LayerKind::Gelu => {
                    act_elems += l.ofm.elems() as f64
                }
                LayerKind::ResidualAdd { .. } => act_elems += l.ofm.elems() as f64,
                LayerKind::Matmul { .. } => xf_macs += l.digital_macs() as f64,
                // mean/variance reduction pass + scale-shift pass
                LayerKind::LayerNorm => xf_elems += 2.0 * l.ofm.elems() as f64,
                // one table read (+ add) per output element
                LayerKind::Embedding { .. } => xf_elems += l.ofm.elems() as f64,
                _ => {}
            }
        }
        let batch = self.cfg.dnn.batch as f64;
        let pool = comp::pooling_unit(tech);
        let act = comp::activation_unit(tech);
        let e_pool = pool_elems * batch * pool.energy_per_op_pj;
        let e_act = act_elems * batch * act.energy_per_op_pj;
        rep.energy_pj += e_pool + e_act;
        // pooled through 64-wide units, pipelined
        rep.latency_ns += (pool_elems + act_elems) * batch / 64.0 * self.clk_ns();
        rep.energy_breakdown.push("pool_act", Metrics {
            energy_pj: e_pool + e_act,
            ..Metrics::ZERO
        });
        if xf_macs > 0.0 || xf_elems > 0.0 {
            // digital matmul MACs (multiply + add) and element ops run
            // on the accumulator lanes, `accumulator_size` per cycle
            let acc_unit = comp::accumulator(tech);
            let e_xf = (2.0 * xf_macs + xf_elems) * batch * acc_unit.energy_per_op_pj;
            rep.energy_pj += e_xf;
            rep.latency_ns += (xf_macs + xf_elems) * batch
                / self.cfg.system.accumulator_size as f64
                * self.clk_ns();
            rep.energy_breakdown.push("digital_xformer", Metrics {
                energy_pj: e_xf,
                ..Metrics::ZERO
            });
        }

        // ---- global accumulator + buffer (paper: gated off when unused)
        let gacc_e = traffic.accumulator_adds as f64 * gacc.energy_per_op_pj;
        let gbuf_e = (traffic.global_buffer_writes + traffic.global_buffer_reads) as f64
            * self.cfg.dnn.activation_precision as f64
            * buf.energy_per_op_pj;
        rep.energy_pj += gacc_e + gbuf_e;
        rep.latency_ns += traffic.accumulator_adds as f64
            / self.cfg.system.accumulator_size as f64
            * self.clk_ns();
        rep.energy_breakdown.push("global_acc_buf", Metrics {
            energy_pj: gacc_e + gbuf_e,
            ..Metrics::ZERO
        });

        // ---- leakage (area-proportional densities)
        rep.leakage_uw = adc_leakage_uw
            + rep.chiplets_area_um2 * 2.0e-3  // ~2 mW/mm² logic+SRAM density
            + rep.global_area_um2 * 2.0e-3;
        // power-gated fabric: only the running layer's share leaks
        // (µW × ns = fJ ⇒ /1e3 to pJ)
        rep.leakage_energy_pj = rep.leakage_uw * active_share_time_ns / 1.0e3;
        rep.energy_pj += rep.leakage_energy_pj;
        rep.energy_breakdown.push("leakage", Metrics {
            energy_pj: rep.leakage_energy_pj,
            ..Metrics::ZERO
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;
    use crate::mapping::{build_traffic, map_dnn, Placement};

    fn run(model: &str, ds: &str, cfg: &SiamConfig) -> CircuitReport {
        let dnn = build_model(model, ds).unwrap();
        let map = map_dnn(&dnn, cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, cfg);
        CircuitEstimator::new(cfg).estimate(&dnn, &map, &traffic)
    }

    #[test]
    fn tile_area_near_calibration_anchor() {
        // ≈0.5 mm² per 16-crossbar tile at the paper's configuration
        let cfg = SiamConfig::paper_default();
        let est = CircuitEstimator::new(&cfg);
        let mm2 = est.tile_area() / 1e6;
        assert!((0.2..0.9).contains(&mm2), "tile area {mm2} mm²");
    }

    #[test]
    fn resnet50_energy_near_gpu_claim_anchor() {
        // 130× vs V100 (≈82 mJ/inference) ⇒ expect O(0.5–2 mJ)
        let cfg = SiamConfig::paper_default().with_model("resnet50", "imagenet");
        let rep = run("resnet50", "imagenet", &cfg);
        let mj = rep.energy_pj / 1e9;
        assert!((0.1..5.0).contains(&mj), "ResNet-50 energy {mj} mJ");
    }

    #[test]
    fn monolithic_area_matches_fig1_scale() {
        // Fig. 1a: ResNet-50 monolithic RRAM IMC ≈ 450 mm² (802 tiles)
        let cfg = SiamConfig::paper_default()
            .with_chip_mode(ChipMode::Monolithic)
            .with_model("resnet50", "imagenet");
        let rep = run("resnet50", "imagenet", &cfg);
        let mm2 = rep.chiplets_area_um2 / 1e6;
        assert!((150.0..900.0).contains(&mm2), "monolithic area {mm2} mm²");
    }

    #[test]
    fn sequential_readout_is_slower() {
        let mut cfg = SiamConfig::paper_default();
        let fast = run("lenet5", "cifar10", &cfg).latency_ns;
        cfg.chiplet.read_out = ReadOut::Sequential;
        let slow = run("lenet5", "cifar10", &cfg).latency_ns;
        assert!(slow > 10.0 * fast, "sequential {slow} vs parallel {fast}");
    }

    #[test]
    fn higher_adc_resolution_costs_energy() {
        let mut cfg = SiamConfig::paper_default();
        let e4 = run("resnet110", "cifar10", &cfg).energy_pj;
        cfg.chiplet.adc_bits = 8;
        let e8 = run("resnet110", "cifar10", &cfg).energy_pj;
        assert!(e8 > e4);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let cfg = SiamConfig::paper_default();
        let rep = run("resnet110", "cifar10", &cfg);
        let sum: f64 = rep
            .energy_breakdown
            .components
            .iter()
            .map(|(_, m)| m.energy_pj)
            .sum();
        assert!((sum - rep.energy_pj).abs() / rep.energy_pj < 1e-9);
    }

    #[test]
    fn layer_cost_cache_is_transparent() {
        // cached and uncached estimation must agree bit-for-bit, and
        // points differing only in tiles/chiplet must share one entry
        let cache = LayerCostCache::new();
        let cfg16 = SiamConfig::paper_default();
        let cfg36 = SiamConfig::paper_default().with_tiles_per_chiplet(36);
        for cfg in [&cfg16, &cfg36] {
            let dnn = build_model("resnet110", "cifar10").unwrap();
            let map = map_dnn(&dnn, cfg).unwrap();
            let pl = Placement::new(map.num_chiplets);
            let traffic = build_traffic(&dnn, &map, &pl, cfg);
            let est = CircuitEstimator::new(cfg);
            let plain = est.estimate(&dnn, &map, &traffic);
            let cached = est.estimate_cached(&dnn, &map, &traffic, Some(&cache));
            assert_eq!(plain.energy_pj.to_bits(), cached.energy_pj.to_bits());
            assert_eq!(plain.latency_ns.to_bits(), cached.latency_ns.to_bits());
            assert_eq!(plain.per_layer.len(), cached.per_layer.len());
        }
        assert_eq!(cache.len(), 1, "tiles/chiplet must not split the key");
        // a different ADC resolution is a genuinely different circuit
        let mut cfg_adc = SiamConfig::paper_default();
        cfg_adc.chiplet.adc_bits = 8;
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg_adc).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg_adc);
        CircuitEstimator::new(&cfg_adc).estimate_cached(&dnn, &map, &traffic, Some(&cache));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn attention_layer_cost_includes_digital_scores() {
        // an attention block must cost strictly more than an fc layer of
        // the same crossbar geometry: the score matmuls are extra
        use crate::dnn::{Layer, LayerKind, TensorShape};
        let cfg = SiamConfig::paper_default();
        let est = CircuitEstimator::new(&cfg);
        let ifm = TensorShape::new(14, 14, 192);
        let attn = Layer {
            name: "attn".into(),
            kind: LayerKind::Attention { heads: 3, dim: 192 },
            ifm,
            ofm: ifm,
        };
        let a = est.layer_cost(&attn, 0);
        assert!(a.energy_pj > 0.0 && a.latency_ns > 0.0);
        // strip the digital part by comparing against a no-score proxy:
        // a conv1x1 with the same unrolled matrix and token count
        let proxy = Layer {
            name: "proxy".into(),
            kind: LayerKind::Conv { kh: 1, kw: 1, stride: 1, padding: 0, out_ch: 4 * 192 },
            ifm,
            ofm: TensorShape::new(14, 14, 4 * 192),
        };
        let p = est.layer_cost(&proxy, 0);
        assert!(a.energy_pj > p.energy_pj, "scores add energy");
        assert!(a.latency_ns > p.latency_ns, "scores add latency");
    }

    #[test]
    fn vit_estimates_with_digital_breakdown() {
        let cfg = SiamConfig::paper_default().with_model("vit_tiny", "imagenet");
        let rep = run("vit_tiny", "imagenet", &cfg);
        assert!(rep.energy_pj > 0.0 && rep.latency_ns > 0.0);
        let digital = rep
            .energy_breakdown
            .components
            .iter()
            .find(|(n, _)| n == "digital_xformer")
            .map(|(_, m)| m.energy_pj)
            .expect("transformers report a digital component");
        assert!(digital > 0.0);
        // the breakdown still sums to the total
        let sum: f64 = rep
            .energy_breakdown
            .components
            .iter()
            .map(|(_, m)| m.energy_pj)
            .sum();
        assert!((sum - rep.energy_pj).abs() / rep.energy_pj < 1e-9);
        // CNNs do not grow the new component
        let cnn = run("resnet110", "cifar10", &SiamConfig::paper_default());
        assert!(cnn
            .energy_breakdown
            .components
            .iter()
            .all(|(n, _)| n != "digital_xformer"));
    }

    #[test]
    fn batch_scales_energy_linearly() {
        let mut cfg = SiamConfig::paper_default();
        let e1 = run("lenet5", "cifar10", &cfg).energy_pj;
        cfg.dnn.batch = 4;
        let e4 = run("lenet5", "cifar10", &cfg).energy_pj;
        assert!((e4 / e1 - 4.0).abs() < 0.2, "batch scaling {}", e4 / e1);
    }
}
