//! # SIAM-RS
//!
//! A Rust reproduction of **SIAM: Chiplet-based Scalable In-Memory
//! Acceleration with Mesh for Deep Neural Networks** (Krishnan et al.,
//! ACM TECS 2021, DOI 10.1145/3476999).
//!
//! SIAM is an end-to-end benchmarking simulator for chiplet-based
//! in-memory-computing (IMC) DNN accelerators. This crate implements the
//! paper's four engines plus the substrates they need:
//!
//! * [`config`] — the Table-2 user inputs (TOML presets in `configs/`).
//! * [`dnn`] — layer graph + model zoo (ResNet/VGG/DenseNet/LeNet plus
//!   ViT/BERT transformers) + the file-based network frontend
//!   (`model = "file:net.toml"`, `configs/models/`, docs/MODELS.md).
//! * [`mapping`] — partition & mapping engine (Eq. 1 + Algorithm 1).
//! * [`circuit`] — NeuroSim-style bottom-up circuit estimator.
//! * [`noc`] — intra-chiplet network simulator (three-tier engine
//!   hierarchy: flow-level, packet-level, flit-level golden).
//! * [`nop`] — network-on-package engine (wires, TX/RX drivers, router).
//! * [`dram`] — Ramulator/VAMPIRE-style DDR3/DDR4 access estimator.
//! * [`cost`] — Appendix-A fabrication cost / yield model.
//! * [`fault`] — yield-aware fault injection and spare-chiplet
//!   failover remap (docs/RELIABILITY.md).
//! * [`variation`] — seeded Monte-Carlo analog device variation:
//!   programming noise, conductance drift, stuck-at cells and ADC
//!   offset propagated to a per-point accuracy proxy and perturbed
//!   read energy (docs/RELIABILITY.md).
//! * [`obs`] — observability: deterministic Chrome trace-event
//!   emission, self-profiling wall-clock spans, leveled logging and the
//!   self-describing `meta` run-metadata block
//!   (docs/OBSERVABILITY.md).
//! * [`runtime`] — PJRT executor for the AOT-compiled Pallas crossbar
//!   kernels (functional inference mode; Python never serves).
//! * [`serve`] — discrete-event inference-serving simulator: streaming
//!   traffic through the layer-pipelined chiplet system (throughput,
//!   tail latency, utilization and energy under load).
//! * [`coordinator`] — orchestration, design-space exploration, reports.
//!
//! Quickstart:
//!
//! ```no_run
//! use siam::config::SiamConfig;
//! use siam::coordinator::simulate;
//!
//! let cfg = SiamConfig::paper_default();
//! let report = simulate(&cfg).unwrap();
//! println!("{}", report.summary());
//! ```
//!
//! Design-space sweeps run through the parallel memoizing engine in
//! [`coordinator::dse`] (`SweepBuilder`): points are evaluated on a
//! work-stealing thread pool while sweep-invariant stage outputs (DNN
//! graph, per-layer circuit costs, DRAM estimates, repeated NoC/NoP
//! epochs) are shared through a [`coordinator::SweepContext`].
//!
//! A guided tour of the crate — module-by-module dataflow, the staged
//! sweep pipeline, and which stages are cached versus evaluated per
//! point — lives in [ARCHITECTURE.md](../../../ARCHITECTURE.md) at the
//! repository root.

#![warn(missing_docs)]

pub mod circuit;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dnn;
pub mod dram;
pub mod fault;
pub mod gpu_baseline;
pub mod mapping;
pub mod metrics;
pub mod noc;
pub mod nop;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod variation;

pub use config::SiamConfig;
pub use metrics::Metrics;
