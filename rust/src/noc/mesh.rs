//! Mesh geometry shared by the NoC (tiles) and NoP (chiplets) simulators:
//! node coordinates, X–Y routing, link identifiers.

use crate::mapping::Placement;

/// Directions out of a router. `L` is the local ejection port.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// +x neighbour.
    East = 0,
    /// −x neighbour.
    West = 1,
    /// −y neighbour.
    North = 2,
    /// +y neighbour.
    South = 3,
}

/// A 2-D mesh with an arbitrary node→coordinate embedding.
#[derive(Debug, Clone)]
pub struct Mesh {
    /// Columns.
    pub width: usize,
    /// Rows.
    pub height: usize,
    coords: Vec<(u16, u16)>, // (row, col) per node id
    embedding: u64,          // order-sensitive digest of `coords`
}

/// splitmix64-style fold of the coordinate sequence: two meshes with
/// equal dims but different node→coordinate embeddings (e.g. a
/// dataflow-permuted placement) must never share an epoch-cache
/// fingerprint.
fn embed_tag(coords: &[(u16, u16)]) -> u64 {
    let mut x = 0x6A09_E667_F3BC_C909u64;
    for &(r, c) in coords {
        x ^= ((r as u64) << 16) | c as u64;
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
    }
    x
}

impl Mesh {
    /// Square-ish mesh over `n` nodes in snake order (consecutive ids are
    /// neighbours — the placement rule of Section 6.1).
    pub fn new(n: usize) -> Mesh {
        assert!(n > 0);
        let width = (n as f64).sqrt().ceil() as usize;
        let height = n.div_ceil(width);
        let coords: Vec<(u16, u16)> = (0..n)
            .map(|i| {
                let r = i / width;
                let c = i % width;
                let c = if r % 2 == 0 { c } else { width - 1 - c };
                (r as u16, c as u16)
            })
            .collect();
        let embedding = embed_tag(&coords);
        Mesh {
            width,
            height,
            coords,
            embedding,
        }
    }

    /// Mesh over a chiplet placement (compute chiplets + accumulator +
    /// DRAM nodes), honoring a dataflow-permuted embedding if present.
    pub fn from_placement(p: &Placement) -> Mesh {
        let coords: Vec<(u16, u16)> = (0..p.nodes())
            .map(|i| {
                let (r, c) = p.coord(i);
                (r as u16, c as u16)
            })
            .collect();
        let embedding = embed_tag(&coords);
        Mesh {
            width: p.width,
            height: p.height,
            coords,
            embedding,
        }
    }

    /// Number of nodes embedded in the mesh.
    pub fn nodes(&self) -> usize {
        self.coords.len()
    }

    /// Order-sensitive digest of the node→coordinate embedding, folded
    /// into epoch-cache fingerprints so permuted placements of equal
    /// dimensions never alias.
    pub fn embedding_tag(&self) -> u64 {
        self.embedding
    }

    /// (row, col) of a node id.
    pub fn coord(&self, node: u32) -> (u16, u16) {
        self.coords[node as usize]
    }

    /// Manhattan hop distance between two nodes.
    pub fn hops(&self, a: u32, b: u32) -> u32 {
        let (ra, ca) = self.coord(a);
        let (rb, cb) = self.coord(b);
        (ra.abs_diff(rb) + ca.abs_diff(cb)) as u32
    }

    /// Unique link id for (row, col, dir). Four slots per grid position.
    fn link_id(&self, r: u16, c: u16, d: Dir) -> u32 {
        ((r as usize * self.width + c as usize) * 4 + d as usize) as u32
    }

    /// Size of the link-id space (4 directed slots per grid position).
    pub fn num_links(&self) -> usize {
        self.width * self.height * 4
    }

    /// X–Y route: the sequence of link ids from `a` to `b` (column-first,
    /// then row — the paper's X–Y dimension order).
    pub fn route(&self, a: u32, b: u32, out: &mut Vec<u32>) {
        out.clear();
        let (ra, ca) = self.coord(a);
        let (rb, cb) = self.coord(b);
        let (mut r, mut c) = (ra, ca);
        while c != cb {
            let d = if cb > c { Dir::East } else { Dir::West };
            out.push(self.link_id(r, c, d));
            c = if cb > c { c + 1 } else { c - 1 };
        }
        while r != rb {
            let d = if rb > r { Dir::South } else { Dir::North };
            out.push(self.link_id(r, c, d));
            r = if rb > r { r + 1 } else { r - 1 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snake_adjacency() {
        let m = Mesh::new(16);
        for i in 0..15u32 {
            assert_eq!(m.hops(i, i + 1), 1);
        }
    }

    #[test]
    fn route_length_equals_hops() {
        let m = Mesh::new(16);
        let mut buf = Vec::new();
        for a in 0..16u32 {
            for b in 0..16u32 {
                m.route(a, b, &mut buf);
                assert_eq!(buf.len() as u32, m.hops(a, b), "{a}->{b}");
            }
        }
    }

    #[test]
    fn xy_routes_column_first() {
        let m = Mesh::new(9); // 3x3
        let mut buf = Vec::new();
        // node 0 is (0,0); node 8 is (2,0) in snake order
        let (r8, c8) = m.coord(8);
        m.route(0, 8, &mut buf);
        assert_eq!(buf.len() as u16, r8 + c8);
    }

    #[test]
    fn embedding_tag_distinguishes_permutations() {
        use crate::mapping::{Placement, TrafficMatrix};
        // a permuted (dataflow) placement has the same dims/node count
        // as row-major but a different embedding — the tag must differ,
        // or the epoch cache would alias their simulations
        let rowmajor = Placement::new(7);
        let mut w = TrafficMatrix::new(rowmajor.nodes());
        w.add(0, 6, 1_000_000); // force a non-identity optimum
        let dataflow = Placement::dataflow(7, &w);
        assert!(dataflow.is_permuted(), "optimizer should beat row-major here");
        let a = Mesh::from_placement(&rowmajor);
        let b = Mesh::from_placement(&dataflow);
        assert_eq!((a.width, a.height, a.nodes()), (b.width, b.height, b.nodes()));
        assert_ne!(a.embedding_tag(), b.embedding_tag());
        // and the snake-order constructor agrees with the identity
        // placement embedding
        assert_eq!(Mesh::new(9).embedding_tag(), Mesh::new(9).embedding_tag());
    }

    #[test]
    fn links_unique_per_route_step() {
        let m = Mesh::new(25);
        let mut buf = Vec::new();
        m.route(0, 24, &mut buf);
        let mut sorted = buf.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), buf.len());
    }
}
