//! Intra-chiplet NoC engine (Section 4.3.2): a customized network
//! simulator in the spirit of BookSim, driven by Algorithm-2 traces,
//! plus router/link power-area models and an analytical H-tree/P2P
//! alternative.
//!
//! Mesh epochs run through a three-tier engine hierarchy (see
//! `ARCHITECTURE.md`): the flow-level [`FlowSim`] serves production
//! sweeps, falling back internally to the per-packet [`PacketSim`] for
//! irregular traces, with the cycle-accurate [`FlitSim`] as the golden
//! reference on small traces.

pub mod flow;
pub mod htree;
pub mod mesh;
pub mod power;
pub mod sim;
pub mod store;

pub use flow::FlowSim;
pub use mesh::Mesh;
pub use sim::{EpochCache, EpochResult, FlitSim, PacketSim, TierCounts};
pub use store::{EpochStore, LoadReport};

use crate::config::{ChipMode, NocTopology, SiamConfig};
use crate::mapping::{MappingResult, Traffic};
use crate::metrics::Metrics;

/// One observed epoch evaluation, as delivered to the tracing hook of
/// [`evaluate_cached_obs`] / [`evaluate_mapped_obs`] (and their NoP
/// counterparts): which layer (and chiplet, for chiplet-local NoC
/// epochs) the epoch belongs to, whether an [`EpochCache`] replayed it,
/// and the engine-tier tally of its answer. Observers are pure — they
/// see results after the fact and cannot perturb them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EpochObs {
    /// Weight-layer position the epoch belongs to.
    pub layer: usize,
    /// Chiplet index for chiplet-local (NoC) epochs; `None` for
    /// package-level (NoP) epochs.
    pub chiplet: Option<usize>,
    /// Whether an [`EpochCache`] replayed the epoch.
    pub hit: bool,
    /// Engine-tier tally of this epoch's answer (zero for analytical
    /// H-tree epochs, which bypass the tier hierarchy).
    pub tiers: TierCounts,
}

/// The per-epoch observer callback type (see [`EpochObs`]).
pub type EpochObserver<'a> = &'a mut dyn FnMut(&EpochObs);

/// Aggregated NoC evaluation for a mapped DNN.
#[derive(Debug, Clone, Default)]
pub struct NocReport {
    /// Total NoC metrics (area = all routers+links across chiplets).
    pub metrics: Metrics,
    /// Serialized NoC cycles across the layer sequence.
    pub cycles: u64,
    /// Packets delivered over all epochs.
    pub packets: u64,
    /// Flit-link traversals over all epochs (drives energy).
    pub flit_hops: u64,
    /// Mean packet latency across all epochs, cycles.
    pub avg_packet_latency_cycles: f64,
    /// Per-weight-layer serialized cycles as `(layer position, cycles)`
    /// in layer order (chiplets of one layer max-combined; layers with
    /// no NoC traffic are absent). Sums to `cycles` on single-kind
    /// systems; under heterogeneous classes the chiplets of one layer
    /// may clock differently, so the wall-clock figures live in
    /// `per_layer_ns` and this stays a raw-cycle diagnostic.
    pub per_layer_cycles: Vec<(usize, u64)>,
    /// Engine-tier tally over all mesh epochs: which tier of the
    /// flow/packet hierarchy answered each piece (zero on H-tree
    /// topologies, which are analytical). Tags replay from the epoch
    /// cache, so the tally is identical for cached/uncached and
    /// serial/parallel evaluation.
    pub tiers: TierCounts,
    /// Per-weight-layer serialized wall-clock time as `(layer position,
    /// ns)`, max-combined across the layer's chiplets in each chiplet's
    /// own clock domain. Sums to `metrics.latency_ns` under
    /// heterogeneous classes; the serving simulator turns these into
    /// per-stage service times.
    pub per_layer_ns: Vec<(usize, f64)>,
}

/// Evaluate all NoC epochs of a traffic picture.
///
/// Epochs of the *same* weight layer run on different chiplets in
/// parallel (their cycle counts max-combine); different layers execute
/// sequentially (cycle counts add) — the paper's layer-by-layer dataflow
/// (Algorithm 4).
pub fn evaluate(cfg: &SiamConfig, traffic: &Traffic, num_chiplets: usize) -> NocReport {
    evaluate_cached(cfg, traffic, num_chiplets, None)
}

/// [`evaluate`] with an optional [`EpochCache`] shared across sweep
/// points: mesh-topology epochs identical to previously simulated ones
/// are replayed instead of re-simulated. Passing `None` is equivalent to
/// [`evaluate`]; results are bit-identical either way.
pub fn evaluate_cached(
    cfg: &SiamConfig,
    traffic: &Traffic,
    num_chiplets: usize,
    cache: Option<&EpochCache>,
) -> NocReport {
    evaluate_cached_obs(cfg, traffic, num_chiplets, cache, None)
}

/// [`evaluate_cached`] with an optional per-epoch observer — the tracing
/// hook behind `siam simulate --trace`. The observer is invoked once per
/// epoch, after it evaluates, with the epoch's layer/chiplet, cache-hit
/// flag and tier tally ([`EpochObs`]); results are bit-identical with
/// and without an observer.
pub fn evaluate_cached_obs(
    cfg: &SiamConfig,
    traffic: &Traffic,
    num_chiplets: usize,
    cache: Option<&EpochCache>,
    mut obs: Option<EpochObserver<'_>>,
) -> NocReport {
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let tiles = cfg.chiplet.tiles_per_chiplet;
    let mesh = Mesh::new(tiles.max(2));

    // per-(layer, chiplet) serialized cycles, then max across chiplets
    // per layer, then sum across layers.
    let mut per_key: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut lat_sum = 0u64;

    let tile_pitch_mm = 0.7; // ~sqrt of the 0.5 mm² calibrated tile
    let htree = htree::HTreeModel::new(tiles.max(2), cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    // flow-level engine (top tier): its arena — busy-until vector,
    // memoized X–Y routes, certificate buffers — is reused across every
    // epoch of this evaluation
    let mut fsim = FlowSim::new(&mesh);

    let mut tiers = TierCounts::default();
    for ep in &traffic.noc_epochs {
        let (r, t, hit) = match cfg.chiplet.noc_topology {
            NocTopology::Mesh => match cache {
                Some(c) => fsim.run_cached_tagged(&ep.flows, c),
                None => {
                    let (r, t) = fsim.run_counted(&ep.flows);
                    (r, t, false)
                }
            },
            NocTopology::Tree | NocTopology::HTree => {
                (htree.run(&ep.flows), TierCounts::default(), false)
            }
        };
        tiers.accumulate(&t);
        if let Some(o) = obs.as_deref_mut() {
            o(&EpochObs {
                layer: ep.layer,
                chiplet: Some(ep.chiplet),
                hit,
                tiers: t,
            });
        }
        *per_key.entry((ep.layer, ep.chiplet)).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
        lat_sum += r.total_latency_cycles;
    }
    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    for ((layer, _chiplet), cyc) in per_key {
        let e = per_layer.entry(layer).or_default();
        *e = (*e).max(cyc);
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- power & area
    let router = power::router(
        cfg.chiplet.noc_width,
        cfg.chiplet.noc_buffer_depth,
        5,
        &tech,
    );
    let link = power::link(cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    let (area, leakage, e_per_hop) = match cfg.chiplet.noc_topology {
        NocTopology::Mesh => {
            let links = (2 * mesh.width * mesh.height - mesh.width - mesh.height) as f64;
            (
                num_chiplets as f64 * (tiles as f64 * router.area_um2 + links * link.area_um2),
                num_chiplets as f64 * tiles as f64 * router.leakage_uw,
                router.flit_energy_pj + link.flit_energy_pj,
            )
        }
        NocTopology::Tree | NocTopology::HTree => (
            num_chiplets as f64 * htree.area_um2,
            num_chiplets as f64 * 2.0 * tech.leakage,
            htree.flit_level_energy_pj,
        ),
    };

    let clk_ns = 1.0e3 / cfg.chiplet.frequency_mhz;
    let per_layer_ns: Vec<(usize, f64)> = per_layer_cycles
        .iter()
        .map(|&(l, c)| (l, c as f64 * clk_ns))
        .collect();
    NocReport {
        metrics: Metrics {
            area_um2: area,
            energy_pj: flit_hops as f64 * e_per_hop,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        avg_packet_latency_cycles: if packets == 0 {
            0.0
        } else {
            lat_sum as f64 / packets as f64
        },
        per_layer_cycles,
        per_layer_ns,
        tiers,
    }
}

/// Analytic lower-bound NoC evaluation — the cheap scoring tier behind
/// `sweep --search pareto|halving` (see `coordinator::dse`).
///
/// Epoch-independent figures (`metrics.energy_pj`, `metrics.area_um2`,
/// `metrics.leakage_uw`, `packets`, `flit_hops`) are **bit-identical**
/// to [`evaluate`]: flit-hop counts are trace-determined, so energy and
/// area never depend on contention. `cycles`, `metrics.latency_ns` and
/// the per-layer figures are **provable lower bounds** of the full
/// engine's answer (see `flow::epoch_bound`); H-tree/P2P topologies are
/// analytical to begin with, so there the whole report is identical.
/// `tiers` stays zero — no engine tier ran.
pub fn evaluate_bound(cfg: &SiamConfig, traffic: &Traffic, num_chiplets: usize) -> NocReport {
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let tiles = cfg.chiplet.tiles_per_chiplet;
    let mesh = Mesh::new(tiles.max(2));
    let tile_pitch_mm = 0.7; // ~sqrt of the 0.5 mm² calibrated tile
    let htree = htree::HTreeModel::new(tiles.max(2), cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    let fsim = FlowSim::new(&mesh); // source of the engine defaults only

    let mut per_key: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut lat_sum = 0u64;
    for ep in &traffic.noc_epochs {
        let r = match cfg.chiplet.noc_topology {
            NocTopology::Mesh => {
                flow::epoch_bound(&mesh, fsim.router_delay, fsim.flits_per_packet, &ep.flows)
            }
            NocTopology::Tree | NocTopology::HTree => htree.run(&ep.flows),
        };
        *per_key.entry((ep.layer, ep.chiplet)).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
        lat_sum += r.total_latency_cycles;
    }
    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    for ((layer, _chiplet), cyc) in per_key {
        let e = per_layer.entry(layer).or_default();
        *e = (*e).max(cyc);
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- power & area: identical to `evaluate_cached_obs`
    let router = power::router(
        cfg.chiplet.noc_width,
        cfg.chiplet.noc_buffer_depth,
        5,
        &tech,
    );
    let link = power::link(cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    let (area, leakage, e_per_hop) = match cfg.chiplet.noc_topology {
        NocTopology::Mesh => {
            let links = (2 * mesh.width * mesh.height - mesh.width - mesh.height) as f64;
            (
                num_chiplets as f64 * (tiles as f64 * router.area_um2 + links * link.area_um2),
                num_chiplets as f64 * tiles as f64 * router.leakage_uw,
                router.flit_energy_pj + link.flit_energy_pj,
            )
        }
        NocTopology::Tree | NocTopology::HTree => (
            num_chiplets as f64 * htree.area_um2,
            num_chiplets as f64 * 2.0 * tech.leakage,
            htree.flit_level_energy_pj,
        ),
    };

    let clk_ns = 1.0e3 / cfg.chiplet.frequency_mhz;
    let per_layer_ns: Vec<(usize, f64)> = per_layer_cycles
        .iter()
        .map(|&(l, c)| (l, c as f64 * clk_ns))
        .collect();
    NocReport {
        metrics: Metrics {
            area_um2: area,
            energy_pj: flit_hops as f64 * e_per_hop,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        avg_packet_latency_cycles: if packets == 0 {
            0.0
        } else {
            lat_sum as f64 / packets as f64
        },
        per_layer_cycles,
        per_layer_ns,
        tiers: TierCounts::default(),
    }
}

/// Class-aware variant of [`evaluate_bound`], mirroring
/// [`evaluate_mapped`]: single-kind systems take [`evaluate_bound`];
/// heterogeneous systems bound each chiplet's epochs on its own class's
/// mesh and max-combine a layer's chiplets in wall-clock ns. The same
/// exactness split applies — energy/area/leakage bit-identical to
/// [`evaluate_mapped`], timing a provable lower bound.
pub fn evaluate_mapped_bound(cfg: &SiamConfig, traffic: &Traffic, map: &MappingResult) -> NocReport {
    if !cfg.has_hetero_classes() || cfg.system.chip_mode == ChipMode::Monolithic {
        return evaluate_bound(cfg, traffic, map.num_chiplets);
    }
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let classes = cfg.resolved_chiplet_classes();
    let tile_pitch_mm = 0.7; // ~sqrt of the 0.5 mm² calibrated tile
    let meshes: Vec<Mesh> = classes
        .iter()
        .map(|c| Mesh::new(c.tiles_per_chiplet.max(2)))
        .collect();
    let htrees: Vec<htree::HTreeModel> = classes
        .iter()
        .map(|c| {
            htree::HTreeModel::new(
                c.tiles_per_chiplet.max(2),
                cfg.chiplet.noc_width,
                tile_pitch_mm,
                &tech,
            )
        })
        .collect();
    let defaults = FlowSim::new(&meshes[0]); // engine defaults only
    let router = power::router(
        cfg.chiplet.noc_width,
        cfg.chiplet.noc_buffer_depth,
        5,
        &tech,
    );
    let link = power::link(cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    let mesh_hop_pj = router.flit_energy_pj + link.flit_energy_pj;

    let mut per_key: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut lat_sum = 0u64;
    let mut energy_pj = 0.0;
    for ep in &traffic.noc_epochs {
        let k = map.chiplet_class[ep.chiplet];
        let (r, hop_pj) = match cfg.chiplet.noc_topology {
            NocTopology::Mesh => (
                flow::epoch_bound(
                    &meshes[k],
                    defaults.router_delay,
                    defaults.flits_per_packet,
                    &ep.flows,
                ),
                mesh_hop_pj,
            ),
            NocTopology::Tree | NocTopology::HTree => {
                (htrees[k].run(&ep.flows), htrees[k].flit_level_energy_pj)
            }
        };
        *per_key.entry((ep.layer, ep.chiplet)).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
        lat_sum += r.total_latency_cycles;
        energy_pj += r.flit_hops as f64 * hop_pj;
    }

    let mut layer_ns: std::collections::BTreeMap<usize, f64> = Default::default();
    let mut layer_cycles: std::collections::BTreeMap<usize, u64> = Default::default();
    for ((layer, chiplet), cyc) in per_key {
        let ns = cyc as f64 * classes[map.chiplet_class[chiplet]].clock_period_ns();
        let e = layer_ns.entry(layer).or_insert(0.0);
        *e = (*e).max(ns);
        let ec = layer_cycles.entry(layer).or_default();
        *ec = (*ec).max(cyc);
    }
    let latency_ns: f64 = layer_ns.values().sum();
    let cycles: u64 = layer_cycles.values().sum();

    // ---- power & area: identical to `evaluate_mapped_obs`
    let (mut area, mut leakage) = (0.0f64, 0.0f64);
    for &k in &map.chiplet_class {
        match cfg.chiplet.noc_topology {
            NocTopology::Mesh => {
                let m = &meshes[k];
                let links = (2 * m.width * m.height - m.width - m.height) as f64;
                let tiles = classes[k].tiles_per_chiplet as f64;
                area += tiles * router.area_um2 + links * link.area_um2;
                leakage += tiles * router.leakage_uw;
            }
            NocTopology::Tree | NocTopology::HTree => {
                area += htrees[k].area_um2;
                leakage += 2.0 * tech.leakage;
            }
        }
    }

    NocReport {
        metrics: Metrics {
            area_um2: area,
            energy_pj,
            latency_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        avg_packet_latency_cycles: if packets == 0 {
            0.0
        } else {
            lat_sum as f64 / packets as f64
        },
        per_layer_cycles: layer_cycles.into_iter().collect(),
        per_layer_ns: layer_ns.into_iter().collect(),
        tiers: TierCounts::default(),
    }
}

/// Class-aware NoC evaluation: like [`evaluate_cached`], but each
/// chiplet's epochs run on its own class's mesh (tile count) and clock.
/// Single-kind systems — including the degenerate single-class identity
/// — take the classic path and are bit-identical to
/// [`evaluate_cached`]; genuinely heterogeneous systems max-combine a
/// layer's chiplets in wall-clock ns (clock domains differ per class)
/// and sum per-class router/link area and leakage.
pub fn evaluate_mapped(
    cfg: &SiamConfig,
    traffic: &Traffic,
    map: &MappingResult,
    cache: Option<&EpochCache>,
) -> NocReport {
    evaluate_mapped_obs(cfg, traffic, map, cache, None)
}

/// [`evaluate_mapped`] with an optional per-epoch observer (see
/// [`evaluate_cached_obs`]).
pub fn evaluate_mapped_obs(
    cfg: &SiamConfig,
    traffic: &Traffic,
    map: &MappingResult,
    cache: Option<&EpochCache>,
    mut obs: Option<EpochObserver<'_>>,
) -> NocReport {
    if !cfg.has_hetero_classes() || cfg.system.chip_mode == ChipMode::Monolithic {
        return evaluate_cached_obs(cfg, traffic, map.num_chiplets, cache, obs);
    }
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let classes = cfg.resolved_chiplet_classes();
    let tile_pitch_mm = 0.7; // ~sqrt of the 0.5 mm² calibrated tile
    let meshes: Vec<Mesh> = classes
        .iter()
        .map(|c| Mesh::new(c.tiles_per_chiplet.max(2)))
        .collect();
    let htrees: Vec<htree::HTreeModel> = classes
        .iter()
        .map(|c| {
            htree::HTreeModel::new(
                c.tiles_per_chiplet.max(2),
                cfg.chiplet.noc_width,
                tile_pitch_mm,
                &tech,
            )
        })
        .collect();
    let mut sims: Vec<FlowSim> = meshes.iter().map(FlowSim::new).collect();
    let router = power::router(
        cfg.chiplet.noc_width,
        cfg.chiplet.noc_buffer_depth,
        5,
        &tech,
    );
    let link = power::link(cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    let mesh_hop_pj = router.flit_energy_pj + link.flit_energy_pj;

    let mut per_key: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut lat_sum = 0u64;
    let mut energy_pj = 0.0;
    let mut tiers = TierCounts::default();
    for ep in &traffic.noc_epochs {
        let k = map.chiplet_class[ep.chiplet];
        let (r, t, hit, hop_pj) = match cfg.chiplet.noc_topology {
            NocTopology::Mesh => {
                let (r, t, hit) = match cache {
                    Some(c) => sims[k].run_cached_tagged(&ep.flows, c),
                    None => {
                        let (r, t) = sims[k].run_counted(&ep.flows);
                        (r, t, false)
                    }
                };
                (r, t, hit, mesh_hop_pj)
            }
            NocTopology::Tree | NocTopology::HTree => (
                htrees[k].run(&ep.flows),
                TierCounts::default(),
                false,
                htrees[k].flit_level_energy_pj,
            ),
        };
        tiers.accumulate(&t);
        if let Some(o) = obs.as_deref_mut() {
            o(&EpochObs {
                layer: ep.layer,
                chiplet: Some(ep.chiplet),
                hit,
                tiers: t,
            });
        }
        *per_key.entry((ep.layer, ep.chiplet)).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
        lat_sum += r.total_latency_cycles;
        energy_pj += r.flit_hops as f64 * hop_pj;
    }

    // per-layer: chiplets of one layer run in parallel; convert each
    // chiplet's cycles in its own clock domain, then take the max in ns
    let mut layer_ns: std::collections::BTreeMap<usize, f64> = Default::default();
    let mut layer_cycles: std::collections::BTreeMap<usize, u64> = Default::default();
    for ((layer, chiplet), cyc) in per_key {
        let ns = cyc as f64 * classes[map.chiplet_class[chiplet]].clock_period_ns();
        let e = layer_ns.entry(layer).or_insert(0.0);
        *e = (*e).max(ns);
        let ec = layer_cycles.entry(layer).or_default();
        *ec = (*ec).max(cyc);
    }
    let latency_ns: f64 = layer_ns.values().sum();
    let cycles: u64 = layer_cycles.values().sum();

    // ---- power & area: per chiplet, by class
    let (mut area, mut leakage) = (0.0f64, 0.0f64);
    for &k in &map.chiplet_class {
        match cfg.chiplet.noc_topology {
            NocTopology::Mesh => {
                let m = &meshes[k];
                let links = (2 * m.width * m.height - m.width - m.height) as f64;
                let tiles = classes[k].tiles_per_chiplet as f64;
                area += tiles * router.area_um2 + links * link.area_um2;
                leakage += tiles * router.leakage_uw;
            }
            NocTopology::Tree | NocTopology::HTree => {
                area += htrees[k].area_um2;
                leakage += 2.0 * tech.leakage;
            }
        }
    }

    NocReport {
        metrics: Metrics {
            area_um2: area,
            energy_pj,
            latency_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        avg_packet_latency_cycles: if packets == 0 {
            0.0
        } else {
            lat_sum as f64 / packets as f64
        },
        per_layer_cycles: layer_cycles.into_iter().collect(),
        per_layer_ns: layer_ns.into_iter().collect(),
        tiers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;
    use crate::mapping::{build_traffic, map_dnn, Placement};

    fn report(model: &str, cfg: &SiamConfig) -> NocReport {
        let dnn = build_model(model, "cifar10").unwrap();
        let map = map_dnn(&dnn, cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, cfg);
        evaluate(cfg, &traffic, map.num_chiplets)
    }

    #[test]
    fn resnet110_noc_produces_work() {
        let cfg = SiamConfig::paper_default();
        let rep = report("resnet110", &cfg);
        assert!(rep.cycles > 0);
        assert!(rep.packets > 0);
        assert!(rep.metrics.energy_pj > 0.0);
        assert!(rep.metrics.area_um2 > 0.0);
    }

    #[test]
    fn tier_tally_and_observer_see_every_mesh_epoch() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let mut seen = 0usize;
        let mut observed = TierCounts::default();
        let mut cb = |o: &EpochObs| {
            seen += 1;
            observed.accumulate(&o.tiers);
            assert!(o.chiplet.is_some(), "NoC epochs are chiplet-local");
        };
        let rep = evaluate_cached_obs(&cfg, &traffic, map.num_chiplets, None, Some(&mut cb));
        assert_eq!(seen, traffic.noc_epochs.len());
        assert_eq!(observed, rep.tiers, "report tally must equal the per-epoch sum");
        assert!(rep.tiers.total() > 0, "mesh epochs must attribute tiers");
        // observed runs are bit-identical to unobserved ones
        let plain = evaluate(&cfg, &traffic, map.num_chiplets);
        assert_eq!(plain.cycles, rep.cycles);
        assert_eq!(plain.tiers, rep.tiers);
        assert_eq!(plain.metrics.energy_pj.to_bits(), rep.metrics.energy_pj.to_bits());
        // warm cache replays the same tally via the stored tags
        let cache = EpochCache::new();
        let cold = evaluate_cached(&cfg, &traffic, map.num_chiplets, Some(&cache));
        let warm = evaluate_cached(&cfg, &traffic, map.num_chiplets, Some(&cache));
        assert!(cache.hits() > 0);
        assert_eq!(cold.tiers, rep.tiers);
        assert_eq!(warm.tiers, rep.tiers, "hits must replay the stored tier tags");
    }

    #[test]
    fn per_layer_cycles_sum_to_total() {
        let cfg = SiamConfig::paper_default();
        let rep = report("resnet110", &cfg);
        let sum: u64 = rep.per_layer_cycles.iter().map(|&(_, c)| c).sum();
        assert_eq!(sum, rep.cycles);
        assert!(rep.per_layer_cycles.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn wider_noc_reduces_latency() {
        let mut cfg = SiamConfig::paper_default();
        cfg.chiplet.noc_width = 16;
        let narrow = report("resnet110", &cfg);
        cfg.chiplet.noc_width = 64;
        let wide = report("resnet110", &cfg);
        assert!(
            wide.cycles < narrow.cycles,
            "wide {} vs narrow {}",
            wide.cycles,
            narrow.cycles
        );
    }

    #[test]
    fn htree_differs_from_mesh() {
        let mut cfg = SiamConfig::paper_default();
        let mesh = report("lenet5", &cfg);
        cfg.chiplet.noc_topology = NocTopology::HTree;
        let htree = report("lenet5", &cfg);
        assert_ne!(mesh.cycles, htree.cycles);
    }

    #[test]
    fn per_layer_ns_matches_cycles_on_single_kind() {
        let cfg = SiamConfig::paper_default();
        let rep = report("resnet110", &cfg);
        let clk = cfg.clock_period_ns();
        assert_eq!(rep.per_layer_ns.len(), rep.per_layer_cycles.len());
        for (&(l, c), &(ln, ns)) in rep.per_layer_cycles.iter().zip(&rep.per_layer_ns) {
            assert_eq!(l, ln);
            assert_eq!(ns.to_bits(), (c as f64 * clk).to_bits());
        }
    }

    #[test]
    fn evaluate_mapped_single_kind_is_bit_identical() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let a = evaluate(&cfg, &traffic, map.num_chiplets);
        let b = evaluate_mapped(&cfg, &traffic, &map, None);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.flit_hops, b.flit_hops);
        assert_eq!(a.metrics.energy_pj.to_bits(), b.metrics.energy_pj.to_bits());
        assert_eq!(a.metrics.latency_ns.to_bits(), b.metrics.latency_ns.to_bits());
        assert_eq!(a.metrics.area_um2.to_bits(), b.metrics.area_um2.to_bits());
    }

    #[test]
    fn hetero_classes_clock_and_mesh_per_class() {
        use crate::config::{ChipletClassConfig, MemCell};
        let base = SiamConfig::paper_default();
        let big = ChipletClassConfig::from_base(&base, "big");
        let mut little = ChipletClassConfig::from_base(&base, "little");
        little.cell = MemCell::Sram;
        little.xbar_rows = 64;
        little.xbar_cols = 64;
        little.adc_bits = 3;
        little.frequency_mhz = 500.0; // half-clock little chiplets
        let cfg = base.with_chiplet_classes(vec![big, little]);
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let rep = evaluate_mapped(&cfg, &traffic, &map, None);
        assert!(rep.cycles > 0 && rep.packets > 0);
        assert!(rep.metrics.latency_ns > 0.0 && rep.metrics.area_um2 > 0.0);
        // per-layer ns partitions the latency exactly
        let sum: f64 = rep.per_layer_ns.iter().map(|&(_, ns)| ns).sum();
        assert!((sum - rep.metrics.latency_ns).abs() <= 1e-9 * rep.metrics.latency_ns.max(1.0));
        // the cache stays transparent on the hetero path too
        let cache = EpochCache::new();
        let warm = evaluate_mapped(&cfg, &traffic, &map, Some(&cache));
        let rewarm = evaluate_mapped(&cfg, &traffic, &map, Some(&cache));
        for r in [&warm, &rewarm] {
            assert_eq!(r.cycles, rep.cycles);
            assert_eq!(r.metrics.latency_ns.to_bits(), rep.metrics.latency_ns.to_bits());
            assert_eq!(r.metrics.energy_pj.to_bits(), rep.metrics.energy_pj.to_bits());
        }
        assert!(cache.hits() > 0, "second hetero evaluation must replay epochs");
    }

    #[test]
    fn bound_is_exact_on_energy_area_and_a_lower_bound_on_time() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let full = evaluate_mapped(&cfg, &traffic, &map, None);
        let lb = evaluate_mapped_bound(&cfg, &traffic, &map);
        assert_eq!(lb.packets, full.packets);
        assert_eq!(lb.flit_hops, full.flit_hops);
        assert_eq!(lb.metrics.energy_pj.to_bits(), full.metrics.energy_pj.to_bits());
        assert_eq!(lb.metrics.area_um2.to_bits(), full.metrics.area_um2.to_bits());
        assert_eq!(lb.metrics.leakage_uw.to_bits(), full.metrics.leakage_uw.to_bits());
        assert!(lb.cycles <= full.cycles, "{} > {}", lb.cycles, full.cycles);
        assert!(lb.metrics.latency_ns <= full.metrics.latency_ns);
        assert_eq!(lb.tiers, TierCounts::default(), "no engine tier runs in the bound");
    }

    #[test]
    fn htree_bound_is_the_full_answer() {
        // H-tree topologies are analytical to begin with: the cheap tier
        // runs the same model, so the whole report is bit-identical.
        let mut cfg = SiamConfig::paper_default();
        cfg.chiplet.noc_topology = NocTopology::HTree;
        let dnn = build_model("lenet5", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let full = evaluate(&cfg, &traffic, map.num_chiplets);
        let lb = evaluate_bound(&cfg, &traffic, map.num_chiplets);
        assert_eq!(lb.cycles, full.cycles);
        assert_eq!(lb.metrics.energy_pj.to_bits(), full.metrics.energy_pj.to_bits());
        assert_eq!(lb.metrics.latency_ns.to_bits(), full.metrics.latency_ns.to_bits());
    }

    #[test]
    fn hetero_bound_keeps_the_exactness_split() {
        use crate::config::{ChipletClassConfig, MemCell};
        let base = SiamConfig::paper_default();
        let big = ChipletClassConfig::from_base(&base, "big");
        let mut little = ChipletClassConfig::from_base(&base, "little");
        little.cell = MemCell::Sram;
        little.xbar_rows = 64;
        little.xbar_cols = 64;
        little.adc_bits = 3;
        little.frequency_mhz = 500.0;
        let cfg = base.with_chiplet_classes(vec![big, little]);
        let dnn = build_model("resnet110", "cifar10").unwrap();
        let map = map_dnn(&dnn, &cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, &cfg);
        let full = evaluate_mapped(&cfg, &traffic, &map, None);
        let lb = evaluate_mapped_bound(&cfg, &traffic, &map);
        assert_eq!(lb.flit_hops, full.flit_hops);
        assert_eq!(lb.metrics.energy_pj.to_bits(), full.metrics.energy_pj.to_bits());
        assert_eq!(lb.metrics.area_um2.to_bits(), full.metrics.area_um2.to_bits());
        assert!(lb.metrics.latency_ns <= full.metrics.latency_ns);
    }

    #[test]
    fn more_tiles_per_chiplet_increases_noc_cost() {
        // Fig. 11b: NoC EDP grows with tiles/chiplet (bigger mesh, more
        // intra-chiplet traffic).
        let cfg4 = SiamConfig::paper_default().with_tiles_per_chiplet(4);
        let cfg36 = SiamConfig::paper_default().with_tiles_per_chiplet(36);
        let r4 = report("resnet110", &cfg4);
        let r36 = report("resnet110", &cfg36);
        let edp4 = r4.metrics.edp();
        let edp36 = r36.metrics.edp();
        assert!(
            edp36 > edp4,
            "NoC EDP should grow with chiplet size: {edp4} vs {edp36}"
        );
    }
}
