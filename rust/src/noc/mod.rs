//! Intra-chiplet NoC engine (Section 4.3.2): a customized network
//! simulator in the spirit of BookSim, driven by Algorithm-2 traces,
//! plus router/link power-area models and an analytical H-tree/P2P
//! alternative.
//!
//! Mesh epochs run through a three-tier engine hierarchy (see
//! `ARCHITECTURE.md`): the flow-level [`FlowSim`] serves production
//! sweeps, falling back internally to the per-packet [`PacketSim`] for
//! irregular traces, with the cycle-accurate [`FlitSim`] as the golden
//! reference on small traces.

pub mod flow;
pub mod htree;
pub mod mesh;
pub mod power;
pub mod sim;

pub use flow::FlowSim;
pub use mesh::Mesh;
pub use sim::{EpochCache, EpochResult, FlitSim, PacketSim};

use crate::config::{NocTopology, SiamConfig};
use crate::mapping::Traffic;
use crate::metrics::Metrics;

/// Aggregated NoC evaluation for a mapped DNN.
#[derive(Debug, Clone, Default)]
pub struct NocReport {
    /// Total NoC metrics (area = all routers+links across chiplets).
    pub metrics: Metrics,
    /// Serialized NoC cycles across the layer sequence.
    pub cycles: u64,
    /// Packets delivered over all epochs.
    pub packets: u64,
    /// Flit-link traversals over all epochs (drives energy).
    pub flit_hops: u64,
    /// Mean packet latency across all epochs, cycles.
    pub avg_packet_latency_cycles: f64,
    /// Per-weight-layer serialized cycles as `(layer position, cycles)`
    /// in layer order (chiplets of one layer max-combined; layers with
    /// no NoC traffic are absent). Sums to `cycles`; the serving
    /// simulator turns these into per-stage service times.
    pub per_layer_cycles: Vec<(usize, u64)>,
}

/// Evaluate all NoC epochs of a traffic picture.
///
/// Epochs of the *same* weight layer run on different chiplets in
/// parallel (their cycle counts max-combine); different layers execute
/// sequentially (cycle counts add) — the paper's layer-by-layer dataflow
/// (Algorithm 4).
pub fn evaluate(cfg: &SiamConfig, traffic: &Traffic, num_chiplets: usize) -> NocReport {
    evaluate_cached(cfg, traffic, num_chiplets, None)
}

/// [`evaluate`] with an optional [`EpochCache`] shared across sweep
/// points: mesh-topology epochs identical to previously simulated ones
/// are replayed instead of re-simulated. Passing `None` is equivalent to
/// [`evaluate`]; results are bit-identical either way.
pub fn evaluate_cached(
    cfg: &SiamConfig,
    traffic: &Traffic,
    num_chiplets: usize,
    cache: Option<&EpochCache>,
) -> NocReport {
    let tech = crate::circuit::Tech::from_device(&cfg.device);
    let tiles = cfg.chiplet.tiles_per_chiplet;
    let mesh = Mesh::new(tiles.max(2));

    // per-(layer, chiplet) serialized cycles, then max across chiplets
    // per layer, then sum across layers.
    let mut per_key: std::collections::BTreeMap<(usize, usize), u64> = Default::default();
    let mut packets = 0u64;
    let mut flit_hops = 0u64;
    let mut lat_sum = 0u64;

    let tile_pitch_mm = 0.7; // ~sqrt of the 0.5 mm² calibrated tile
    let htree = htree::HTreeModel::new(tiles.max(2), cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    // flow-level engine (top tier): its arena — busy-until vector,
    // memoized X–Y routes, certificate buffers — is reused across every
    // epoch of this evaluation
    let mut fsim = FlowSim::new(&mesh);

    for ep in &traffic.noc_epochs {
        let r = match cfg.chiplet.noc_topology {
            NocTopology::Mesh => match cache {
                Some(c) => fsim.run_cached(&ep.flows, c),
                None => fsim.run(&ep.flows),
            },
            NocTopology::Tree | NocTopology::HTree => htree.run(&ep.flows),
        };
        *per_key.entry((ep.layer, ep.chiplet)).or_default() += r.completion_cycles;
        packets += r.packets;
        flit_hops += r.flit_hops;
        lat_sum += r.total_latency_cycles;
    }
    let mut per_layer: std::collections::BTreeMap<usize, u64> = Default::default();
    for ((layer, _chiplet), cyc) in per_key {
        let e = per_layer.entry(layer).or_default();
        *e = (*e).max(cyc);
    }
    let cycles: u64 = per_layer.values().sum();
    let per_layer_cycles: Vec<(usize, u64)> = per_layer.into_iter().collect();

    // ---- power & area
    let router = power::router(
        cfg.chiplet.noc_width,
        cfg.chiplet.noc_buffer_depth,
        5,
        &tech,
    );
    let link = power::link(cfg.chiplet.noc_width, tile_pitch_mm, &tech);
    let (area, leakage, e_per_hop) = match cfg.chiplet.noc_topology {
        NocTopology::Mesh => {
            let links = (2 * mesh.width * mesh.height - mesh.width - mesh.height) as f64;
            (
                num_chiplets as f64 * (tiles as f64 * router.area_um2 + links * link.area_um2),
                num_chiplets as f64 * tiles as f64 * router.leakage_uw,
                router.flit_energy_pj + link.flit_energy_pj,
            )
        }
        NocTopology::Tree | NocTopology::HTree => (
            num_chiplets as f64 * htree.area_um2,
            num_chiplets as f64 * 2.0 * tech.leakage,
            htree.flit_level_energy_pj,
        ),
    };

    let clk_ns = 1.0e3 / cfg.chiplet.frequency_mhz;
    NocReport {
        metrics: Metrics {
            area_um2: area,
            energy_pj: flit_hops as f64 * e_per_hop,
            latency_ns: cycles as f64 * clk_ns,
            leakage_uw: leakage,
        },
        cycles,
        packets,
        flit_hops,
        avg_packet_latency_cycles: if packets == 0 {
            0.0
        } else {
            lat_sum as f64 / packets as f64
        },
        per_layer_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;
    use crate::dnn::build_model;
    use crate::mapping::{build_traffic, map_dnn, Placement};

    fn report(model: &str, cfg: &SiamConfig) -> NocReport {
        let dnn = build_model(model, "cifar10").unwrap();
        let map = map_dnn(&dnn, cfg).unwrap();
        let pl = Placement::new(map.num_chiplets);
        let traffic = build_traffic(&dnn, &map, &pl, cfg);
        evaluate(cfg, &traffic, map.num_chiplets)
    }

    #[test]
    fn resnet110_noc_produces_work() {
        let cfg = SiamConfig::paper_default();
        let rep = report("resnet110", &cfg);
        assert!(rep.cycles > 0);
        assert!(rep.packets > 0);
        assert!(rep.metrics.energy_pj > 0.0);
        assert!(rep.metrics.area_um2 > 0.0);
    }

    #[test]
    fn per_layer_cycles_sum_to_total() {
        let cfg = SiamConfig::paper_default();
        let rep = report("resnet110", &cfg);
        let sum: u64 = rep.per_layer_cycles.iter().map(|&(_, c)| c).sum();
        assert_eq!(sum, rep.cycles);
        assert!(rep.per_layer_cycles.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn wider_noc_reduces_latency() {
        let mut cfg = SiamConfig::paper_default();
        cfg.chiplet.noc_width = 16;
        let narrow = report("resnet110", &cfg);
        cfg.chiplet.noc_width = 64;
        let wide = report("resnet110", &cfg);
        assert!(
            wide.cycles < narrow.cycles,
            "wide {} vs narrow {}",
            wide.cycles,
            narrow.cycles
        );
    }

    #[test]
    fn htree_differs_from_mesh() {
        let mut cfg = SiamConfig::paper_default();
        let mesh = report("lenet5", &cfg);
        cfg.chiplet.noc_topology = NocTopology::HTree;
        let htree = report("lenet5", &cfg);
        assert_ne!(mesh.cycles, htree.cycles);
    }

    #[test]
    fn more_tiles_per_chiplet_increases_noc_cost() {
        // Fig. 11b: NoC EDP grows with tiles/chiplet (bigger mesh, more
        // intra-chiplet traffic).
        let cfg4 = SiamConfig::paper_default().with_tiles_per_chiplet(4);
        let cfg36 = SiamConfig::paper_default().with_tiles_per_chiplet(36);
        let r4 = report("resnet110", &cfg4);
        let r36 = report("resnet110", &cfg36);
        let edp4 = r4.metrics.edp();
        let edp36 = r36.metrics.edp();
        assert!(
            edp36 > edp4,
            "NoC EDP should grow with chiplet size: {edp4} vs {edp36}"
        );
    }
}
