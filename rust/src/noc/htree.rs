//! Analytical H-tree / tree point-to-point interconnect model (the
//! NeuroSim-style alternative to the mesh NoC — Table 1 row "NoC-mesh,
//! NoC-tree and H-Tree").
//!
//! An H-tree over `n` leaves has `log2(n)` levels; all traffic funnels
//! through the root, so an epoch's latency is dominated by root
//! serialization plus the tree depth, and its energy by bits × levels
//! traversed.

use super::sim::EpochResult;
use crate::circuit::Tech;
use crate::mapping::Flow;

/// Analytical H-tree interconnect over `leaves` tiles.
pub struct HTreeModel {
    /// Leaf (tile) count.
    pub leaves: usize,
    /// Tree levels: ceil(log2(leaves)).
    pub levels: u32,
    /// Cycles to cross one tree level.
    pub level_delay: u64,
    /// Energy per flit per level, pJ (wire halves per level going down).
    pub flit_level_energy_pj: f64,
    /// Total wiring + mux area, µm².
    pub area_um2: f64,
}

impl HTreeModel {
    /// Model an H-tree over `leaves` tiles at the given flit width and
    /// tile pitch.
    pub fn new(leaves: usize, flit_bits: usize, tile_pitch_mm: f64, tech: &Tech) -> Self {
        let levels = (leaves.max(2) as f64).log2().ceil() as u32;
        // total H-tree wire length ≈ pitch × leaves (geometric series)
        let wire_mm = tile_pitch_mm * leaves as f64;
        HTreeModel {
            leaves,
            levels,
            level_delay: 2,
            flit_level_energy_pj: 0.04 * flit_bits as f64 * tile_pitch_mm * tech.energy,
            area_um2: flit_bits as f64 * 0.2 * wire_mm * 1000.0 * tech.area.sqrt(),
        }
    }

    /// All flows share the root: serialize packets, add depth latency.
    pub fn run(&self, flows: &[Flow]) -> EpochResult {
        let packets: u64 = flows.iter().map(|f| f.count).sum();
        if packets == 0 {
            return EpochResult::default();
        }
        let depth = 2 * self.levels as u64 * self.level_delay; // up + down
        let completion = packets + depth;
        EpochResult {
            completion_cycles: completion,
            packets,
            // average packet waits half the serialization queue
            total_latency_cycles: packets * depth + packets * packets / 2,
            flit_hops: packets * 2 * self.levels as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(count: u64) -> Flow {
        Flow {
            src: 0,
            dst: 1,
            count,
            start: 0,
            stride: 1,
        }
    }

    #[test]
    fn levels_log2() {
        let t = Tech::new(32);
        assert_eq!(HTreeModel::new(16, 32, 0.7, &t).levels, 4);
        assert_eq!(HTreeModel::new(9, 32, 0.7, &t).levels, 4); // ceil
    }

    #[test]
    fn root_serializes() {
        let t = Tech::new(32);
        let h = HTreeModel::new(16, 32, 0.7, &t);
        let r1 = h.run(&[f(10)]);
        let r2 = h.run(&[f(10), f(10)]);
        assert_eq!(r2.packets, 20);
        assert!(r2.completion_cycles > r1.completion_cycles);
    }

    #[test]
    fn empty_is_zero() {
        let t = Tech::new(32);
        let h = HTreeModel::new(8, 32, 0.7, &t);
        assert_eq!(h.run(&[]), EpochResult::default());
    }
}
