//! Trace-driven wormhole network simulation over a mesh.
//!
//! Two engines, cross-validated in tests:
//!
//! * [`PacketSim`] — the production engine: per-link busy-until list
//!   scheduling of single-flit packets in global injection order. For
//!   credit-less single-flit wormhole with X–Y routing this reproduces
//!   the flit-level schedule exactly in the common case and within a few
//!   percent under heavy contention, at orders-of-magnitude lower cost.
//! * [`FlitSim`] — a faithful cycle-by-cycle router model (5-port,
//!   input-buffered, credit flow control, round-robin arbitration) used
//!   as the golden reference on small traces.
//!
//! For design-space sweeps, [`EpochCache`] memoizes epoch results keyed
//! by `(mesh dims, simulator parameters, flow trace)`: neighbouring
//! sweep points share most of their Algorithm-2 traces (the NoC traffic
//! of a layer does not depend on the chiplet count, and the NoP traffic
//! repeats whenever the chiplet allocation coincides), so identical
//! epochs are simulated once and replayed from the cache thereafter.

use super::mesh::Mesh;
use crate::mapping::Flow;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Result of simulating one epoch (one Algorithm-2 trace).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpochResult {
    /// Cycle at which the last tail flit is ejected.
    pub completion_cycles: u64,
    /// Packets delivered during the epoch.
    pub packets: u64,
    /// Σ per-packet (arrival − injection): for avg-latency reporting.
    pub total_latency_cycles: u64,
    /// Flit-link traversals (drives link + router energy).
    pub flit_hops: u64,
}

impl EpochResult {
    /// Mean packet latency in cycles (0 for an empty epoch).
    pub fn avg_latency(&self) -> f64 {
        if self.packets == 0 {
            0.0
        } else {
            self.total_latency_cycles as f64 / self.packets as f64
        }
    }

    /// Fold another epoch in, serially (epochs execute layer-by-layer,
    /// so completion cycles add).
    pub fn accumulate(&mut self, o: &EpochResult) {
        self.completion_cycles += o.completion_cycles;
        self.packets += o.packets;
        self.total_latency_cycles += o.total_latency_cycles;
        self.flit_hops += o.flit_hops;
    }
}

/// Cache key: the complete input of one [`PacketSim::run`] call. The
/// snake-order coordinate embedding is a pure function of the mesh
/// dimensions and node count, so `(width, height, nodes)` plus the
/// simulator parameters and the flow trace pin the result exactly.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct EpochKey {
    width: u16,
    height: u16,
    nodes: u32,
    router_delay: u64,
    flits_per_packet: u64,
    extrapolate: bool,
    flows: Box<[Flow]>,
}

/// Soft bound on retained epochs; past it, new results are returned but
/// not stored (protects pathological sweeps from unbounded growth).
const EPOCH_CACHE_CAP: usize = 1 << 16;

/// Thread-safe memo table for epoch results, shared across the points of
/// a design-space sweep (see the crate's `ARCHITECTURE.md`).
///
/// Identical `(mesh dims, simulator parameters, flow trace)` inputs hit
/// the cache and skip re-simulation; distinct inputs never alias, so a
/// cached sweep is numerically identical to an uncached one.
#[derive(Debug, Default)]
pub struct EpochCache {
    map: Mutex<HashMap<EpochKey, EpochResult>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EpochCache {
    /// Create an empty cache.
    pub fn new() -> EpochCache {
        EpochCache::default()
    }

    /// Lookups answered from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to simulate.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct epochs currently retained.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when no epoch has been stored yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Production list-scheduling engine.
pub struct PacketSim<'m> {
    mesh: &'m Mesh,
    /// Router pipeline cycles per hop (head flit).
    pub router_delay: u64,
    /// Flits per packet (Algorithm-2 packets are one bus-width flit).
    pub flits_per_packet: u64,
    /// Steady-state extrapolation (§Perf). Exact (validated in tests);
    /// disable to force the brute-force schedule.
    pub extrapolate: bool,
}

impl<'m> PacketSim<'m> {
    /// List-scheduling simulator over `mesh` with the paper's defaults:
    /// 2-cycle routers, single-flit packets, steady-state extrapolation
    /// enabled.
    pub fn new(mesh: &'m Mesh) -> Self {
        PacketSim {
            mesh,
            router_delay: 2,
            flits_per_packet: 1,
            extrapolate: true,
        }
    }

    /// Simulate one epoch of flows (timestamps restart at 0) and return
    /// its completion cycle, packet count, latency sum and flit-hop
    /// count.
    ///
    /// # Examples
    ///
    /// ```
    /// use siam::mapping::Flow;
    /// use siam::noc::{Mesh, PacketSim};
    ///
    /// let mesh = Mesh::new(16); // 4x4 tile mesh
    /// let sim = PacketSim::new(&mesh);
    /// // one packet from tile 0 to its neighbour
    /// let epoch = [Flow { src: 0, dst: 1, count: 1, start: 0, stride: 1 }];
    /// let result = sim.run(&epoch);
    /// assert_eq!(result.packets, 1);
    /// // 1 hop: router pipeline (2 cycles) + 1 serialization cycle
    /// assert_eq!(result.completion_cycles, 3);
    /// ```
    pub fn run(&self, flows: &[Flow]) -> EpochResult {
        let mut res = EpochResult::default();
        if flows.is_empty() {
            return res;
        }
        let mut busy = vec![0u64; self.mesh.num_links()];
        let mut routes: Vec<Vec<u32>> = Vec::with_capacity(flows.len());
        let mut route = Vec::with_capacity(self.mesh.width + self.mesh.height);
        for f in flows {
            self.mesh.route(f.src, f.dst, &mut route);
            routes.push(route.clone());
        }

        // §Perf fast path: Algorithm-2 epochs have one shared stride and
        // all starts < stride, so injection rounds never interleave —
        // iterate rounds in order with no priority queue at all.
        let stride = flows[0].stride;
        let uniform = flows
            .iter()
            .all(|f| f.stride == stride && f.start < stride && f.count > 0);
        if uniform {
            let mut order: Vec<u32> = (0..flows.len() as u32).collect();
            order.sort_unstable_by_key(|&i| flows[i as usize].start);
            let max_count = flows.iter().map(|f| f.count).max().unwrap();
            let equal_counts = flows.iter().all(|f| f.count == max_count);
            // steady-state detection (§Perf): once two consecutive rounds
            // produce identical completion/latency deltas, the max-plus
            // schedule has become periodic with period 1 and the remaining
            // rounds extrapolate exactly.
            let warmup = 16 + 2 * (self.mesh.width + self.mesh.height) as u64;
            let mut prev = (0u64, 0u64); // (completion, latency) after round
            let mut prev_delta = (u64::MAX, u64::MAX);
            let mut round = 0u64;
            while round < max_count {
                let mut round_lat = 0u64;
                for &fi in &order {
                    let f = &flows[fi as usize];
                    if round >= f.count {
                        continue;
                    }
                    let inject = f.start + round * stride;
                    let before = res.total_latency_cycles;
                    self.send(&routes[fi as usize], inject, &mut busy, &mut res);
                    round_lat += res.total_latency_cycles - before;
                }
                let delta = (
                    res.completion_cycles - prev.0,
                    round_lat.wrapping_sub(prev.1),
                );
                if self.extrapolate && equal_counts && round > warmup && delta == prev_delta && round_lat >= prev.1 {
                    let remaining = max_count - round - 1;
                    if remaining > 0 {
                        // per-round packet stats are constant in steady state
                        let per_round_pkts = order.len() as u64;
                        let per_round_hops: u64 = order
                            .iter()
                            .map(|&fi| routes[fi as usize].len() as u64)
                            .sum::<u64>()
                            * self.flits_per_packet;
                        res.packets += per_round_pkts * remaining;
                        res.flit_hops += per_round_hops * remaining;
                        res.completion_cycles += delta.0 * remaining;
                        // latency per round grows by a constant increment
                        let lat_growth = round_lat - prev.1; // == delta.1
                        res.total_latency_cycles += remaining * round_lat
                            + lat_growth * remaining * (remaining + 1) / 2;
                        return res;
                    }
                }
                prev_delta = delta;
                prev = (res.completion_cycles, round_lat);
                round += 1;
            }
            return res;
        }

        // general path: k-way merge by next injection time
        let mut heap: BinaryHeap<Reverse<(u64, u32, u64)>> = flows
            .iter()
            .enumerate()
            .filter(|(_, f)| f.count > 0)
            .map(|(i, f)| Reverse((f.start, i as u32, 0u64)))
            .collect();
        while let Some(Reverse((inject, fi, emitted))) = heap.pop() {
            let f = &flows[fi as usize];
            self.send(&routes[fi as usize], inject, &mut busy, &mut res);
            if emitted + 1 < f.count {
                heap.push(Reverse((inject + f.stride, fi, emitted + 1)));
            }
        }
        res
    }

    /// [`run`](PacketSim::run) through an [`EpochCache`]: identical
    /// epochs (same mesh dimensions, simulator parameters and flow
    /// trace) are simulated once and replayed thereafter. Results are
    /// bit-identical to the uncached path.
    pub fn run_cached(&self, flows: &[Flow], cache: &EpochCache) -> EpochResult {
        let key = EpochKey {
            width: self.mesh.width as u16,
            height: self.mesh.height as u16,
            nodes: self.mesh.nodes() as u32,
            router_delay: self.router_delay,
            flits_per_packet: self.flits_per_packet,
            extrapolate: self.extrapolate,
            flows: flows.into(),
        };
        if let Some(r) = cache.map.lock().unwrap().get(&key) {
            cache.hits.fetch_add(1, Ordering::Relaxed);
            return *r;
        }
        cache.misses.fetch_add(1, Ordering::Relaxed);
        let r = self.run(flows);
        let mut map = cache.map.lock().unwrap();
        if map.len() < EPOCH_CACHE_CAP {
            map.insert(key, r);
        }
        r
    }

    /// Schedule one packet along its route (wormhole list scheduling).
    #[inline]
    fn send(&self, r: &[u32], inject: u64, busy: &mut [u64], res: &mut EpochResult) {
        let mut head = inject;
        for &l in r {
            let start = (head + self.router_delay).max(busy[l as usize]);
            busy[l as usize] = start + self.flits_per_packet;
            head = start;
        }
        let arrival = head + self.flits_per_packet;
        res.packets += 1;
        res.completion_cycles = res.completion_cycles.max(arrival);
        res.total_latency_cycles += arrival - inject;
        res.flit_hops += r.len() as u64 * self.flits_per_packet;
    }
}

/// Golden-reference flit-level simulator (small traces only).
pub struct FlitSim<'m> {
    mesh: &'m Mesh,
    /// Input-buffer depth per link, flits (credit backpressure bound).
    pub buffer_depth: usize,
    /// Router pipeline cycles per hop.
    pub router_delay: u64,
}

#[derive(Debug, Clone, Copy)]
struct FlitPkt {
    inject: u64,
    route_pos: u32,
    flow: u32,
}

impl<'m> FlitSim<'m> {
    /// Cycle-accurate simulator over `mesh` with the given input-buffer
    /// depth and the default 2-cycle router pipeline.
    pub fn new(mesh: &'m Mesh, buffer_depth: usize) -> Self {
        FlitSim {
            mesh,
            buffer_depth,
            router_delay: 2,
        }
    }

    /// Cycle-accurate run. Packets are single-flit; each link accepts one
    /// flit per cycle; input buffers exert backpressure via credits.
    pub fn run(&self, flows: &[Flow]) -> EpochResult {
        let mut res = EpochResult::default();
        // expand packets (small traces only)
        let mut routes: Vec<Vec<u32>> = Vec::with_capacity(flows.len());
        let mut pending: Vec<(u64, u32)> = Vec::new(); // (inject, flow)
        for (i, f) in flows.iter().enumerate() {
            let mut r = Vec::new();
            self.mesh.route(f.src, f.dst, &mut r);
            routes.push(r);
            for n in 0..f.count {
                pending.push((f.start + n * f.stride, i as u32));
            }
        }
        pending.sort_unstable();
        let total_packets = pending.len() as u64;

        // per-link FIFO occupancy
        let nl = self.mesh.num_links();
        let mut queues: Vec<Vec<FlitPkt>> = vec![Vec::new(); nl];
        let mut next_pending = 0usize;
        let mut in_flight = 0u64;
        let mut cycle = 0u64;
        let mut rr: Vec<usize> = vec![0; nl];

        while next_pending < pending.len() || in_flight > 0 {
            // inject packets whose time has come (source queue = first link)
            while next_pending < pending.len() && pending[next_pending].0 <= cycle {
                let (inject, flow) = pending[next_pending];
                let r = &routes[flow as usize];
                if r.is_empty() {
                    // src == dst after self-loop filtering: deliver now
                    res.packets += 1;
                    next_pending += 1;
                    continue;
                }
                let first = r[0] as usize;
                if queues[first].len() < self.buffer_depth {
                    queues[first].push(FlitPkt {
                        inject,
                        route_pos: 0,
                        flow,
                    });
                    in_flight += 1;
                    next_pending += 1;
                } else {
                    break; // source blocked: retry next cycle
                }
            }

            // move the head flit of each link's queue forward (one flit
            // per link per cycle), round-robin across contenders is
            // implicit because each queue advances at most one flit.
            let mut moved = false;
            for l in 0..nl {
                if queues[l].is_empty() {
                    continue;
                }
                let idx = rr[l] % queues[l].len();
                let pkt = queues[l][idx];
                let r = &routes[pkt.flow as usize];
                let pos = pkt.route_pos as usize;
                // minimum dwell: router pipeline delay since entering
                if cycle < pkt.inject + (pos as u64 + 1) * self.router_delay {
                    continue;
                }
                if pos + 1 == r.len() {
                    // eject
                    queues[l].remove(idx);
                    in_flight -= 1;
                    res.packets += 1;
                    let lat = cycle + 1 - pkt.inject;
                    res.total_latency_cycles += lat;
                    res.completion_cycles = res.completion_cycles.max(cycle + 1);
                    res.flit_hops += r.len() as u64;
                    moved = true;
                } else {
                    let nxt = r[pos + 1] as usize;
                    if queues[nxt].len() < self.buffer_depth {
                        let mut p = queues[l].remove(idx);
                        p.route_pos += 1;
                        queues[nxt].push(p);
                        moved = true;
                    } else {
                        rr[l] += 1; // head blocked, try another next cycle
                    }
                }
            }
            let _ = moved;
            cycle += 1;
            if cycle > 100_000_000 {
                panic!("FlitSim runaway: deadlock or trace too large");
            }
        }
        debug_assert_eq!(res.packets, total_packets);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(src: u32, dst: u32, count: u64, start: u64, stride: u64) -> Flow {
        Flow {
            src,
            dst,
            count,
            start,
            stride,
        }
    }

    #[test]
    fn single_packet_latency() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let r = sim.run(&[flow(0, 1, 1, 0, 1)]);
        // 1 hop: router_delay + serialization = 3 cycles
        assert_eq!(r.completion_cycles, 3);
        assert_eq!(r.packets, 1);
        assert_eq!(r.flit_hops, 1);
    }

    #[test]
    fn uncontended_stream_pipelines() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let r = sim.run(&[flow(0, 3, 100, 0, 1)]);
        // steady state: one packet per cycle on the 3-hop path
        let hops = m.hops(0, 3) as u64;
        let expected = 99 + hops * sim.router_delay + 1;
        assert_eq!(r.completion_cycles, expected);
    }

    #[test]
    fn contention_serializes() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        // both flows traverse the link 1->2 on row 0 (X-first routing)
        let a = sim.run(&[flow(0, 2, 50, 0, 1)]);
        let both = sim.run(&[flow(0, 2, 50, 0, 1), flow(1, 2, 50, 0, 1)]);
        assert!(
            both.completion_cycles > a.completion_cycles,
            "{} vs {}",
            both.completion_cycles,
            a.completion_cycles
        );
        assert_eq!(both.packets, 100);
    }

    #[test]
    fn packet_sim_matches_flit_sim_uncontended() {
        let m = Mesh::new(9);
        let flows = vec![flow(0, 8, 20, 0, 3)];
        let p = PacketSim::new(&m).run(&flows);
        let f = FlitSim::new(&m, 64).run(&flows);
        assert_eq!(p.packets, f.packets);
        let rel = (p.completion_cycles as f64 - f.completion_cycles as f64).abs()
            / f.completion_cycles as f64;
        assert!(rel < 0.25, "packet {} vs flit {}", p.completion_cycles, f.completion_cycles);
    }

    #[test]
    fn packet_sim_close_to_flit_sim_contended() {
        let m = Mesh::new(16);
        let flows = vec![
            flow(0, 10, 30, 0, 2),
            flow(3, 10, 30, 1, 2),
            flow(12, 10, 30, 0, 3),
            flow(5, 6, 30, 0, 1),
        ];
        let p = PacketSim::new(&m).run(&flows);
        let f = FlitSim::new(&m, 8).run(&flows);
        assert_eq!(p.packets, f.packets);
        let rel = (p.completion_cycles as f64 - f.completion_cycles as f64).abs()
            / f.completion_cycles as f64;
        assert!(
            rel < 0.35,
            "packet {} vs flit {} (rel {rel})",
            p.completion_cycles,
            f.completion_cycles
        );
    }

    #[test]
    fn epoch_results_accumulate() {
        let mut a = EpochResult {
            completion_cycles: 10,
            packets: 5,
            total_latency_cycles: 20,
            flit_hops: 7,
        };
        let b = EpochResult {
            completion_cycles: 3,
            packets: 1,
            total_latency_cycles: 3,
            flit_hops: 1,
        };
        a.accumulate(&b);
        assert_eq!(a.completion_cycles, 13);
        assert_eq!(a.packets, 6);
    }

    #[test]
    fn steady_state_extrapolation_is_exact() {
        let m = Mesh::new(16);
        let mut brute = PacketSim::new(&m);
        brute.extrapolate = false;
        let fast = PacketSim::new(&m);
        // several contention patterns, all uniform-stride Algorithm-2 style
        let cases: Vec<Vec<Flow>> = vec![
            vec![flow(0, 10, 5000, 0, 3), flow(3, 10, 5000, 1, 3), flow(12, 5, 5000, 2, 3)],
            vec![flow(0, 2, 4000, 0, 2), flow(1, 2, 4000, 1, 2)],
            (0..8)
                .map(|i| flow(i, 15, 1500, i as u64, 9))
                .collect(),
        ];
        for (ci, flows) in cases.iter().enumerate() {
            let a = fast.run(flows);
            let b = brute.run(flows);
            assert_eq!(a, b, "case {ci}: extrapolated != brute-force");
        }
    }

    #[test]
    fn empty_epoch_is_zero() {
        let m = Mesh::new(4);
        assert_eq!(PacketSim::new(&m).run(&[]), EpochResult::default());
    }

    #[test]
    fn cache_replays_identical_epochs() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2), flow(3, 10, 50, 1, 2)];
        let a = sim.run_cached(&flows, &cache);
        let b = sim.run_cached(&flows, &cache);
        assert_eq!(a, b);
        assert_eq!(a, sim.run(&flows), "cached result must match uncached");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cache_distinguishes_meshes_and_traces() {
        let m1 = Mesh::new(16);
        let m2 = Mesh::new(9);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 5, 10, 0, 1)];
        let r1 = PacketSim::new(&m1).run_cached(&flows, &cache);
        let r2 = PacketSim::new(&m2).run_cached(&flows, &cache);
        assert_eq!(cache.misses(), 2, "different meshes must not alias");
        assert_eq!(r1, PacketSim::new(&m1).run(&flows));
        assert_eq!(r2, PacketSim::new(&m2).run(&flows));
        let other = vec![flow(0, 5, 11, 0, 1)];
        PacketSim::new(&m1).run_cached(&other, &cache);
        assert_eq!(cache.misses(), 3, "different traces must not alias");
    }
}
