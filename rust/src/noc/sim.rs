//! Trace-driven wormhole network simulation over a mesh — the packet-
//! and flit-level tiers of the engine hierarchy (the flow-level tier
//! lives in [`super::flow`]):
//!
//! * [`PacketSim`] — per-link busy-until list scheduling of single-flit
//!   packets in global injection order. For credit-less single-flit
//!   wormhole with X–Y routing this reproduces the flit-level schedule
//!   exactly in the common case and within a few percent under heavy
//!   contention, at orders-of-magnitude lower cost. Serves as the
//!   fallback scheduler for traces the flow-level engine cannot handle
//!   in closed form.
//! * [`FlitSim`] — a faithful cycle-by-cycle router model (5-port,
//!   input-buffered, credit flow control, round-robin arbitration) used
//!   as the golden reference on small traces.
//!
//! For design-space sweeps, [`EpochCache`] memoizes epoch results keyed
//! by a 128-bit fingerprint of `(engine, mesh dims, simulator
//! parameters, flow trace)`: neighbouring sweep points share most of
//! their Algorithm-2 traces (the NoC traffic of a layer does not depend
//! on the chiplet count, and the NoP traffic repeats whenever the
//! chiplet allocation coincides), so identical epochs are simulated
//! once and replayed from the cache thereafter. The cache is
//! lock-striped: keys spread over [`SHARD_COUNT`] independently locked
//! shards, so sweep workers rarely contend on the same mutex.

use super::mesh::Mesh;
use crate::mapping::Flow;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Result of simulating one epoch (one Algorithm-2 trace).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpochResult {
    /// Cycle at which the last tail flit is ejected.
    pub completion_cycles: u64,
    /// Packets delivered during the epoch.
    pub packets: u64,
    /// Σ per-packet (arrival − injection): for avg-latency reporting.
    pub total_latency_cycles: u64,
    /// Flit-link traversals (drives link + router energy).
    pub flit_hops: u64,
}

impl EpochResult {
    /// Mean packet latency in cycles (0 for an empty epoch).
    pub fn avg_latency(&self) -> f64 {
        if self.packets == 0 {
            0.0
        } else {
            self.total_latency_cycles as f64 / self.packets as f64
        }
    }

    /// Fold another epoch in, serially (epochs execute layer-by-layer,
    /// so completion cycles add).
    pub fn accumulate(&mut self, o: &EpochResult) {
        self.completion_cycles += o.completion_cycles;
        self.packets += o.packets;
        self.total_latency_cycles += o.total_latency_cycles;
        self.flit_hops += o.flit_hops;
    }
}

/// Flow-engine tier counters: how many times each resolution tier of
/// the engine hierarchy fired while simulating epochs (see
/// `ARCHITECTURE.md`, "Three-tier interconnect engine", and
/// `docs/OBSERVABILITY.md` for the taxonomy).
///
/// Counters are *tier events*, not epochs: one epoch may resolve
/// several flows in closed form and still round-simulate a contended
/// component. Tags are stored in the [`EpochCache`] next to their
/// [`EpochResult`] and replayed on hits, so the counts are a pure
/// function of the evaluation trace — identical for serial and
/// parallel sweeps, warm and cold caches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierCounts {
    /// Flows answered in closed form (uncontended / singleton flows).
    pub closed_form: u64,
    /// Contended components resolved by the shift-periodicity
    /// certificate.
    pub periodic: u64,
    /// Oversaturated components resolved by the linear-growth
    /// steady-state extrapolation.
    pub extrapolated: u64,
    /// Wholesale delegations to the per-packet scheduler (irregular
    /// traces, or epochs simulated by [`PacketSim`] directly).
    pub packet_fallback: u64,
}

impl TierCounts {
    /// Fold another counter set in.
    pub fn accumulate(&mut self, o: &TierCounts) {
        self.closed_form += o.closed_form;
        self.periodic += o.periodic;
        self.extrapolated += o.extrapolated;
        self.packet_fallback += o.packet_fallback;
    }

    /// Total tier events.
    pub fn total(&self) -> u64 {
        self.closed_form + self.periodic + self.extrapolated + self.packet_fallback
    }

    /// The `engine_tiers` JSON fragment.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("closed_form", self.closed_form)
            .set("periodic", self.periodic)
            .set("extrapolated", self.extrapolated)
            .set("packet_fallback", self.packet_fallback);
        o
    }

    /// Compact one-line rendering for summary tables, e.g.
    /// `"closed 12  periodic 3  extrap 1  packet 0"`.
    pub fn render(&self) -> String {
        format!(
            "closed {}  periodic {}  extrap {}  packet {}",
            self.closed_form, self.periodic, self.extrapolated, self.packet_fallback
        )
    }
}

/// Shared-stride (Algorithm-2) trace test: `Some(stride)` when every
/// flow has the same stride, starts inside the first round, and a
/// positive count. This is the uniform-trace contract both
/// list-scheduling engines key their fast paths on — one definition,
/// used by `PacketSim` and `FlowSim`, so the engines' bit-exactness
/// guarantee cannot drift through divergent copies.
pub(crate) fn uniform_stride(flows: &[Flow]) -> Option<u64> {
    let stride = flows.first()?.stride;
    flows
        .iter()
        .all(|f| f.stride == stride && f.start < stride && f.count > 0)
        .then_some(stride)
}

/// Warm-up rounds before the linear-growth extrapolation may arm
/// (§Perf): sized to exceed any delayed-onset contention window (a
/// growing queue overtaking a slower timing path, bounded by ~mesh
/// diameter × per-hop delay rounds). Shared by both engines.
pub(crate) fn warmup_rounds(mesh: &Mesh) -> u64 {
    16 + 2 * (mesh.width + mesh.height) as u64
}

/// Closed-form tail of a linear-growth steady state, shared by both
/// engines' extrapolations: aggregate stats for `remaining` further
/// rounds of `per_round_pkts` packets / `per_round_hops` flit-hops
/// whose completion advances by a constant `completion_delta` and whose
/// per-round latency starts at `round_lat` and grows by `lat_growth`
/// each round (arithmetic series). One definition so the series math
/// cannot drift between the engines.
pub(crate) struct SteadyTail {
    pub packets: u64,
    pub flit_hops: u64,
    pub latency: u64,
    pub completion: u64,
}

pub(crate) fn steady_tail(
    remaining: u64,
    per_round_pkts: u64,
    per_round_hops: u64,
    round_lat: u64,
    lat_growth: u64,
    completion_delta: u64,
) -> SteadyTail {
    SteadyTail {
        packets: per_round_pkts * remaining,
        flit_hops: per_round_hops * remaining,
        latency: remaining * round_lat + lat_growth * remaining * (remaining + 1) / 2,
        completion: completion_delta * remaining,
    }
}

/// Engine discriminant folded into [`EpochKey`] fingerprints: the
/// per-packet scheduler. Distinct engines never share cache entries.
pub(crate) const ENGINE_PACKET: u8 = 0;
/// Engine discriminant for the flow-level engine ([`super::FlowSim`]).
pub(crate) const ENGINE_FLOW: u8 = 1;

/// splitmix64 finalizer: full-avalanche 64-bit mixer.
#[inline]
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

/// Cache key: a 128-bit fingerprint over the complete input of one
/// epoch simulation — engine discriminant, mesh dimensions, node count
/// and the node→coordinate embedding digest ([`Mesh::embedding_tag`];
/// dataflow-permuted placements re-embed the same node ids, so
/// dimensions alone no longer determine coordinates), simulator
/// parameters, and every field of every flow in trace order.
///
/// Fingerprinting replaces the seed design's `Box<[Flow]>` key: lookups
/// hash 16 bytes instead of re-hashing the whole trace, misses no
/// longer clone the trace into the table, and collision-checking an
/// entry compares two words. The cost is a theoretical collision — two
/// lanes of independently seeded splitmix64 mixing put the probability
/// for a sweep retaining `N` epochs at ~`N²/2^129`, far below any other
/// source of error in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub(crate) struct EpochKey {
    /// Low fingerprint lane (also selects the cache shard). Exposed to
    /// the persistent store (`noc::store`) for serialization.
    pub(crate) lo: u64,
    /// High fingerprint lane.
    pub(crate) hi: u64,
}

impl EpochKey {
    /// Fingerprint one epoch-simulation input.
    pub(crate) fn fingerprint(
        engine: u8,
        mesh: &Mesh,
        router_delay: u64,
        flits_per_packet: u64,
        extrapolate: bool,
        flows: &[Flow],
    ) -> EpochKey {
        let mut lo = 0x9E37_79B9_7F4A_7C15u64;
        let mut hi = 0xC2B2_AE3D_27D4_EB4Fu64;
        let mut feed = |v: u64| {
            lo = mix(lo ^ v);
            hi = mix(hi.rotate_left(23) ^ v.wrapping_mul(0xFF51_AFD7_ED55_8CCD));
        };
        feed(engine as u64);
        feed(mesh.width as u64);
        feed(mesh.height as u64);
        feed(mesh.nodes() as u64);
        feed(mesh.embedding_tag());
        feed(router_delay);
        feed(flits_per_packet);
        feed(extrapolate as u64);
        feed(flows.len() as u64);
        for f in flows {
            feed(((f.src as u64) << 32) | f.dst as u64);
            feed(f.count);
            feed(f.start);
            feed(f.stride);
        }
        EpochKey { lo, hi }
    }
}

/// Lock shards in [`EpochCache`]. A power of two so shard selection is
/// a mask on the fingerprint's low bits.
pub const SHARD_COUNT: usize = 16;

/// Soft bound on retained epochs per shard; past it, new results are
/// returned but not stored (protects pathological sweeps from unbounded
/// growth).
const SHARD_CAP: usize = (1 << 16) / SHARD_COUNT;

/// Poison-tolerant lock: a sweep worker that panics while holding a
/// shard must not wedge every other worker — the map holds plain data
/// whose invariants a mid-operation panic cannot break, so the poison
/// flag is safely ignored.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One lock stripe of the cache, with its own hit/miss counters. The
/// value carries the engine-tier tag next to the result so replays
/// restore the same tier attribution the original simulation had —
/// tier counts stay deterministic under racing double-computes and
/// warm caches.
#[derive(Debug, Default)]
struct Shard {
    map: Mutex<HashMap<EpochKey, (EpochResult, TierCounts)>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Thread-safe memo table for epoch results, shared across the points of
/// a design-space sweep (see the crate's `ARCHITECTURE.md`).
///
/// Identical `(engine, mesh dims, simulator parameters, flow trace)`
/// inputs hit the cache and skip re-simulation; distinct inputs never
/// alias (up to the documented 128-bit fingerprint collision bound), so
/// a cached sweep is numerically identical to an uncached one. Keys
/// spread over [`SHARD_COUNT`] independently locked shards, so parallel
/// sweep workers contend only when they race for the same stripe.
#[derive(Debug)]
pub struct EpochCache {
    shards: [Shard; SHARD_COUNT],
    /// Entries installed from a persistent store (`noc::store`) rather
    /// than simulated this run — counted separately from hits/misses so
    /// warm runs are attributable.
    hydrated: AtomicU64,
}

impl Default for EpochCache {
    fn default() -> EpochCache {
        EpochCache {
            shards: std::array::from_fn(|_| Shard::default()),
            hydrated: AtomicU64::new(0),
        }
    }
}

impl EpochCache {
    /// Create an empty cache.
    pub fn new() -> EpochCache {
        EpochCache::default()
    }

    /// Lookups answered from the cache so far (sum over shards).
    pub fn hits(&self) -> u64 {
        self.shards.iter().map(|s| s.hits.load(Ordering::Relaxed)).sum()
    }

    /// Lookups that had to simulate (sum over shards).
    pub fn misses(&self) -> u64 {
        self.shards.iter().map(|s| s.misses.load(Ordering::Relaxed)).sum()
    }

    /// Per-shard `(hits, misses)` counters, in shard order — exposes
    /// striping balance to benchmarks and diagnostics.
    pub fn shard_stats(&self) -> Vec<(u64, u64)> {
        self.shards
            .iter()
            .map(|s| {
                (
                    s.hits.load(Ordering::Relaxed),
                    s.misses.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Fraction of lookups answered from the cache (0 when never used).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = (self.hits(), self.misses());
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Number of distinct epochs currently retained.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock(&s.map).len()).sum()
    }

    /// True when no epoch has been stored yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries installed from a persistent store this run (not lookups:
    /// hydration touches neither the hit nor the miss counters, so a
    /// warm run's hit rate still describes the in-memory traffic).
    pub fn hydrated(&self) -> u64 {
        self.hydrated.load(Ordering::Relaxed)
    }

    /// Install a precomputed `(result, tiers)` entry (disk hydration).
    /// Returns `true` when the entry was newly inserted; an existing
    /// entry is left untouched (the fingerprint guarantees it is
    /// identical) and a full shard rejects the insert, mirroring
    /// [`get_or_compute_tagged`](EpochCache::get_or_compute_tagged)'s
    /// cap. Only new inserts count as hydrated.
    pub(crate) fn insert(&self, key: EpochKey, result: EpochResult, tiers: TierCounts) -> bool {
        let shard = &self.shards[key.lo as usize & (SHARD_COUNT - 1)];
        let mut map = lock(&shard.map);
        if map.contains_key(&key) || map.len() >= SHARD_CAP {
            return false;
        }
        map.insert(key, (result, tiers));
        drop(map);
        self.hydrated.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Every retained entry, sorted by fingerprint — a deterministic
    /// order for the persistent store's append pass, independent of
    /// shard iteration and hash-map ordering.
    pub(crate) fn snapshot_entries(&self) -> Vec<(EpochKey, EpochResult, TierCounts)> {
        let mut out: Vec<(EpochKey, EpochResult, TierCounts)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let map = lock(&shard.map);
            out.extend(map.iter().map(|(&k, &(r, t))| (k, r, t)));
        }
        out.sort_unstable_by_key(|&(k, _, _)| k);
        out
    }

    /// Replay `key` from its shard, or compute, store and return it. No
    /// lock is held while `compute` runs, so a slow simulation never
    /// blocks other workers' lookups (at worst two racing workers both
    /// simulate the same epoch — identical results, last insert wins).
    pub(crate) fn get_or_compute(
        &self,
        key: EpochKey,
        compute: impl FnOnce() -> EpochResult,
    ) -> EpochResult {
        self.get_or_compute_tagged(key, || (compute(), TierCounts::default())).0
    }

    /// [`get_or_compute`](EpochCache::get_or_compute) with an
    /// engine-tier tag stored (and replayed) next to the result: hits
    /// return the tag the original simulation recorded, so per-point
    /// tier attribution is a pure function of the evaluation trace no
    /// matter which worker populated the entry.
    pub(crate) fn get_or_compute_tagged(
        &self,
        key: EpochKey,
        compute: impl FnOnce() -> (EpochResult, TierCounts),
    ) -> (EpochResult, TierCounts, bool) {
        let shard = &self.shards[key.lo as usize & (SHARD_COUNT - 1)];
        if let Some(&(r, t)) = lock(&shard.map).get(&key) {
            shard.hits.fetch_add(1, Ordering::Relaxed);
            return (r, t, true);
        }
        shard.misses.fetch_add(1, Ordering::Relaxed);
        let (r, t) = compute();
        let mut map = lock(&shard.map);
        if map.len() < SHARD_CAP {
            map.insert(key, (r, t));
        }
        (r, t, false)
    }

    /// Poison one shard's mutex (a worker panics mid-lock), for the
    /// poison-tolerance regression test.
    #[cfg(test)]
    fn poison_one_shard(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.shards[0].map.lock().unwrap();
            panic!("poisoning shard 0");
        }));
    }
}

/// Production list-scheduling engine.
pub struct PacketSim<'m> {
    mesh: &'m Mesh,
    /// Router pipeline cycles per hop (head flit).
    pub router_delay: u64,
    /// Flits per packet (Algorithm-2 packets are one bus-width flit).
    pub flits_per_packet: u64,
    /// Steady-state extrapolation (§Perf). Exact (validated in tests);
    /// disable to force the brute-force schedule.
    pub extrapolate: bool,
}

impl<'m> PacketSim<'m> {
    /// List-scheduling simulator over `mesh` with the paper's defaults:
    /// 2-cycle routers, single-flit packets, steady-state extrapolation
    /// enabled.
    pub fn new(mesh: &'m Mesh) -> Self {
        PacketSim {
            mesh,
            router_delay: 2,
            flits_per_packet: 1,
            extrapolate: true,
        }
    }

    /// Simulate one epoch of flows (timestamps restart at 0) and return
    /// its completion cycle, packet count, latency sum and flit-hop
    /// count.
    ///
    /// # Examples
    ///
    /// ```
    /// use siam::mapping::Flow;
    /// use siam::noc::{Mesh, PacketSim};
    ///
    /// let mesh = Mesh::new(16); // 4x4 tile mesh
    /// let sim = PacketSim::new(&mesh);
    /// // one packet from tile 0 to its neighbour
    /// let epoch = [Flow { src: 0, dst: 1, count: 1, start: 0, stride: 1 }];
    /// let result = sim.run(&epoch);
    /// assert_eq!(result.packets, 1);
    /// // 1 hop: router pipeline (2 cycles) + 1 serialization cycle
    /// assert_eq!(result.completion_cycles, 3);
    /// ```
    pub fn run(&self, flows: &[Flow]) -> EpochResult {
        let mut res = EpochResult::default();
        if flows.is_empty() {
            return res;
        }
        let mut busy = vec![0u64; self.mesh.num_links()];
        let mut routes: Vec<Vec<u32>> = Vec::with_capacity(flows.len());
        let mut route = Vec::with_capacity(self.mesh.width + self.mesh.height);
        for f in flows {
            self.mesh.route(f.src, f.dst, &mut route);
            routes.push(route.clone());
        }

        // §Perf fast path: Algorithm-2 epochs have one shared stride and
        // all starts < stride, so injection rounds never interleave —
        // iterate rounds in order with no priority queue at all.
        if let Some(stride) = uniform_stride(flows) {
            let mut order: Vec<u32> = (0..flows.len() as u32).collect();
            // (start, index): deterministic total order so tied starts
            // schedule identically here and in the flow-level engine
            order.sort_unstable_by_key(|&i| (flows[i as usize].start, i));
            let max_count = flows.iter().map(|f| f.count).max().unwrap();
            let equal_counts = flows.iter().all(|f| f.count == max_count);
            // steady-state detection (§Perf): once two consecutive rounds
            // produce identical completion/latency deltas, the max-plus
            // schedule has become periodic with period 1 and the remaining
            // rounds extrapolate exactly.
            let warmup = warmup_rounds(self.mesh);
            let mut prev = (0u64, 0u64); // (completion, latency) after round
            let mut prev_delta = (u64::MAX, u64::MAX);
            let mut round = 0u64;
            while round < max_count {
                let mut round_lat = 0u64;
                for &fi in &order {
                    let f = &flows[fi as usize];
                    if round >= f.count {
                        continue;
                    }
                    let inject = f.start + round * stride;
                    let before = res.total_latency_cycles;
                    self.send(&routes[fi as usize], inject, &mut busy, &mut res);
                    round_lat += res.total_latency_cycles - before;
                }
                let delta = (
                    res.completion_cycles - prev.0,
                    round_lat.wrapping_sub(prev.1),
                );
                let steady = delta == prev_delta && round_lat >= prev.1;
                if self.extrapolate && equal_counts && round > warmup && steady {
                    let remaining = max_count - round - 1;
                    if remaining > 0 {
                        // per-round packet stats are constant in steady state
                        let per_round_pkts = order.len() as u64;
                        let per_round_hops: u64 = order
                            .iter()
                            .map(|&fi| routes[fi as usize].len() as u64)
                            .sum::<u64>()
                            * self.flits_per_packet;
                        // latency per round grows by a constant increment
                        let tail = steady_tail(
                            remaining,
                            per_round_pkts,
                            per_round_hops,
                            round_lat,
                            round_lat - prev.1, // == delta.1
                            delta.0,
                        );
                        res.packets += tail.packets;
                        res.flit_hops += tail.flit_hops;
                        res.completion_cycles += tail.completion;
                        res.total_latency_cycles += tail.latency;
                        return res;
                    }
                }
                prev_delta = delta;
                prev = (res.completion_cycles, round_lat);
                round += 1;
            }
            return res;
        }

        // general path: k-way merge by next injection time
        let mut heap: BinaryHeap<Reverse<(u64, u32, u64)>> = flows
            .iter()
            .enumerate()
            .filter(|(_, f)| f.count > 0)
            .map(|(i, f)| Reverse((f.start, i as u32, 0u64)))
            .collect();
        while let Some(Reverse((inject, fi, emitted))) = heap.pop() {
            let f = &flows[fi as usize];
            self.send(&routes[fi as usize], inject, &mut busy, &mut res);
            if emitted + 1 < f.count {
                heap.push(Reverse((inject + f.stride, fi, emitted + 1)));
            }
        }
        res
    }

    /// [`run`](PacketSim::run) through an [`EpochCache`]: identical
    /// epochs (same mesh dimensions, simulator parameters and flow
    /// trace) are simulated once and replayed thereafter. Results are
    /// bit-identical to the uncached path.
    pub fn run_cached(&self, flows: &[Flow], cache: &EpochCache) -> EpochResult {
        let key = EpochKey::fingerprint(
            ENGINE_PACKET,
            self.mesh,
            self.router_delay,
            self.flits_per_packet,
            self.extrapolate,
            flows,
        );
        // a directly-scheduled epoch is one per-packet tier event
        let tag = TierCounts {
            packet_fallback: 1,
            ..TierCounts::default()
        };
        cache.get_or_compute_tagged(key, || (self.run(flows), tag)).0
    }

    /// Schedule one packet along its route (wormhole list scheduling).
    #[inline]
    fn send(&self, r: &[u32], inject: u64, busy: &mut [u64], res: &mut EpochResult) {
        let mut head = inject;
        for &l in r {
            let start = (head + self.router_delay).max(busy[l as usize]);
            busy[l as usize] = start + self.flits_per_packet;
            head = start;
        }
        let arrival = head + self.flits_per_packet;
        res.packets += 1;
        res.completion_cycles = res.completion_cycles.max(arrival);
        res.total_latency_cycles += arrival - inject;
        res.flit_hops += r.len() as u64 * self.flits_per_packet;
    }
}

/// Golden-reference flit-level simulator (small traces only).
pub struct FlitSim<'m> {
    mesh: &'m Mesh,
    /// Input-buffer depth per link, flits (credit backpressure bound).
    pub buffer_depth: usize,
    /// Router pipeline cycles per hop.
    pub router_delay: u64,
}

#[derive(Debug, Clone, Copy)]
struct FlitPkt {
    inject: u64,
    route_pos: u32,
    flow: u32,
}

impl<'m> FlitSim<'m> {
    /// Cycle-accurate simulator over `mesh` with the given input-buffer
    /// depth and the default 2-cycle router pipeline.
    pub fn new(mesh: &'m Mesh, buffer_depth: usize) -> Self {
        FlitSim {
            mesh,
            buffer_depth,
            router_delay: 2,
        }
    }

    /// Cycle-accurate run. Packets are single-flit; each link accepts one
    /// flit per cycle; input buffers exert backpressure via credits.
    pub fn run(&self, flows: &[Flow]) -> EpochResult {
        let mut res = EpochResult::default();
        // expand packets (small traces only)
        let mut routes: Vec<Vec<u32>> = Vec::with_capacity(flows.len());
        let mut pending: Vec<(u64, u32)> = Vec::new(); // (inject, flow)
        for (i, f) in flows.iter().enumerate() {
            let mut r = Vec::new();
            self.mesh.route(f.src, f.dst, &mut r);
            routes.push(r);
            for n in 0..f.count {
                pending.push((f.start + n * f.stride, i as u32));
            }
        }
        pending.sort_unstable();
        let total_packets = pending.len() as u64;

        // per-link FIFO occupancy
        let nl = self.mesh.num_links();
        let mut queues: Vec<Vec<FlitPkt>> = vec![Vec::new(); nl];
        let mut next_pending = 0usize;
        let mut in_flight = 0u64;
        let mut cycle = 0u64;
        let mut rr: Vec<usize> = vec![0; nl];

        while next_pending < pending.len() || in_flight > 0 {
            // inject packets whose time has come (source queue = first link)
            while next_pending < pending.len() && pending[next_pending].0 <= cycle {
                let (inject, flow) = pending[next_pending];
                let r = &routes[flow as usize];
                if r.is_empty() {
                    // src == dst after self-loop filtering: deliver now
                    res.packets += 1;
                    next_pending += 1;
                    continue;
                }
                let first = r[0] as usize;
                if queues[first].len() < self.buffer_depth {
                    queues[first].push(FlitPkt {
                        inject,
                        route_pos: 0,
                        flow,
                    });
                    in_flight += 1;
                    next_pending += 1;
                } else {
                    break; // source blocked: retry next cycle
                }
            }

            // move the head flit of each link's queue forward (one flit
            // per link per cycle), round-robin across contenders is
            // implicit because each queue advances at most one flit.
            let mut moved = false;
            for l in 0..nl {
                if queues[l].is_empty() {
                    continue;
                }
                let idx = rr[l] % queues[l].len();
                let pkt = queues[l][idx];
                let r = &routes[pkt.flow as usize];
                let pos = pkt.route_pos as usize;
                // minimum dwell: router pipeline delay since entering
                if cycle < pkt.inject + (pos as u64 + 1) * self.router_delay {
                    continue;
                }
                if pos + 1 == r.len() {
                    // eject
                    queues[l].remove(idx);
                    in_flight -= 1;
                    res.packets += 1;
                    let lat = cycle + 1 - pkt.inject;
                    res.total_latency_cycles += lat;
                    res.completion_cycles = res.completion_cycles.max(cycle + 1);
                    res.flit_hops += r.len() as u64;
                    moved = true;
                } else {
                    let nxt = r[pos + 1] as usize;
                    if queues[nxt].len() < self.buffer_depth {
                        let mut p = queues[l].remove(idx);
                        p.route_pos += 1;
                        queues[nxt].push(p);
                        moved = true;
                    } else {
                        rr[l] += 1; // head blocked, try another next cycle
                    }
                }
            }
            let _ = moved;
            cycle += 1;
            if cycle > 100_000_000 {
                panic!("FlitSim runaway: deadlock or trace too large");
            }
        }
        debug_assert_eq!(res.packets, total_packets);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(src: u32, dst: u32, count: u64, start: u64, stride: u64) -> Flow {
        Flow {
            src,
            dst,
            count,
            start,
            stride,
        }
    }

    #[test]
    fn single_packet_latency() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let r = sim.run(&[flow(0, 1, 1, 0, 1)]);
        // 1 hop: router_delay + serialization = 3 cycles
        assert_eq!(r.completion_cycles, 3);
        assert_eq!(r.packets, 1);
        assert_eq!(r.flit_hops, 1);
    }

    #[test]
    fn uncontended_stream_pipelines() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let r = sim.run(&[flow(0, 3, 100, 0, 1)]);
        // steady state: one packet per cycle on the 3-hop path
        let hops = m.hops(0, 3) as u64;
        let expected = 99 + hops * sim.router_delay + 1;
        assert_eq!(r.completion_cycles, expected);
    }

    #[test]
    fn contention_serializes() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        // both flows traverse the link 1->2 on row 0 (X-first routing)
        let a = sim.run(&[flow(0, 2, 50, 0, 1)]);
        let both = sim.run(&[flow(0, 2, 50, 0, 1), flow(1, 2, 50, 0, 1)]);
        assert!(
            both.completion_cycles > a.completion_cycles,
            "{} vs {}",
            both.completion_cycles,
            a.completion_cycles
        );
        assert_eq!(both.packets, 100);
    }

    #[test]
    fn packet_sim_matches_flit_sim_uncontended() {
        let m = Mesh::new(9);
        let flows = vec![flow(0, 8, 20, 0, 3)];
        let p = PacketSim::new(&m).run(&flows);
        let f = FlitSim::new(&m, 64).run(&flows);
        assert_eq!(p.packets, f.packets);
        let rel = (p.completion_cycles as f64 - f.completion_cycles as f64).abs()
            / f.completion_cycles as f64;
        assert!(rel < 0.25, "packet {} vs flit {}", p.completion_cycles, f.completion_cycles);
    }

    #[test]
    fn packet_sim_close_to_flit_sim_contended() {
        let m = Mesh::new(16);
        let flows = vec![
            flow(0, 10, 30, 0, 2),
            flow(3, 10, 30, 1, 2),
            flow(12, 10, 30, 0, 3),
            flow(5, 6, 30, 0, 1),
        ];
        let p = PacketSim::new(&m).run(&flows);
        let f = FlitSim::new(&m, 8).run(&flows);
        assert_eq!(p.packets, f.packets);
        let rel = (p.completion_cycles as f64 - f.completion_cycles as f64).abs()
            / f.completion_cycles as f64;
        assert!(
            rel < 0.35,
            "packet {} vs flit {} (rel {rel})",
            p.completion_cycles,
            f.completion_cycles
        );
    }

    #[test]
    fn epoch_results_accumulate() {
        let mut a = EpochResult {
            completion_cycles: 10,
            packets: 5,
            total_latency_cycles: 20,
            flit_hops: 7,
        };
        let b = EpochResult {
            completion_cycles: 3,
            packets: 1,
            total_latency_cycles: 3,
            flit_hops: 1,
        };
        a.accumulate(&b);
        assert_eq!(a.completion_cycles, 13);
        assert_eq!(a.packets, 6);
    }

    #[test]
    fn steady_state_extrapolation_is_exact() {
        let m = Mesh::new(16);
        let mut brute = PacketSim::new(&m);
        brute.extrapolate = false;
        let fast = PacketSim::new(&m);
        // several contention patterns, all uniform-stride Algorithm-2 style
        let cases: Vec<Vec<Flow>> = vec![
            vec![flow(0, 10, 5000, 0, 3), flow(3, 10, 5000, 1, 3), flow(12, 5, 5000, 2, 3)],
            vec![flow(0, 2, 4000, 0, 2), flow(1, 2, 4000, 1, 2)],
            (0..8)
                .map(|i| flow(i, 15, 1500, i as u64, 9))
                .collect(),
        ];
        for (ci, flows) in cases.iter().enumerate() {
            let a = fast.run(flows);
            let b = brute.run(flows);
            assert_eq!(a, b, "case {ci}: extrapolated != brute-force");
        }
    }

    #[test]
    fn empty_epoch_is_zero() {
        let m = Mesh::new(4);
        assert_eq!(PacketSim::new(&m).run(&[]), EpochResult::default());
    }

    #[test]
    fn cache_replays_identical_epochs() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2), flow(3, 10, 50, 1, 2)];
        let a = sim.run_cached(&flows, &cache);
        let b = sim.run_cached(&flows, &cache);
        assert_eq!(a, b);
        assert_eq!(a, sim.run(&flows), "cached result must match uncached");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cache_distinguishes_meshes_and_traces() {
        let m1 = Mesh::new(16);
        let m2 = Mesh::new(9);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 5, 10, 0, 1)];
        let r1 = PacketSim::new(&m1).run_cached(&flows, &cache);
        let r2 = PacketSim::new(&m2).run_cached(&flows, &cache);
        assert_eq!(cache.misses(), 2, "different meshes must not alias");
        assert_eq!(r1, PacketSim::new(&m1).run(&flows));
        assert_eq!(r2, PacketSim::new(&m2).run(&flows));
        let other = vec![flow(0, 5, 11, 0, 1)];
        PacketSim::new(&m1).run_cached(&other, &cache);
        assert_eq!(cache.misses(), 3, "different traces must not alias");
    }

    #[test]
    fn cache_survives_a_poisoned_shard() {
        // a panicking sweep worker must not wedge every other thread:
        // lookups, inserts and counters keep working after a poison
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2)];
        let a = sim.run_cached(&flows, &cache);
        cache.poison_one_shard();
        let b = sim.run_cached(&flows, &cache);
        assert_eq!(a, b);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn shard_counters_sum_to_totals() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let cache = EpochCache::new();
        for c in 1..40u64 {
            let flows = vec![flow(0, 10, c, 0, 2)];
            sim.run_cached(&flows, &cache); // miss
            sim.run_cached(&flows, &cache); // hit
        }
        let stats = cache.shard_stats();
        assert_eq!(stats.len(), SHARD_COUNT);
        assert_eq!(stats.iter().map(|s| s.0).sum::<u64>(), cache.hits());
        assert_eq!(stats.iter().map(|s| s.1).sum::<u64>(), cache.misses());
        assert_eq!(cache.hits(), 39);
        assert_eq!(cache.misses(), 39);
        // 39 distinct fingerprints should not all land in one stripe
        assert!(
            stats.iter().filter(|s| s.1 > 0).count() > 1,
            "fingerprints failed to spread across shards: {stats:?}"
        );
        assert!((cache.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cache_replays_the_tier_tag_on_hits() {
        // the tier attribution stored at miss time must come back on
        // every hit — tier counts are a pure function of the trace
        let m = Mesh::new(16);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2)];
        let key = EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &flows);
        let tag = TierCounts {
            periodic: 2,
            closed_form: 3,
            ..TierCounts::default()
        };
        let sim = PacketSim::new(&m);
        let (r0, t0, hit0) = cache.get_or_compute_tagged(key, || (sim.run(&flows), tag));
        assert!(!hit0);
        assert_eq!(t0, tag);
        let (r1, t1, hit1) = cache.get_or_compute_tagged(key, || unreachable!("must hit"));
        assert!(hit1);
        assert_eq!((r0, t0), (r1, t1), "hit must replay result and tag");
        let mut sum = TierCounts::default();
        sum.accumulate(&t0);
        sum.accumulate(&t1);
        assert_eq!(sum.total(), 10);
        assert!(sum.render().contains("periodic 4"));
        assert!(sum.to_json().get("closed_form").is_some());
    }

    #[test]
    fn hydration_counts_only_new_inserts_and_skips_lookup_counters() {
        let m = Mesh::new(16);
        let sim = PacketSim::new(&m);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2)];
        let key = EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &flows);
        let r = sim.run(&flows);
        let tag = TierCounts {
            packet_fallback: 1,
            ..TierCounts::default()
        };
        assert!(cache.insert(key, r, tag), "fresh insert must hydrate");
        assert!(!cache.insert(key, r, tag), "re-insert must be a no-op");
        assert_eq!(cache.hydrated(), 1);
        assert_eq!((cache.hits(), cache.misses()), (0, 0), "hydration is not a lookup");
        // the hydrated entry replays like a simulated one
        let warm = sim.run_cached(&flows, &cache);
        assert_eq!(warm, r);
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
        // snapshot is fingerprint-sorted and complete
        let snap = cache.snapshot_entries();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0], (key, r, tag));
        let other = vec![flow(3, 10, 50, 1, 2)];
        sim.run_cached(&other, &cache);
        let snap = cache.snapshot_entries();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].0 <= snap[1].0, "snapshot must be key-sorted");
    }

    #[test]
    fn fingerprint_distinguishes_every_field() {
        let m = Mesh::new(16);
        let base = EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &[flow(0, 1, 5, 0, 2)]);
        let variants = [
            EpochKey::fingerprint(ENGINE_FLOW, &m, 2, 1, true, &[flow(0, 1, 5, 0, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 3, 1, true, &[flow(0, 1, 5, 0, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 2, true, &[flow(0, 1, 5, 0, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, false, &[flow(0, 1, 5, 0, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &[flow(1, 0, 5, 0, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &[flow(0, 1, 6, 0, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &[flow(0, 1, 5, 1, 2)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &[flow(0, 1, 5, 0, 3)]),
            EpochKey::fingerprint(ENGINE_PACKET, &m, 2, 1, true, &[]),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(base, *v, "variant {i} collided with base");
        }
    }
}
