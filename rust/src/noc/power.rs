//! NoC router + link area/energy models (Orion-style constants at 32 nm,
//! scaled by flit width and technology).

use crate::circuit::Tech;

/// Area/energy/leakage figures of one NoC router.
#[derive(Debug, Clone, Copy)]
pub struct RouterModel {
    /// Router silicon area, µm².
    pub area_um2: f64,
    /// Energy per flit traversing the router (buffer + crossbar + arb), pJ.
    pub flit_energy_pj: f64,
    /// Router leakage, µW.
    pub leakage_uw: f64,
}

/// Area/energy figures of one inter-tile link.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Wire area per link, µm² (repeaters + wiring track share).
    pub area_um2: f64,
    /// Energy per flit per link traversal, pJ.
    pub flit_energy_pj: f64,
}

/// 5-port input-buffered wormhole router.
/// Anchor: 32-bit, 4-deep buffers at 32 nm ≈ 12 000 µm², 0.32 pJ/flit.
pub fn router(flit_bits: usize, buffer_depth: usize, ports: usize, tech: &Tech) -> RouterModel {
    let w = flit_bits as f64 / 32.0;
    let p = ports as f64 / 5.0;
    let b = buffer_depth as f64 / 4.0;
    RouterModel {
        area_um2: 12_000.0 * w * p * (0.6 + 0.4 * b) * tech.area,
        flit_energy_pj: 0.32 * w * (0.7 + 0.3 * b) * tech.energy,
        leakage_uw: 18.0 * w * p * tech.leakage,
    }
}

/// On-chip link between adjacent tiles.
/// Anchor: 32-bit, 0.7 mm (the pitch of a ~0.5 mm² tile) at 32 nm:
/// 0.9 pJ/flit ≈ 0.04 pJ/bit/mm repeated wire.
pub fn link(flit_bits: usize, length_mm: f64, tech: &Tech) -> LinkModel {
    let bits = flit_bits as f64;
    LinkModel {
        // 0.2 µm wire pitch × length, all bits, plus repeater overhead
        area_um2: bits * 0.2 * (length_mm * 1000.0) * 1.15 * tech.area.sqrt(),
        flit_energy_pj: 0.04 * bits * length_mm * tech.energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_scales_with_width() {
        let t = Tech::new(32);
        let r32 = router(32, 4, 5, &t);
        let r64 = router(64, 4, 5, &t);
        assert!((r64.area_um2 / r32.area_um2 - 2.0).abs() < 1e-9);
        assert!(r64.flit_energy_pj > r32.flit_energy_pj);
    }

    #[test]
    fn link_energy_proportional_to_length() {
        let t = Tech::new(32);
        let l1 = link(32, 1.0, &t);
        let l2 = link(32, 2.0, &t);
        assert!((l2.flit_energy_pj / l1.flit_energy_pj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn anchors() {
        let t = Tech::new(32);
        let r = router(32, 4, 5, &t);
        assert!((r.area_um2 - 12_000.0).abs() < 1.0);
        assert!((r.flit_energy_pj - 0.32).abs() < 1e-9);
        let l = link(32, 0.7, &t);
        assert!((l.flit_energy_pj - 0.896).abs() < 1e-6);
    }
}
