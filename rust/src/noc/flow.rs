//! Flow-level epoch engine — the top tier of the NoC/NoP simulator
//! hierarchy (see `ARCHITECTURE.md`, "Three-tier interconnect engine").
//!
//! [`FlowSim`] schedules whole flows (count × stride packet trains)
//! against per-link occupancy instead of expanding each flow packet by
//! packet the way [`PacketSim`](super::PacketSim) does:
//!
//! * **Uncontended flows** (no link shared with any other flow) are
//!   answered in closed form — O(route length) per flow, independent of
//!   the packet count.
//! * **Contended flow groups** are isolated into link-disjoint
//!   components (union–find over shared links) and round-simulated with
//!   an *exact* shift-periodicity certificate: once the component's
//!   link-occupancy state repeats shifted by one stride, every remaining
//!   round is a time-translate of the last one and the tail is summed in
//!   closed form. Subcritical Algorithm-2 components certify within a
//!   handful of rounds — far before `PacketSim`'s fixed warm-up.
//! * **Oversaturated components** (queues grow without bound, so the
//!   shifted state never repeats) fall back to the same
//!   empirically-validated linear-growth extrapolation `PacketSim` uses,
//!   restricted to the component.
//! * **Irregular traces** (mixed strides, late starts — nothing
//!   Algorithm 2 emits) fall back to `PacketSim`'s k-way-merge
//!   per-packet scheduler wholesale.
//!
//! Within each component, rounds replay `PacketSim`'s list-scheduling
//! `send` arithmetic in the same `(start, flow index)` order, and
//! components never share links, so the engine reproduces `PacketSim`
//! bit-for-bit on uncontended and steady-state traces (asserted by the
//! property tests in `tests/proptests.rs`).
//!
//! The engine owns a per-instance **simulation arena**: the busy-until
//! vector, X–Y routes memoized by `(src, dst)`, union–find scratch and
//! certificate buffers are reused across every epoch of a sweep point,
//! so steady-state epoch evaluation allocates nothing.

use super::mesh::Mesh;
use super::sim::{
    steady_tail, uniform_stride, warmup_rounds, EpochCache, EpochKey, EpochResult, PacketSim,
    TierCounts, ENGINE_FLOW,
};
use crate::mapping::Flow;
use std::collections::HashMap;

/// Reusable per-engine simulation state (see module docs). All buffers
/// grow to the high-water mark of the epochs they served and are reused
/// verbatim afterwards.
#[derive(Debug, Default)]
struct Arena {
    /// Memoized X–Y routes: `(src, dst)` → index into `route_spans`.
    route_ids: HashMap<(u32, u32), u32>,
    /// Flattened storage for all interned routes.
    route_pool: Vec<u32>,
    /// `(offset, len)` of each interned route inside `route_pool`.
    route_spans: Vec<(u32, u32)>,
    /// Scratch for `Mesh::route`.
    route_buf: Vec<u32>,
    /// Per-link busy-until time; sparsely reset after every run.
    busy: Vec<u64>,
    /// Links dirtied by the current run (drives the sparse reset).
    touched: Vec<u32>,
    /// Per-link generation stamp for the union–find link walk.
    link_stamp: Vec<u32>,
    /// Last flow index seen on each link in the current generation.
    link_last: Vec<u32>,
    /// Current generation.
    stamp: u32,
    /// Per-flow interned route id for the current run.
    flow_route: Vec<u32>,
    /// Union–find parents over flow indices.
    uf: Vec<u32>,
    /// `(component root, start, flow index)` — sorted so components are
    /// contiguous and ordered by `(start, index)` within each run.
    grouped: Vec<(u32, u64, u32)>,
    /// Links of the component currently being certified (active flows).
    state_links: Vec<u32>,
    /// Busy-until snapshot of `state_links` after the previous round.
    state_prev: Vec<u64>,
}

/// Immutable view of the interned routes, split off the arena so route
/// lookups can coexist with mutable borrows of the link state.
struct RouteTable<'a> {
    pool: &'a [u32],
    spans: &'a [(u32, u32)],
    flow_route: &'a [u32],
}

impl RouteTable<'_> {
    fn route(&self, fi: u32) -> &[u32] {
        let (off, len) = self.spans[self.flow_route[fi as usize] as usize];
        &self.pool[off as usize..off as usize + len as usize]
    }
}

fn find(uf: &mut [u32], mut x: u32) -> u32 {
    while uf[x as usize] != x {
        let parent = uf[x as usize];
        uf[x as usize] = uf[parent as usize];
        x = uf[x as usize];
    }
    x
}

fn union(uf: &mut [u32], a: u32, b: u32) {
    let (ra, rb) = (find(uf, a), find(uf, b));
    if ra != rb {
        uf[ra as usize] = rb;
    }
}

/// Flow-level list-scheduling engine (see module docs). Results match
/// [`PacketSim`](super::PacketSim) exactly on Algorithm-2 (uniform)
/// traces; irregular traces delegate to it outright.
pub struct FlowSim<'m> {
    mesh: &'m Mesh,
    /// Router pipeline cycles per hop (head flit).
    pub router_delay: u64,
    /// Flits per packet (Algorithm-2 packets are one bus-width flit).
    pub flits_per_packet: u64,
    /// Gates the tier-2 linear-growth fallback for oversaturated
    /// components (the shift-periodicity certificate is exact and always
    /// on). Disable to force certificate-or-full round simulation — the
    /// brute-force escape hatch for detecting or bisecting a suspected
    /// extrapolation divergence, mirroring [`PacketSim::extrapolate`].
    pub extrapolate: bool,
    arena: Arena,
}

impl<'m> FlowSim<'m> {
    /// Flow-level simulator over `mesh` with the paper's defaults:
    /// 2-cycle routers, single-flit packets, linear-growth fallback
    /// enabled.
    pub fn new(mesh: &'m Mesh) -> Self {
        FlowSim {
            mesh,
            router_delay: 2,
            flits_per_packet: 1,
            extrapolate: true,
            arena: Arena::default(),
        }
    }

    /// Intern the X–Y route for `(src, dst)`, memoized across all epochs
    /// this engine simulates.
    fn intern_route(&mut self, src: u32, dst: u32) -> u32 {
        if let Some(&id) = self.arena.route_ids.get(&(src, dst)) {
            return id;
        }
        let mut buf = std::mem::take(&mut self.arena.route_buf);
        self.mesh.route(src, dst, &mut buf);
        let off = self.arena.route_pool.len() as u32;
        let len = buf.len() as u32;
        self.arena.route_pool.extend_from_slice(&buf);
        self.arena.route_buf = buf;
        let id = self.arena.route_spans.len() as u32;
        self.arena.route_spans.push((off, len));
        self.arena.route_ids.insert((src, dst), id);
        id
    }

    /// Simulate one epoch of flows (timestamps restart at 0).
    ///
    /// # Examples
    ///
    /// ```
    /// use siam::mapping::Flow;
    /// use siam::noc::{FlowSim, Mesh, PacketSim};
    ///
    /// let mesh = Mesh::new(16);
    /// let epoch = [
    ///     Flow { src: 0, dst: 2, count: 400, start: 0, stride: 3 },
    ///     Flow { src: 1, dst: 2, count: 400, start: 1, stride: 3 },
    /// ];
    /// let mut flow_level = FlowSim::new(&mesh);
    /// // identical to the per-packet engine, at a fraction of the cost
    /// assert_eq!(flow_level.run(&epoch), PacketSim::new(&mesh).run(&epoch));
    /// ```
    pub fn run(&mut self, flows: &[Flow]) -> EpochResult {
        self.run_counted(flows).0
    }

    /// [`run`](FlowSim::run) plus the [`TierCounts`] tally of which
    /// engine tier answered each piece of the epoch: one `closed_form`
    /// per uncontended flow, one `periodic` per certificate fire, one
    /// `extrapolated` per tier-2 tail, one `packet_fallback` per
    /// wholesale delegation of an irregular trace. Fully-round-simulated
    /// components (they finish before any certificate fires) are counted
    /// nowhere — the counters tally tier *events*, not components. The
    /// result half is bit-identical to [`run`](FlowSim::run).
    pub fn run_counted(&mut self, flows: &[Flow]) -> (EpochResult, TierCounts) {
        let mut res = EpochResult::default();
        let mut tiers = TierCounts::default();
        if flows.is_empty() {
            return (res, tiers);
        }

        // Single-flow epochs (the dominant shape of small-CNN traces,
        // where most layers occupy one tile) take the closed form
        // directly — it is exact for any (start, stride), so no
        // uniformity check is needed and nothing touches the link state.
        if flows.len() == 1 {
            let f = &flows[0];
            if f.count > 0 {
                let id = self.intern_route(f.src, f.dst);
                let hops = self.arena.route_spans[id as usize].1 as u64;
                singleton_result(f, hops, self.router_delay, self.flits_per_packet, &mut res);
                tiers.closed_form += 1;
            }
            return (res, tiers);
        }

        // Algorithm-2 epochs share one stride with all starts inside the
        // first round; anything else is irregular — delegate to the
        // per-packet k-way-merge scheduler (bottom of the fallback chain).
        let Some(stride) = uniform_stride(flows) else {
            let mut psim = PacketSim::new(self.mesh);
            psim.router_delay = self.router_delay;
            psim.flits_per_packet = self.flits_per_packet;
            psim.extrapolate = self.extrapolate;
            tiers.packet_fallback += 1;
            return (psim.run(flows), tiers);
        };

        let n = flows.len();

        // ---- intern routes (memoized across epochs)
        self.arena.flow_route.clear();
        for f in flows {
            let id = self.intern_route(f.src, f.dst);
            self.arena.flow_route.push(id);
        }

        // ---- size the per-link state lazily
        let nl = self.mesh.num_links();
        if self.arena.busy.len() < nl {
            self.arena.busy.resize(nl, 0);
            self.arena.link_stamp.resize(nl, 0);
            self.arena.link_last.resize(nl, 0);
        }

        // ---- union flows sharing any link into contention components
        self.arena.uf.clear();
        self.arena.uf.extend(0..n as u32);
        self.arena.stamp = self.arena.stamp.wrapping_add(1);
        if self.arena.stamp == 0 {
            self.arena.link_stamp.fill(0);
            self.arena.stamp = 1;
        }
        let stamp = self.arena.stamp;
        self.arena.touched.clear();
        for fi in 0..n as u32 {
            let (off, len) = self.arena.route_spans[self.arena.flow_route[fi as usize] as usize];
            for &link in &self.arena.route_pool[off as usize..(off + len) as usize] {
                let l = link as usize;
                if self.arena.link_stamp[l] == stamp {
                    let other = self.arena.link_last[l];
                    union(&mut self.arena.uf, fi, other);
                } else {
                    self.arena.link_stamp[l] = stamp;
                    self.arena.touched.push(link);
                }
                self.arena.link_last[l] = fi;
            }
        }

        // ---- group flows by component, ordered by (start, index) within
        // each — PacketSim's injection-round order.
        self.arena.grouped.clear();
        for fi in 0..n as u32 {
            let root = find(&mut self.arena.uf, fi);
            self.arena.grouped.push((root, flows[fi as usize].start, fi));
        }
        self.arena.grouped.sort_unstable();

        let d = self.router_delay;
        let fpp = self.flits_per_packet;
        let extrapolate = self.extrapolate;
        let warmup = warmup_rounds(self.mesh);

        let Arena {
            route_pool,
            route_spans,
            flow_route,
            busy,
            touched,
            grouped,
            state_links,
            state_prev,
            ..
        } = &mut self.arena;
        let routes = RouteTable {
            pool: route_pool.as_slice(),
            spans: route_spans.as_slice(),
            flow_route: flow_route.as_slice(),
        };

        let mut i = 0usize;
        while i < grouped.len() {
            let root = grouped[i].0;
            let mut j = i + 1;
            while j < grouped.len() && grouped[j].0 == root {
                j += 1;
            }
            if j - i == 1 {
                let fi = grouped[i].2;
                let hops = routes.route(fi).len() as u64;
                singleton_result(&flows[fi as usize], hops, d, fpp, &mut res);
                tiers.closed_form += 1;
            } else {
                run_component(
                    flows,
                    &grouped[i..j],
                    &routes,
                    stride,
                    d,
                    fpp,
                    warmup,
                    extrapolate,
                    busy,
                    state_links,
                    state_prev,
                    &mut res,
                    &mut tiers,
                );
            }
            i = j;
        }

        // sparse reset: only links this run dirtied
        for &l in touched.iter() {
            busy[l as usize] = 0;
        }

        (res, tiers)
    }

    /// [`run`](FlowSim::run) through an [`EpochCache`]: identical epochs
    /// (same mesh dimensions, engine parameters and flow trace) are
    /// simulated once and replayed thereafter. Results are bit-identical
    /// to the uncached path.
    pub fn run_cached(&mut self, flows: &[Flow], cache: &EpochCache) -> EpochResult {
        self.run_cached_tagged(flows, cache).0
    }

    /// [`run_counted`](FlowSim::run_counted) through an [`EpochCache`].
    /// The tier tally is stored in the cache entry beside the result, so
    /// a hit replays the counts of the run that populated the entry —
    /// tier counters are a pure function of the evaluation trace and
    /// stay deterministic whether epochs are computed or replayed, in
    /// serial or parallel sweeps. The final `bool` is the hit flag.
    pub fn run_cached_tagged(
        &mut self,
        flows: &[Flow],
        cache: &EpochCache,
    ) -> (EpochResult, TierCounts, bool) {
        let key = EpochKey::fingerprint(
            ENGINE_FLOW,
            self.mesh,
            self.router_delay,
            self.flits_per_packet,
            self.extrapolate,
            flows,
        );
        cache.get_or_compute_tagged(key, || self.run_counted(flows))
    }
}

/// Analytic lower bound for one epoch on `mesh` — the scoring kernel of
/// the cheap search tier (`sweep --search pareto|halving`, see
/// `coordinator::dse`).
///
/// `packets` and `flit_hops` are **exact**: X–Y routes are
/// deterministic, so every engine tier moves `count × hops ×
/// flits_per_packet` flit-links per flow regardless of contention —
/// which makes every downstream energy/area figure exact too.
/// `completion_cycles` and `total_latency_cycles` are **provable lower
/// bounds** of every tier's answer: contention only delays packets
/// (per-link busy-until values are monotone in the set of competing
/// flows), so each flow's private-route closed form bounds it from
/// below, and each link serializes at one packet per
/// `flits_per_packet` cycles, so the most-loaded link's drain time
/// bounds the epoch completion.
pub(crate) fn epoch_bound(
    mesh: &Mesh,
    router_delay: u64,
    flits_per_packet: u64,
    flows: &[Flow],
) -> EpochResult {
    let mut res = EpochResult::default();
    let mut route = Vec::new();
    let mut loads: HashMap<u32, u64> = HashMap::new();
    for f in flows {
        if f.count == 0 {
            continue;
        }
        mesh.route(f.src, f.dst, &mut route);
        singleton_result(f, route.len() as u64, router_delay, flits_per_packet, &mut res);
        for &l in &route {
            *loads.entry(l).or_default() += f.count;
        }
    }
    let link_floor = loads
        .values()
        .map(|&p| p * flits_per_packet)
        .max()
        .unwrap_or(0);
    res.completion_cycles = res.completion_cycles.max(link_floor);
    res
}

/// Closed form for a flow whose links nobody else uses. Exact: with a
/// private route the list schedule degenerates to per-link arithmetic —
/// packets pipeline freely when `stride >= flits_per_packet` and queue
/// behind the first link with constant extra delay `F - stride` per
/// packet otherwise.
fn singleton_result(f: &Flow, hops: u64, d: u64, fpp: u64, res: &mut EpochResult) {
    let n = f.count;
    let (completion, latency) = if hops == 0 {
        // src == dst after self-loop filtering: deliver after serialization
        (f.start + (n - 1) * f.stride + fpp, n * fpp)
    } else {
        let gap = f.stride.max(fpp);
        let queueing = fpp.saturating_sub(f.stride);
        (
            f.start + (n - 1) * gap + hops * d + fpp,
            n * (hops * d + fpp) + queueing * (n * (n - 1) / 2),
        )
    };
    res.packets += n;
    res.flit_hops += n * hops * fpp;
    res.total_latency_cycles += latency;
    res.completion_cycles = res.completion_cycles.max(completion);
}

/// Links written by the flows of `members` still active at `round`,
/// sorted and deduplicated — the certificate's state vector.
fn rebuild_state_links(
    flows: &[Flow],
    members: &[(u32, u64, u32)],
    routes: &RouteTable<'_>,
    round: u64,
    state_links: &mut Vec<u32>,
) {
    state_links.clear();
    for m in members {
        if flows[m.2 as usize].count > round {
            state_links.extend_from_slice(routes.route(m.2));
        }
    }
    state_links.sort_unstable();
    state_links.dedup();
}

/// Round-simulate one contention component (flows sharing links), with
/// the shift-periodicity certificate (exact) and the linear-growth
/// fallback (PacketSim's validated heuristic) for oversaturated links.
#[allow(clippy::too_many_arguments)]
fn run_component(
    flows: &[Flow],
    members: &[(u32, u64, u32)],
    routes: &RouteTable<'_>,
    stride: u64,
    d: u64,
    fpp: u64,
    warmup: u64,
    extrapolate: bool,
    busy: &mut [u64],
    state_links: &mut Vec<u32>,
    state_prev: &mut Vec<u64>,
    res: &mut EpochResult,
    tiers: &mut TierCounts,
) {
    let max_count = members
        .iter()
        .map(|m| flows[m.2 as usize].count)
        .max()
        .unwrap();
    let equal_counts = members
        .iter()
        .all(|m| flows[m.2 as usize].count == max_count);

    // `boundary`: first round at which some flow exhausts — the active
    // set (and hence the certificate's state vector) is constant below it.
    let mut boundary = members
        .iter()
        .map(|m| flows[m.2 as usize].count)
        .min()
        .unwrap();
    rebuild_state_links(flows, members, routes, 0, state_links);
    state_prev.clear();
    let mut have_prev = false;

    let mut comp_completion = 0u64;
    let mut prev = (0u64, 0u64); // (completion, latency) after prev round
    let mut prev_delta = (u64::MAX, u64::MAX);
    let mut same_delta_rounds = 0u32;
    let mut round = 0u64;
    while round < max_count {
        if round == boundary {
            // a flow exhausted: shrink the state vector to the surviving
            // flows' links and re-arm the certificate
            rebuild_state_links(flows, members, routes, round, state_links);
            boundary = members
                .iter()
                .map(|m| flows[m.2 as usize].count)
                .filter(|&c| c > round)
                .min()
                .unwrap_or(max_count);
            have_prev = false;
        }

        // ---- one injection round, PacketSim's send arithmetic verbatim
        let mut round_lat = 0u64;
        let mut round_max = 0u64;
        let mut active_cnt = 0u64;
        let mut active_hops = 0u64;
        for m in members {
            let f = &flows[m.2 as usize];
            if round >= f.count {
                continue;
            }
            let inject = f.start + round * stride;
            let r = routes.route(m.2);
            let mut head = inject;
            for &l in r {
                let start = (head + d).max(busy[l as usize]);
                busy[l as usize] = start + fpp;
                head = start;
            }
            let arrival = head + fpp;
            res.packets += 1;
            res.flit_hops += r.len() as u64 * fpp;
            round_lat += arrival - inject;
            round_max = round_max.max(arrival);
            active_cnt += 1;
            active_hops += r.len() as u64;
        }
        res.total_latency_cycles += round_lat;
        comp_completion = comp_completion.max(round_max);

        // ---- tier 1: exact shift-periodicity certificate. If every
        // active link's busy-until advanced by exactly `stride` since the
        // previous round, round r+1 is a time-translate of round r (same
        // state up to the shift, same injections up to the shift), so the
        // whole window up to the next exhaustion is summed in closed form
        // and the link state jumps forward exactly.
        if have_prev && boundary > round + 1 {
            let periodic = state_links
                .iter()
                .zip(state_prev.iter())
                .all(|(&l, &pb)| busy[l as usize] == pb + stride);
            if periodic {
                tiers.periodic += 1;
                let k = boundary - 1 - round;
                res.packets += active_cnt * k;
                res.flit_hops += active_hops * fpp * k;
                res.total_latency_cycles += round_lat * k;
                comp_completion = comp_completion.max(round_max + stride * k);
                for &l in state_links.iter() {
                    busy[l as usize] += stride * k;
                }
                round = boundary; // jump past the certified window
                have_prev = false;
                prev = (comp_completion, round_lat);
                prev_delta = (u64::MAX, u64::MAX);
                same_delta_rounds = 0;
                continue;
            }
        }

        // ---- tier 2: linear-growth fallback for oversaturated links
        // (queues grow every round, so the shifted state never repeats).
        // PacketSim's §Perf extrapolation arithmetic, restricted to this
        // component, armed one round later (three equal consecutive
        // (completion, latency) deltas instead of two) for extra margin
        // against pre-asymptotic coincidences.
        let delta = (comp_completion - prev.0, round_lat.wrapping_sub(prev.1));
        if delta == prev_delta {
            same_delta_rounds += 1;
        } else {
            same_delta_rounds = 0;
        }
        let armed = extrapolate && equal_counts && round > warmup;
        if armed && same_delta_rounds >= 2 && round_lat >= prev.1 {
            let remaining = max_count - round - 1;
            if remaining > 0 {
                tiers.extrapolated += 1;
                let tail = steady_tail(
                    remaining,
                    active_cnt,
                    active_hops * fpp,
                    round_lat,
                    round_lat - prev.1, // == delta.1
                    delta.0,
                );
                res.packets += tail.packets;
                res.flit_hops += tail.flit_hops;
                comp_completion += tail.completion;
                res.total_latency_cycles += tail.latency;
                break;
            }
        }

        state_prev.clear();
        state_prev.extend(state_links.iter().map(|&l| busy[l as usize]));
        have_prev = true;
        prev_delta = delta;
        prev = (comp_completion, round_lat);
        round += 1;
    }

    res.completion_cycles = res.completion_cycles.max(comp_completion);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(src: u32, dst: u32, count: u64, start: u64, stride: u64) -> Flow {
        Flow {
            src,
            dst,
            count,
            start,
            stride,
        }
    }

    fn brute(mesh: &Mesh) -> PacketSim<'_> {
        let mut p = PacketSim::new(mesh);
        p.extrapolate = false;
        p
    }

    #[test]
    fn empty_epoch_is_zero() {
        let m = Mesh::new(4);
        assert_eq!(FlowSim::new(&m).run(&[]), EpochResult::default());
    }

    #[test]
    fn singleton_closed_form_matches_brute_force() {
        let m = Mesh::new(16);
        for (count, start, stride) in [(1, 0, 1), (7, 2, 3), (500, 0, 1), (1000, 4, 5)] {
            let flows = [flow(0, 10, count, start, stride)];
            let got = FlowSim::new(&m).run(&flows);
            let want = brute(&m).run(&flows);
            assert_eq!(got, want, "count={count} start={start} stride={stride}");
        }
    }

    #[test]
    fn disjoint_flows_use_closed_forms() {
        // row 0 and row 3 of a 4x4 snake mesh never share links
        let m = Mesh::new(16);
        let flows = [flow(0, 3, 4000, 0, 2), flow(12, 15, 4000, 1, 2)];
        let got = FlowSim::new(&m).run(&flows);
        let want = brute(&m).run(&flows);
        assert_eq!(got, want);
    }

    #[test]
    fn contended_component_matches_brute_force() {
        let m = Mesh::new(16);
        let cases: Vec<Vec<Flow>> = vec![
            vec![flow(0, 10, 5000, 0, 3), flow(3, 10, 5000, 1, 3), flow(12, 5, 5000, 2, 3)],
            vec![flow(0, 2, 4000, 0, 2), flow(1, 2, 4000, 1, 2)],
            (0..8).map(|i| flow(i, 15, 1500, i as u64, 9)).collect(),
        ];
        for (ci, flows) in cases.iter().enumerate() {
            let got = FlowSim::new(&m).run(flows);
            let want = brute(&m).run(flows);
            assert_eq!(got, want, "case {ci}");
        }
    }

    #[test]
    fn unequal_counts_match_brute_force() {
        // flows exhaust at different rounds: the certificate must re-arm
        // at every exhaustion boundary and still be exact
        let m = Mesh::new(16);
        let flows = [
            flow(0, 10, 900, 0, 4),
            flow(3, 10, 350, 1, 4),
            flow(12, 10, 120, 2, 4),
            flow(5, 6, 40, 3, 4),
        ];
        let got = FlowSim::new(&m).run(&flows);
        let want = brute(&m).run(&flows);
        assert_eq!(got, want);
    }

    #[test]
    fn single_flow_closed_form_handles_irregular_parameters() {
        // the closed form is exact for any (start, stride), including
        // starts past the first round — no uniformity requirement
        let m = Mesh::new(16);
        for (count, start, stride) in [(40, 9, 2), (1, 17, 1), (300, 5, 1), (60, 3, 6)] {
            let flows = [flow(2, 13, count, start, stride)];
            let got = FlowSim::new(&m).run(&flows);
            let want = brute(&m).run(&flows);
            assert_eq!(got, want, "count={count} start={start} stride={stride}");
        }
    }

    #[test]
    fn irregular_trace_delegates_to_packet_sim() {
        // mixed strides: not an Algorithm-2 shape
        let m = Mesh::new(16);
        let flows = [flow(0, 10, 50, 0, 2), flow(3, 10, 70, 5, 3)];
        let got = FlowSim::new(&m).run(&flows);
        let want = PacketSim::new(&m).run(&flows);
        assert_eq!(got, want);
    }

    #[test]
    fn arena_reuse_is_stateless_across_epochs() {
        // the same engine must give identical answers before and after
        // simulating unrelated epochs (busy-until state fully reset)
        let m = Mesh::new(16);
        let a = [flow(0, 10, 300, 0, 2), flow(3, 10, 300, 1, 2)];
        let b = [flow(5, 6, 80, 0, 1)];
        let mut sim = FlowSim::new(&m);
        let first = sim.run(&a);
        sim.run(&b);
        sim.run(&a);
        let again = sim.run(&a);
        assert_eq!(first, again);
    }

    #[test]
    fn multi_flit_packets_match_brute_force() {
        let m = Mesh::new(9);
        let mut fast = FlowSim::new(&m);
        fast.flits_per_packet = 4;
        let mut slow = brute(&m);
        slow.flits_per_packet = 4;
        // stride < flits_per_packet: self-saturating singleton
        let flows = [flow(0, 8, 200, 0, 2)];
        assert_eq!(fast.run(&flows), slow.run(&flows));
        // and a contended pair
        let flows = [flow(0, 2, 200, 0, 2), flow(1, 2, 200, 1, 2)];
        assert_eq!(fast.run(&flows), slow.run(&flows));
    }

    #[test]
    fn tier2_toggle_forces_full_simulation() {
        // extrapolate=false disables the tier-2 heuristic (the escape
        // hatch for bisecting a suspected divergence); on a saturated
        // same-source component both modes must still equal brute force
        let m = Mesh::new(16);
        let flows: Vec<Flow> = (1..6).map(|t| flow(0, t, 300, 0, 2)).collect();
        let want = brute(&m).run(&flows);
        assert_eq!(FlowSim::new(&m).run(&flows), want);
        let mut exact = FlowSim::new(&m);
        exact.extrapolate = false;
        assert_eq!(exact.run(&flows), want);
    }

    #[test]
    fn cached_runs_replay_and_count() {
        let m = Mesh::new(16);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2), flow(3, 10, 50, 1, 2)];
        let mut sim = FlowSim::new(&m);
        let a = sim.run_cached(&flows, &cache);
        let b = sim.run_cached(&flows, &cache);
        assert_eq!(a, b);
        assert_eq!(a, FlowSim::new(&m).run(&flows));
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn tier_counts_attribute_each_answer_to_its_tier() {
        let m = Mesh::new(16);
        // two uncontended flows: two closed forms, nothing else
        let disjoint = [flow(0, 3, 4000, 0, 2), flow(12, 15, 4000, 1, 2)];
        let (res, tiers) = FlowSim::new(&m).run_counted(&disjoint);
        assert_eq!(res, FlowSim::new(&m).run(&disjoint), "counting must not perturb the result");
        assert_eq!(tiers.closed_form, 2);
        assert_eq!(tiers.periodic + tiers.extrapolated + tiers.packet_fallback, 0);

        // irregular trace: one wholesale packet fallback
        let irregular = [flow(0, 10, 50, 0, 2), flow(3, 10, 70, 5, 3)];
        let (_, tiers) = FlowSim::new(&m).run_counted(&irregular);
        assert_eq!(tiers.packet_fallback, 1);
        assert_eq!(tiers.closed_form, 0);

        // long contended component: the certificate (or, failing that,
        // the tier-2 tail) must fire at least once
        let contended = [flow(0, 10, 5000, 0, 3), flow(3, 10, 5000, 1, 3)];
        let (_, tiers) = FlowSim::new(&m).run_counted(&contended);
        assert!(tiers.periodic + tiers.extrapolated >= 1, "no tier fired: {tiers:?}");
    }

    #[test]
    fn cached_tier_tags_replay_on_hits() {
        let m = Mesh::new(16);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 3, 4000, 0, 2), flow(12, 15, 4000, 1, 2)];
        let mut sim = FlowSim::new(&m);
        let (r1, t1, hit1) = sim.run_cached_tagged(&flows, &cache);
        let (r2, t2, hit2) = sim.run_cached_tagged(&flows, &cache);
        assert!(!hit1 && hit2);
        assert_eq!(r1, r2);
        assert_eq!(t1, t2, "hit must replay the stored tier tag");
        assert_eq!(t1.closed_form, 2);
    }

    #[test]
    fn epoch_bound_is_exact_on_counts_and_a_true_lower_bound_on_time() {
        let m = Mesh::new(16);
        let cases: Vec<Vec<Flow>> = vec![
            vec![flow(0, 10, 300, 0, 2)], // singleton: bound is exact
            vec![flow(0, 3, 4000, 0, 2), flow(12, 15, 4000, 1, 2)], // disjoint
            vec![flow(0, 10, 5000, 0, 3), flow(3, 10, 5000, 1, 3), flow(12, 5, 5000, 2, 3)],
            vec![flow(0, 2, 4000, 0, 2), flow(1, 2, 4000, 1, 2)], // hot sink
            vec![flow(0, 10, 50, 0, 2), flow(3, 10, 70, 5, 3)],   // irregular
            (1..6).map(|t| flow(0, t, 300, 0, 2)).collect(),      // saturated
        ];
        for (ci, flows) in cases.iter().enumerate() {
            let full = FlowSim::new(&m).run(flows);
            let lb = epoch_bound(&m, 2, 1, flows);
            assert_eq!(lb.packets, full.packets, "case {ci}: packets are exact");
            assert_eq!(lb.flit_hops, full.flit_hops, "case {ci}: flit-hops are exact");
            assert!(
                lb.completion_cycles <= full.completion_cycles,
                "case {ci}: completion bound {} above the engine's {}",
                lb.completion_cycles,
                full.completion_cycles
            );
            assert!(
                lb.total_latency_cycles <= full.total_latency_cycles,
                "case {ci}: latency bound above the engine"
            );
        }
        // Uncontended epochs collapse to the closed forms: bound == engine.
        for flows in &cases[..2] {
            assert_eq!(epoch_bound(&m, 2, 1, flows), FlowSim::new(&m).run(flows));
        }
    }

    #[test]
    fn flow_and_packet_cache_entries_never_alias() {
        // same trace, same mesh — but the engines key separately, so a
        // FlowSim result can never be replayed as a PacketSim result
        let m = Mesh::new(16);
        let cache = EpochCache::new();
        let flows = vec![flow(0, 10, 50, 0, 2)];
        FlowSim::new(&m).run_cached(&flows, &cache);
        PacketSim::new(&m).run_cached(&flows, &cache);
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 0);
    }
}
