//! Persistent, append-only on-disk epoch cache (`--cache-file`).
//!
//! The in-memory [`EpochCache`](crate::noc::EpochCache) dies with the
//! process; this module makes its contents durable so a re-run of
//! `simulate`, `sweep` or `serve` replays previously computed epochs
//! instead of re-simulating them. The file is a log of checksummed
//! records keyed by the canonical 128-bit epoch fingerprints
//! (`EpochKey::fingerprint`), which already encode every input that can
//! change an epoch result (engine, mesh shape and embedding, router
//! delay, packet length, extrapolation flag and the full flow list) —
//! so a fingerprint hit is safe to replay across processes.
//!
//! # File format
//!
//! ```text
//! header (24 bytes)
//!   +0  magic       b"SIAMEPC1"            (8 bytes)
//!   +8  version     u32 LE = 1
//!   +12 reserved    u32 LE = 0
//!   +16 generation  u64 LE = EPOCH_STORE_GENERATION
//! records (repeated until EOF)
//!   +0  len         u32 LE                 payload length in bytes
//!   +4  checksum    u64 LE                 FNV-1a over the payload
//!   +12 payload     len bytes
//! epoch payload (kind 0, 81 bytes)
//!   kind, key.lo, key.hi,
//!   completion_cycles, packets, total_latency_cycles, flit_hops,
//!   closed_form, periodic, extrapolated, packet_fallback
//! point payload (kind 1, 17 bytes)
//!   kind, fingerprint.lo, fingerprint.hi
//! ```
//!
//! All integers are little-endian; `kind` is a single byte.
//!
//! # Recovery contract
//!
//! The invariant is *a torn tail is data loss, never wrong results*:
//!
//! * missing file → created with a fresh header;
//! * zero-length file → re-initialised with a fresh header;
//! * a partial header that is a byte-prefix of a fresh header (a torn
//!   initial write) → re-initialised;
//! * bad magic or unknown version → **hard error**; the store never
//!   clobbers a file it does not recognise;
//! * stale generation → the log is discarded and the file reset to a
//!   fresh header ([`LoadReport::stale_generation`]);
//! * the first invalid record (zero or oversized length, length past
//!   EOF, checksum mismatch, unknown kind, wrong payload size) →
//!   the file is truncated at the last valid record boundary
//!   ([`LoadReport::truncated_bytes`]) and scanning stops.
//!
//! Appends go through a single `O_APPEND` handle with one `write` per
//! batch, so concurrent writers interleave only at record boundaries;
//! duplicate fingerprints written by independent handles are counted
//! and ignored at load time ([`LoadReport::duplicate_records`]).
//! See `docs/CACHING.md` for the user-facing guide.

use std::collections::HashSet;
use std::fs::{File, OpenOptions};
use std::io::{ErrorKind, Write};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard, PoisonError};

use anyhow::{bail, Context, Result};

use super::sim::{EpochCache, EpochKey, EpochResult, TierCounts};
use crate::obs::meta::fnv1a;

/// On-disk format version; bumped only on incompatible layout changes
/// (an unknown version is a hard error, never a silent reset).
pub const EPOCH_STORE_VERSION: u32 = 1;

/// Cache generation: bumped whenever simulator semantics change in a
/// way that invalidates previously recorded epoch results. A file with
/// a different generation is discarded (reset to a fresh header) at
/// open time rather than replayed.
pub const EPOCH_STORE_GENERATION: u64 = 1;

const MAGIC: [u8; 8] = *b"SIAMEPC1";
const HEADER_LEN: usize = 24;
/// Frame prefix: `u32` payload length + `u64` FNV-1a checksum.
const FRAME_LEN: usize = 12;
/// Upper bound on a single payload; anything larger is corruption.
const MAX_RECORD_LEN: u32 = 4096;
const KIND_EPOCH: u8 = 0;
const KIND_POINT: u8 = 1;
const EPOCH_PAYLOAD_LEN: usize = 1 + 10 * 8;
const POINT_PAYLOAD_LEN: usize = 1 + 2 * 8;

/// What `EpochStore::open` found (and repaired) in an existing file.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// Distinct epoch records replayed from the log.
    pub epochs_loaded: usize,
    /// Distinct sweep-point fingerprints replayed from the log.
    pub points_loaded: usize,
    /// Valid records whose fingerprint was already seen earlier in the
    /// log (benign: concurrent handles may race the same entry).
    pub duplicate_records: usize,
    /// Bytes discarded from the tail (torn/corrupt records, or the
    /// whole log on a stale generation). Zero for a clean file.
    pub truncated_bytes: u64,
    /// True when the file carried an outdated generation and its log
    /// was discarded rather than replayed.
    pub stale_generation: bool,
}

struct StoreInner {
    file: File,
    known: HashSet<EpochKey>,
    known_points: HashSet<(u64, u64)>,
    entries: Vec<(EpochKey, EpochResult, TierCounts)>,
}

/// A handle on a persistent epoch cache file.
///
/// Thread-safe: all mutation goes through an internal mutex and a
/// single `O_APPEND` file handle, so one `EpochStore` can be shared
/// (via `Arc`) by every worker of a parallel sweep.
pub struct EpochStore {
    path: PathBuf,
    inner: Mutex<StoreInner>,
}

impl std::fmt::Debug for EpochStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = lock(&self.inner);
        f.debug_struct("EpochStore")
            .field("path", &self.path)
            .field("epochs", &inner.entries.len())
            .field("points", &inner.known_points.len())
            .finish()
    }
}

fn lock(m: &Mutex<StoreInner>) -> MutexGuard<'_, StoreInner> {
    // A poisoned store mutex means a writer panicked between state
    // updates; the on-disk recovery contract already handles any torn
    // tail, so continuing with the in-memory view is safe.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn header_bytes(generation: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(&MAGIC);
    h[8..12].copy_from_slice(&EPOCH_STORE_VERSION.to_le_bytes());
    // bytes 12..16 stay zero (reserved)
    h[16..24].copy_from_slice(&generation.to_le_bytes());
    h
}

fn read_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().expect("8-byte slice"))
}

fn epoch_payload(key: EpochKey, r: EpochResult, t: TierCounts) -> [u8; EPOCH_PAYLOAD_LEN] {
    let mut p = [0u8; EPOCH_PAYLOAD_LEN];
    p[0] = KIND_EPOCH;
    let words = [
        key.lo,
        key.hi,
        r.completion_cycles,
        r.packets,
        r.total_latency_cycles,
        r.flit_hops,
        t.closed_form,
        t.periodic,
        t.extrapolated,
        t.packet_fallback,
    ];
    for (i, w) in words.iter().enumerate() {
        p[1 + i * 8..9 + i * 8].copy_from_slice(&w.to_le_bytes());
    }
    p
}

fn point_payload(fp: (u64, u64)) -> [u8; POINT_PAYLOAD_LEN] {
    let mut p = [0u8; POINT_PAYLOAD_LEN];
    p[0] = KIND_POINT;
    p[1..9].copy_from_slice(&fp.0.to_le_bytes());
    p[9..17].copy_from_slice(&fp.1.to_le_bytes());
    p
}

/// Append one `[len][checksum][payload]` frame to `buf`.
fn frame_into(buf: &mut Vec<u8>, payload: &[u8]) {
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&fnv1a(payload).to_le_bytes());
    buf.extend_from_slice(payload);
}

enum Record {
    Epoch(EpochKey, EpochResult, TierCounts),
    Point((u64, u64)),
}

fn parse_payload(p: &[u8]) -> Option<Record> {
    match p[0] {
        KIND_EPOCH if p.len() == EPOCH_PAYLOAD_LEN => {
            let w = |i: usize| read_u64(p, 1 + i * 8);
            Some(Record::Epoch(
                EpochKey { lo: w(0), hi: w(1) },
                EpochResult {
                    completion_cycles: w(2),
                    packets: w(3),
                    total_latency_cycles: w(4),
                    flit_hops: w(5),
                },
                TierCounts {
                    closed_form: w(6),
                    periodic: w(7),
                    extrapolated: w(8),
                    packet_fallback: w(9),
                },
            ))
        }
        KIND_POINT if p.len() == POINT_PAYLOAD_LEN => {
            Some(Record::Point((read_u64(p, 1), read_u64(p, 9))))
        }
        _ => None,
    }
}

impl EpochStore {
    /// Open (or create) the cache file at `path`, replaying every valid
    /// record and repairing the tail per the module-level recovery
    /// contract. Returns the store handle plus a [`LoadReport`]
    /// describing what was loaded, deduplicated and discarded.
    ///
    /// Hard errors: unreadable file/directory, a file that is not a
    /// SIAM epoch cache (bad magic), or an unknown format version —
    /// the store refuses to overwrite data it does not understand.
    pub fn open(path: impl AsRef<Path>) -> Result<(EpochStore, LoadReport)> {
        let path = path.as_ref();
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == ErrorKind::NotFound => Vec::new(),
            Err(e) => {
                return Err(e).with_context(|| format!("reading cache file {}", path.display()))
            }
        };

        let mut report = LoadReport::default();
        let mut known = HashSet::new();
        let mut known_points = HashSet::new();
        let mut entries = Vec::new();
        let fresh = header_bytes(EPOCH_STORE_GENERATION);
        // `None` → rewrite the file as a fresh header; `Some(n)` →
        // keep the first `n` bytes (truncating if shorter than now).
        let mut keep: Option<u64> = None;

        if bytes.is_empty() {
            // Missing or zero-length: initialise in place.
        } else if bytes.len() < HEADER_LEN {
            if fresh[..bytes.len()] == bytes[..] {
                // Torn initial header write from a previous run.
                report.truncated_bytes = bytes.len() as u64;
            } else {
                bail!(
                    "{} is not a SIAM epoch cache file (short, unrecognised header)",
                    path.display()
                );
            }
        } else if bytes[..8] != MAGIC {
            bail!(
                "{} is not a SIAM epoch cache file (bad magic); refusing to overwrite",
                path.display()
            );
        } else {
            let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4-byte slice"));
            if version != EPOCH_STORE_VERSION {
                bail!(
                    "{}: unsupported epoch cache version {} (this build reads version {})",
                    path.display(),
                    version,
                    EPOCH_STORE_VERSION
                );
            }
            let generation = read_u64(&bytes, 16);
            if generation != EPOCH_STORE_GENERATION {
                report.stale_generation = true;
                report.truncated_bytes = (bytes.len() - HEADER_LEN) as u64;
            } else {
                let mut off = HEADER_LEN;
                while off < bytes.len() {
                    let Some(end) = Self::record_end(&bytes, off) else {
                        report.truncated_bytes = (bytes.len() - off) as u64;
                        break;
                    };
                    match parse_payload(&bytes[off + FRAME_LEN..end]) {
                        Some(Record::Epoch(key, result, tiers)) => {
                            if known.insert(key) {
                                entries.push((key, result, tiers));
                                report.epochs_loaded += 1;
                            } else {
                                report.duplicate_records += 1;
                            }
                        }
                        Some(Record::Point(fp)) => {
                            if known_points.insert(fp) {
                                report.points_loaded += 1;
                            } else {
                                report.duplicate_records += 1;
                            }
                        }
                        None => {
                            report.truncated_bytes = (bytes.len() - off) as u64;
                            break;
                        }
                    }
                    off = end;
                }
                keep = Some((bytes.len() as u64) - report.truncated_bytes);
            }
        }

        match keep {
            Some(valid_end) => {
                if report.truncated_bytes > 0 {
                    let f = OpenOptions::new()
                        .write(true)
                        .open(path)
                        .with_context(|| format!("repairing cache file {}", path.display()))?;
                    f.set_len(valid_end)
                        .with_context(|| format!("truncating cache file {}", path.display()))?;
                }
            }
            None => {
                std::fs::write(path, fresh)
                    .with_context(|| format!("initialising cache file {}", path.display()))?;
            }
        }

        let file = OpenOptions::new()
            .append(true)
            .create(true)
            .open(path)
            .with_context(|| format!("opening cache file {} for append", path.display()))?;
        let store = EpochStore {
            path: path.to_path_buf(),
            inner: Mutex::new(StoreInner {
                file,
                known,
                known_points,
                entries,
            }),
        };
        Ok((store, report))
    }

    /// End offset of the record framed at `off`, or `None` if the
    /// frame header, length or checksum is invalid (payload kinds are
    /// validated by `parse_payload`, after the checksum).
    fn record_end(bytes: &[u8], off: usize) -> Option<usize> {
        if off + FRAME_LEN > bytes.len() {
            return None;
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().expect("4-byte slice"));
        if len == 0 || len > MAX_RECORD_LEN {
            return None;
        }
        let start = off + FRAME_LEN;
        let end = start + len as usize;
        if end > bytes.len() {
            return None;
        }
        if fnv1a(&bytes[start..end]) != read_u64(bytes, off + 4) {
            return None;
        }
        Some(end)
    }

    /// Path this store was opened at.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of distinct epoch records held (loaded + absorbed).
    pub fn epochs(&self) -> usize {
        lock(&self.inner).known.len()
    }

    /// Number of distinct sweep-point fingerprints held.
    pub fn points(&self) -> usize {
        lock(&self.inner).known_points.len()
    }

    /// Copy every stored epoch into `cache`, returning how many were
    /// actually inserted (entries already present, or dropped by the
    /// shard capacity limit, do not count). Inserted entries bump the
    /// cache's `hydrated` counter, never its hit/miss counters.
    pub fn hydrate(&self, cache: &EpochCache) -> usize {
        let inner = lock(&self.inner);
        let mut fresh = 0;
        for &(key, result, tiers) in &inner.entries {
            if cache.insert(key, result, tiers) {
                fresh += 1;
            }
        }
        fresh
    }

    /// Append every cache entry not already on disk, returning how many
    /// new records were written. The batch is framed in memory and
    /// written with a single append so concurrent handles interleave
    /// only at batch boundaries.
    pub fn absorb(&self, cache: &EpochCache) -> Result<usize> {
        let snapshot = cache.snapshot_entries();
        let mut inner = lock(&self.inner);
        let mut buf = Vec::new();
        let mut fresh = 0;
        for (key, result, tiers) in snapshot {
            if !inner.known.insert(key) {
                continue;
            }
            frame_into(&mut buf, &epoch_payload(key, result, tiers));
            inner.entries.push((key, result, tiers));
            fresh += 1;
        }
        if !buf.is_empty() {
            inner
                .file
                .write_all(&buf)
                .with_context(|| format!("appending to cache file {}", self.path.display()))?;
        }
        Ok(fresh)
    }

    /// True when `fingerprint` was recorded by a previous sweep run —
    /// i.e. this exact point configuration has been evaluated before
    /// and its epochs are already in the log.
    pub fn known_point(&self, fingerprint: (u64, u64)) -> bool {
        lock(&self.inner).known_points.contains(&fingerprint)
    }

    /// Record a sweep-point fingerprint. Returns `Ok(true)` if it was
    /// new, `Ok(false)` if this handle already knew it (nothing
    /// written).
    pub fn record_point(&self, fingerprint: (u64, u64)) -> Result<bool> {
        let mut inner = lock(&self.inner);
        if !inner.known_points.insert(fingerprint) {
            return Ok(false);
        }
        let mut buf = Vec::new();
        frame_into(&mut buf, &point_payload(fingerprint));
        inner
            .file
            .write_all(&buf)
            .with_context(|| format!("appending to cache file {}", self.path.display()))?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("siam_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{}_{}.siamepc", name, std::process::id()));
        let _ = std::fs::remove_file(&path);
        path
    }

    fn entry(i: u64) -> (EpochKey, EpochResult, TierCounts) {
        (
            EpochKey {
                lo: i.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                hi: !i,
            },
            EpochResult {
                completion_cycles: 100 + i,
                packets: 10 + i,
                total_latency_cycles: 1000 + i,
                flit_hops: 40 + i,
            },
            TierCounts {
                closed_form: i % 2,
                periodic: (i + 1) % 2,
                extrapolated: 0,
                packet_fallback: 0,
            },
        )
    }

    fn populated_store(path: &Path, n: u64) -> EpochCache {
        let cache = EpochCache::default();
        for i in 0..n {
            let (k, r, t) = entry(i);
            assert!(cache.insert(k, r, t));
        }
        let (store, report) = EpochStore::open(path).unwrap();
        assert_eq!(report, LoadReport::default());
        assert_eq!(store.absorb(&cache).unwrap(), n as usize);
        cache
    }

    #[test]
    fn round_trip_is_bit_identical_and_absorb_dedups() {
        let path = tmp("round_trip");
        let cache = populated_store(&path, 8);

        let (store, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.epochs_loaded, 8);
        assert_eq!(report.truncated_bytes, 0);
        assert_eq!(report.duplicate_records, 0);
        assert!(!report.stale_generation);

        let warm = EpochCache::default();
        assert_eq!(store.hydrate(&warm), 8);
        assert_eq!(warm.hydrated(), 8);
        assert_eq!(warm.snapshot_entries(), cache.snapshot_entries());
        // Everything hydrated is already known: nothing new to write.
        assert_eq!(store.absorb(&warm).unwrap(), 0);
        // Hydrating the same cache twice inserts nothing new.
        assert_eq!(store.hydrate(&warm), 0);
    }

    #[test]
    fn torn_tail_is_truncated_to_the_last_valid_record() {
        let path = tmp("torn_tail");
        populated_store(&path, 3);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap(); // cut into the last record
        drop(f);

        let (store, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.epochs_loaded, 2);
        assert_eq!(report.truncated_bytes, (EPOCH_PAYLOAD_LEN + FRAME_LEN - 5) as u64);
        assert_eq!(store.epochs(), 2);
        // The repaired file reloads with nothing left to discard.
        drop(store);
        let (_, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.truncated_bytes, 0);
        assert_eq!(report.epochs_loaded, 2);
    }

    #[test]
    fn flipped_checksum_byte_discards_the_tail_never_reads_garbage() {
        let path = tmp("checksum_flip");
        populated_store(&path, 3);
        let mut bytes = std::fs::read(&path).unwrap();
        // Corrupt one payload byte inside the *second* record.
        let second = HEADER_LEN + (FRAME_LEN + EPOCH_PAYLOAD_LEN) + FRAME_LEN + 20;
        bytes[second] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let (store, report) = EpochStore::open(&path).unwrap();
        // Record 1 survives; records 2 and 3 are gone (loss, not lies).
        assert_eq!(report.epochs_loaded, 1);
        assert_eq!(
            report.truncated_bytes,
            2 * (FRAME_LEN + EPOCH_PAYLOAD_LEN) as u64
        );
        let warm = EpochCache::default();
        assert_eq!(store.hydrate(&warm), 1);
        let (k, r, t) = entry(0);
        assert_eq!(warm.snapshot_entries(), vec![(k, r, t)]);
    }

    #[test]
    fn stale_generation_discards_the_log_and_resets_the_header() {
        let path = tmp("stale_gen");
        populated_store(&path, 4);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[16..24].copy_from_slice(&(EPOCH_STORE_GENERATION + 1).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();

        let (store, report) = EpochStore::open(&path).unwrap();
        assert!(report.stale_generation);
        assert_eq!(report.epochs_loaded, 0);
        assert_eq!(
            report.truncated_bytes,
            4 * (FRAME_LEN + EPOCH_PAYLOAD_LEN) as u64
        );
        assert_eq!(store.epochs(), 0);
        // The reset file is immediately reusable at the new generation.
        drop(store);
        let (_, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report, LoadReport::default());
    }

    #[test]
    fn foreign_or_newer_files_are_hard_errors_and_left_untouched() {
        let path = tmp("foreign");
        std::fs::write(&path, b"definitely not an epoch cache file").unwrap();
        let err = EpochStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        assert_eq!(
            std::fs::read(&path).unwrap(),
            b"definitely not an epoch cache file"
        );

        let mut newer = header_bytes(EPOCH_STORE_GENERATION).to_vec();
        newer[8..12].copy_from_slice(&(EPOCH_STORE_VERSION + 1).to_le_bytes());
        std::fs::write(&path, &newer).unwrap();
        let err = EpochStore::open(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported epoch cache version"), "{err}");
        assert_eq!(std::fs::read(&path).unwrap(), newer);
    }

    #[test]
    fn empty_and_torn_header_files_are_initialised_in_place() {
        let path = tmp("empty");
        std::fs::write(&path, b"").unwrap();
        let (store, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report, LoadReport::default());
        assert_eq!(store.epochs(), 0);
        drop(store);

        // A prefix of a fresh header (torn initial write) re-inits.
        std::fs::write(&path, &header_bytes(EPOCH_STORE_GENERATION)[..10]).unwrap();
        let (_, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.truncated_bytes, 10);
        assert_eq!(report.epochs_loaded, 0);

        // A short file that is NOT a header prefix is a hard error.
        std::fs::write(&path, b"SIAMEPCX").unwrap();
        assert!(EpochStore::open(&path).is_err());
    }

    #[test]
    fn record_length_past_eof_truncates_at_the_frame() {
        let path = tmp("past_eof");
        populated_store(&path, 2);
        // Append a frame whose length claims bytes that do not exist.
        let mut extra = Vec::new();
        extra.extend_from_slice(&200u32.to_le_bytes());
        extra.extend_from_slice(&0u64.to_le_bytes());
        extra.extend_from_slice(&[0xAB; 30]);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&extra).unwrap();
        drop(f);

        let (_, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.epochs_loaded, 2);
        assert_eq!(report.truncated_bytes, extra.len() as u64);
    }

    #[test]
    fn unknown_record_kind_truncates_even_with_a_valid_checksum() {
        let path = tmp("unknown_kind");
        populated_store(&path, 1);
        let payload = [9u8, 1, 2, 3];
        let mut frame = Vec::new();
        frame_into(&mut frame, &payload);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&frame).unwrap();
        drop(f);

        let (_, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.epochs_loaded, 1);
        assert_eq!(report.truncated_bytes, frame.len() as u64);
    }

    #[test]
    fn point_fingerprints_round_trip_and_dedup() {
        let path = tmp("points");
        let (store, _) = EpochStore::open(&path).unwrap();
        assert!(store.record_point((7, 9)).unwrap());
        assert!(!store.record_point((7, 9)).unwrap());
        assert!(store.record_point((8, 0)).unwrap());
        assert!(store.known_point((7, 9)));
        assert!(!store.known_point((1, 1)));
        drop(store);

        let (store, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.points_loaded, 2);
        assert_eq!(report.duplicate_records, 0);
        assert_eq!(store.points(), 2);
        assert!(store.known_point((7, 9)) && store.known_point((8, 0)));
    }

    #[test]
    fn duplicate_records_from_independent_handles_are_counted_once() {
        let path = tmp("dup_handles");
        let cache = EpochCache::default();
        let (k, r, t) = entry(42);
        cache.insert(k, r, t);
        // Two handles on the same path: each has its own known-set, so
        // a point raced by both handles lands in the log twice and the
        // next load counts (and ignores) the duplicate.
        let (a, _) = EpochStore::open(&path).unwrap();
        a.absorb(&cache).unwrap();
        let (b, _) = EpochStore::open(&path).unwrap();
        assert_eq!(b.absorb(&cache).unwrap(), 0); // b loaded it already
        b.record_point((1, 2)).unwrap();
        a.record_point((1, 2)).unwrap(); // a does not know b wrote it
        drop((a, b));

        let (_, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report.epochs_loaded, 1);
        assert_eq!(report.points_loaded, 1);
        assert_eq!(report.duplicate_records, 1);
        assert_eq!(report.truncated_bytes, 0);
    }

    #[test]
    fn missing_file_is_created_with_a_fresh_header() {
        let path = tmp("fresh");
        let (store, report) = EpochStore::open(&path).unwrap();
        assert_eq!(report, LoadReport::default());
        assert_eq!(store.path(), path.as_path());
        assert_eq!(
            std::fs::read(&path).unwrap(),
            header_bytes(EPOCH_STORE_GENERATION)
        );
    }
}
