//! Functional inference mode: run real DNN compute through the
//! AOT-compiled IMC crossbar executables, so the simulator reports not
//! just performance but the *numerical* effect of the crossbar fabric
//! (ADC quantization) on model outputs.

use super::Runtime;
use crate::util::Rng;
use anyhow::Result;

/// He-style synthetic weights in [-1, 1] (clipped), deterministic.
pub fn synth_weights(rng: &mut Rng, shape: &[usize]) -> Vec<f32> {
    let fan_in: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
    let std = (2.0 / fan_in as f64).sqrt();
    (0..shape.iter().product::<usize>())
        .map(|_| (rng.normal() * std).clamp(-1.0, 1.0) as f32)
        .collect()
}

/// Synthetic input batch in [0, 1] — a tiny-CIFAR-like workload.
pub fn synth_images(rng: &mut Rng, batch: usize) -> Vec<f32> {
    (0..batch * 32 * 32 * 3).map(|_| rng.f64() as f32).collect()
}

/// Result of one functional CNN forward.
#[derive(Debug, Clone)]
pub struct FunctionalRun {
    /// Flattened `batch × classes` logits.
    pub logits: Vec<f32>,
    /// Images in the batch.
    pub batch: usize,
    /// Classifier width.
    pub classes: usize,
    /// Flash-ADC resolution the kernel was compiled for.
    pub adc_bits: u8,
    /// Wall-clock of the PJRT execution (the Rust hot path), seconds.
    pub exec_seconds: f64,
}

impl FunctionalRun {
    /// Predicted class per image.
    pub fn argmax(&self) -> Vec<usize> {
        (0..self.batch)
            .map(|b| {
                let row = &self.logits[b * self.classes..(b + 1) * self.classes];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

/// Run the functional CNN (batch 4, CIFAR-shaped) through the crossbar
/// fabric artifact with the given ADC resolution (4 or 8).
pub fn run_cnn(rt: &Runtime, adc_bits: u8, seed: u64) -> Result<FunctionalRun> {
    let name = format!("cnn_fwd_b4_adc{adc_bits}");
    let exe = rt.load(&name)?;
    let batch = exe.info.params[0][0];
    let classes = exe.info.output[1];

    let mut rng = Rng::new(seed);
    let mut inputs = vec![synth_images(&mut rng, batch)];
    for shape in &exe.info.params[1..] {
        inputs.push(synth_weights(&mut rng, shape));
    }

    let t0 = std::time::Instant::now();
    let logits = exe.run_f32(&inputs)?;
    let exec_seconds = t0.elapsed().as_secs_f64();
    Ok(FunctionalRun {
        logits,
        batch,
        classes,
        adc_bits,
        exec_seconds,
    })
}

/// Exact integer GEMM reference (the Rust-side oracle for the lossless
/// 8-bit-ADC crossbar artifact): x (m×k, integer codes) · w (k×n).
pub fn ref_gemm(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let xv = x[i * k + l];
            if xv == 0.0 {
                continue;
            }
            let (row, orow) = (&w[l * n..(l + 1) * n], &mut out[i * n..(i + 1) * n]);
            for (o, &wv) in orow.iter_mut().zip(row) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// Integer test data for the GEMM artifacts (uint8 codes / int8 codes,
/// carried as f32, matching the kernel's contract).
pub fn synth_gemm_inputs(rng: &mut Rng, m: usize, k: usize, n: usize) -> (Vec<f32>, Vec<f32>) {
    let x = (0..m * k).map(|_| rng.below(256) as f32).collect();
    let w = (0..k * n)
        .map(|_| rng.range(0, 255) as f32 - 128.0)
        .collect();
    (x, w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ref_gemm_identity() {
        // 2x2 identity times anything
        let x = vec![1.0, 0.0, 0.0, 1.0];
        let w = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(ref_gemm(&x, &w, 2, 2, 2), w);
    }

    #[test]
    fn synth_weights_bounded() {
        let mut rng = Rng::new(1);
        let w = synth_weights(&mut rng, &[3, 3, 3, 8]);
        assert_eq!(w.len(), 216);
        assert!(w.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn synth_gemm_inputs_in_code_range() {
        let mut rng = Rng::new(2);
        let (x, w) = synth_gemm_inputs(&mut rng, 4, 8, 4);
        assert!(x.iter().all(|&v| (0.0..256.0).contains(&v) && v.fract() == 0.0));
        assert!(w.iter().all(|&v| (-128.0..128.0).contains(&v)));
    }
}
