//! PJRT runtime: load the AOT-compiled Pallas/JAX artifacts
//! (`artifacts/*.hlo.txt`, emitted once by `python/compile/aot.py`) and
//! execute them from Rust. Python is never on this path — the
//! interchange format is HLO *text* (xla_extension 0.5.1 rejects jax's
//! 64-bit-id serialized protos; the text parser reassigns ids).

pub mod functional;

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One entry of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Artifact name (e.g. `xbar_gemm_64x128x64_adc8`).
    pub name: String,
    /// HLO text file name within the artifacts directory.
    pub file: String,
    /// Parameter shapes, in call order.
    pub params: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
    /// Metadata (kind, adc_bits, …) as parsed JSON.
    pub meta: Json,
}

impl ArtifactInfo {
    fn from_json(v: &Json) -> Result<ArtifactInfo> {
        let shape = |j: &Json| -> Result<Vec<usize>> {
            j.as_arr()
                .ok_or_else(|| anyhow!("shape not an array"))?
                .iter()
                .map(|d| {
                    d.as_f64()
                        .map(|f| f as usize)
                        .ok_or_else(|| anyhow!("bad dim"))
                })
                .collect()
        };
        Ok(ArtifactInfo {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .context("manifest entry missing name")?
                .to_string(),
            file: v
                .get("file")
                .and_then(Json::as_str)
                .context("manifest entry missing file")?
                .to_string(),
            params: v
                .get("params")
                .and_then(Json::as_arr)
                .context("missing params")?
                .iter()
                .map(shape)
                .collect::<Result<_>>()?,
            output: shape(v.get("output").context("missing output")?)?,
            meta: v.get("meta").cloned().unwrap_or(Json::Null),
        })
    }

    /// Numeric metadata field, if present.
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(Json::as_f64)
    }
}

/// A compiled artifact ready to execute on the PJRT CPU client.
pub struct Executable {
    /// Manifest entry the executable was loaded from.
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 inputs; shapes are validated against the
    /// manifest. Returns the flattened f32 output.
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        if inputs.len() != self.info.params.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.params.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&self.info.params).enumerate() {
            let elems: usize = shape.iter().product();
            if data.len() != elems {
                bail!(
                    "{}: input {i} has {} elems, shape {:?} needs {elems}",
                    self.info.name,
                    data.len(),
                    shape
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The artifact registry + PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Parsed `manifest.json` entries.
    pub manifest: Vec<ArtifactInfo>,
}

impl Runtime {
    /// Open an artifacts directory (reads `manifest.json`, creates the
    /// PJRT CPU client).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!(
                "reading {:?} — run `make artifacts` first",
                dir.join("manifest.json")
            )
        })?;
        let parsed = json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let manifest = parsed
            .as_arr()
            .context("manifest.json is not an array")?
            .iter()
            .map(ArtifactInfo::from_json)
            .collect::<Result<Vec<_>>>()?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
        })
    }

    /// Default artifacts location relative to the repo root.
    pub fn open_default() -> Result<Runtime> {
        Runtime::open("artifacts")
    }

    /// Manifest entry by artifact name.
    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.manifest.iter().find(|a| a.name == name)
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let info = self
            .find(name)
            .with_context(|| {
                let names: Vec<&str> = self.manifest.iter().map(|a| a.name.as_str()).collect();
                format!("artifact '{name}' not in manifest ({names:?})")
            })?
            .clone();
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable { info, exe })
    }

    /// PJRT platform name (e.g. `cpu`; `stub` in offline builds).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
