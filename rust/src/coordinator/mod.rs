//! The coordinator: SIAM's top-level wrapper, in Rust.
//!
//! [`simulate`] evaluates one configuration through the staged pipeline
//! in [`pipeline`]: partition & mapping first (sequential by necessity),
//! then the circuit, NoC, NoP and DRAM engines concurrently (the paper:
//! "all engines except the partition and mapping engine work
//! simultaneously"), aggregated into a [`SimReport`].
//!
//! For design-space exploration use [`SweepBuilder`]: it evaluates whole
//! grids of `(tiles_per_chiplet, chiplet count)` points on a
//! work-stealing thread pool while sharing the sweep-invariant stage
//! outputs through a [`SweepContext`] — see `ARCHITECTURE.md` at the
//! repository root for the pipeline diagram and which stages are cached
//! versus evaluated per point.

pub mod dse;
pub mod pipeline;
pub mod report;
pub mod sensitivity;

pub use dse::{
    best_by_edap, sweep, sweep_serial, FigureOfMerit, SweepBuilder, SweepPoint, SweepResult,
    SweepStats,
};
pub use pipeline::{attach_meta, run_point_profiled, trace_point, SweepContext};
pub use report::{sweep_json, FailoverReport, ServeReport, SimReport};
pub use sensitivity::{layer_cycles_vs_nop_speedup, layer_latency_vs_chiplets, LayerPoint};

use crate::config::SiamConfig;
use anyhow::Result;

/// Run the full SIAM pipeline for one configuration.
///
/// Builds a fresh [`SweepContext`] and evaluates the single point with
/// the stage-3 engines running concurrently. Sweeping many points this
/// way wastes the shared context — use [`SweepBuilder`] instead.
pub fn simulate(cfg: &SiamConfig) -> Result<SimReport> {
    let ctx = SweepContext::new(cfg)?;
    pipeline::run_point(cfg, &ctx, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipMode, ChipletStructure};

    #[test]
    fn paper_default_simulates() {
        let rep = simulate(&SiamConfig::paper_default()).unwrap();
        assert_eq!(rep.model, "resnet110");
        assert!(rep.num_chiplets > 0);
        assert!(rep.total.energy_pj > 0.0);
        assert!(rep.total.latency_ns > 0.0);
        assert!(rep.total.area_um2 > 0.0);
        assert!(rep.wall_seconds < 120.0);
    }

    #[test]
    fn custom_beats_homogeneous_edap() {
        // Fig. 12a: custom architecture outperforms homogeneous (fewer
        // chiplets => smaller NoP => lower EDAP).
        let custom = simulate(
            &SiamConfig::paper_default().with_chiplet_structure(ChipletStructure::Custom),
        )
        .unwrap();
        let homog = simulate(&SiamConfig::paper_default().with_total_chiplets(64)).unwrap();
        assert!(
            custom.total.edap() < homog.total.edap(),
            "custom {} vs homogeneous {}",
            custom.total.edap(),
            homog.total.edap()
        );
    }

    #[test]
    fn monolithic_has_zero_nop() {
        let rep =
            simulate(&SiamConfig::paper_default().with_chip_mode(ChipMode::Monolithic)).unwrap();
        assert_eq!(rep.nop.energy_pj, 0.0);
        assert_eq!(rep.num_chiplets, 1);
    }

    #[test]
    fn report_json_and_summary_render() {
        let rep = simulate(&SiamConfig::paper_default()).unwrap();
        let s = rep.summary();
        assert!(s.contains("resnet110"));
        assert!(s.contains("EDAP"));
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"model\""));
        crate::util::json::parse(&j).expect("report JSON parses");
    }

    #[test]
    fn imc_dominates_energy_nop_dominates_area() {
        // Fig. 10 shape: energy mostly IMC circuit; area mostly NoP.
        let rep = simulate(&SiamConfig::paper_default()).unwrap();
        assert!(
            rep.circuit.energy_pj > rep.noc.energy_pj,
            "IMC energy {} vs NoC {}",
            rep.circuit.energy_pj,
            rep.noc.energy_pj
        );
        assert!(
            rep.nop.area_um2 > rep.noc.area_um2,
            "NoP area {} vs NoC {}",
            rep.nop.area_um2,
            rep.noc.area_um2
        );
    }
}
