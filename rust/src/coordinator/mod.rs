//! The coordinator: SIAM's top-level wrapper, in Rust. Runs the
//! partition & mapping engine, then the circuit, NoC, NoP and DRAM
//! engines concurrently (the paper: "all engines except the partition
//! and mapping engine work simultaneously"), and aggregates everything
//! into a [`SimReport`].

pub mod dse;
pub mod report;
pub mod sensitivity;

pub use dse::{sweep, SweepPoint};
pub use report::SimReport;
pub use sensitivity::{layer_cycles_vs_nop_speedup, layer_latency_vs_chiplets, LayerPoint};

use crate::circuit::CircuitEstimator;
use crate::config::SiamConfig;
use crate::dnn::build_model;
use crate::mapping::{build_traffic, map_dnn, Placement};
use anyhow::{Context, Result};

/// Run the full SIAM pipeline for one configuration.
pub fn simulate(cfg: &SiamConfig) -> Result<SimReport> {
    let t0 = std::time::Instant::now();
    cfg.validate()?;
    let dnn = build_model(&cfg.dnn.model, &cfg.dnn.dataset)?;

    // ---- Engine 1 (sequential by necessity): partition & mapping
    let map = map_dnn(&dnn, cfg).context("partition & mapping")?;
    let placement = Placement::new(map.num_chiplets);
    let traffic = build_traffic(&dnn, &map, &placement, cfg);

    // ---- Engines 2-4 run concurrently on the mapping outputs
    let stats = dnn.stats();
    let (circuit, noc, nop, dram) = std::thread::scope(|s| {
        let circuit = s.spawn(|| CircuitEstimator::new(cfg).estimate(&dnn, &map, &traffic));
        let noc = s.spawn(|| crate::noc::evaluate(cfg, &traffic, map.num_chiplets));
        let nop = s.spawn(|| crate::nop::evaluate(cfg, &traffic, &placement));
        let dram = s.spawn(|| crate::dram::estimate(&stats, cfg));
        (
            circuit.join().expect("circuit engine"),
            noc.join().expect("noc engine"),
            nop.join().expect("nop engine"),
            dram.join().expect("dram engine"),
        )
    });

    Ok(SimReport::assemble(
        cfg,
        &dnn,
        &map,
        &traffic,
        circuit,
        noc,
        nop,
        dram,
        t0.elapsed().as_secs_f64(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChipMode, ChipletStructure};

    #[test]
    fn paper_default_simulates() {
        let rep = simulate(&SiamConfig::paper_default()).unwrap();
        assert_eq!(rep.model, "resnet110");
        assert!(rep.num_chiplets > 0);
        assert!(rep.total.energy_pj > 0.0);
        assert!(rep.total.latency_ns > 0.0);
        assert!(rep.total.area_um2 > 0.0);
        assert!(rep.wall_seconds < 120.0);
    }

    #[test]
    fn custom_beats_homogeneous_edap() {
        // Fig. 12a: custom architecture outperforms homogeneous (fewer
        // chiplets => smaller NoP => lower EDAP).
        let custom = simulate(
            &SiamConfig::paper_default().with_chiplet_structure(ChipletStructure::Custom),
        )
        .unwrap();
        let homog = simulate(&SiamConfig::paper_default().with_total_chiplets(64)).unwrap();
        assert!(
            custom.total.edap() < homog.total.edap(),
            "custom {} vs homogeneous {}",
            custom.total.edap(),
            homog.total.edap()
        );
    }

    #[test]
    fn monolithic_has_zero_nop() {
        let rep =
            simulate(&SiamConfig::paper_default().with_chip_mode(ChipMode::Monolithic)).unwrap();
        assert_eq!(rep.nop.energy_pj, 0.0);
        assert_eq!(rep.num_chiplets, 1);
    }

    #[test]
    fn report_json_and_summary_render() {
        let rep = simulate(&SiamConfig::paper_default()).unwrap();
        let s = rep.summary();
        assert!(s.contains("resnet110"));
        assert!(s.contains("EDAP"));
        let j = rep.to_json().to_string_pretty();
        assert!(j.contains("\"model\""));
        crate::util::json::parse(&j).expect("report JSON parses");
    }

    #[test]
    fn imc_dominates_energy_nop_dominates_area() {
        // Fig. 10 shape: energy mostly IMC circuit; area mostly NoP.
        let rep = simulate(&SiamConfig::paper_default()).unwrap();
        assert!(
            rep.circuit.energy_pj > rep.noc.energy_pj,
            "IMC energy {} vs NoC {}",
            rep.circuit.energy_pj,
            rep.noc.energy_pj
        );
        assert!(
            rep.nop.area_um2 > rep.noc.area_um2,
            "NoP area {} vs NoC {}",
            rep.nop.area_um2,
            rep.noc.area_um2
        );
    }
}
