//! Design-space exploration: a parallel, memoizing sweep engine over
//! the `tiles/chiplet × chiplet count` axes of the paper's Figs. 9, 11,
//! 12 and 14.
//!
//! [`SweepBuilder`] is the front door: it fixes a point grid, evaluates
//! every point through the staged pipeline (see
//! [`pipeline`](super::pipeline)) and ranks the results by a
//! [`FigureOfMerit`]. Evaluation runs on a work-stealing pool of scoped
//! threads — workers claim grid indices from a shared atomic counter,
//! so a slow point (say VGG-16 at 4 tiles/chiplet) never idles the
//! other cores — while the sweep-invariant stages (DNN graph, per-layer
//! circuit costs, DRAM estimate) and repeated NoC/NoP epochs are shared
//! through one [`SweepContext`].
//!
//! Results are returned **in grid order regardless of completion
//! order**, and every stage cache is keyed by the full set of inputs it
//! reads, so the parallel engine is bit-identical to the serial one
//! (asserted by the regression tests below and measured by
//! `benches/table3_simtime.rs`).
//!
//! Two certified pruned search modes ride on the same engine
//! ([`SearchMode`]): Pareto-front pruning over (latency, energy, area)
//! and successive halving. Both first score the whole grid with a
//! closed-form lower-bound pass ([`run_point_bound`] — no packet
//! simulation, no cache traffic) and only skip candidates the bound
//! rules out, so both provably return the same best point as
//! exhaustion. A sweep can also persist its epoch results across
//! processes through an append-only [`EpochStore`] file (`[sweep]
//! cache_file` / `--cache-file`), hydrating the in-memory cache on the
//! next run and recording per-point config fingerprints for
//! incremental re-sweeps (see `docs/CACHING.md`).

use super::pipeline::{run_point_bound, run_point_profiled, SweepContext};
use super::{ServeReport, SimReport};
use crate::config::{ChipletStructure, SearchMode, ServeMode, SiamConfig};
use crate::noc::{EpochStore, TierCounts};
use crate::obs::{self, Profiler};
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// The `tiles_per_chiplet` coordinate of the point.
    pub tiles_per_chiplet: usize,
    /// None = custom structure (exactly-fitting chiplet count).
    pub total_chiplets: Option<usize>,
    /// Per-class chiplet budgets applied at this point (the
    /// [`SweepBuilder::class_splits`] axis; entries parallel the base
    /// config's class list, `None` = as many as needed). `None` when
    /// the axis is unused.
    pub class_split: Option<Vec<Option<usize>>>,
    /// Per-class square crossbar sizes applied at this point (the
    /// [`SweepBuilder::class_xbars`] axis). `None` when the axis is
    /// unused.
    pub class_xbars: Option<Vec<usize>>,
    /// The full simulation report of the point.
    pub report: SimReport,
    /// Serving run under the QoS target load (populated only by
    /// [`SweepBuilder::qos`] sweeps).
    pub serve: Option<ServeReport>,
}

impl SweepPoint {
    /// Energy-delay-area product of the point (the default ranking key).
    pub fn edap(&self) -> f64 {
        self.report.total.edap()
    }
}

/// Ranking key for sweep results. All variants are "lower is better"
/// after internal sign normalization.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FigureOfMerit {
    /// Energy × delay × area (the paper's Fig. 12 metric).
    #[default]
    Edap,
    /// Energy × delay.
    Edp,
    /// Total inference energy.
    Energy,
    /// Total inference latency.
    Latency,
    /// Total area.
    Area,
    /// Energy efficiency (ranked higher-is-better internally).
    InferencesPerJoule,
    /// QoS mode: p99 latency under the target offered load (set through
    /// [`SweepBuilder::qos`]), in three tiers — points meeting the
    /// `[serve] qos_p99_ms` target rank first, then points missing it,
    /// then points shedding load. Score through
    /// [`FigureOfMerit::score_point`].
    QosP99,
    /// Yield-aware fabrication cost: the Appendix-A normalized cost of
    /// the point's dies (spares included) divided by the probability the
    /// package survives with its required chiplet count
    /// ([`crate::cost::CostModel::yield_adjusted_cost`] at the paper's
    /// default wafer/defect parameters) — the expected fabrication
    /// spend per working system. Extends the `fig13_fabcost` math with
    /// the spare-chiplet survival term; pinned to hand-computed values
    /// by the golden yield tests.
    YieldCost,
    /// Accuracy-floor-constrained EDAP: points whose Monte-Carlo
    /// variation accuracy proxy falls below the `[variation]
    /// accuracy_floor` are pruned (ranked at `+∞`), the survivors rank
    /// by EDAP. Needs a live `[variation]` block on the base config —
    /// points without a variation report are treated as failing the
    /// floor, so an EDAP-optimal but variation-blind point can never
    /// win a variation-aware sweep by accident.
    VariationAware,
}

impl FigureOfMerit {
    /// Scalar score of a report under this figure of merit; lower is
    /// better for every variant. [`FigureOfMerit::QosP99`] needs the
    /// serving run attached to the sweep point — use
    /// [`FigureOfMerit::score_point`]; on a bare report it ranks last.
    pub fn score(&self, report: &SimReport) -> f64 {
        match self {
            FigureOfMerit::Edap => report.total.edap(),
            FigureOfMerit::Edp => report.total.edp(),
            FigureOfMerit::Energy => report.total.energy_pj,
            FigureOfMerit::Latency => report.total.latency_ns,
            FigureOfMerit::Area => report.total.area_um2,
            FigureOfMerit::InferencesPerJoule => -report.inferences_per_joule(),
            FigureOfMerit::QosP99 => f64::INFINITY,
            FigureOfMerit::YieldCost => {
                let spares = report.fault.as_ref().map_or(0, |f| f.spare_chiplets);
                let n = report.num_chiplets.saturating_sub(spares).max(1);
                let per_die_mm2 = report.silicon_area_mm2 / report.num_chiplets.max(1) as f64;
                crate::cost::CostModel::default().yield_adjusted_cost(n, spares, per_die_mm2)
            }
            FigureOfMerit::VariationAware => match &report.variation {
                Some(v) if v.meets_floor => report.total.edap(),
                _ => f64::INFINITY,
            },
        }
    }

    /// Scalar score of a full sweep point; lower is better. For
    /// [`FigureOfMerit::QosP99`] this is the serving run's
    /// [`ServeReport::qos_score_ms`] (p99 ms plus a shedding penalty);
    /// every other variant delegates to [`FigureOfMerit::score`].
    pub fn score_point(&self, point: &SweepPoint) -> f64 {
        match self {
            FigureOfMerit::QosP99 => point
                .serve
                .as_ref()
                .map(|s| s.qos_score_ms())
                .unwrap_or(f64::INFINITY),
            _ => self.score(&point.report),
        }
    }
}

/// Shared-stage cache statistics of one sweep run, read off the
/// [`SweepContext`] after the grid completes. The epoch counters are
/// the headline: they say how much NoC/NoP simulation the flow-level
/// engine actually had to do versus replay.
#[derive(Debug, Clone, Default)]
pub struct SweepStats {
    /// Epoch simulations answered from the shared [`EpochCache`].
    ///
    /// [`EpochCache`]: crate::noc::EpochCache
    pub epoch_hits: u64,
    /// Epoch simulations that had to run an engine.
    pub epoch_misses: u64,
    /// Distinct epochs retained at the end of the sweep.
    pub epochs_cached: usize,
    /// Per-shard `(hits, misses)` of the shared epoch cache, in shard
    /// order.
    pub shards: Vec<(u64, u64)>,
    /// Flow-engine tier tally (closed-form / periodic / extrapolated /
    /// packet-fallback answers) summed over every surviving point's
    /// report — deterministic across thread counts, since cache hits
    /// replay the tier tag recorded at fill time.
    pub tiers: TierCounts,
    /// Host wall-clock of the whole sweep, seconds.
    pub wall_seconds: f64,
    /// Grid points evaluated per second (skipped points included —
    /// they cost a mapping attempt too).
    pub points_per_sec: f64,
    /// Epochs hydrated into the in-memory cache from the persistent
    /// store before evaluation began (0 without a cache file). Warm
    /// replays count as hits, not misses — this field is what tells a
    /// warm run apart from a miraculously lucky cold one.
    pub epochs_hydrated: u64,
    /// Grid points whose config fingerprints were already in the
    /// persistent store, i.e. points a previous run had explored
    /// (0 without a cache file).
    pub points_known: usize,
}

impl SweepStats {
    /// Fraction of epoch lookups answered from the cache (0 when the
    /// sweep simulated no epochs).
    pub fn epoch_hit_rate(&self) -> f64 {
        let total = self.epoch_hits + self.epoch_misses;
        if total == 0 {
            0.0
        } else {
            self.epoch_hits as f64 / total as f64
        }
    }
}

/// Outcome of a sweep: all surviving points in deterministic grid order
/// plus the ranking configuration.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Evaluated points in grid order (tiles-major, counts-minor);
    /// points whose homogeneous architecture could not fit the DNN are
    /// skipped, mirroring Algorithm 1's error path.
    pub points: Vec<SweepPoint>,
    /// Cache statistics of the run (epoch hit/miss counts).
    pub stats: SweepStats,
    fom: FigureOfMerit,
}

impl SweepResult {
    /// Points sorted by the figure of merit, best first. Ties keep grid
    /// order (stable sort), so rankings are deterministic.
    pub fn ranked(&self) -> Vec<&SweepPoint> {
        let mut v: Vec<&SweepPoint> = self.points.iter().collect();
        v.sort_by(|a, b| self.fom.score_point(a).total_cmp(&self.fom.score_point(b)));
        v
    }

    /// The best point under the configured figure of merit.
    pub fn best(&self) -> Option<&SweepPoint> {
        self.ranked().into_iter().next()
    }

    /// Number of surviving points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no point survived (e.g. every architecture overflowed).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Builder for a design-space sweep: point grid, figure of merit,
/// parallelism and early-exit budget.
///
/// # Examples
///
/// ```
/// use siam::config::SiamConfig;
/// use siam::coordinator::{FigureOfMerit, SweepBuilder};
///
/// let base = SiamConfig::paper_default().with_model("lenet5", "cifar10");
/// let result = SweepBuilder::new(&base)
///     .tiles(&[4, 16])
///     .chiplet_counts(&[None]) // custom (exactly-fitting) architecture
///     .figure_of_merit(FigureOfMerit::Edap)
///     .run()
///     .unwrap();
/// assert_eq!(result.len(), 2);
/// let best = result.best().unwrap();
/// assert!(result.points.iter().all(|p| best.edap() <= p.edap()));
/// ```
#[derive(Debug, Clone)]
pub struct SweepBuilder {
    base: SiamConfig,
    tiles: Vec<usize>,
    counts: Vec<Option<usize>>,
    class_splits: Vec<Vec<Option<usize>>>,
    class_xbars: Vec<Vec<usize>>,
    fom: FigureOfMerit,
    search: SearchMode,
    halving_keep: f64,
    threads: Option<usize>,
    budget: Option<usize>,
    qos_qps: Option<f64>,
    profiler: Option<Arc<Profiler>>,
    cache: Option<Arc<EpochStore>>,
}

/// One coordinate of the sweep grid.
#[derive(Debug, Clone)]
struct GridPoint {
    tiles: usize,
    count: Option<usize>,
    split: Option<Vec<Option<usize>>>,
    xbars: Option<Vec<usize>>,
}

impl SweepBuilder {
    /// A sweep over `base` with the paper's default grid: tiles/chiplet
    /// ∈ {4, 9, 16, 25, 36} on the custom (exactly-fitting)
    /// architecture, ranked by EDAP, using all available cores.
    pub fn new(base: &SiamConfig) -> SweepBuilder {
        SweepBuilder {
            base: base.clone(),
            tiles: vec![4, 9, 16, 25, 36],
            counts: vec![None],
            class_splits: Vec::new(),
            class_xbars: Vec::new(),
            fom: FigureOfMerit::default(),
            search: base.sweep.search,
            halving_keep: base.sweep.halving_keep,
            threads: None,
            budget: None,
            qos_qps: None,
            profiler: None,
            cache: None,
        }
    }

    /// Set the tiles-per-chiplet axis of the grid.
    pub fn tiles(mut self, tiles: &[usize]) -> SweepBuilder {
        self.tiles = tiles.to_vec();
        self
    }

    /// Set the chiplet-count axis of the grid; `None` entries evaluate
    /// the custom (exactly-fitting) architecture.
    pub fn chiplet_counts(mut self, counts: &[Option<usize>]) -> SweepBuilder {
        self.counts = counts.to_vec();
        self
    }

    /// Heterogeneous axis: per-class chiplet budgets. Each entry is one
    /// grid coordinate — a vector parallel to the base config's
    /// `[[system.chiplet_class]]` list assigning every class a budget
    /// (`None` = as many as needed). Requires classes on the base
    /// config; combine with `chiplet_counts(&[None])`, since the legacy
    /// total-count axis is superseded by classes.
    pub fn class_splits(mut self, splits: &[Vec<Option<usize>>]) -> SweepBuilder {
        self.class_splits = splits.to_vec();
        self
    }

    /// Heterogeneous axis: per-class square crossbar sizes. Each entry
    /// assigns every base class an `n` meaning an `n × n` crossbar.
    /// Requires classes on the base config.
    pub fn class_xbars(mut self, xbars: &[Vec<usize>]) -> SweepBuilder {
        self.class_xbars = xbars.to_vec();
        self
    }

    /// Set the ranking key (default: EDAP).
    pub fn figure_of_merit(mut self, fom: FigureOfMerit) -> SweepBuilder {
        self.fom = fom;
        self
    }

    /// Select the grid traversal strategy (default: the base config's
    /// `[sweep] search`, itself defaulting to exhaustive). The pruned
    /// modes — [`SearchMode::Pareto`] and [`SearchMode::Halving`] —
    /// push fewer points through the full engines but provably return
    /// the same [`SweepResult::best`] as exhaustion (the certificates
    /// live in `docs/CACHING.md` and the method docs below); only
    /// fully evaluated points appear in [`SweepResult::points`].
    pub fn search(mut self, mode: SearchMode) -> SweepBuilder {
        self.search = mode;
        self
    }

    /// Fraction of cheap-ranked candidates the halving search promotes
    /// to full evaluation in its first round, in (0, 1] (default: the
    /// base config's `[sweep] halving_keep`, itself defaulting to 0.5).
    pub fn halving_keep(mut self, keep: f64) -> SweepBuilder {
        self.halving_keep = keep;
        self
    }

    /// Persist epochs across runs in the append-only cache file at
    /// `path` (created on first use): the sweep hydrates the in-memory
    /// cache from it before evaluating and appends whatever it had to
    /// compute afterwards, alongside every grid point's config
    /// fingerprint (the incremental re-sweep marker).
    pub fn cache_file(mut self, path: &str) -> SweepBuilder {
        self.base.sweep.cache_file = Some(path.to_string());
        self
    }

    /// Share an already-open [`EpochStore`] handle instead of opening
    /// `[sweep] cache_file` — several sweeps (or threads) appending
    /// through one handle interleave at batch granularity and never
    /// record an epoch or point fingerprint twice.
    pub fn cache_store(mut self, store: Arc<EpochStore>) -> SweepBuilder {
        self.cache = Some(store);
        self
    }

    /// Fix the worker count (default: all available cores).
    pub fn threads(mut self, threads: usize) -> SweepBuilder {
        self.threads = Some(threads.max(1));
        self
    }

    /// Force single-threaded evaluation (the reference engine used by
    /// the determinism regression tests).
    pub fn serial(self) -> SweepBuilder {
        self.threads(1)
    }

    /// Early-exit budget: evaluate only the first `budget` grid points
    /// (grid order, so the truncation is deterministic). Useful for
    /// bounding coarse scans of large grids.
    pub fn budget(mut self, budget: usize) -> SweepBuilder {
        self.budget = Some(budget);
        self
    }

    /// Attach a self-profiler: every grid point folds a `sweep:point`
    /// wall-clock span into `prof` (and the staged pipeline adds its
    /// `stage:*` spans). Profiling observes only — results are
    /// bit-identical with and without it (`siam sweep --profile`).
    pub fn profile(mut self, prof: Arc<Profiler>) -> SweepBuilder {
        self.profiler = Some(prof);
        self
    }

    /// Yield-aware mode: rank points by expected fabrication cost per
    /// working system — Appendix-A die cost of the point's chiplets
    /// (spares included) divided by its spare-aware survival
    /// probability ([`FigureOfMerit::YieldCost`]). Bigger chiplets
    /// yield worse per die but need fewer dies; spares on the base
    /// config shift the optimum — this axis finds the break-even.
    pub fn yield_aware(self) -> SweepBuilder {
        self.figure_of_merit(FigureOfMerit::YieldCost)
    }

    /// Variation-aware mode: rank points by EDAP among those whose
    /// Monte-Carlo accuracy proxy meets the `[variation]
    /// accuracy_floor`; points below the floor (or without a variation
    /// report at all) are pruned to `+∞`
    /// ([`FigureOfMerit::VariationAware`]). Requires a live
    /// `[variation]` block on the base config — an inert block yields
    /// no reports, so every point would be pruned.
    pub fn variation_aware(self) -> SweepBuilder {
        self.figure_of_merit(FigureOfMerit::VariationAware)
    }

    /// QoS mode: additionally run the serving simulator on every
    /// surviving point at `target_qps` offered open-loop load (the
    /// `[serve]` block supplies requests / queue depth / seed and the
    /// `qos_p99_ms` latency target) and rank points by p99-under-load
    /// instead of single-shot EDAP — points meeting the target first,
    /// then misses, then shedders. Each point is evaluated once through
    /// the serving stage-graph builder, which yields the single-shot
    /// report alongside the stage service times, so QoS ranking adds
    /// only the event loop per point. `target_qps` must be positive and
    /// finite — [`SweepBuilder::run`] rejects the per-point auto-rate
    /// (0), which would measure every point at a different load.
    pub fn qos(mut self, target_qps: f64) -> SweepBuilder {
        self.qos_qps = Some(target_qps);
        self.fom = FigureOfMerit::QosP99;
        self
    }

    /// The grid in deterministic order — tiles-major, then counts, then
    /// class splits, then class crossbar sizes — truncated to the
    /// budget. Unused class axes contribute a single pass-through
    /// coordinate.
    fn grid(&self) -> Vec<GridPoint> {
        let splits: Vec<Option<Vec<Option<usize>>>> = if self.class_splits.is_empty() {
            vec![None]
        } else {
            self.class_splits.iter().cloned().map(Some).collect()
        };
        let xbars: Vec<Option<Vec<usize>>> = if self.class_xbars.is_empty() {
            vec![None]
        } else {
            self.class_xbars.iter().cloned().map(Some).collect()
        };
        let mut g = Vec::new();
        for &t in &self.tiles {
            for &c in &self.counts {
                for s in &splits {
                    for x in &xbars {
                        g.push(GridPoint {
                            tiles: t,
                            count: c,
                            split: s.clone(),
                            xbars: x.clone(),
                        });
                    }
                }
            }
        }
        if let Some(b) = self.budget {
            g.truncate(b);
        }
        g
    }

    /// Evaluate the sweep and return the surviving points in grid
    /// order.
    ///
    /// Points whose homogeneous architecture cannot fit the DNN are
    /// skipped (Algorithm 1's error path); any other failure aborts the
    /// sweep with the first error in grid order.
    pub fn run(&self) -> Result<SweepResult> {
        if let Some(q) = self.qos_qps {
            // auto-rate (0) would measure every point at a different
            // load, making the p99 ranking incomparable across points
            if !(q > 0.0 && q.is_finite()) {
                anyhow::bail!(
                    "QoS sweeps need a positive finite target_qps, got {q} \
                     (rate 0 = per-point auto-rate, which is not a common target)"
                );
            }
        }
        let nclass = self.base.system.chiplet_classes.len();
        if !self.class_splits.is_empty() || !self.class_xbars.is_empty() {
            if nclass == 0 {
                anyhow::bail!(
                    "class_splits/class_xbars need [[system.chiplet_class]] blocks on the base config"
                );
            }
            if self.counts.iter().any(|c| c.is_some()) {
                anyhow::bail!(
                    "chiplet classes supersede the total-count axis; \
                     use chiplet_counts(&[None]) with class_splits"
                );
            }
            if let Some(bad) = self.class_splits.iter().find(|s| s.len() != nclass) {
                anyhow::bail!(
                    "class split {bad:?} has {} entries but the base config has {nclass} classes",
                    bad.len()
                );
            }
            if let Some(bad) = self.class_xbars.iter().find(|x| x.len() != nclass) {
                anyhow::bail!(
                    "class crossbar set {bad:?} has {} entries but the base config has {nclass} classes",
                    bad.len()
                );
            }
        }
        match self.search {
            SearchMode::Exhaustive => {}
            SearchMode::Halving => {
                if self.fom == FigureOfMerit::QosP99 {
                    anyhow::bail!(
                        "halving search cannot lower-bound serving p99; \
                         QoS sweeps must stay exhaustive"
                    );
                }
                if !(self.halving_keep.is_finite()
                    && self.halving_keep > 0.0
                    && self.halving_keep <= 1.0)
                {
                    anyhow::bail!(
                        "halving_keep must be finite and in (0, 1], got {}",
                        self.halving_keep
                    );
                }
            }
            SearchMode::Pareto => {
                let supported = matches!(
                    self.fom,
                    FigureOfMerit::Edap
                        | FigureOfMerit::Edp
                        | FigureOfMerit::Energy
                        | FigureOfMerit::Latency
                        | FigureOfMerit::Area
                        | FigureOfMerit::InferencesPerJoule
                );
                if !supported {
                    anyhow::bail!(
                        "pareto search prunes on the (latency, energy, area) axes and \
                         supports only figures of merit monotone in them; \
                         {:?} is not — use exhaustive search",
                        self.fom
                    );
                }
            }
        }
        let t0 = std::time::Instant::now();
        let grid = self.grid();
        let ctx = SweepContext::new(&self.base)?;
        let store = match (&self.cache, &self.base.sweep.cache_file) {
            (Some(s), _) => Some(s.clone()),
            (None, Some(path)) => {
                let (s, loaded) = EpochStore::open(path)?;
                obs::log::verbose(&format!(
                    "sweep: cache {path}: {} epoch(s), {} point(s) loaded",
                    loaded.epochs_loaded, loaded.points_loaded
                ));
                Some(Arc::new(s))
            }
            (None, None) => None,
        };
        if let Some(s) = &store {
            s.hydrate(ctx.epoch_cache());
        }
        let threads = self
            .threads
            .unwrap_or_else(default_threads)
            .min(grid.len().max(1));
        let prof = self.profiler.as_deref();
        obs::log::verbose(&format!(
            "sweep: {} grid point(s) on {threads} thread(s), {:?} search",
            grid.len(),
            self.search
        ));

        let indexed = match self.search {
            SearchMode::Exhaustive => {
                let all: Vec<usize> = (0..grid.len()).collect();
                self.eval_indices(&grid, &all, &ctx, threads, prof)?
            }
            SearchMode::Halving => self.run_halving(&grid, &ctx, threads, prof)?,
            SearchMode::Pareto => self.run_pareto(&grid, &ctx, threads, prof)?,
        };
        let points: Vec<SweepPoint> = indexed.into_iter().map(|(_, p)| p).collect();

        let mut points_known = 0usize;
        if let Some(s) = &store {
            s.absorb(ctx.epoch_cache())?;
            for gp in &grid {
                // the [sweep] block never changes a point's result, so
                // strip it before fingerprinting: switching search mode
                // or cache path must not un-know explored points
                let mut pc = point_config(&self.base, gp);
                pc.sweep = Default::default();
                if !s.record_point(crate::obs::meta::point_fingerprint(&pc))? {
                    points_known += 1;
                }
            }
        }
        let mut stats = stats_of(&ctx, &points, grid.len(), t0);
        stats.points_known = points_known;
        Ok(SweepResult {
            stats,
            points,
            fom: self.fom,
        })
    }

    /// Fully evaluate the grid points at `which` (ascending grid
    /// indices) and return the survivors tagged with their grid index.
    /// `threads <= 1` is the in-order serial reference path; otherwise
    /// a work-stealing pool claims indices from a shared counter and
    /// results land in index order no matter who finishes when.
    fn eval_indices(
        &self,
        grid: &[GridPoint],
        which: &[usize],
        ctx: &SweepContext,
        threads: usize,
        prof: Option<&Profiler>,
    ) -> Result<Vec<(usize, SweepPoint)>> {
        let threads = threads.min(which.len().max(1));
        if threads <= 1 {
            let mut points = Vec::with_capacity(which.len());
            for &gi in which {
                if let Some(p) = eval_point(&self.base, ctx, &grid[gi], self.qos_qps, prof)? {
                    points.push((gi, p));
                }
            }
            return Ok(points);
        }
        let outcomes = pooled(threads, which.len(), |i| {
            eval_point(&self.base, ctx, &grid[which[i]], self.qos_qps, prof)
        });
        let mut points = Vec::with_capacity(which.len());
        for (j, outcome) in outcomes.into_iter().enumerate() {
            match outcome {
                Ok(Some(p)) => points.push((which[j], p)),
                Ok(None) => {} // skipped: architecture too small
                Err(e) => return Err(e),
            }
        }
        Ok(points)
    }

    /// The cheap closed-form pass over the whole grid
    /// ([`run_point_bound`]): one lower-bound report per grid index,
    /// `None` where the architecture cannot fit the DNN — the same skip
    /// path full evaluation takes, so pruned searches and exhaustion
    /// always agree on which points exist.
    fn cheap_pass(
        &self,
        grid: &[GridPoint],
        ctx: &SweepContext,
        threads: usize,
        prof: Option<&Profiler>,
    ) -> Result<Vec<Option<SimReport>>> {
        let outcomes = pooled(threads.min(grid.len().max(1)), grid.len(), |i| {
            let cfg = point_config(&self.base, &grid[i]);
            let run = || run_point_bound(&cfg, ctx);
            let outcome = match prof {
                Some(p) => p.time("sweep:bound", run),
                None => run(),
            };
            match outcome {
                Ok(r) => Ok(Some(r)),
                Err(e) if is_too_small(&e) => Ok(None),
                Err(e) => Err(e),
            }
        });
        outcomes.into_iter().collect()
    }

    /// Successive halving with a certificate. Round one ranks every
    /// feasible point by its cheap lower-bound score and fully
    /// evaluates the best `halving_keep` fraction; round two fully
    /// evaluates every remaining point whose bound does not exceed the
    /// best full score seen. The exhaustive argmin's bound never
    /// exceeds its true score, and its true score never exceeds the
    /// best evaluated one — so it is always promoted, and
    /// [`SweepResult::best`] equals exhaustion's (ties included: the
    /// threshold is non-strict, and ranking tie-breaks stay in grid
    /// order because results merge back in grid order).
    fn run_halving(
        &self,
        grid: &[GridPoint],
        ctx: &SweepContext,
        threads: usize,
        prof: Option<&Profiler>,
    ) -> Result<Vec<(usize, SweepPoint)>> {
        let cheap = self.cheap_pass(grid, ctx, threads, prof)?;
        let mut order: Vec<(f64, usize)> = cheap
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|r| (self.fom.score(r), i)))
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        if order.is_empty() {
            return Ok(Vec::new());
        }
        let k = ((order.len() as f64 * self.halving_keep).ceil() as usize).clamp(1, order.len());
        let mut promoted: Vec<usize> = order[..k].iter().map(|&(_, i)| i).collect();
        promoted.sort_unstable();
        let mut points = self.eval_indices(grid, &promoted, ctx, threads, prof)?;
        let best = points
            .iter()
            .map(|(_, p)| self.fom.score_point(p))
            .fold(f64::INFINITY, f64::min);
        let mut second: Vec<usize> = order[k..]
            .iter()
            .filter(|&&(bound, _)| bound <= best)
            .map(|&(_, i)| i)
            .collect();
        second.sort_unstable();
        obs::log::verbose(&format!(
            "sweep: halving promoted {k} + {} of {} candidate(s)",
            second.len(),
            order.len()
        ));
        points.extend(self.eval_indices(grid, &second, ctx, threads, prof)?);
        points.sort_by_key(|&(i, _)| i);
        Ok(points)
    }

    /// Pareto-front pruning with a certificate. Fully evaluate every
    /// point on the cheap-pass (latency, energy, area) front, then
    /// discard a remaining point only when an evaluated point's *true*
    /// vector strictly dominates its cheap lower-bound vector in all
    /// three axes: the bound sits below the truth componentwise, so the
    /// discarded point is strictly dominated for real, and every
    /// supported figure of merit strictly improves under all-axis
    /// domination — no discarded point can tie or beat the evaluated
    /// best. Everything not discarded is fully evaluated too.
    fn run_pareto(
        &self,
        grid: &[GridPoint],
        ctx: &SweepContext,
        threads: usize,
        prof: Option<&Profiler>,
    ) -> Result<Vec<(usize, SweepPoint)>> {
        let cheap = self.cheap_pass(grid, ctx, threads, prof)?;
        let bounds: Vec<Option<[f64; 3]>> =
            cheap.iter().map(|r| r.as_ref().map(pareto_axes)).collect();
        let feasible: Vec<usize> = (0..grid.len()).filter(|&i| bounds[i].is_some()).collect();
        // the cheap front: feasible points not strictly dominated in
        // all three axes by another cheap vector (equal vectors never
        // dominate each other, so exact ties all stay)
        let front: Vec<usize> = feasible
            .iter()
            .copied()
            .filter(|&i| {
                let b = bounds[i].unwrap();
                !feasible
                    .iter()
                    .any(|&j| j != i && dominates(bounds[j].unwrap(), b))
            })
            .collect();
        let mut points = self.eval_indices(grid, &front, ctx, threads, prof)?;
        let truths: Vec<[f64; 3]> =
            points.iter().map(|(_, p)| pareto_axes(&p.report)).collect();
        let mut on_front = vec![false; grid.len()];
        for &i in &front {
            on_front[i] = true;
        }
        let rest: Vec<usize> = feasible
            .iter()
            .copied()
            .filter(|&i| !on_front[i])
            .filter(|&i| {
                let b = bounds[i].unwrap();
                !truths.iter().any(|&t| dominates(t, b))
            })
            .collect();
        obs::log::verbose(&format!(
            "sweep: pareto evaluated {} front + {} undominated of {} candidate(s)",
            front.len(),
            rest.len(),
            feasible.len()
        ));
        points.extend(self.eval_indices(grid, &rest, ctx, threads, prof)?);
        points.sort_by_key(|&(i, _)| i);
        Ok(points)
    }
}

/// Read the shared-stage cache counters off a finished sweep's context
/// and fold in the per-point engine-tier tallies and the run's host
/// wall-clock.
fn stats_of(
    ctx: &SweepContext,
    points: &[SweepPoint],
    attempted: usize,
    t0: std::time::Instant,
) -> SweepStats {
    let cache = ctx.epoch_cache();
    let mut tiers = TierCounts::default();
    for p in points {
        tiers.accumulate(&p.report.engine_tiers);
    }
    let wall_seconds = t0.elapsed().as_secs_f64();
    SweepStats {
        epoch_hits: cache.hits(),
        epoch_misses: cache.misses(),
        epochs_cached: cache.len(),
        epochs_hydrated: cache.hydrated(),
        points_known: 0,
        shards: cache.shard_stats(),
        tiers,
        wall_seconds,
        points_per_sec: if wall_seconds > 0.0 {
            attempted as f64 / wall_seconds
        } else {
            0.0
        },
    }
}

/// Worker threads used when the caller does not fix a count.
fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f` over `0..n` on a work-stealing pool and return the results
/// in index order. Workers claim the next index from a shared counter
/// and write into that index's slot, so the output order is
/// independent of scheduling — the serial/parallel bit-identity of
/// every search mode rests on this.
fn pooled<T: Send, F: Fn(usize) -> T + Sync>(threads: usize, n: usize, f: F) -> Vec<T> {
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap()
                .expect("every index is claimed by a worker")
        })
        .collect()
}

/// The configuration a grid point denotes: the base config with the
/// point's tile count, chiplet budget, class split, and crossbar sizes
/// applied. Both the cheap bound pass and full evaluation derive their
/// config here, so they can never disagree about what a point means.
fn point_config(base: &SiamConfig, gp: &GridPoint) -> SiamConfig {
    let mut cfg = match gp.count {
        Some(c) => base
            .clone()
            .with_tiles_per_chiplet(gp.tiles)
            .with_total_chiplets(c),
        None => base
            .clone()
            .with_tiles_per_chiplet(gp.tiles)
            .with_chiplet_structure(ChipletStructure::Custom),
    };
    if let Some(split) = &gp.split {
        for (class, budget) in cfg.system.chiplet_classes.iter_mut().zip(split) {
            class.count = *budget;
        }
    }
    if let Some(xbars) = &gp.xbars {
        for (class, &n) in cfg.system.chiplet_classes.iter_mut().zip(xbars) {
            class.xbar_rows = n;
            class.xbar_cols = n;
        }
    }
    cfg
}

/// Whether `e` is the "architecture cannot fit the DNN" mapping error
/// — the one sweep skip path (Algorithm 1's error path).
fn is_too_small(e: &anyhow::Error) -> bool {
    e.downcast_ref::<crate::mapping::MappingError>()
        .is_some_and(|m| matches!(m, crate::mapping::MappingError::ExceedsChiplets { .. }))
}

/// The three pruning axes of pareto search, in a fixed order:
/// end-to-end latency (ns), total energy (pJ), total area (um^2).
fn pareto_axes(report: &SimReport) -> [f64; 3] {
    [
        report.total.latency_ns,
        report.total.energy_pj,
        report.total.area_um2,
    ]
}

/// Strict all-axis Pareto domination: `a` beats `b` in *every*
/// coordinate. Deliberately strict — equal vectors never dominate each
/// other, so exact ties survive pruning and dominance-based discards
/// can never drop a point tied with the best.
fn dominates(a: [f64; 3], b: [f64; 3]) -> bool {
    a.iter().zip(b.iter()).all(|(x, y)| x < y)
}

/// Evaluate one grid point; `Ok(None)` means the point is skipped
/// because the architecture cannot fit the DNN (homogeneous overflow or
/// an infeasible class split). With a QoS target the point is evaluated
/// once through the serving stage-graph builder — which yields both the
/// single-shot report and the stage service times (replaying epochs
/// through the shared cache) — and the serving run is attached.
fn eval_point(
    base: &SiamConfig,
    ctx: &SweepContext,
    gp: &GridPoint,
    qos_qps: Option<f64>,
    prof: Option<&Profiler>,
) -> Result<Option<SweepPoint>> {
    let (tiles, count) = (gp.tiles, gp.count);
    let cfg = point_config(base, gp);
    let evaluate = || match qos_qps {
        None => run_point_profiled(&cfg, ctx, false, prof).map(|report| (report, None)),
        Some(qps) => {
            let mut scfg = cfg.clone();
            scfg.serve.mode = ServeMode::Open;
            scfg.serve.rate_qps = qps;
            crate::serve::StageGraph::build(&scfg, ctx).map(|graph| {
                let serve = crate::serve::run_graph(&graph, &scfg.serve);
                (graph.single_shot, Some(serve))
            })
        }
    };
    let outcome = match prof {
        Some(p) => p.time("sweep:point", evaluate),
        None => evaluate(),
    };
    obs::log::verbose(&format!("sweep: point tiles={tiles} chiplets={count:?} evaluated"));
    match outcome {
        Ok((report, serve)) => Ok(Some(SweepPoint {
            tiles_per_chiplet: tiles,
            total_chiplets: count,
            class_split: gp.split.clone(),
            class_xbars: gp.xbars.clone(),
            report,
            serve,
        })),
        // homogeneous architecture too small: skip the point
        // (Algorithm 1's error path)
        Err(e) if is_too_small(&e) => Ok(None),
        Err(e) => Err(e),
    }
}

/// Sweep the chiplet design space on all available cores. Points that
/// do not fit (homogeneous overflow) are skipped, mirroring Algorithm
/// 1's error path. Kept as the stable functional entry point; the
/// builder exposes the full engine.
pub fn sweep(
    base: &SiamConfig,
    tiles_options: &[usize],
    chiplet_counts: &[Option<usize>],
) -> Result<Vec<SweepPoint>> {
    Ok(SweepBuilder::new(base)
        .tiles(tiles_options)
        .chiplet_counts(chiplet_counts)
        .run()?
        .points)
}

/// [`sweep`] on a single thread — the reference engine the parallel
/// path is validated against (and the "before" side of the
/// `table3_simtime` speedup measurement).
pub fn sweep_serial(
    base: &SiamConfig,
    tiles_options: &[usize],
    chiplet_counts: &[Option<usize>],
) -> Result<Vec<SweepPoint>> {
    Ok(SweepBuilder::new(base)
        .tiles(tiles_options)
        .chiplet_counts(chiplet_counts)
        .serial()
        .run()?
        .points)
}

/// The EDAP-optimal point of a sweep.
pub fn best_by_edap(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points
        .iter()
        .min_by(|a, b| a.edap().partial_cmp(&b.edap()).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::tests::assert_reports_identical;

    #[test]
    fn sweep_skips_too_small_architectures() {
        let base = SiamConfig::paper_default(); // resnet110
        let pts = sweep(&base, &[16], &[Some(1), None]).unwrap();
        // 1 homogeneous chiplet cannot fit ResNet-110 => skipped;
        // the custom point always exists.
        assert_eq!(pts.len(), 1);
        assert!(pts[0].total_chiplets.is_none());
    }

    #[test]
    fn best_point_exists() {
        let base = SiamConfig::paper_default();
        let pts = sweep(&base, &[9, 16], &[None]).unwrap();
        assert_eq!(pts.len(), 2);
        let best = best_by_edap(&pts).unwrap();
        assert!(best.edap() <= pts[0].edap());
    }

    #[test]
    fn parallel_sweep_matches_serial_rankings() {
        // The headline regression: on the paper-default grid the
        // parallel engine must return byte-identical points, in the
        // same order, as the serial reference.
        let base = SiamConfig::paper_default();
        let tiles = [4, 9, 16];
        let counts = [Some(36), None];
        let serial = sweep_serial(&base, &tiles, &counts).unwrap();
        let parallel = sweep(&base, &tiles, &counts).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.tiles_per_chiplet, p.tiles_per_chiplet);
            assert_eq!(s.total_chiplets, p.total_chiplets);
            assert_reports_identical(&s.report, &p.report);
        }
        // identical rankings, not just identical sets
        let key = |pts: &[SweepPoint]| -> Vec<(usize, Option<usize>, u64)> {
            let r = SweepResult {
                points: pts.to_vec(),
                stats: SweepStats::default(),
                fom: FigureOfMerit::Edap,
            };
            r.ranked()
                .iter()
                .map(|p| (p.tiles_per_chiplet, p.total_chiplets, p.edap().to_bits()))
                .collect()
        };
        assert_eq!(key(&serial), key(&parallel));
    }

    #[test]
    fn sweep_reports_cache_stats() {
        let base = SiamConfig::paper_default();
        let res = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .run()
            .unwrap();
        let s = res.stats;
        assert!(s.epoch_misses > 0, "a cold sweep must simulate something");
        assert!(s.epochs_cached > 0);
        assert!((0.0..=1.0).contains(&s.epoch_hit_rate()));
        assert!(
            s.epochs_cached <= s.epoch_misses as usize,
            "cannot retain more epochs than were simulated"
        );
        // the new observability fields ride along
        let shard_hits: u64 = s.shards.iter().map(|&(h, _)| h).sum();
        let shard_misses: u64 = s.shards.iter().map(|&(_, m)| m).sum();
        assert_eq!(shard_hits, s.epoch_hits);
        assert_eq!(shard_misses, s.epoch_misses);
        assert!(s.tiers.total() > 0, "mesh epochs must tally engine tiers");
        assert!(s.wall_seconds > 0.0);
        assert!(s.points_per_sec > 0.0);
    }

    #[test]
    fn profiled_sweep_is_bit_identical_and_records_spans() {
        let base = SiamConfig::paper_default();
        let prof = Arc::new(Profiler::new());
        let profiled = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .profile(prof.clone())
            .run()
            .unwrap();
        let plain = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .run()
            .unwrap();
        assert_eq!(profiled.len(), plain.len());
        for (a, b) in profiled.points.iter().zip(&plain.points) {
            assert_reports_identical(&a.report, &b.report);
        }
        let snap = prof.snapshot();
        let labels: Vec<&str> = snap.iter().map(|(l, _)| l.as_str()).collect();
        assert!(labels.contains(&"sweep:point"));
        assert!(labels.contains(&"stage:noc"), "pipeline spans fold in: {labels:?}");
        let point = snap.iter().find(|(l, _)| l == "sweep:point").unwrap();
        assert_eq!(point.1.calls, 2, "one span per grid point");
    }

    #[test]
    fn builder_budget_truncates_grid_deterministically() {
        let base = SiamConfig::paper_default();
        let full = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .run()
            .unwrap();
        let capped = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .budget(1)
            .run()
            .unwrap();
        assert_eq!(full.len(), 2);
        assert_eq!(capped.len(), 1);
        assert_eq!(
            capped.points[0].tiles_per_chiplet,
            full.points[0].tiles_per_chiplet
        );
    }

    #[test]
    fn qos_sweep_attaches_serving_runs_and_ranks_by_p99() {
        let mut base = SiamConfig::paper_default();
        base.serve.requests = 96;
        // well below any point's bottleneck rate: nothing sheds
        let res = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .qos(1000.0)
            .run()
            .unwrap();
        assert_eq!(res.len(), 2);
        for p in &res.points {
            let s = p.serve.as_ref().expect("QoS sweep attaches serving runs");
            assert_eq!(s.mode, "open");
            assert_eq!(s.offered_qps, 1000.0);
            assert!(s.p99_ms > 0.0);
            // the [serve] qos_p99_ms target rides along into the ranking
            assert_eq!(s.qos_p99_target_ms, base.serve.qos_p99_ms);
        }
        let ranked = res.ranked();
        let fom = FigureOfMerit::QosP99;
        for w in ranked.windows(2) {
            assert!(fom.score_point(w[0]) <= fom.score_point(w[1]));
        }
        // EDAP sweeps leave the serving slot empty
        let plain = SweepBuilder::new(&base).tiles(&[9]).run().unwrap();
        assert!(plain.points[0].serve.is_none());
        // a per-point auto-rate target is rejected up front
        let err = SweepBuilder::new(&base).tiles(&[9]).qos(0.0).run();
        assert!(err.is_err(), "qos(0.0) must be rejected");
    }

    #[test]
    fn qos_sweep_parallel_matches_serial_bitwise() {
        // the serve engine is deterministic and every stage cache is
        // keyed by its full input set, so QoS sweeps are bit-identical
        // across thread counts
        let mut base = SiamConfig::paper_default();
        base.serve.requests = 96;
        let builder = SweepBuilder::new(&base)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .qos(1000.0);
        let serial = builder.clone().serial().run().unwrap();
        let parallel = builder.run().unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.points.iter().zip(&parallel.points) {
            let (a, b) = (s.serve.as_ref().unwrap(), p.serve.as_ref().unwrap());
            assert_eq!(a.p50_ms.to_bits(), b.p50_ms.to_bits());
            assert_eq!(a.p95_ms.to_bits(), b.p95_ms.to_bits());
            assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits());
            assert_eq!(a.throughput_qps.to_bits(), b.throughput_qps.to_bits());
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
        }
    }

    fn big_little_base() -> SiamConfig {
        use crate::config::{ChipletClassConfig, MemCell};
        let base = SiamConfig::paper_default();
        let big = ChipletClassConfig::from_base(&base, "big");
        let mut little = ChipletClassConfig::from_base(&base, "little");
        little.cell = MemCell::Sram;
        little.xbar_rows = 64;
        little.xbar_cols = 64;
        little.adc_bits = 3;
        little.nop_ebit_pj = 0.3;
        base.with_chiplet_classes(vec![big, little])
    }

    #[test]
    fn class_axis_sweep_parallel_matches_serial_bitwise() {
        // the new heterogeneous axes must keep the engine's headline
        // property: bit-identical results at any thread count
        let builder = SweepBuilder::new(&big_little_base())
            .tiles(&[16])
            .chiplet_counts(&[None])
            .class_splits(&[
                vec![None, None],
                vec![None, Some(2)],
                vec![Some(4), Some(2)],
            ])
            .class_xbars(&[vec![128, 64], vec![128, 32]]);
        let serial = builder.clone().serial().run().unwrap();
        let parallel = builder.run().unwrap();
        assert_eq!(serial.len(), parallel.len());
        assert!(!serial.is_empty(), "class grid must produce points");
        for (s, p) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(s.class_split, p.class_split);
            assert_eq!(s.class_xbars, p.class_xbars);
            assert_reports_identical(&s.report, &p.report);
        }
        // the class coordinates ride into the points
        assert!(serial.points.iter().all(|p| p.class_split.is_some()
            && p.class_xbars.is_some()
            && p.report.chiplets_per_class.len() == 2));
    }

    #[test]
    fn class_axes_validated_up_front() {
        // class axes without classes on the base config
        let err = SweepBuilder::new(&SiamConfig::paper_default())
            .class_splits(&[vec![None]])
            .run();
        assert!(err.is_err());
        // length mismatch against the base class list
        let err = SweepBuilder::new(&big_little_base())
            .class_splits(&[vec![None]])
            .run();
        assert!(err.is_err());
        // the superseded total-count axis cannot combine with splits
        let err = SweepBuilder::new(&big_little_base())
            .chiplet_counts(&[Some(36)])
            .class_splits(&[vec![None, None]])
            .run();
        assert!(err.is_err());
    }

    #[test]
    fn figure_of_merit_ranking_is_sorted() {
        let base = SiamConfig::paper_default();
        for fom in [
            FigureOfMerit::Edap,
            FigureOfMerit::Energy,
            FigureOfMerit::Latency,
            FigureOfMerit::InferencesPerJoule,
            FigureOfMerit::YieldCost,
        ] {
            let res = SweepBuilder::new(&base)
                .tiles(&[9, 16, 25])
                .chiplet_counts(&[None])
                .figure_of_merit(fom)
                .run()
                .unwrap();
            let ranked = res.ranked();
            assert_eq!(ranked.len(), 3);
            for w in ranked.windows(2) {
                assert!(fom.score(&w[0].report) <= fom.score(&w[1].report));
            }
            assert_eq!(
                res.best().unwrap().tiles_per_chiplet,
                ranked[0].tiles_per_chiplet
            );
        }
    }

    #[test]
    fn variation_aware_sweep_prunes_points_below_the_accuracy_floor() {
        let mut base = SiamConfig::paper_default();
        base.variation.sigma_program = 0.05;
        base.variation.drift_nu = 0.02;
        base.variation.drift_time_s = 1.0e4;
        base.variation.mc_samples = 16;
        // a floor every noisy point clears: the sweep reduces to EDAP
        base.variation.accuracy_floor = 0.0;
        let res = SweepBuilder::new(&base)
            .tiles(&[9, 16, 25])
            .chiplet_counts(&[None])
            .variation_aware()
            .run()
            .unwrap();
        assert_eq!(res.fom, FigureOfMerit::VariationAware);
        assert_eq!(res.len(), 3);
        for p in &res.points {
            let v = p.report.variation.as_ref().expect("noisy sweep attaches variation");
            assert!(v.meets_floor);
            let score = FigureOfMerit::VariationAware.score(&p.report);
            assert_eq!(score.to_bits(), p.report.total.edap().to_bits());
        }
        let best = res.best().unwrap();
        assert_eq!(
            best.tiles_per_chiplet,
            best_by_edap(&res.points).unwrap().tiles_per_chiplet,
            "with every point above the floor, variation-aware = EDAP"
        );
        // a floor no noisy point can clear prunes the whole grid to +∞
        let mut strict = base.clone();
        strict.variation.accuracy_floor = 1.0;
        let res = SweepBuilder::new(&strict)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .variation_aware()
            .run()
            .unwrap();
        for p in &res.points {
            assert!(!p.report.variation.as_ref().unwrap().meets_floor);
            assert_eq!(FigureOfMerit::VariationAware.score(&p.report), f64::INFINITY);
        }
        // variation-blind points (no [variation] block) never win
        let blind = crate::coordinator::simulate(&SiamConfig::paper_default()).unwrap();
        assert!(blind.variation.is_none());
        assert_eq!(FigureOfMerit::VariationAware.score(&blind), f64::INFINITY);
    }

    #[test]
    fn yield_aware_sweep_scores_match_the_cost_model() {
        // the YieldCost axis must reproduce the Appendix-A
        // yield_adjusted_cost math exactly — same CostModel::default()
        // the fig13_fabcost example uses
        let base = SiamConfig::paper_default();
        let res = SweepBuilder::new(&base)
            .tiles(&[9, 16, 25])
            .chiplet_counts(&[None])
            .yield_aware()
            .run()
            .unwrap();
        assert_eq!(res.fom, FigureOfMerit::YieldCost);
        let m = crate::cost::CostModel::default();
        for p in &res.points {
            let r = &p.report;
            let spares = r.fault.as_ref().map_or(0, |f| f.spare_chiplets);
            let n = r.num_chiplets.saturating_sub(spares).max(1);
            let per_die = r.silicon_area_mm2 / r.num_chiplets.max(1) as f64;
            let want = m.yield_adjusted_cost(n, spares, per_die);
            let got = FigureOfMerit::YieldCost.score(r);
            assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");
            assert!(got.is_finite() && got > 0.0);
        }
        // spares on the base config shift every point's score upward
        // (same required dies + extra silicon) while survival rises
        let spared = base.clone().with_spare_chiplets(2);
        let res2 = SweepBuilder::new(&spared)
            .tiles(&[16])
            .chiplet_counts(&[None])
            .yield_aware()
            .run()
            .unwrap();
        let r2 = &res2.points[0].report;
        let f = r2.fault.as_ref().expect("spares attach a fault report");
        assert_eq!(f.spare_chiplets, 2);
        assert!(!f.remapped, "no injected faults: spares stay idle");
        let n2 = r2.num_chiplets - 2;
        let per_die2 = r2.silicon_area_mm2 / r2.num_chiplets as f64;
        let s_with = m.system_survival(n2, 2, per_die2);
        let s_without = m.system_survival(n2, 0, per_die2);
        assert!(s_with > s_without, "{s_with} vs {s_without}");
    }

    /// Figures of merit both pruned search modes support.
    const PRUNABLE: [FigureOfMerit; 6] = [
        FigureOfMerit::Edap,
        FigureOfMerit::Edp,
        FigureOfMerit::Energy,
        FigureOfMerit::Latency,
        FigureOfMerit::Area,
        FigureOfMerit::InferencesPerJoule,
    ];

    #[test]
    fn pruned_searches_match_the_exhaustive_argmax() {
        // the certificate in practice: on the paper-default grid both
        // pruned modes must return exhaustion's best point, bit for
        // bit, for every figure of merit they support
        let base = SiamConfig::paper_default();
        let tiles = [4, 9, 16, 25, 36];
        let exhaustive = SweepBuilder::new(&base)
            .tiles(&tiles)
            .chiplet_counts(&[None])
            .run()
            .unwrap();
        assert_eq!(exhaustive.len(), tiles.len());
        for fom in PRUNABLE {
            let want = SweepResult {
                points: exhaustive.points.clone(),
                stats: SweepStats::default(),
                fom,
            };
            let want = want.best().unwrap();
            for mode in [SearchMode::Pareto, SearchMode::Halving] {
                let got = SweepBuilder::new(&base)
                    .tiles(&tiles)
                    .chiplet_counts(&[None])
                    .figure_of_merit(fom)
                    .search(mode)
                    .run()
                    .unwrap();
                assert!(
                    !got.points.is_empty() && got.len() <= tiles.len(),
                    "{mode:?} must return a non-empty subset"
                );
                let best = got.best().unwrap();
                assert_eq!(best.tiles_per_chiplet, want.tiles_per_chiplet, "{fom:?} {mode:?}");
                assert_reports_identical(&best.report, &want.report);
            }
        }
        // halving additionally covers YieldCost (cheap score is exact)
        let want = SweepResult {
            points: exhaustive.points.clone(),
            stats: SweepStats::default(),
            fom: FigureOfMerit::YieldCost,
        };
        let halved = SweepBuilder::new(&base)
            .tiles(&tiles)
            .chiplet_counts(&[None])
            .figure_of_merit(FigureOfMerit::YieldCost)
            .search(SearchMode::Halving)
            .run()
            .unwrap();
        assert_eq!(
            halved.best().unwrap().tiles_per_chiplet,
            want.best().unwrap().tiles_per_chiplet
        );
    }

    #[test]
    fn pruned_searches_are_bit_identical_serial_vs_parallel() {
        // pruning decisions depend only on deterministic bound scores,
        // so thread count must not change which points survive or what
        // they contain
        let base = SiamConfig::paper_default();
        for mode in [SearchMode::Pareto, SearchMode::Halving] {
            let builder = SweepBuilder::new(&base)
                .tiles(&[4, 9, 16])
                .chiplet_counts(&[None])
                .search(mode);
            let serial = builder.clone().serial().run().unwrap();
            let parallel = builder.run().unwrap();
            assert_eq!(serial.len(), parallel.len(), "{mode:?}");
            for (s, p) in serial.points.iter().zip(&parallel.points) {
                assert_eq!(s.tiles_per_chiplet, p.tiles_per_chiplet);
                assert_reports_identical(&s.report, &p.report);
            }
        }
    }

    #[test]
    fn pruned_searches_reject_unsupported_figures_of_merit() {
        let base = SiamConfig::paper_default();
        // pareto prunes on (latency, energy, area); anything else errs
        for fom in [
            FigureOfMerit::YieldCost,
            FigureOfMerit::VariationAware,
            FigureOfMerit::QosP99,
        ] {
            let err = SweepBuilder::new(&base)
                .tiles(&[9])
                .figure_of_merit(fom)
                .search(SearchMode::Pareto)
                .run();
            assert!(err.is_err(), "pareto must reject {fom:?}");
        }
        // halving cannot lower-bound serving percentiles
        let err = SweepBuilder::new(&base)
            .tiles(&[9])
            .qos(100.0)
            .search(SearchMode::Halving)
            .run();
        assert!(err.is_err(), "halving must reject QoS sweeps");
        // and its keep fraction must be a real fraction
        let err = SweepBuilder::new(&base)
            .tiles(&[9])
            .search(SearchMode::Halving)
            .halving_keep(0.0)
            .run();
        assert!(err.is_err(), "halving_keep(0.0) must be rejected");
    }

    #[test]
    fn cheap_bounds_sit_below_every_supported_score() {
        // the soundness invariant both certificates rest on: the
        // closed-form pass never scores a point above its true score,
        // on any supported figure of merit, and its pareto axes sit
        // componentwise at or below the truth
        let base = SiamConfig::paper_default();
        let b = SweepBuilder::new(&base).tiles(&[4, 9, 16, 25]).chiplet_counts(&[None]);
        let grid = b.grid();
        let ctx = SweepContext::new(&base).unwrap();
        let cheap = b.cheap_pass(&grid, &ctx, 1, None).unwrap();
        let full = sweep_serial(&base, &[4, 9, 16, 25], &[None]).unwrap();
        assert_eq!(cheap.len(), full.len());
        for (bound, point) in cheap.iter().zip(&full) {
            let bound = bound.as_ref().expect("every paper-default point fits");
            let truth = &point.report;
            for fom in PRUNABLE {
                let (lb, s) = (fom.score(bound), fom.score(truth));
                assert!(lb <= s, "{fom:?}: bound {lb} above true score {s}");
            }
            let (lb, t) = (pareto_axes(bound), pareto_axes(truth));
            for k in 0..3 {
                assert!(lb[k] <= t[k], "axis {k}: {} above {}", lb[k], t[k]);
            }
            // yield cost ignores timing, so the bound is exact
            let fom = FigureOfMerit::YieldCost;
            assert_eq!(fom.score(bound).to_bits(), fom.score(truth).to_bits());
        }
    }

    #[test]
    fn a_persistent_cache_file_makes_the_second_sweep_warm() {
        let dir = std::env::temp_dir().join("siam_dse_cache_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("warm_{}.cache", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let path = path.to_str().unwrap().to_string();
        let base = SiamConfig::paper_default();
        let run = || {
            SweepBuilder::new(&base)
                .tiles(&[9, 16])
                .chiplet_counts(&[None])
                .cache_file(&path)
                .run()
                .unwrap()
        };
        let cold = run();
        assert_eq!(cold.stats.epochs_hydrated, 0, "nothing to hydrate cold");
        assert_eq!(cold.stats.points_known, 0, "no point is known cold");
        assert!(cold.stats.epoch_misses > 0, "a cold sweep simulates");
        let warm = run();
        assert!(warm.stats.epochs_hydrated > 0, "warm runs hydrate from disk");
        assert_eq!(warm.stats.points_known, 2, "both points were recorded");
        assert_eq!(warm.stats.epoch_misses, 0, "a warm sweep only replays");
        assert!(warm.stats.epoch_hits > 0);
        // and warmth never changes results
        assert_eq!(cold.len(), warm.len());
        for (c, w) in cold.points.iter().zip(&warm.points) {
            assert_reports_identical(&c.report, &w.report);
        }
        let _ = std::fs::remove_file(&path);
    }
}
