//! Design-space exploration driver: sweep tiles/chiplet × chiplet count
//! (the paper's Figs. 9, 11, 12, 14 axes) and rank by a figure of merit.

use super::{simulate, SimReport};
use crate::config::{ChipletStructure, SiamConfig};
use anyhow::Result;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub tiles_per_chiplet: usize,
    /// None = custom structure (exactly-fitting chiplet count).
    pub total_chiplets: Option<usize>,
    pub report: SimReport,
}

impl SweepPoint {
    pub fn edap(&self) -> f64 {
        self.report.total.edap()
    }
}

/// Sweep the chiplet design space. Points that do not fit (homogeneous
/// overflow) are skipped, mirroring Algorithm 1's error path.
pub fn sweep(
    base: &SiamConfig,
    tiles_options: &[usize],
    chiplet_counts: &[Option<usize>],
) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &tiles in tiles_options {
        for &count in chiplet_counts {
            let cfg = match count {
                Some(c) => base.clone().with_tiles_per_chiplet(tiles).with_total_chiplets(c),
                None => base
                    .clone()
                    .with_tiles_per_chiplet(tiles)
                    .with_chiplet_structure(ChipletStructure::Custom),
            };
            match simulate(&cfg) {
                Ok(report) => out.push(SweepPoint {
                    tiles_per_chiplet: tiles,
                    total_chiplets: count,
                    report,
                }),
                // homogeneous architecture too small: skip the point
                // (Algorithm 1's error path)
                Err(e)
                    if e
                        .downcast_ref::<crate::mapping::MappingError>()
                        .is_some_and(|m| {
                            matches!(m, crate::mapping::MappingError::ExceedsChiplets { .. })
                        }) =>
                {
                    continue
                }
                Err(e) => return Err(e),
            }
        }
    }
    Ok(out)
}

/// The EDAP-optimal point of a sweep.
pub fn best_by_edap(points: &[SweepPoint]) -> Option<&SweepPoint> {
    points
        .iter()
        .min_by(|a, b| a.edap().partial_cmp(&b.edap()).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_skips_too_small_architectures() {
        let base = SiamConfig::paper_default(); // resnet110
        let pts = sweep(&base, &[16], &[Some(1), None]).unwrap();
        // 1 homogeneous chiplet cannot fit ResNet-110 => skipped;
        // the custom point always exists.
        assert_eq!(pts.len(), 1);
        assert!(pts[0].total_chiplets.is_none());
    }

    #[test]
    fn best_point_exists() {
        let base = SiamConfig::paper_default();
        let pts = sweep(&base, &[9, 16], &[None]).unwrap();
        assert_eq!(pts.len(), 2);
        let best = best_by_edap(&pts).unwrap();
        assert!(best.edap() <= pts[0].edap());
    }
}
