//! The staged per-point simulation pipeline and the sweep-invariant
//! [`SweepContext`].
//!
//! One SIAM evaluation decomposes into stages (see `ARCHITECTURE.md`
//! for the full diagram):
//!
//! ```text
//! DNN graph build ──► partition & mapping ──► { circuit, NoC, NoP, DRAM } ──► metrics
//!   (cached)              (per point)          (cached)(keyed)(keyed)(cached)    (per point)
//! ```
//!
//! * **Sweep-invariant stages** — the DNN graph/stats, the per-layer
//!   circuit compute costs, and the DRAM weight-load estimate do not
//!   depend on the `(tiles_per_chiplet, chiplet count)` axes the
//!   design-space sweep varies, so they are computed once and shared
//!   through an immutable [`SweepContext`].
//! * **Keyed stages** — NoC/NoP epoch simulations repeat across
//!   neighbouring points whenever the trace coincides; the flow-level
//!   engine ([`crate::noc::FlowSim`]) answers them through the sharded
//!   [`crate::noc::EpochCache`], keyed by 128-bit trace fingerprints
//!   over canonicalized (order-independent) flow traces.
//! * **Per-point stages** — partition & mapping (Algorithm 1), traffic
//!   generation (Algorithm 2) and metric assembly genuinely differ per
//!   point and always run.
//!
//! Every cache is keyed by the complete set of configuration fields its
//! stage reads, so [`run_point`] returns bit-identical results whether
//! a context is shared across a sweep or built fresh per call.

use crate::circuit::{CircuitEstimator, CircuitReport, LayerCostCache};
use crate::config::{ChipMode, PlacementPolicy, SiamConfig};
use crate::coordinator::report::SimReport;
use crate::fault::FaultReport;
use crate::dnn::{resolve_model, Dnn, DnnStats};
use crate::dram::DramReport;
use crate::mapping::{build_traffic, map_dnn, MappingResult, Placement, Traffic, TrafficMatrix};
use crate::noc::{EpochCache, EpochObs, NocReport};
use crate::nop::NopReport;
use crate::obs::{CacheSnapshot, Profiler, RunMeta, TraceBuffer};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Immutable bundle of sweep-invariant stage outputs plus the shared
/// caches, safe to share across worker threads (`&SweepContext: Send`).
///
/// Build one per sweep (or per single simulation) from the base
/// configuration; every [`run_point`] evaluated against it reuses:
///
/// * the DNN layer graph and its aggregate statistics,
/// * per-layer circuit compute costs ([`LayerCostCache`]),
/// * DRAM weight-load estimates (keyed by model size + DRAM config),
/// * NoC/NoP epoch results ([`EpochCache`]).
pub struct SweepContext {
    dnn: Arc<Dnn>,
    stats: DnnStats,
    model: String,
    dataset: String,
    layer_costs: LayerCostCache,
    epoch_cache: EpochCache,
    dram_cache: Mutex<HashMap<DramKey, DramReport>>,
}

/// Everything `dram::estimate` reads: model size and the DRAM block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct DramKey {
    model_bytes: usize,
    kind: crate::config::DramKind,
    bus_bits: usize,
    subset_fraction_bits: u64,
}

impl DramKey {
    fn of(cfg: &SiamConfig, model_bytes: usize) -> DramKey {
        DramKey {
            model_bytes,
            kind: cfg.dram.kind,
            bus_bits: cfg.dram.bus_bits,
            subset_fraction_bits: cfg.dram.subset_fraction.to_bits(),
        }
    }
}

impl SweepContext {
    /// Build the context for `base`: constructs the DNN graph once
    /// (resolving `file:` models through the network-file frontend) and
    /// initializes the shared (empty) stage caches.
    pub fn new(base: &SiamConfig) -> Result<SweepContext> {
        let dnn = Arc::new(resolve_model(&base.dnn.model, &base.dnn.dataset)?);
        let stats = dnn.stats();
        Ok(SweepContext {
            dnn,
            stats,
            model: base.dnn.model.clone(),
            dataset: base.dnn.dataset.clone(),
            layer_costs: LayerCostCache::new(),
            epoch_cache: EpochCache::new(),
            dram_cache: Mutex::new(HashMap::new()),
        })
    }

    /// The prebuilt DNN layer graph.
    pub fn dnn(&self) -> &Dnn {
        &self.dnn
    }

    /// Aggregate statistics of the prebuilt DNN.
    pub fn stats(&self) -> DnnStats {
        self.stats
    }

    /// The shared NoC/NoP epoch cache (hit/miss counters included).
    pub fn epoch_cache(&self) -> &EpochCache {
        &self.epoch_cache
    }

    /// The shared per-layer circuit-cost cache.
    pub fn layer_costs(&self) -> &LayerCostCache {
        &self.layer_costs
    }

    fn matches_model(&self, cfg: &SiamConfig) -> bool {
        cfg.dnn.model == self.model && cfg.dnn.dataset == self.dataset
    }
}

/// Stage 1: the DNN layer graph — reused from the context when the
/// model/dataset match, rebuilt otherwise (correctness guard for callers
/// that mutate the workload between points). `file:` models resolve
/// through the network-file frontend.
pub(crate) fn stage_dnn(cfg: &SiamConfig, ctx: &SweepContext) -> Result<Arc<Dnn>> {
    if ctx.matches_model(cfg) {
        Ok(ctx.dnn.clone())
    } else {
        Ok(Arc::new(resolve_model(&cfg.dnn.model, &cfg.dnn.dataset)?))
    }
}

/// Stage 2 (always per point): partition & mapping (Algorithm 1 or the
/// class-aware packer), interposer placement, and Algorithm-2 traffic
/// generation. With `placement = "dataflow"` the row-major placement
/// used to generate traffic is then re-embedded against the actual
/// inter-chiplet flow weights — node ids are stable across embeddings,
/// so the traffic stays valid and only NoP distances change.
///
/// With `[fault]` injection or `[system] spare_chiplets` configured the
/// fault-aware mapping path runs instead ([`crate::fault`]) and the
/// returned [`FaultReport`] is `Some`; the fault-free default goes
/// through the exact pre-fault code path (bit-identity regression-pinned
/// in `tests/integration.rs`).
pub(crate) fn stage_mapping(
    cfg: &SiamConfig,
    dnn: &Dnn,
) -> Result<(MappingResult, Placement, Traffic, Option<FaultReport>)> {
    let (map, fault) = if cfg.system.spare_chiplets == 0 && cfg.fault.is_none() {
        (map_dnn(dnn, cfg).context("partition & mapping")?, None)
    } else {
        let (m, r) = crate::fault::map_dnn_with_faults(dnn, cfg)
            .context("partition & mapping under faults")?;
        (m, Some(r))
    };
    let mut placement = Placement::new(map.num_chiplets);
    let traffic = build_traffic(dnn, &map, &placement, cfg);
    if cfg.system.placement == PlacementPolicy::Dataflow
        && cfg.system.chip_mode == ChipMode::Chiplet
    {
        let weights = TrafficMatrix::from_nop_traffic(&traffic, placement.nodes());
        placement = Placement::dataflow(map.num_chiplets, &weights);
    }
    Ok((map, placement, traffic, fault))
}

/// Stage 3a: circuit estimation, sharing per-layer compute costs
/// through the context.
pub(crate) fn stage_circuit(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    dnn: &Dnn,
    map: &MappingResult,
    traffic: &Traffic,
) -> CircuitReport {
    CircuitEstimator::new(cfg).estimate_cached(dnn, map, traffic, Some(&ctx.layer_costs))
}

/// Stage 3b: intra-chiplet NoC simulation — the flow-level epoch engine
/// ([`crate::noc::FlowSim`]) through the shared sharded epoch cache,
/// class-aware (each chiplet's epochs run on its class's mesh/clock).
pub(crate) fn stage_noc(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    traffic: &Traffic,
    map: &MappingResult,
) -> NocReport {
    crate::noc::evaluate_mapped(cfg, traffic, map, Some(&ctx.epoch_cache))
}

/// Stage 3c: inter-chiplet NoP simulation — the flow-level epoch engine
/// over the interposer mesh, through the shared sharded epoch cache,
/// with per-class TX/RX driver macros.
pub(crate) fn stage_nop(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    traffic: &Traffic,
    placement: &Placement,
    map: &MappingResult,
) -> NopReport {
    crate::nop::evaluate_mapped(cfg, traffic, placement, map, Some(&ctx.epoch_cache))
}

/// Stage 3d: DRAM weight-load estimation, memoized on (model bytes,
/// DRAM config) — invariant across the whole sweep grid.
pub(crate) fn stage_dram(cfg: &SiamConfig, ctx: &SweepContext, stats: &DnnStats) -> DramReport {
    let bytes = stats.model_bytes(cfg.dnn.weight_precision);
    let key = DramKey::of(cfg, bytes);
    if let Some(r) = ctx.dram_cache.lock().unwrap().get(&key) {
        return *r;
    }
    let r = crate::dram::estimate_with(bytes, &cfg.dram);
    ctx.dram_cache.lock().unwrap().insert(key, r);
    r
}

/// Run the full staged pipeline for one design point against a context.
///
/// With `concurrent_engines` the four stage-3 engines run on scoped
/// threads (the paper: "all engines except the partition and mapping
/// engine work simultaneously") — right for one-off simulations. Sweep
/// workers pass `false` since the sweep executor already saturates the
/// cores with whole points. Both modes produce identical reports.
pub fn run_point(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    concurrent_engines: bool,
) -> Result<SimReport> {
    run_point_profiled(cfg, ctx, concurrent_engines, None)
}

/// Run `f`, attributing its wall-clock to `label` when a profiler is
/// attached. With `None` this is a plain call — profiling observes
/// only, so profiled and unprofiled runs are bit-identical.
fn timed<R>(prof: Option<&Profiler>, label: &str, f: impl FnOnce() -> R) -> R {
    match prof {
        Some(p) => p.time(label, f),
        None => f(),
    }
}

/// [`run_point`] with optional self-profiling: each pipeline stage's
/// host wall-clock is folded into `prof` under a `stage:*` label
/// (`stage:dnn`, `stage:mapping`, `stage:circuit`, `stage:noc`,
/// `stage:nop`, `stage:dram`, `stage:variation`). The profiler is a
/// pure observer; reports are bit-identical to [`run_point`]'s. With
/// `concurrent_engines` the stage-3 spans overlap in wall time — the
/// table reports per-stage attribution, not the critical path.
pub fn run_point_profiled(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    concurrent_engines: bool,
    prof: Option<&Profiler>,
) -> Result<SimReport> {
    let t0 = std::time::Instant::now();
    cfg.validate()?;
    let dnn = timed(prof, "stage:dnn", || stage_dnn(cfg, ctx))?;
    let stats = if ctx.matches_model(cfg) {
        ctx.stats
    } else {
        dnn.stats()
    };

    let (map, placement, traffic, fault) =
        timed(prof, "stage:mapping", || stage_mapping(cfg, &dnn))?;

    let (circuit, noc, nop, dram) = if concurrent_engines {
        std::thread::scope(|s| {
            let circuit = s.spawn(|| {
                timed(prof, "stage:circuit", || stage_circuit(cfg, ctx, &dnn, &map, &traffic))
            });
            let noc = s.spawn(|| timed(prof, "stage:noc", || stage_noc(cfg, ctx, &traffic, &map)));
            let nop = s.spawn(|| {
                timed(prof, "stage:nop", || stage_nop(cfg, ctx, &traffic, &placement, &map))
            });
            let dram = s.spawn(|| timed(prof, "stage:dram", || stage_dram(cfg, ctx, &stats)));
            (
                circuit.join().expect("circuit engine"),
                noc.join().expect("noc engine"),
                nop.join().expect("nop engine"),
                dram.join().expect("dram engine"),
            )
        })
    } else {
        (
            timed(prof, "stage:circuit", || stage_circuit(cfg, ctx, &dnn, &map, &traffic)),
            timed(prof, "stage:noc", || stage_noc(cfg, ctx, &traffic, &map)),
            timed(prof, "stage:nop", || stage_nop(cfg, ctx, &traffic, &placement, &map)),
            timed(prof, "stage:dram", || stage_dram(cfg, ctx, &stats)),
        )
    };

    // the analog variation model consumes the circuit outputs before
    // assembly moves them; the variation-free default skips this path
    // entirely (zero-variation bit-identity, pinned in tests)
    let variation = if cfg.variation.is_none() {
        None
    } else {
        Some(timed(prof, "stage:variation", || {
            crate::variation::evaluate(cfg, &map, imc_energy(&circuit))
        }))
    };
    Ok(assemble_point(cfg, &dnn, &map, &traffic, circuit, noc, nop, dram, fault, variation, t0))
}

/// Cheap closed-form-tier evaluation of one design point — the scoring
/// pass behind the pruned search modes of [`crate::coordinator::dse`]
/// (`SearchMode::Pareto` / `SearchMode::Halving`).
///
/// Identical staging to [`run_point`] (validation, mapping, circuit,
/// DRAM, fault and variation handling, metric assembly) except that the
/// NoC/NoP engines are replaced by their analytic bound evaluators
/// ([`crate::noc::evaluate_mapped_bound`] /
/// [`crate::nop::evaluate_mapped_bound`]). Every epoch-independent
/// figure — engine energies, areas, leakage powers, packet and
/// flit-hop counts — is **bit-identical** to the full pipeline, while
/// every latency/cycle figure (and anything derived from latency, such
/// as leakage *energy* inside the totals) is a provable lower bound.
/// Nothing touches the shared epoch cache and no engine tiers are
/// counted, so cheap passes never perturb full evaluations.
pub fn run_point_bound(cfg: &SiamConfig, ctx: &SweepContext) -> Result<SimReport> {
    let t0 = std::time::Instant::now();
    cfg.validate()?;
    let dnn = stage_dnn(cfg, ctx)?;
    let stats = if ctx.matches_model(cfg) {
        ctx.stats
    } else {
        dnn.stats()
    };
    let (map, placement, traffic, fault) = stage_mapping(cfg, &dnn)?;
    let circuit = stage_circuit(cfg, ctx, &dnn, &map, &traffic);
    let noc = crate::noc::evaluate_mapped_bound(cfg, &traffic, &map);
    let nop = crate::nop::evaluate_mapped_bound(cfg, &traffic, &placement, &map);
    let dram = stage_dram(cfg, ctx, &stats);
    let variation = if cfg.variation.is_none() {
        None
    } else {
        Some(crate::variation::evaluate(cfg, &map, imc_energy(&circuit)))
    };
    Ok(assemble_point(cfg, &dnn, &map, &traffic, circuit, noc, nop, dram, fault, variation, t0))
}

/// Shared tail of [`run_point_profiled`] and [`trace_point`]: fold the
/// engine outputs into a [`SimReport`] and attach the fault / variation
/// outcomes — identical float operations in identical order on both
/// paths, so traced runs stay bit-identical to untraced ones.
#[allow(clippy::too_many_arguments)]
fn assemble_point(
    cfg: &SiamConfig,
    dnn: &Dnn,
    map: &MappingResult,
    traffic: &Traffic,
    circuit: CircuitReport,
    noc: NocReport,
    nop: NopReport,
    dram: DramReport,
    fault: Option<FaultReport>,
    variation: Option<crate::variation::VariationReport>,
    t0: std::time::Instant,
) -> SimReport {
    let mut report = SimReport::assemble(
        cfg,
        dnn,
        map,
        traffic,
        circuit,
        noc,
        nop,
        dram,
        t0.elapsed().as_secs_f64(),
    );
    report.fault = fault;
    if let Some(v) = variation {
        report.circuit.energy_pj += v.read_energy_delta_pj;
        report.total.energy_pj += v.read_energy_delta_pj;
        report.variation = Some(v);
    }
    report
}

/// Attach the provenance `meta` block to a finished simulation report:
/// config fingerprint, seeds, model source, the context's epoch-cache
/// snapshot, and the report's own engine-tier tally and wall-clock.
/// The CLI calls this after [`run_point`] / [`trace_point`]; library
/// callers that don't need provenance can skip it.
pub fn attach_meta(cfg: &SiamConfig, ctx: &SweepContext, report: &mut SimReport) {
    let mut meta = RunMeta::for_config(cfg);
    meta.model_source = report.model_source.clone();
    meta.wall_seconds = report.wall_seconds;
    meta.epoch_cache = Some(CacheSnapshot::capture(ctx.epoch_cache()));
    meta.engine_tiers = Some(report.engine_tiers);
    report.meta = Some(meta);
}

/// Process id of the simulation timeline in exported traces (the serve
/// engine uses pid 1).
const TRACE_PID_SIM: u32 = 2;

/// [`run_point`] with the layer-by-layer dataflow rendered into a
/// Chrome trace — the entry point behind `siam simulate --trace`.
///
/// The trace is in **simulated** time: per layer, the compute / NoC /
/// NoP phases serialize (the paper's Algorithm-4 dataflow), drawn as
/// `"X"` spans on three threads of one `simulate` process, and every
/// interconnect epoch lands as an `"i"` instant (cache hit or miss,
/// with its tier tally) at its layer's phase start. Engines run on the
/// serial path through the shared epoch cache, so the report is
/// bit-identical to [`run_point`]'s — regression-pinned by the
/// observability tests. The `meta` block is attached.
pub fn trace_point(
    cfg: &SiamConfig,
    ctx: &SweepContext,
    trace: &mut TraceBuffer,
) -> Result<SimReport> {
    let t0 = std::time::Instant::now();
    cfg.validate()?;
    let dnn = stage_dnn(cfg, ctx)?;
    let stats = if ctx.matches_model(cfg) {
        ctx.stats
    } else {
        dnn.stats()
    };
    let (map, placement, traffic, fault) = stage_mapping(cfg, &dnn)?;

    let circuit = stage_circuit(cfg, ctx, &dnn, &map, &traffic);
    let mut noc_obs: Vec<EpochObs> = Vec::new();
    let noc = {
        let mut rec = |o: &EpochObs| noc_obs.push(*o);
        let cache = Some(ctx.epoch_cache());
        crate::noc::evaluate_mapped_obs(cfg, &traffic, &map, cache, Some(&mut rec))
    };
    let mut nop_obs: Vec<EpochObs> = Vec::new();
    let nop = {
        let mut rec = |o: &EpochObs| nop_obs.push(*o);
        crate::nop::evaluate_mapped_obs(
            cfg,
            &traffic,
            &placement,
            &map,
            Some(ctx.epoch_cache()),
            Some(&mut rec),
        )
    };
    let dram = stage_dram(cfg, ctx, &stats);

    render_sim_trace(trace, &circuit, &noc, &nop, &dram, &noc_obs, &nop_obs);

    let variation = if cfg.variation.is_none() {
        None
    } else {
        Some(crate::variation::evaluate(cfg, &map, imc_energy(&circuit)))
    };
    let mut report =
        assemble_point(cfg, &dnn, &map, &traffic, circuit, noc, nop, dram, fault, variation, t0);
    attach_meta(cfg, ctx, &mut report);
    Ok(report)
}

/// Render one inference's layer-serial timeline into `trace`: named
/// process/thread tracks, the whole-inference span, per-layer compute /
/// NoC / NoP phase spans, the per-epoch instants, and the off-inference
/// DRAM weight load as a marker at t = 0.
fn render_sim_trace(
    trace: &mut TraceBuffer,
    circuit: &CircuitReport,
    noc: &NocReport,
    nop: &NopReport,
    dram: &DramReport,
    noc_obs: &[EpochObs],
    nop_obs: &[EpochObs],
) {
    trace.process_name(TRACE_PID_SIM, "simulate");
    trace.thread_name(TRACE_PID_SIM, 0, "inference");
    trace.thread_name(TRACE_PID_SIM, 1, "compute");
    trace.thread_name(TRACE_PID_SIM, 2, "noc");
    trace.thread_name(TRACE_PID_SIM, 3, "nop");

    let noc_ns: HashMap<usize, f64> = noc.per_layer_ns.iter().copied().collect();
    let nop_clk_ns = 1.0e3 / nop.eff_freq_mhz;
    let nop_ns: HashMap<usize, f64> = nop
        .per_layer_cycles
        .iter()
        .map(|&(l, c)| (l, c as f64 * nop_clk_ns))
        .collect();

    // layer-serial cursor: compute, then NoC, then NoP per layer
    let mut t = 0.0f64;
    let mut noc_start: HashMap<usize, f64> = HashMap::new();
    let mut nop_start: HashMap<usize, f64> = HashMap::new();
    for (li, lc) in circuit.per_layer.iter().enumerate() {
        let name = format!("layer {li} compute");
        trace.complete(&name, t, lc.latency_ns, TRACE_PID_SIM, 1, Json::Null);
        t += lc.latency_ns;
        let n = noc_ns.get(&li).copied().unwrap_or(0.0);
        if n > 0.0 {
            trace.complete(&format!("layer {li} noc"), t, n, TRACE_PID_SIM, 2, Json::Null);
        }
        noc_start.insert(li, t);
        t += n;
        let p = nop_ns.get(&li).copied().unwrap_or(0.0);
        if p > 0.0 {
            trace.complete(&format!("layer {li} nop"), t, p, TRACE_PID_SIM, 3, Json::Null);
        }
        nop_start.insert(li, t);
        t += p;
    }
    trace.complete("inference", 0.0, t, TRACE_PID_SIM, 0, Json::Null);

    for (tid, starts, obs) in [(2u32, &noc_start, noc_obs), (3u32, &nop_start, nop_obs)] {
        for o in obs {
            let ts = starts.get(&o.layer).copied().unwrap_or(0.0);
            let mut args = Json::obj();
            args.set("layer", o.layer).set("tiers", o.tiers.to_json());
            match o.chiplet {
                Some(c) => args.set("chiplet", c),
                None => args.set("chiplet", Json::Null),
            };
            let name = if o.hit { "epoch hit" } else { "epoch miss" };
            trace.instant(name, ts, TRACE_PID_SIM, tid, args);
        }
    }

    let mut dargs = Json::obj();
    dargs
        .set("latency_ns", dram.latency_ns)
        .set("energy_pj", dram.energy_pj)
        .set("requests", dram.requests);
    trace.instant("dram weight load (off-inference)", 0.0, TRACE_PID_SIM, 0, dargs);
}

/// The IMC compute (read) energy of a circuit report — the base the
/// variation model's read-current perturbation scales.
pub(crate) fn imc_energy(circuit: &CircuitReport) -> f64 {
    circuit
        .energy_breakdown
        .get("imc_compute")
        .map_or(0.0, |m| m.energy_pj)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::SiamConfig;

    /// Compare two reports on every deterministic field, bit-for-bit.
    pub(crate) fn assert_reports_identical(a: &SimReport, b: &SimReport) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.num_chiplets, b.num_chiplets);
        assert_eq!(a.num_chiplets_required, b.num_chiplets_required);
        assert_eq!(a.total_tiles, b.total_tiles);
        assert_eq!(a.noc_cycles, b.noc_cycles);
        assert_eq!(a.nop_cycles, b.nop_cycles);
        assert_eq!(a.accumulator_adds, b.accumulator_adds);
        for (x, y) in [
            (a.total.area_um2, b.total.area_um2),
            (a.total.energy_pj, b.total.energy_pj),
            (a.total.latency_ns, b.total.latency_ns),
            (a.total.leakage_uw, b.total.leakage_uw),
            (a.circuit.energy_pj, b.circuit.energy_pj),
            (a.noc.energy_pj, b.noc.energy_pj),
            (a.nop.energy_pj, b.nop.energy_pj),
            (a.dram.energy_pj, b.dram.energy_pj),
            (a.dram.latency_ns, b.dram.latency_ns),
            (a.xbar_utilization, b.xbar_utilization),
            (a.silicon_area_mm2, b.silicon_area_mm2),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
        }
    }

    #[test]
    fn shared_context_matches_fresh_context() {
        let base = SiamConfig::paper_default();
        let shared = SweepContext::new(&base).unwrap();
        for tiles in [9, 16] {
            let cfg = base.clone().with_tiles_per_chiplet(tiles);
            let warm = run_point(&cfg, &shared, false).unwrap();
            let cold_ctx = SweepContext::new(&cfg).unwrap();
            let cold = run_point(&cfg, &cold_ctx, false).unwrap();
            assert_reports_identical(&warm, &cold);
        }
        // the second point must have reused sweep-invariant work
        assert_eq!(shared.layer_costs().len(), 1);
        assert!(shared.epoch_cache().hits() > 0, "expected epoch reuse");
    }

    #[test]
    fn bound_point_is_exact_off_the_epoch_axis_and_below_it_on_time() {
        let cfg = SiamConfig::paper_default();
        let ctx = SweepContext::new(&cfg).unwrap();
        let lb = run_point_bound(&cfg, &ctx).unwrap();
        assert_eq!(ctx.epoch_cache().len(), 0, "cheap pass must not touch the epoch cache");
        let full = run_point(&cfg, &ctx, false).unwrap();
        assert_eq!(lb.total.area_um2.to_bits(), full.total.area_um2.to_bits());
        assert_eq!(lb.silicon_area_mm2.to_bits(), full.silicon_area_mm2.to_bits());
        assert_eq!(lb.circuit.energy_pj.to_bits(), full.circuit.energy_pj.to_bits());
        assert_eq!(lb.noc.energy_pj.to_bits(), full.noc.energy_pj.to_bits());
        assert_eq!(lb.nop.energy_pj.to_bits(), full.nop.energy_pj.to_bits());
        assert_eq!(lb.num_chiplets, full.num_chiplets);
        assert!(lb.total.latency_ns <= full.total.latency_ns);
        assert!(lb.total.energy_pj <= full.total.energy_pj);
        assert_eq!(lb.engine_tiers.total(), 0, "no engine tier runs in the cheap pass");
    }

    #[test]
    fn concurrent_and_serial_engines_agree() {
        let cfg = SiamConfig::paper_default();
        let ctx = SweepContext::new(&cfg).unwrap();
        let a = run_point(&cfg, &ctx, true).unwrap();
        let b = run_point(&cfg, &ctx, false).unwrap();
        assert_reports_identical(&a, &b);
    }

    #[test]
    fn context_guards_against_model_mismatch() {
        // a caller may reuse a context with a different workload; the
        // pipeline must rebuild rather than silently reuse
        let ctx = SweepContext::new(&SiamConfig::paper_default()).unwrap();
        let other = SiamConfig::paper_default().with_model("lenet5", "cifar10");
        let rep = run_point(&other, &ctx, false).unwrap();
        assert_eq!(rep.model, "lenet5");
    }

    use crate::config::{ChipletClassConfig, MemCell};

    #[test]
    fn degenerate_single_class_reproduces_reports_bitwise() {
        // the acceptance regression: a single [[system.chiplet_class]]
        // restating the base config must reproduce the classic custom
        // and homogeneous results bit-for-bit, end to end
        let base = SiamConfig::paper_default();
        for legacy_cfg in [base.clone(), base.clone().with_total_chiplets(36)] {
            let ctx = SweepContext::new(&legacy_cfg).unwrap();
            let legacy = run_point(&legacy_cfg, &ctx, false).unwrap();
            let mut only = ChipletClassConfig::from_base(&base, "only");
            only.count = legacy_cfg.system.total_chiplets;
            let class_cfg = base.clone().with_chiplet_classes(vec![only]);
            let class_ctx = SweepContext::new(&class_cfg).unwrap();
            let class = run_point(&class_cfg, &class_ctx, false).unwrap();
            assert_reports_identical(&legacy, &class);
        }
    }

    fn big_little_cfg() -> SiamConfig {
        let base = SiamConfig::paper_default();
        let big = ChipletClassConfig::from_base(&base, "big");
        let mut little = ChipletClassConfig::from_base(&base, "little");
        little.cell = MemCell::Sram;
        little.xbar_rows = 64;
        little.xbar_cols = 64;
        little.tiles_per_chiplet = 8;
        little.xbars_per_tile = 8;
        little.adc_bits = 3;
        little.nop_ebit_pj = 0.3;
        little.nop_txrx_area_um2 = 3000.0;
        base.with_chiplet_classes(vec![big, little])
    }

    #[test]
    fn hetero_point_simulates_and_reports_classes() {
        let cfg = big_little_cfg();
        let ctx = SweepContext::new(&cfg).unwrap();
        let rep = run_point(&cfg, &ctx, false).unwrap();
        assert_eq!(rep.chiplets_per_class.len(), 2);
        assert!(rep.chiplets_per_class.iter().all(|&(_, c)| c > 0));
        assert!(rep.total.energy_pj > 0.0 && rep.total.latency_ns > 0.0);
        assert!(rep.nop.energy_pj > 0.0);
    }

    #[test]
    fn dataflow_and_rowmajor_share_context_without_aliasing() {
        // both placement policies against ONE shared epoch cache: the
        // mesh embedding tag keeps their NoP epochs from aliasing, so
        // each must match a fresh-context run of itself bit-for-bit
        let mut rowmajor_cfg = big_little_cfg();
        rowmajor_cfg.system.placement = crate::config::PlacementPolicy::RowMajor;
        let mut dataflow_cfg = big_little_cfg();
        dataflow_cfg.system.placement = crate::config::PlacementPolicy::Dataflow;

        let shared = SweepContext::new(&rowmajor_cfg).unwrap();
        let rm_warm = run_point(&rowmajor_cfg, &shared, false).unwrap();
        let df_warm = run_point(&dataflow_cfg, &shared, false).unwrap();

        let rm_cold = run_point(&rowmajor_cfg, &SweepContext::new(&rowmajor_cfg).unwrap(), false)
            .unwrap();
        let df_cold = run_point(&dataflow_cfg, &SweepContext::new(&dataflow_cfg).unwrap(), false)
            .unwrap();
        assert_reports_identical(&rm_warm, &rm_cold);
        assert_reports_identical(&df_warm, &df_cold);
        // placement moves distances, never silicon: areas agree exactly
        assert_eq!(
            df_warm.nop.area_um2.to_bits(),
            rm_warm.nop.area_um2.to_bits(),
            "placement must not change NoP area"
        );
    }
}
