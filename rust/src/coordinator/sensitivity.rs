//! Layer-sensitivity analysis (Section 6.4, Fig. 14c/d): map a single
//! DNN layer across a varying number of chiplets and model the
//! compute/communication trade, SIMBA-style.
//!
//! Model: with `k` chiplets assigned to one layer, the layer's input
//! vectors are processed in parallel across the chiplets (column-split
//! weights replicated as needed), so compute time scales ≈ 1/k; the
//! input activation stream, however, must reach *all* k chiplets; with
//! a row/column multicast tree on the mesh the stream is sent once plus
//! a per-extra-destination replication overhead (~5 % per chiplet).
//! Small-compute layers therefore show the U-shape SIMBA measures
//! (res3a_branch1 rises again at 16 chiplets) while compute-heavy layers
//! keep improving through 8 chiplets (res5[a-c]_branch2b).

use crate::config::{ReadOut, SiamConfig};
use crate::dnn::Dnn;

/// One point of the sensitivity curve.
#[derive(Debug, Clone, Copy)]
pub struct LayerPoint {
    /// Chiplets assigned to the layer.
    pub chiplets: usize,
    /// Compute time of the layer, ns.
    pub compute_ns: f64,
    /// NoP streaming time of the layer, ns.
    pub nop_ns: f64,
}

impl LayerPoint {
    /// Compute + communication time, ns.
    pub fn total_ns(&self) -> f64 {
        self.compute_ns + self.nop_ns
    }
}

/// Latency of `layer_name` mapped across `k` chiplets, for each k.
pub fn layer_latency_vs_chiplets(
    cfg: &SiamConfig,
    dnn: &Dnn,
    layer_name: &str,
    counts: &[usize],
) -> Option<Vec<LayerPoint>> {
    let layer = dnn.layers.iter().find(|l| l.name == layer_name)?;
    if !layer.is_weight_layer() {
        return None;
    }
    let act_bits = cfg.dnn.activation_precision as f64;
    let seq = match cfg.chiplet.read_out {
        ReadOut::Parallel => 1.0,
        ReadOut::Sequential => cfg.chiplet.xbar_rows as f64,
    };
    let cycles_per_vec = act_bits * cfg.chiplet.cols_per_adc as f64 * seq;
    let vectors = layer.input_vectors() as f64;
    let clk_ns = cfg.clock_period_ns();
    let nop_clk_ns = 1.0e3 / cfg.system.nop.frequency_mhz;
    let bpc = cfg.system.nop.bits_per_cycle() as f64;
    let in_bits = layer.ifm.elems() as f64 * act_bits;

    Some(
        counts
            .iter()
            .map(|&k| {
                let kf = k as f64;
                let compute_ns =
                    (vectors / kf).ceil() * cycles_per_vec * clk_ns + 20.0 * clk_ns;
                // one multicast stream + 5 % replication per extra dst
                let nop_ns =
                    (in_bits / bpc).ceil() * nop_clk_ns * (1.0 + 0.05 * (kf - 1.0));
                LayerPoint {
                    chiplets: k,
                    compute_ns,
                    nop_ns,
                }
            })
            .collect(),
    )
}

/// Fig. 14d: normalized total cycles of a layer (fixed chiplet count)
/// as the NoP bandwidth is scaled by `speedups`.
pub fn layer_cycles_vs_nop_speedup(
    cfg: &SiamConfig,
    dnn: &Dnn,
    layer_name: &str,
    chiplets: usize,
    speedups: &[f64],
) -> Option<Vec<(f64, f64)>> {
    let base = layer_latency_vs_chiplets(cfg, dnn, layer_name, &[chiplets])?[0];
    let norm = base.total_ns();
    Some(
        speedups
            .iter()
            .map(|&s| (s, (base.compute_ns + base.nop_ns / s) / norm))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::build_model;

    #[test]
    fn res3a_branch1_shows_u_shape() {
        // Fig. 14c top: latency falls then rises slightly at 16 chiplets
        // (SIMBA-like NoP bandwidth, as in the calibration experiment)
        let cfg = SiamConfig::paper_default().with_nop_speedup(4.0);
        let dnn = build_model("resnet50", "imagenet").unwrap();
        let pts =
            layer_latency_vs_chiplets(&cfg, &dnn, "res3a_branch1", &[1, 2, 4, 8, 16]).unwrap();
        let t: Vec<f64> = pts.iter().map(|p| p.total_ns()).collect();
        assert!(t[1] < t[0], "2 chiplets faster than 1");
        let min = t.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            t[4] > min,
            "16-chiplet point should sit above the minimum (U-shape), got {t:?}"
        );
    }

    #[test]
    fn res5_branch2b_keeps_decreasing() {
        // Fig. 14c bottom: compute-heavy layer improves monotonically
        // (SIMBA-like NoP bandwidth, as in the calibration experiment)
        let cfg = SiamConfig::paper_default().with_nop_speedup(4.0);
        let dnn = build_model("resnet50", "imagenet").unwrap();
        let pts =
            layer_latency_vs_chiplets(&cfg, &dnn, "res5a_branch2b", &[1, 2, 4, 8]).unwrap();
        for w in pts.windows(2) {
            assert!(
                w[1].total_ns() <= w[0].total_ns(),
                "latency should not increase: {pts:?}"
            );
        }
    }

    #[test]
    fn nop_speedup_monotone() {
        // Fig. 14d: more NoP bandwidth, fewer normalized cycles
        let cfg = SiamConfig::paper_default().with_nop_speedup(4.0);
        let dnn = build_model("resnet50", "imagenet").unwrap();
        let pts =
            layer_cycles_vs_nop_speedup(&cfg, &dnn, "res3a_branch1", 4, &[1.0, 2.0, 4.0, 8.0])
                .unwrap();
        assert!((pts[0].1 - 1.0).abs() < 1e-9);
        for w in pts.windows(2) {
            assert!(w[1].1 < w[0].1);
        }
    }

    #[test]
    fn unknown_layer_is_none() {
        let cfg = SiamConfig::paper_default();
        let dnn = build_model("resnet50", "imagenet").unwrap();
        assert!(layer_latency_vs_chiplets(&cfg, &dnn, "nope", &[1]).is_none());
    }
}
