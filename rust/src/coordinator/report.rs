//! Simulation report: the hardware performance metrics SIAM emits
//! (area, energy, latency, energy-efficiency, power, leakage, IMC
//! utilization) plus per-engine breakdowns, with text and JSON renderers.

use crate::circuit::CircuitReport;
use crate::config::SiamConfig;
use crate::dnn::Dnn;
use crate::dram::DramReport;
use crate::mapping::{MappingResult, Traffic};
use crate::metrics::{Breakdown, Metrics};
use crate::noc::NocReport;
use crate::nop::NopReport;
use crate::util::json::Json;
use crate::util::table::eng;

/// Complete output of one SIAM run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Simulated model (zoo name).
    pub model: String,
    /// Dataset variant.
    pub dataset: String,
    /// Model parameters.
    pub params: usize,
    /// MACs per inference.
    pub macs: usize,
    /// Chiplets the architecture contains.
    pub num_chiplets: usize,
    /// Chiplets the DNN actually occupies.
    pub num_chiplets_required: usize,
    /// IMC tiles the mapping uses.
    pub total_tiles: usize,
    /// Crossbar-level utilization (Fig. 9 metric).
    pub xbar_utilization: f64,
    /// Programmed-cell utilization within allocated crossbars.
    pub cell_utilization: f64,
    /// Activation/partial-sum bits crossing the interposer.
    pub inter_chiplet_bits: f64,
    /// Activation bits moving tile-to-tile inside chiplets.
    pub intra_chiplet_bits: f64,
    /// Global accumulator additions.
    pub accumulator_adds: u64,
    /// IMC circuit metrics (compute + global acc/buffer).
    pub circuit: Metrics,
    /// Intra-chiplet interconnect.
    pub noc: Metrics,
    /// Inter-chiplet interconnect.
    pub nop: Metrics,
    /// Off-chip weight load (reported separately; excluded from the
    /// inference totals per Section 6.1).
    pub dram: DramReport,
    /// Inference totals (circuit + NoC + NoP; leakage energy folded in).
    pub total: Metrics,
    /// Serialized NoC cycles.
    pub noc_cycles: u64,
    /// Serialized NoP cycles.
    pub nop_cycles: u64,
    /// Yielded silicon (chiplet dies incl. NoP drivers/routers), mm² —
    /// excludes the passive interposer wiring; drives the cost model.
    pub silicon_area_mm2: f64,
    /// Wall-clock the simulation took, seconds.
    pub wall_seconds: f64,
}

impl SimReport {
    /// Fold the four engine outputs into the paper's reported totals
    /// (layer-serial dataflow; interconnect leakage accrues over its
    /// active window).
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        cfg: &SiamConfig,
        dnn: &Dnn,
        map: &MappingResult,
        traffic: &Traffic,
        circuit: CircuitReport,
        noc: NocReport,
        nop: NopReport,
        dram: DramReport,
        wall_seconds: f64,
    ) -> SimReport {
        let stats = dnn.stats();
        let c = circuit.total_metrics();
        // Layer-by-layer dataflow: compute, NoC and NoP phases serialize.
        // Circuit energy already contains the power-gated fabric leakage;
        // the interconnect's own leakage accrues over its active window.
        let mut total = Metrics {
            area_um2: c.area_um2 + noc.metrics.area_um2 + nop.metrics.area_um2,
            energy_pj: c.energy_pj + noc.metrics.energy_pj + nop.metrics.energy_pj,
            latency_ns: c.latency_ns + noc.metrics.latency_ns + nop.metrics.latency_ns,
            leakage_uw: c.leakage_uw + noc.metrics.leakage_uw + nop.metrics.leakage_uw,
        };
        total.energy_pj += noc.metrics.leakage_energy_pj() + nop.metrics.leakage_energy_pj();
        let silicon_area_mm2 =
            (c.area_um2 + noc.metrics.area_um2 + nop.die_area_um2) / 1.0e6;

        SimReport {
            model: dnn.name.clone(),
            dataset: cfg.dnn.dataset.clone(),
            params: stats.params,
            macs: stats.macs,
            num_chiplets: map.num_chiplets,
            num_chiplets_required: map.num_chiplets_required,
            total_tiles: map.total_tiles(cfg.chiplet.xbars_per_tile),
            xbar_utilization: map.xbar_utilization(),
            cell_utilization: map.cell_utilization(),
            inter_chiplet_bits: traffic.inter_chiplet_bits,
            intra_chiplet_bits: traffic.intra_chiplet_bits,
            accumulator_adds: traffic.accumulator_adds,
            circuit: c,
            noc: noc.metrics,
            nop: nop.metrics,
            dram,
            total,
            noc_cycles: noc.cycles,
            nop_cycles: nop.cycles,
            silicon_area_mm2,
            wall_seconds,
        }
    }

    /// Inferences per joule (the Section-6.5 comparison metric).
    pub fn inferences_per_joule(&self) -> f64 {
        1.0e12 / self.total.energy_pj
    }

    /// Throughput at batch 1, inferences/s.
    pub fn inferences_per_second(&self) -> f64 {
        1.0e9 / self.total.latency_ns
    }

    /// Fig. 10-style breakdown across IMC / NoC / NoP.
    pub fn component_breakdown(&self) -> Breakdown {
        let mut b = Breakdown::default();
        b.push("imc_circuit", self.circuit);
        b.push("noc", self.noc);
        b.push("nop", self.nop);
        b
    }

    /// One-paragraph human-readable summary of the headline metrics.
    pub fn summary(&self) -> String {
        let t = &self.total;
        format!(
            "{model} on {ds}: {params:.2}M params, {chiplets} chiplets ({req} used), \
             {tiles} tiles, util {util:.1}%\n\
             area {area} mm² | energy {energy} µJ | latency {lat} ms | \
             power {pw} mW | EDAP {edap:.3e} pJ·ns·mm²\n\
             eff {eff:.1} inf/J | {ips:.2} inf/s | NoC {nocp:.1}% E, NoP {nopp:.1}% E | \
             DRAM load {dram_ms:.2} ms / {dram_mj:.2} mJ | sim {wall:.2}s",
            model = self.model,
            ds = self.dataset,
            params = self.params as f64 / 1e6,
            chiplets = self.num_chiplets,
            req = self.num_chiplets_required,
            tiles = self.total_tiles,
            util = 100.0 * self.xbar_utilization,
            area = eng(t.area_mm2()),
            energy = eng(t.energy_uj()),
            lat = eng(t.latency_ms()),
            pw = eng(t.avg_power_mw()),
            edap = t.edap(),
            eff = self.inferences_per_joule(),
            ips = self.inferences_per_second(),
            nocp = 100.0 * self.noc.energy_pj / t.energy_pj,
            nopp = 100.0 * self.nop.energy_pj / t.energy_pj,
            dram_ms = self.dram.latency_ns / 1e6,
            dram_mj = self.dram.energy_pj / 1e9,
            wall = self.wall_seconds,
        )
    }

    /// Machine-readable report (stable keys; parsed back in tests).
    pub fn to_json(&self) -> Json {
        let m = |x: &Metrics| {
            let mut o = Json::obj();
            o.set("area_mm2", x.area_mm2())
                .set("energy_pj", x.energy_pj)
                .set("latency_ns", x.latency_ns)
                .set("leakage_uw", x.leakage_uw)
                .set("edp", x.edp())
                .set("edap", x.edap());
            o
        };
        let mut o = Json::obj();
        o.set("model", self.model.as_str())
            .set("dataset", self.dataset.as_str())
            .set("params", self.params)
            .set("macs", self.macs)
            .set("num_chiplets", self.num_chiplets)
            .set("num_chiplets_required", self.num_chiplets_required)
            .set("total_tiles", self.total_tiles)
            .set("xbar_utilization", self.xbar_utilization)
            .set("cell_utilization", self.cell_utilization)
            .set("inter_chiplet_bits", self.inter_chiplet_bits)
            .set("intra_chiplet_bits", self.intra_chiplet_bits)
            .set("accumulator_adds", self.accumulator_adds)
            .set("circuit", m(&self.circuit))
            .set("noc", m(&self.noc))
            .set("nop", m(&self.nop))
            .set("total", m(&self.total))
            .set("silicon_area_mm2", self.silicon_area_mm2)
            .set("noc_cycles", self.noc_cycles)
            .set("nop_cycles", self.nop_cycles)
            .set("inferences_per_joule", self.inferences_per_joule())
            .set("inferences_per_second", self.inferences_per_second())
            .set("wall_seconds", self.wall_seconds);
        let mut d = Json::obj();
        d.set("latency_ns", self.dram.latency_ns)
            .set("energy_pj", self.dram.energy_pj)
            .set("requests", self.dram.requests)
            .set("row_hit_rate", self.dram.row_hit_rate);
        o.set("dram", d);
        o
    }
}
