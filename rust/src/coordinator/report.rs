//! Simulation reports: the hardware performance metrics SIAM emits
//! (area, energy, latency, energy-efficiency, power, leakage, IMC
//! utilization) plus per-engine breakdowns, with text and JSON
//! renderers — [`SimReport`] for one single-shot evaluation and
//! [`ServeReport`] for one serving (streaming-traffic) run.

use crate::circuit::CircuitReport;
use crate::config::SiamConfig;
use crate::dnn::Dnn;
use crate::dram::DramReport;
use crate::mapping::{MappingResult, Traffic};
use crate::metrics::{Breakdown, Metrics};
use crate::noc::{NocReport, TierCounts};
use crate::nop::NopReport;
use crate::obs::RunMeta;
use crate::util::json::Json;
use crate::util::table::eng;

/// Serialize a `(class name, chiplet count)` split as the JSON array
/// used by [`SimReport::to_json`], [`ServeReport::to_json`] and the
/// `siam sweep --json` output.
pub fn classes_json(classes: &[(String, usize)]) -> Json {
    Json::Arr(
        classes
            .iter()
            .map(|(name, chiplets)| {
                let mut e = Json::obj();
                e.set("name", name.as_str()).set("chiplets", *chiplets);
                e
            })
            .collect(),
    )
}

/// Machine-readable sweep artifact (`siam sweep --json`, schema
/// `siam-sweep/v3`): the table's fields per point, the shared-stage and
/// persistent-cache counters, the search mode, and the run's
/// self-describing `meta` block. v3 over v2: `stats.epochs_hydrated`,
/// `stats.points_known`, `stats.search`, and `meta.epoch_cache.hydrated`
/// (all additive — see `docs/CACHING.md`).
pub fn sweep_json(cfg: &SiamConfig, res: &super::SweepResult) -> Json {
    let mut points = Vec::with_capacity(res.points.len());
    for p in &res.points {
        let mut o = Json::obj();
        o.set("tiles_per_chiplet", p.tiles_per_chiplet)
            .set(
                "total_chiplets",
                p.total_chiplets.map(Json::from).unwrap_or(Json::Null),
            )
            .set("num_chiplets", p.report.num_chiplets)
            .set("area_mm2", p.report.total.area_mm2())
            .set("energy_uj", p.report.total.energy_uj())
            .set("latency_ms", p.report.total.latency_ms())
            .set("edap", p.report.total.edap());
        if !p.report.chiplets_per_class.is_empty() {
            o.set("classes", classes_json(&p.report.chiplets_per_class));
        }
        if let Some(split) = &p.class_split {
            o.set(
                "class_split",
                Json::Arr(
                    split
                        .iter()
                        .map(|c| c.map(Json::from).unwrap_or(Json::Null))
                        .collect(),
                ),
            );
        }
        if let Some(xb) = &p.class_xbars {
            o.set("class_xbars", Json::Arr(xb.iter().map(|&x| Json::from(x)).collect()));
        }
        // reliability fragments ride along exactly as SimReport emits
        // them, so sweep artifacts carry fault/variation provenance
        if let Some(f) = &p.report.fault {
            o.set("fault", f.to_json());
        }
        if let Some(v) = &p.report.variation {
            o.set("variation", v.to_json());
        }
        points.push(o);
    }
    let mut stats = Json::obj();
    stats
        .set("epoch_hits", res.stats.epoch_hits)
        .set("epoch_misses", res.stats.epoch_misses)
        .set("epoch_hit_rate", res.stats.epoch_hit_rate())
        .set("epochs_cached", res.stats.epochs_cached)
        .set("epochs_hydrated", res.stats.epochs_hydrated)
        .set("points_known", res.stats.points_known)
        .set("search", cfg.sweep.search.as_str())
        .set("engine_tiers", res.stats.tiers.to_json())
        .set("wall_seconds", res.stats.wall_seconds)
        .set("points_per_sec", res.stats.points_per_sec);
    // provenance: builtin vs file path + content fingerprint, so sweep
    // artifacts can be traced to the exact network that produced them
    let model_source = res
        .points
        .first()
        .map(|p| p.report.model_source.clone())
        .unwrap_or_else(|| {
            if cfg.dnn.model.starts_with("file:") {
                cfg.dnn.model.clone()
            } else {
                "builtin".into()
            }
        });
    let mut meta = RunMeta::for_config(cfg);
    meta.model_source = model_source.clone();
    meta.wall_seconds = res.stats.wall_seconds;
    meta.epoch_cache = Some(crate::obs::CacheSnapshot {
        hits: res.stats.epoch_hits,
        misses: res.stats.epoch_misses,
        entries: res.stats.epochs_cached,
        hydrated: res.stats.epochs_hydrated,
        shards: res.stats.shards.clone(),
    });
    meta.engine_tiers = Some(res.stats.tiers);
    let mut out = Json::obj();
    out.set("schema", "siam-sweep/v3")
        .set("model", cfg.dnn.model.as_str())
        .set("dataset", cfg.dnn.dataset.as_str())
        .set("model_source", model_source.as_str())
        .set("points", points)
        .set("stats", stats)
        .set("meta", meta.to_json());
    if let Some(best) = super::best_by_edap(&res.points) {
        let mut b = Json::obj();
        b.set("tiles_per_chiplet", best.tiles_per_chiplet)
            .set("num_chiplets", best.report.num_chiplets)
            .set("edap", best.report.total.edap());
        out.set("best_by_edap", b);
    }
    out
}

/// Complete output of one SIAM run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Simulated model (zoo name or the file's `[model] name`).
    pub model: String,
    /// Dataset variant.
    pub dataset: String,
    /// Model provenance: `"builtin"`, or `"file:<path>#<fingerprint>"`
    /// for network-file workloads — sweep artifacts carry this so a
    /// result can be traced to the exact file content that produced it.
    pub model_source: String,
    /// Model parameters.
    pub params: usize,
    /// MACs per inference.
    pub macs: usize,
    /// Chiplets the architecture contains.
    pub num_chiplets: usize,
    /// Chiplets the DNN actually occupies.
    pub num_chiplets_required: usize,
    /// Heterogeneous class split as `(class name, chiplets)` in class
    /// order; empty for single-kind systems.
    pub chiplets_per_class: Vec<(String, usize)>,
    /// IMC tiles the mapping uses.
    pub total_tiles: usize,
    /// Crossbar-level utilization (Fig. 9 metric).
    pub xbar_utilization: f64,
    /// Programmed-cell utilization within allocated crossbars.
    pub cell_utilization: f64,
    /// Activation/partial-sum bits crossing the interposer.
    pub inter_chiplet_bits: f64,
    /// Activation bits moving tile-to-tile inside chiplets.
    pub intra_chiplet_bits: f64,
    /// Global accumulator additions.
    pub accumulator_adds: u64,
    /// IMC circuit metrics (compute + global acc/buffer).
    pub circuit: Metrics,
    /// Intra-chiplet interconnect.
    pub noc: Metrics,
    /// Inter-chiplet interconnect.
    pub nop: Metrics,
    /// Off-chip weight load (reported separately; excluded from the
    /// inference totals per Section 6.1).
    pub dram: DramReport,
    /// Inference totals (circuit + NoC + NoP; leakage energy folded in).
    pub total: Metrics,
    /// Serialized NoC cycles.
    pub noc_cycles: u64,
    /// Serialized NoP cycles.
    pub nop_cycles: u64,
    /// Yielded silicon (chiplet dies incl. NoP drivers/routers), mm² —
    /// excludes the passive interposer wiring; drives the cost model.
    pub silicon_area_mm2: f64,
    /// What the fault injection did to this point (`None` on fault-free
    /// runs — the default; set by [`crate::coordinator::pipeline::run_point`]).
    pub fault: Option<crate::fault::FaultReport>,
    /// What the analog variation model predicts for this point (`None`
    /// with `[variation]` absent or inert — the default; set by
    /// [`crate::coordinator::pipeline::run_point`]).
    pub variation: Option<crate::variation::VariationReport>,
    /// Wall-clock the simulation took, seconds.
    pub wall_seconds: f64,
    /// How the interconnect epochs were answered (closed-form /
    /// periodic-certificate / extrapolated / packet fallback), summed
    /// over the NoC and NoP engines. Deterministic for a given
    /// (config, cache state): cache hits replay the tag recorded at
    /// fill time. Excluded from cross-run bit-compare helpers, which
    /// assert the physics, not the instrumentation.
    pub engine_tiers: TierCounts,
    /// Provenance block (`None` until a front-end attaches it — the
    /// CLI and benches do; library callers may leave it unset).
    pub meta: Option<RunMeta>,
}

impl SimReport {
    /// Fold the four engine outputs into the paper's reported totals
    /// (layer-serial dataflow; interconnect leakage accrues over its
    /// active window).
    #[allow(clippy::too_many_arguments)]
    pub fn assemble(
        cfg: &SiamConfig,
        dnn: &Dnn,
        map: &MappingResult,
        traffic: &Traffic,
        circuit: CircuitReport,
        noc: NocReport,
        nop: NopReport,
        dram: DramReport,
        wall_seconds: f64,
    ) -> SimReport {
        let stats = dnn.stats();
        let c = circuit.total_metrics();
        let (chiplets_per_class, total_tiles) = if cfg.has_hetero_classes() {
            let classes = cfg.resolved_chiplet_classes();
            let mut counts = vec![0usize; classes.len()];
            for &k in &map.chiplet_class {
                counts[k] += 1;
            }
            // tiles follow the owning class's geometry, per layer
            let tiles = map
                .per_layer
                .iter()
                .map(|lm| lm.xbars.div_ceil(classes[lm.class].xbars_per_tile))
                .sum();
            (
                classes
                    .iter()
                    .zip(counts)
                    .map(|(cl, n)| (cl.name.clone(), n))
                    .collect(),
                tiles,
            )
        } else {
            (Vec::new(), map.total_tiles(cfg.chiplet.xbars_per_tile))
        };
        // Layer-by-layer dataflow: compute, NoC and NoP phases serialize.
        // Circuit energy already contains the power-gated fabric leakage;
        // the interconnect's own leakage accrues over its active window.
        let mut total = Metrics {
            area_um2: c.area_um2 + noc.metrics.area_um2 + nop.metrics.area_um2,
            energy_pj: c.energy_pj + noc.metrics.energy_pj + nop.metrics.energy_pj,
            latency_ns: c.latency_ns + noc.metrics.latency_ns + nop.metrics.latency_ns,
            leakage_uw: c.leakage_uw + noc.metrics.leakage_uw + nop.metrics.leakage_uw,
        };
        total.energy_pj += noc.metrics.leakage_energy_pj() + nop.metrics.leakage_energy_pj();
        let silicon_area_mm2 =
            (c.area_um2 + noc.metrics.area_um2 + nop.die_area_um2) / 1.0e6;
        let mut engine_tiers = noc.tiers;
        engine_tiers.accumulate(&nop.tiers);

        SimReport {
            model: dnn.name.clone(),
            // the graph's dataset is authoritative for both sources:
            // `build_model` stamps the resolved name onto builtins and
            // file models declare their own
            dataset: dnn.dataset.clone(),
            model_source: dnn.source.describe(),
            params: stats.params,
            macs: stats.macs,
            num_chiplets: map.num_chiplets,
            num_chiplets_required: map.num_chiplets_required,
            chiplets_per_class,
            total_tiles,
            xbar_utilization: map.xbar_utilization(),
            cell_utilization: map.cell_utilization(),
            inter_chiplet_bits: traffic.inter_chiplet_bits,
            intra_chiplet_bits: traffic.intra_chiplet_bits,
            accumulator_adds: traffic.accumulator_adds,
            circuit: c,
            noc: noc.metrics,
            nop: nop.metrics,
            dram,
            total,
            noc_cycles: noc.cycles,
            nop_cycles: nop.cycles,
            silicon_area_mm2,
            fault: None,
            variation: None,
            wall_seconds,
            engine_tiers,
            meta: None,
        }
    }

    /// Inferences per joule (the Section-6.5 comparison metric).
    pub fn inferences_per_joule(&self) -> f64 {
        1.0e12 / self.total.energy_pj
    }

    /// Throughput at batch 1, inferences/s.
    pub fn inferences_per_second(&self) -> f64 {
        1.0e9 / self.total.latency_ns
    }

    /// Fig. 10-style breakdown across IMC / NoC / NoP.
    pub fn component_breakdown(&self) -> Breakdown {
        let mut b = Breakdown::default();
        b.push("imc_circuit", self.circuit);
        b.push("noc", self.noc);
        b.push("nop", self.nop);
        b
    }

    /// One-paragraph human-readable summary of the headline metrics.
    pub fn summary(&self) -> String {
        let t = &self.total;
        let classes = if self.chiplets_per_class.is_empty() {
            String::new()
        } else {
            let parts: Vec<String> = self
                .chiplets_per_class
                .iter()
                .map(|(n, c)| format!("{n}\u{00d7}{c}"))
                .collect();
            format!(" [{}]", parts.join(" + "))
        };
        let fault_line = match &self.fault {
            Some(f) if f.remapped => format!(
                "\nfault: {dead} dead chiplet(s) {ids:?}, {fx} faulty xbars, \
                 {spares} spare(s), remapped onto {surv} surviving xbars (seed {seed})",
                dead = f.dead_chiplets.len(),
                ids = f.dead_chiplets,
                fx = f.faulty_xbars,
                spares = f.spare_chiplets,
                surv = f.surviving_capacity_xbars,
                seed = f.seed,
            ),
            Some(f) => format!(
                "\nfault: clean injection (seed {}), {} spare(s) idle",
                f.seed, f.spare_chiplets
            ),
            None => String::new(),
        };
        let variation_line = match &self.variation {
            Some(v) => format!(
                "\nvariation: accuracy proxy {mean:.4} ± {ci:.4} (floor {floor} {verdict}), \
                 σ_prog {sp:.4}, drift {t:.0}s ×{f:.4} read E, {mc} MC samples (seed {seed})",
                mean = v.accuracy_proxy_mean,
                ci = v.accuracy_proxy_ci95,
                floor = v.accuracy_floor,
                verdict = if v.meets_floor { "met" } else { "MISSED" },
                sp = v.sigma_program_effective,
                t = v.drift_time_s,
                f = v.drift_energy_factor,
                mc = v.mc_samples,
                seed = v.seed,
            ),
            None => String::new(),
        };
        format!(
            "{model} on {ds}: {params:.2}M params, {chiplets} chiplets{classes} ({req} used), \
             {tiles} tiles, util {util:.1}%\n\
             area {area} mm² | energy {energy} µJ | latency {lat} ms | \
             power {pw} mW | EDAP {edap:.3e} pJ·ns·mm²\n\
             eff {eff:.1} inf/J | {ips:.2} inf/s | NoC {nocp:.1}% E, NoP {nopp:.1}% E | \
             DRAM load {dram_ms:.2} ms / {dram_mj:.2} mJ | sim {wall:.2}s{fault_line}{variation_line}",
            model = self.model,
            ds = self.dataset,
            params = self.params as f64 / 1e6,
            chiplets = self.num_chiplets,
            req = self.num_chiplets_required,
            tiles = self.total_tiles,
            util = 100.0 * self.xbar_utilization,
            area = eng(t.area_mm2()),
            energy = eng(t.energy_uj()),
            lat = eng(t.latency_ms()),
            pw = eng(t.avg_power_mw()),
            edap = t.edap(),
            eff = self.inferences_per_joule(),
            ips = self.inferences_per_second(),
            nocp = 100.0 * self.noc.energy_pj / t.energy_pj,
            nopp = 100.0 * self.nop.energy_pj / t.energy_pj,
            dram_ms = self.dram.latency_ns / 1e6,
            dram_mj = self.dram.energy_pj / 1e9,
            wall = self.wall_seconds,
        )
    }

    /// Machine-readable report (stable keys; parsed back in tests).
    pub fn to_json(&self) -> Json {
        let m = |x: &Metrics| {
            let mut o = Json::obj();
            o.set("area_mm2", x.area_mm2())
                .set("energy_pj", x.energy_pj)
                .set("latency_ns", x.latency_ns)
                .set("leakage_uw", x.leakage_uw)
                .set("edp", x.edp())
                .set("edap", x.edap());
            o
        };
        let mut o = Json::obj();
        o.set("model", self.model.as_str())
            .set("dataset", self.dataset.as_str())
            .set("model_source", self.model_source.as_str())
            .set("params", self.params)
            .set("macs", self.macs)
            .set("num_chiplets", self.num_chiplets)
            .set("num_chiplets_required", self.num_chiplets_required)
            .set("total_tiles", self.total_tiles)
            .set("xbar_utilization", self.xbar_utilization)
            .set("cell_utilization", self.cell_utilization)
            .set("inter_chiplet_bits", self.inter_chiplet_bits)
            .set("intra_chiplet_bits", self.intra_chiplet_bits)
            .set("accumulator_adds", self.accumulator_adds)
            .set("circuit", m(&self.circuit))
            .set("noc", m(&self.noc))
            .set("nop", m(&self.nop))
            .set("total", m(&self.total))
            .set("silicon_area_mm2", self.silicon_area_mm2)
            .set("noc_cycles", self.noc_cycles)
            .set("nop_cycles", self.nop_cycles)
            .set("inferences_per_joule", self.inferences_per_joule())
            .set("inferences_per_second", self.inferences_per_second())
            .set("wall_seconds", self.wall_seconds);
        let mut d = Json::obj();
        d.set("latency_ns", self.dram.latency_ns)
            .set("energy_pj", self.dram.energy_pj)
            .set("requests", self.dram.requests)
            .set("row_hit_rate", self.dram.row_hit_rate);
        o.set("dram", d);
        if !self.chiplets_per_class.is_empty() {
            o.set("classes", classes_json(&self.chiplets_per_class));
        }
        if let Some(f) = &self.fault {
            o.set("fault", f.to_json());
        }
        if let Some(v) = &self.variation {
            o.set("variation", v.to_json());
        }
        o.set("engine_tiers", self.engine_tiers.to_json());
        if let Some(meta) = &self.meta {
            o.set("meta", meta.to_json());
        }
        o
    }
}

/// Outcome of a mid-run chiplet-failure scenario (`[serve]
/// fail_at_request`): when the failure hit, how long the remap took,
/// what was shed, and the tail latency before / during / after the
/// outage window. Carried in [`ServeReport::failover`].
#[derive(Debug, Clone)]
pub struct FailoverReport {
    /// The chiplet that died mid-run.
    pub fail_chiplet: usize,
    /// Failure instant (arrival time of request `fail_at_request`), ms.
    pub fail_time_ms: f64,
    /// Configured remap latency (`[serve] remap_latency_us`), ms.
    pub remap_latency_ms: f64,
    /// Pipeline stages hosted (fully or partly) on the dead chiplet.
    pub dead_stages: usize,
    /// Did the system remap onto surviving capacity and complete
    /// requests afterwards? `false` when the remap failed (no spare
    /// capacity — see `remap_error`) or nothing completed after it.
    pub recovered: bool,
    /// Failure instant → first completion on the remapped pipeline, ms
    /// (0 when not recovered).
    pub recovery_ms: f64,
    /// Requests shed because of the failure: in-flight work lost on
    /// the dead stages plus arrivals shed at the ingress over the rest
    /// of the run (pre-failure sheds included; a stable healthy phase
    /// sheds nothing).
    pub shed_total: usize,
    /// In-flight requests lost on the dead stages at the failure
    /// instant.
    pub shed_in_flight: usize,
    /// p99 latency over completions before the failure, ms.
    pub p99_before_ms: f64,
    /// p99 latency over completions in the outage window (failure →
    /// remap done), ms. Requests queued behind the dead stage complete
    /// after the remap, so this window mostly shows the drained
    /// downstream tail; 0 when nothing completed in it.
    pub p99_during_ms: f64,
    /// p99 latency over completions after the remap, ms (0 when none).
    pub p99_after_ms: f64,
    /// Spare chiplets the architecture carried into the scenario.
    pub spare_chiplets: usize,
    /// Why the remap failed, when it did (e.g. the surviving capacity
    /// cannot hold the DNN).
    pub remap_error: Option<String>,
}

impl FailoverReport {
    /// Machine-readable form (nested under `"failover"` in
    /// [`ServeReport::to_json`]).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("fail_chiplet", self.fail_chiplet)
            .set("fail_time_ms", self.fail_time_ms)
            .set("remap_latency_ms", self.remap_latency_ms)
            .set("dead_stages", self.dead_stages)
            .set("recovered", self.recovered)
            .set("recovery_ms", self.recovery_ms)
            .set("shed_total", self.shed_total)
            .set("shed_in_flight", self.shed_in_flight)
            .set("p99_before_ms", self.p99_before_ms)
            .set("p99_during_ms", self.p99_during_ms)
            .set("p99_after_ms", self.p99_after_ms)
            .set("spare_chiplets", self.spare_chiplets);
        match &self.remap_error {
            Some(e) => o.set("remap_error", e.as_str()),
            None => o.set("remap_error", Json::Null),
        };
        o
    }
}

/// Complete output of one serving run: throughput, tail latency,
/// utilization and energy-per-inference under streaming traffic
/// (produced by [`crate::serve`]).
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Served model (zoo name or the file's `[model] name`).
    pub model: String,
    /// Dataset variant.
    pub dataset: String,
    /// Model provenance (`"builtin"` or `"file:<path>#<fingerprint>"`).
    pub model_source: String,
    /// Traffic generator: `"open"` or `"closed"`.
    pub mode: String,
    /// Open-loop offered rate, inferences/s (0 for closed loop).
    pub offered_qps: f64,
    /// Closed-loop concurrent clients (0 for open loop).
    pub concurrency: usize,
    /// Pipeline stages (ingress + weight layers).
    pub num_stages: usize,
    /// Chiplets the architecture contains.
    pub num_chiplets: usize,
    /// Heterogeneous class split as `(class name, chiplets)`; empty for
    /// single-kind systems. Stage service times already reflect the
    /// owning class (its circuit costs, mesh and clock).
    pub classes: Vec<(String, usize)>,
    /// Index of the bottleneck (slowest) stage.
    pub bottleneck_stage: usize,
    /// Service time of the bottleneck stage, ns.
    pub bottleneck_service_ns: f64,
    /// Analytic throughput ceiling (bottleneck service rate), inf/s.
    pub bottleneck_qps: f64,
    /// Empty-pipeline traversal time (Σ stage services), ns.
    pub single_pass_ns: f64,
    /// Single-shot inference latency of the same point, ns.
    pub single_shot_latency_ns: f64,
    /// Single-shot inference energy of the same point, pJ.
    pub single_shot_energy_pj: f64,
    /// Requests offered.
    pub requests: usize,
    /// Requests that completed the pipeline.
    pub completed: usize,
    /// Open-loop requests shed at the ingress queue.
    pub dropped: usize,
    /// Steady-state delivered throughput, inferences/s.
    pub throughput_qps: f64,
    /// Median request latency, ms.
    pub p50_ms: f64,
    /// 95th-percentile request latency, ms.
    pub p95_ms: f64,
    /// 99th-percentile request latency, ms.
    pub p99_ms: f64,
    /// Mean request latency, ms.
    pub mean_ms: f64,
    /// Crossbar-weighted busy fraction per chiplet over the serving
    /// window.
    pub chiplet_utilization: Vec<f64>,
    /// Mean of `chiplet_utilization`.
    pub mean_utilization: f64,
    /// Max of `chiplet_utilization`.
    pub peak_utilization: f64,
    /// Energy per completed inference under load, pJ (dynamic + ingress
    /// DRAM fetch + leakage amortized over the serving window).
    pub energy_per_inference_pj: f64,
    /// The `[serve] qos_p99_ms` target this run is judged against, ms.
    pub qos_p99_target_ms: f64,
    /// One-time weight load at deployment (not a per-request cost).
    pub weight_load: DramReport,
    /// Mid-run chiplet-failure outcome (`[serve] fail_at_request`
    /// scenarios only).
    pub failover: Option<FailoverReport>,
    /// Token-level generation metrics (`siam serve --decode` runs only;
    /// `None` on classic per-request serving, keeping its JSON
    /// byte-identical).
    pub decode: Option<crate::serve::decode::DecodeReport>,
    /// Analog variation under serving load (`None` with `[variation]`
    /// absent or inert): retention age capped at the drift-refresh
    /// interval, refresh duty charged against stage service time.
    pub variation: Option<crate::variation::VariationReport>,
    /// Wall-clock of the serving simulation, seconds.
    pub wall_seconds: f64,
    /// Provenance block (attached by [`crate::serve::evaluate`];
    /// `None` only on hand-built reports).
    pub meta: Option<RunMeta>,
}

impl ServeReport {
    /// Fraction of offered requests shed at the ingress.
    pub fn drop_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.dropped as f64 / self.requests as f64
        }
    }

    /// Does the run meet its configured p99 target (and shed nothing)?
    pub fn meets_qos(&self) -> bool {
        self.dropped == 0 && self.p99_ms <= self.qos_p99_target_ms
    }

    /// QoS ranking score, lower is better, in three strict
    /// deterministic tiers: runs that meet the configured p99 target,
    /// then runs that miss it, then runs that shed load. The tier
    /// offset (1e12 ms) dominates any achievable p99 or shed term, so
    /// a shedding run can never outrank a non-shedding one; within a
    /// tier, lower shed fraction then lower p99 wins.
    pub fn qos_score_ms(&self) -> f64 {
        let tier = if self.dropped > 0 {
            2.0
        } else if self.p99_ms > self.qos_p99_target_ms {
            1.0
        } else {
            0.0
        };
        tier * 1.0e12 + 1.0e9 * self.drop_rate() + self.p99_ms
    }

    /// One-paragraph human-readable summary of the serving run.
    pub fn summary(&self) -> String {
        let load = match self.mode.as_str() {
            "open" => format!("{:.0} qps offered", self.offered_qps),
            _ => format!("concurrency {}", self.concurrency),
        };
        let mut s = format!(
            "{model} on {ds} serving ({mode}, {load}): {done}/{req} done, \
             {drop:.1}% shed\n\
             throughput {tp:.1} inf/s (bottleneck {cap:.1} inf/s, stage {bs}) | \
             p50 {p50:.3} ms, p95 {p95:.3} ms, p99 {p99:.3} ms\n\
             chiplet util mean {um:.1}% / peak {up:.1}% | \
             {epi:.1} µJ/inf under load (single-shot {essj:.1} µJ) | \
             QoS {qos} (p99 target {qtgt:.3} ms) | sim {wall:.2}s",
            model = self.model,
            ds = self.dataset,
            mode = self.mode,
            load = load,
            done = self.completed,
            req = self.requests,
            drop = 100.0 * self.drop_rate(),
            tp = self.throughput_qps,
            cap = self.bottleneck_qps,
            bs = self.bottleneck_stage,
            p50 = self.p50_ms,
            p95 = self.p95_ms,
            p99 = self.p99_ms,
            um = 100.0 * self.mean_utilization,
            up = 100.0 * self.peak_utilization,
            epi = self.energy_per_inference_pj / 1.0e6,
            essj = self.single_shot_energy_pj / 1.0e6,
            qos = if self.meets_qos() { "met" } else { "MISSED" },
            qtgt = self.qos_p99_target_ms,
            wall = self.wall_seconds,
        );
        if let Some(f) = &self.failover {
            let outcome = if f.recovered {
                format!(
                    "recovered in {rec:.3} ms (remap {rl:.3} ms), \
                     p99 before/during/after {b:.3}/{d:.3}/{a:.3} ms",
                    rec = f.recovery_ms,
                    rl = f.remap_latency_ms,
                    b = f.p99_before_ms,
                    d = f.p99_during_ms,
                    a = f.p99_after_ms,
                )
            } else {
                format!(
                    "NOT recovered{}",
                    f.remap_error.as_deref().map(|e| format!(" ({e})")).unwrap_or_default()
                )
            };
            s.push_str(&format!(
                "\nfailover: chiplet {c} died at {t:.3} ms ({ds} stage(s), \
                 {spares} spare(s)): {shed} request(s) shed, {outcome}",
                c = f.fail_chiplet,
                t = f.fail_time_ms,
                ds = f.dead_stages,
                spares = f.spare_chiplets,
                shed = f.shed_total,
            ));
        }
        if let Some(d) = &self.decode {
            s.push_str(&format!(
                "\ndecode: {toks} tokens @ {tps:.1} tok/s | TTFT p50/p99 \
                 {tf50:.3}/{tf99:.3} ms | TPOT p50/p99 {tp50:.4}/{tp99:.4} ms | \
                 KV {kvb} B/token, peak {kvp:.1} kB{spill} | batch mean {om:.2} / peak {op}",
                toks = d.total_tokens,
                tps = d.tokens_per_second,
                tf50 = d.ttft_p50_ms,
                tf99 = d.ttft_p99_ms,
                tp50 = d.tpot_p50_ms,
                tp99 = d.tpot_p99_ms,
                kvb = d.kv_bytes_per_token,
                kvp = d.kv_peak_bytes as f64 / 1024.0,
                spill = if d.kv_spill_bytes_peak > 0 {
                    format!(
                        ", spilled {:.1} kB to DRAM",
                        d.kv_spill_bytes_peak as f64 / 1024.0
                    )
                } else {
                    String::new()
                },
                om = d.occupancy_mean,
                op = d.occupancy_peak,
            ));
        }
        if let Some(v) = &self.variation {
            s.push_str(&format!(
                "\nvariation: accuracy proxy {mean:.4} ± {ci:.4} (floor {floor} {verdict}), \
                 aged {t:.0}s{refresh}",
                mean = v.accuracy_proxy_mean,
                ci = v.accuracy_proxy_ci95,
                floor = v.accuracy_floor,
                verdict = if v.meets_floor { "met" } else { "MISSED" },
                t = v.drift_time_s,
                refresh = if v.refresh_duty > 0.0 {
                    format!(
                        ", refresh every {:.0}s stealing {:.2e} of service time",
                        v.refresh_interval_s, v.refresh_duty
                    )
                } else {
                    String::new()
                },
            ));
        }
        s
    }

    /// Machine-readable report (stable keys; parsed back in tests).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", self.model.as_str())
            .set("dataset", self.dataset.as_str())
            .set("model_source", self.model_source.as_str())
            .set("mode", self.mode.as_str())
            .set("offered_qps", self.offered_qps)
            .set("concurrency", self.concurrency)
            .set("num_stages", self.num_stages)
            .set("num_chiplets", self.num_chiplets)
            .set("classes", classes_json(&self.classes))
            .set("bottleneck_stage", self.bottleneck_stage)
            .set("bottleneck_service_ns", self.bottleneck_service_ns)
            .set("bottleneck_qps", self.bottleneck_qps)
            .set("single_pass_ns", self.single_pass_ns)
            .set("single_shot_latency_ns", self.single_shot_latency_ns)
            .set("single_shot_energy_pj", self.single_shot_energy_pj)
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("dropped", self.dropped)
            .set("drop_rate", self.drop_rate())
            .set("throughput_qps", self.throughput_qps)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("mean_ms", self.mean_ms)
            .set(
                "chiplet_utilization",
                Json::Arr(self.chiplet_utilization.iter().map(|&u| Json::Num(u)).collect()),
            )
            .set("mean_utilization", self.mean_utilization)
            .set("peak_utilization", self.peak_utilization)
            .set("energy_per_inference_pj", self.energy_per_inference_pj)
            .set("qos_p99_target_ms", self.qos_p99_target_ms)
            .set("meets_qos", self.meets_qos())
            .set("wall_seconds", self.wall_seconds);
        let mut w = Json::obj();
        w.set("latency_ns", self.weight_load.latency_ns)
            .set("energy_pj", self.weight_load.energy_pj)
            .set("requests", self.weight_load.requests);
        o.set("weight_load", w);
        if let Some(f) = &self.failover {
            o.set("failover", f.to_json());
        }
        if let Some(d) = &self.decode {
            o.set("decode", d.to_json());
        }
        if let Some(v) = &self.variation {
            o.set("variation", v.to_json());
        }
        if let Some(meta) = &self.meta {
            o.set("meta", meta.to_json());
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SweepBuilder;

    #[test]
    fn sweep_json_pins_the_v3_schema_keys() {
        // the machine-readable sweep artifact is a published contract:
        // CI validates these keys, so renaming any of them is a
        // schema bump, not a refactor
        let cfg = SiamConfig::paper_default();
        let res = SweepBuilder::new(&cfg)
            .tiles(&[9, 16])
            .chiplet_counts(&[None])
            .run()
            .unwrap();
        let j = sweep_json(&cfg, &res);
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("siam-sweep/v3")
        );
        for key in ["model", "dataset", "model_source", "points", "stats", "meta", "best_by_edap"]
        {
            assert!(j.get(key).is_some(), "sweep json missing {key}");
        }
        let stats = j.get("stats").unwrap();
        for key in [
            "epoch_hits",
            "epoch_misses",
            "epoch_hit_rate",
            "epochs_cached",
            "epochs_hydrated",
            "points_known",
            "search",
            "engine_tiers",
            "wall_seconds",
            "points_per_sec",
        ] {
            assert!(stats.get(key).is_some(), "stats missing {key}");
        }
        assert_eq!(stats.get("search").and_then(Json::as_str), Some("exhaustive"));
        // no cache file: nothing hydrated, nothing known
        assert_eq!(stats.get("epochs_hydrated").and_then(Json::as_f64), Some(0.0));
        assert_eq!(stats.get("points_known").and_then(Json::as_f64), Some(0.0));
        // the meta block mirrors the cache counters, hydration included
        let cache = j.get("meta").unwrap().get("epoch_cache").unwrap();
        for key in ["hits", "misses", "hit_rate", "entries", "hydrated", "shards"] {
            assert!(cache.get(key).is_some(), "meta.epoch_cache missing {key}");
        }
        // the whole artifact round-trips through the JSON parser
        crate::util::json::parse(&j.to_string_pretty()).expect("sweep JSON parses");
    }
}
