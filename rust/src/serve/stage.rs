//! Stage-graph construction: turn one mapped design point into a linear
//! pipeline of service stages for the discrete-event engine.
//!
//! Weight-stationary IMC pins every layer's weights to its chiplet
//! partition, so consecutive inference requests pipeline across layer
//! stages (the steady-state regime of a serving deployment). Each weight
//! layer becomes one stage whose deterministic service time is exactly
//! its share of the single-shot latency:
//!
//! * the layer's bit-serial compute latency (circuit engine),
//! * its intra-chiplet NoC epochs (max across the chiplets the layer
//!   spans — they communicate in parallel),
//! * its inter-chiplet NoP transfers (summed — the interposer is one
//!   shared network).
//!
//! An ingress stage models the per-request input fetch from the DRAM
//! chiplet. The stage service times therefore partition `ingress +
//! single-shot latency` exactly, which pins the closed-loop
//! concurrency-1 throughput to the single-inference reciprocal — the
//! calibration the acceptance tests assert.
//!
//! Transformer workloads pipeline the same way: every weight-bearing
//! layer — attention blocks included — becomes one stage whose service
//! time already carries its digital score-matmul cost and, when the
//! layer's heads shard across chiplets, its NoP head-exchange epoch.
//! Digital-only layers (LayerNorm, GELU, standalone matmuls, embedding
//! lookups) have no crossbar partition of their own, so their latency
//! rides in the residual slot charged to the last stage, exactly like
//! pooling/activation units do for CNNs.

use crate::config::SiamConfig;
use crate::coordinator::pipeline::{
    stage_circuit, stage_dnn, stage_dram, stage_mapping, stage_noc, stage_nop,
};
use crate::coordinator::{SimReport, SweepContext};
use crate::dram::DramReport;
use anyhow::Result;

/// One service stage of the serving pipeline.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Weight-layer position this stage executes (`None` = DRAM ingress).
    pub layer: Option<usize>,
    /// Human-readable stage name (layer name or `ingress(dram)`).
    pub name: String,
    /// Deterministic service time per request, ns.
    pub service_ns: f64,
    /// `(chiplet, crossbars)` shares hosting the stage (empty for
    /// ingress); drives the per-chiplet utilization accounting.
    pub shares: Vec<(usize, usize)>,
}

/// The serving pipeline of one design point plus everything the report
/// needs: per-request energy, leakage power, and the single-shot
/// reference report.
#[derive(Debug, Clone)]
pub struct StageGraph {
    /// Pipeline stages in execution order (ingress first).
    pub stages: Vec<StageSpec>,
    /// Chiplets the architecture contains.
    pub num_chiplets: usize,
    /// Crossbar capacity of each chiplet (per-chiplet utilization
    /// denominators; heterogeneous classes make these differ).
    pub chiplet_capacities_xbars: Vec<usize>,
    /// Dynamic energy per request, pJ (compute + NoC + NoP + ingress
    /// DRAM fetch; leakage excluded — it accrues over wall-clock time).
    pub dynamic_energy_pj: f64,
    /// All-on leakage power of the system, µW (amortized over the
    /// serving window by the report).
    pub leakage_uw: f64,
    /// Per-request input fetch from the DRAM chiplet.
    pub ingress: DramReport,
    /// One-time weight load at deployment (reported separately; not a
    /// per-request cost).
    pub weight_load: DramReport,
    /// The single-shot (batch-1, unloaded) report of the same point.
    pub single_shot: SimReport,
    /// Analog variation under serving conditions (`None` with
    /// `[variation]` absent or inert): retention age capped at the
    /// drift-refresh interval. [`crate::serve::run_graph`] inflates the
    /// stage service times by its refresh duty and the report carries
    /// it as [`crate::coordinator::ServeReport::variation`].
    pub variation: Option<crate::variation::VariationReport>,
}

impl StageGraph {
    /// Build the stage graph for `cfg` against a sweep context. All
    /// heavy stage outputs flow through the context's shared caches
    /// (layer costs, NoC/NoP epochs, DRAM), so building a graph for a
    /// point the sweep already simulated re-simulates nothing.
    pub fn build(cfg: &SiamConfig, ctx: &SweepContext) -> Result<StageGraph> {
        cfg.validate()?;
        let dnn = stage_dnn(cfg, ctx)?;
        let stats = dnn.stats();
        let (map, placement, traffic, fault) = stage_mapping(cfg, &dnn)?;
        let circuit = stage_circuit(cfg, ctx, &dnn, &map, &traffic);
        let noc = stage_noc(cfg, ctx, &traffic, &map);
        let nop = stage_nop(cfg, ctx, &traffic, &placement, &map);
        let weight_load = stage_dram(cfg, ctx, &stats);

        // per-request input fetch: the ingress activations stream in
        // from the DRAM chiplet through the same timing model
        let input_bits = dnn.input.elems() as u64
            * cfg.dnn.activation_precision as u64
            * cfg.dnn.batch as u64;
        let ingress = crate::dram::estimate_with(input_bits.div_ceil(8) as usize, &cfg.dram);

        // NoC wall-clock comes from the report's per-layer ns (each
        // chiplet's cycles already converted in its own class's clock
        // domain); the interposer runs one package-wide clock.
        let clk_nop_ns = 1.0e3 / nop.eff_freq_mhz;
        let noc_ns = |layer: usize| -> f64 {
            noc.per_layer_ns
                .iter()
                .find(|&&(l, _)| l == layer)
                .map_or(0.0, |&(_, ns)| ns)
        };
        let nop_ns = |layer: usize| -> f64 {
            nop.per_layer_cycles
                .iter()
                .find(|&&(l, _)| l == layer)
                .map_or(0.0, |&(_, c)| c as f64 * clk_nop_ns)
        };

        let mut stages = Vec::with_capacity(map.per_layer.len() + 1);
        stages.push(StageSpec {
            layer: None,
            name: "ingress(dram)".into(),
            service_ns: ingress.latency_ns,
            shares: Vec::new(),
        });
        let mut layer_latency_sum = 0.0;
        for (li, lm) in map.per_layer.iter().enumerate() {
            let lc = circuit.per_layer[li];
            layer_latency_sum += lc.latency_ns;
            stages.push(StageSpec {
                layer: Some(li),
                name: dnn.layers[lm.layer_idx].name.clone(),
                service_ns: lc.latency_ns + noc_ns(li) + nop_ns(li),
                shares: lm.chiplets.iter().map(|s| (s.chiplet, s.xbars)).collect(),
            });
        }
        // the circuit engine's non-layer latency (pool/act units, global
        // accumulator) runs after the last weight layer: charge it there
        // so the stage times partition the single-shot latency exactly
        let residual_ns = (circuit.latency_ns - layer_latency_sum).max(0.0);
        if let Some(last) = stages.last_mut() {
            last.service_ns += residual_ns;
        }

        // the analog variation model reads the circuit outputs before
        // assembly moves them; variation-free points skip it entirely
        // (zero-variation bit-identity, pinned in tests)
        let (single_var, serve_var) = if cfg.variation.is_none() {
            (None, None)
        } else {
            let imc = crate::coordinator::pipeline::imc_energy(&circuit);
            (
                Some(crate::variation::evaluate(cfg, &map, imc)),
                Some(crate::variation::evaluate_serving(cfg, &map, imc)),
            )
        };
        let mut dynamic_energy_pj = (circuit.energy_pj - circuit.leakage_energy_pj)
            + noc.metrics.energy_pj
            + nop.metrics.energy_pj
            + ingress.energy_pj;
        if let Some(v) = &serve_var {
            dynamic_energy_pj += v.read_energy_delta_pj;
        }
        let num_chiplets = map.num_chiplets;
        // monolithic mode reports an unbounded chiplet capacity
        // (usize::MAX); the die physically contains exactly the mapped
        // crossbars, so that is the utilization denominator
        let chiplet_capacities_xbars: Vec<usize> = map
            .chiplet_capacities
            .iter()
            .map(|&cap| if cap == usize::MAX { map.total_xbars().max(1) } else { cap })
            .collect();
        let mut single_shot =
            SimReport::assemble(cfg, &dnn, &map, &traffic, circuit, noc, nop, weight_load, 0.0);
        single_shot.fault = fault;
        if let Some(v) = single_var {
            // keep the embedded single-shot consistent with `siam
            // simulate` on the same point
            single_shot.circuit.energy_pj += v.read_energy_delta_pj;
            single_shot.total.energy_pj += v.read_energy_delta_pj;
            single_shot.variation = Some(v);
        }

        Ok(StageGraph {
            stages,
            num_chiplets,
            chiplet_capacities_xbars,
            dynamic_energy_pj,
            leakage_uw: single_shot.total.leakage_uw,
            ingress,
            weight_load,
            single_shot,
            variation: serve_var,
        })
    }

    /// Sum of all stage service times: the time one request takes to
    /// traverse the empty pipeline, ns.
    pub fn single_pass_ns(&self) -> f64 {
        self.stages.iter().map(|s| s.service_ns).sum()
    }

    /// `(index, service_ns)` of the slowest stage — the pipeline's
    /// bottleneck, whose service rate caps the deliverable throughput.
    pub fn bottleneck(&self) -> (usize, f64) {
        self.stages
            .iter()
            .enumerate()
            .map(|(i, s)| (i, s.service_ns))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .expect("stage graph is never empty")
    }

    /// Analytic throughput ceiling: the bottleneck stage's service
    /// rate, inferences/s.
    pub fn bottleneck_qps(&self) -> f64 {
        1.0e9 / self.bottleneck().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SiamConfig;

    #[test]
    fn stage_times_partition_single_shot_latency() {
        let cfg = SiamConfig::paper_default();
        let ctx = SweepContext::new(&cfg).unwrap();
        let g = StageGraph::build(&cfg, &ctx).unwrap();
        // one ingress stage + one stage per mapped weight layer
        assert!(g.stages.len() > 100, "resnet110 has >100 weight layers");
        assert_eq!(g.stages[0].layer, None);
        assert!(g.stages[1..].iter().all(|s| s.layer.is_some()));
        // Σ stage services == ingress + single-shot latency (exactly up
        // to float assembly order)
        let want = g.ingress.latency_ns + g.single_shot.total.latency_ns;
        let got = g.single_pass_ns();
        assert!(
            (got - want).abs() / want < 1e-9,
            "stage sum {got} vs single-shot {want}"
        );
        // the ingress input fetch is tiny next to an inference
        assert!(g.ingress.latency_ns < 0.01 * g.single_shot.total.latency_ns);
        let (_, b) = g.bottleneck();
        assert!(b > 0.0 && b <= got);
    }

    #[test]
    fn shares_stay_within_chiplet_capacity() {
        let cfg = SiamConfig::paper_default();
        let ctx = SweepContext::new(&cfg).unwrap();
        let g = StageGraph::build(&cfg, &ctx).unwrap();
        let mut used = vec![0usize; g.num_chiplets];
        for s in &g.stages {
            for &(c, x) in &s.shares {
                used[c] += x;
            }
        }
        assert_eq!(g.chiplet_capacities_xbars.len(), g.num_chiplets);
        assert!(used
            .iter()
            .zip(&g.chiplet_capacities_xbars)
            .all(|(&u, &cap)| u <= cap));
    }

    #[test]
    fn graph_reuses_sweep_context_caches() {
        let cfg = SiamConfig::paper_default();
        let ctx = SweepContext::new(&cfg).unwrap();
        let a = StageGraph::build(&cfg, &ctx).unwrap();
        let misses = ctx.epoch_cache().misses();
        let b = StageGraph::build(&cfg, &ctx).unwrap();
        // the second build answers every epoch from the shared cache
        assert_eq!(ctx.epoch_cache().misses(), misses, "no new epoch simulations");
        let bits = |g: &StageGraph| {
            g.stages.iter().map(|s| s.service_ns.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(&a), bits(&b), "cached rebuild is bit-identical");
    }
}
