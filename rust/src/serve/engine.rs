//! The deterministic discrete-event engine: a tandem of service stages
//! with bounded queues and blocking-after-service back-pressure.
//!
//! The engine is deliberately decoupled from the hardware model — it
//! consumes only a vector of per-stage service times (ns) — so its
//! invariants (conservation, determinism, back-pressure) are testable on
//! synthetic stage graphs without running the SIAM pipeline.
//!
//! Semantics:
//!
//! * Each stage serves one request at a time, in FIFO order, with a
//!   deterministic service time.
//! * Each stage owns a bounded input queue of `queue_depth` slots. A
//!   stage that finishes a request while the downstream queue is full
//!   **blocks**: it holds the finished request and cannot start another
//!   until space frees (blocking-after-service, the standard production
//!   back-pressure model).
//! * Open-loop arrivals that find the ingress queue full are shed and
//!   counted as `dropped` (admission control keeps the system stable
//!   past saturation). Closed-loop clients never shed — a client whose
//!   request cannot be admitted waits for an ingress slot.
//!
//! Events are processed in `(time, sequence)` order from a binary heap;
//! all state updates are pure f64/integer arithmetic in a fixed order,
//! so a given `(stage graph, workload)` input always produces
//! bit-identical statistics, on any machine and independent of any
//! thread pool the caller runs engines on.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Engine tuning knobs (from the `[serve]` config block).
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    /// Bounded per-stage queue depth.
    pub queue_depth: usize,
}

/// Observer of the engine's event stream — the tracing hook behind
/// `siam serve --trace`.
///
/// Every method has a no-op default, so a sink implements only the
/// events it cares about. Sinks are pure observers: the engine hands
/// them timestamps and identifiers *after* each state update, and
/// nothing flows back, so an instrumented run is bit-identical to an
/// uninstrumented one (the [`NoopSink`] used by [`run`] /
/// [`run_with_failover`] monomorphizes every call site away).
///
/// All timestamps are simulated nanoseconds — deterministic for a given
/// `(stage graph, workload)` input, never host wall-clock.
pub trait EngineSink {
    /// Request `req` was admitted to the ingress queue.
    fn admitted(&mut self, _t_ns: f64, _req: u32) {}
    /// Closed-loop request `req` found the ingress full and waits.
    fn queued(&mut self, _t_ns: f64, _req: u32) {}
    /// Open-loop request `req` was shed at the full ingress.
    fn shed(&mut self, _t_ns: f64, _req: u32) {}
    /// Stage `stage` started serving request `req`.
    fn serve_start(&mut self, _t_ns: f64, _stage: usize, _req: u32) {}
    /// Stage `stage` finished serving request `req`.
    fn serve_end(&mut self, _t_ns: f64, _stage: usize, _req: u32) {}
    /// Stage `stage` finished `req` but the downstream queue is full —
    /// the stage holds the request and stalls (blocking-after-service).
    fn blocked(&mut self, _t_ns: f64, _stage: usize, _req: u32) {}
    /// Stage `stage` handed its held request `req` downstream and is
    /// free again.
    fn unblocked(&mut self, _t_ns: f64, _stage: usize, _req: u32) {}
    /// Request `req` completed the full pipeline with the given sojourn.
    fn completed(&mut self, _t_ns: f64, _req: u32, _latency_ns: f64) {}
    /// The failover plan's failure fired: `dead_stages` went down,
    /// shedding `shed` in-flight requests.
    fn failed(&mut self, _t_ns: f64, _dead_stages: &[usize], _shed: usize) {}
    /// The failover plan's remap completed; all stages are back up.
    fn resumed(&mut self, _t_ns: f64) {}
}

/// The do-nothing [`EngineSink`] behind the uninstrumented entry
/// points; monomorphization erases every sink call.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl EngineSink for NoopSink {}

/// A mid-run chiplet-failure scenario for [`run_with_failover`].
///
/// At `fail_time_ns` the `dead_stages` go down: their in-flight
/// requests (in service, held blocked, or queued) are shed and counted
/// in [`RunStats::failover_shed`], and the stages stop serving. Work
/// keeps flowing *into* a dead stage's bounded queue (back-pressure
/// eventually jams the pipeline up to the ingress, where open-loop
/// arrivals shed normally). If `resume` is set, at its timestamp every
/// stage comes back up with the new per-stage service times — the
/// remapped (degraded) pipeline — and queued work drains; with `resume
/// = None` the pipeline stays jammed for the rest of the run (the
/// no-spare outcome).
#[derive(Debug, Clone)]
pub struct FailoverPlan {
    /// Failure instant, ns.
    pub fail_time_ns: f64,
    /// Indices of the stages hosted on the failed chiplet.
    pub dead_stages: Vec<usize>,
    /// `(resume_time_ns, service_ns)` of the remapped pipeline (must
    /// have the same stage count); `None` = remap impossible.
    pub resume: Option<(f64, Vec<f64>)>,
}

/// The request stream fed to the engine.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Open loop: pre-generated arrival timestamps, ns (ascending).
    Open {
        /// Arrival time of each request, ns.
        arrivals: Vec<f64>,
    },
    /// Closed loop: `concurrency` clients keep exactly that many
    /// requests outstanding until `requests` have been issued.
    Closed {
        /// Outstanding requests held by the client pool.
        concurrency: usize,
        /// Total requests to issue.
        requests: usize,
    },
}

/// Raw outcome of one engine run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Requests offered (open: all arrivals; closed: the request budget).
    pub offered: usize,
    /// Requests that completed the full pipeline.
    pub completed: usize,
    /// Open-loop requests shed at the ingress queue.
    pub dropped: usize,
    /// Sojourn time (arrival → completion) per completed request, ns,
    /// in completion order.
    pub latencies_ns: Vec<f64>,
    /// Completion timestamp per completed request, ns, ascending.
    pub completion_times_ns: Vec<f64>,
    /// First request arrival, ns.
    pub first_arrival_ns: f64,
    /// Last completion, ns.
    pub last_completion_ns: f64,
    /// Accumulated busy time per stage, ns (blocked time excluded —
    /// blocking is starvation, not work).
    pub stage_busy_ns: Vec<f64>,
    /// Requests shed off dead stages at the failure instant (in
    /// service, held blocked, or queued there). Always 0 without a
    /// [`FailoverPlan`].
    pub failover_shed: usize,
}

impl RunStats {
    /// Wall-clock window the run covered, ns.
    pub fn window_ns(&self) -> f64 {
        (self.last_completion_ns - self.first_arrival_ns).max(0.0)
    }

    /// Steady-state delivered throughput, inferences/s: completions per
    /// unit time over the post-warm-up completion window (the first 20 %
    /// of completions are treated as pipeline fill and excluded, which
    /// removes the fill/drain bias from short runs).
    pub fn steady_throughput_qps(&self) -> f64 {
        let n = self.completion_times_ns.len();
        if n < 2 {
            return if self.window_ns() > 0.0 {
                self.completed as f64 / self.window_ns() * 1.0e9
            } else {
                0.0
            };
        }
        let k = n / 5;
        let span = self.completion_times_ns[n - 1] - self.completion_times_ns[k];
        if span <= 0.0 {
            self.completed as f64 / self.window_ns().max(1e-9) * 1.0e9
        } else {
            (n - 1 - k) as f64 / span * 1.0e9
        }
    }
}

/// One pending event. Ordering is `(time, sequence)` — the sequence
/// number breaks simultaneous-event ties deterministically in push
/// order.
struct Ev {
    t: f64,
    seq: u64,
    kind: Kind,
}

enum Kind {
    /// Open-loop request `id` reaches the ingress.
    Arrive(u32),
    /// Stage `j` finishes its in-service request. The epoch stamps the
    /// stage's incarnation at scheduling time: a failure bumps the
    /// stage epoch, so a finish scheduled before the failure arrives
    /// stale and is ignored (the request it would have finished was
    /// shed with the chiplet).
    Finish { j: u32, epoch: u32 },
    /// The failover plan's failure instant.
    Fail,
    /// The failover plan's remap completes: stages come back up with
    /// the degraded service times.
    Resume,
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t.total_cmp(&o.t) == std::cmp::Ordering::Equal && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.seq.cmp(&o.seq))
    }
}

struct Stage {
    queue: VecDeque<u32>,
    serving: Option<u32>,
    blocked: Option<u32>,
    service_ns: f64,
    busy_ns: f64,
    /// The chiplet hosting this stage has failed and not yet remapped.
    down: bool,
    /// Incarnation counter; bumped when the stage dies so in-flight
    /// finish events go stale.
    epoch: u32,
}

struct Sim {
    stages: Vec<Stage>,
    cap: usize,
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Arrival time of every request ever created (indexed by id).
    arrival_ns: Vec<f64>,
    /// Closed-loop requests issued but waiting for an ingress slot.
    pending: VecDeque<u32>,
    /// Closed loop: requests still to issue (0 for open loop).
    to_issue: usize,
    stats: RunStats,
}

impl Sim {
    fn push_event(&mut self, t: f64, kind: Kind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq, kind }));
    }

    fn new_request(&mut self, t: f64) -> u32 {
        let id = self.arrival_ns.len() as u32;
        self.arrival_ns.push(t);
        id
    }

    /// Stage `j` starts its next queued request if it is idle; popping
    /// the queue frees a slot, which back-fills from the blocked
    /// upstream stage (or, at the ingress, from waiting closed-loop
    /// clients), cascading as far up as space propagates.
    fn pull<S: EngineSink>(&mut self, j: usize, t: f64, sink: &mut S) {
        if self.stages[j].down
            || self.stages[j].serving.is_some()
            || self.stages[j].blocked.is_some()
        {
            return;
        }
        let Some(r) = self.stages[j].queue.pop_front() else {
            return;
        };
        self.stages[j].serving = Some(r);
        let s = self.stages[j].service_ns;
        let epoch = self.stages[j].epoch;
        self.stages[j].busy_ns += s;
        sink.serve_start(t, j, r);
        self.push_event(t + s, Kind::Finish { j: j as u32, epoch });
        self.backfill(j, t, sink);
    }

    /// A slot just freed in stage `j`'s queue: refill it from upstream.
    fn backfill<S: EngineSink>(&mut self, j: usize, t: f64, sink: &mut S) {
        if j == 0 {
            if let Some(r) = self.pending.pop_front() {
                debug_assert!(self.stages[0].queue.len() < self.cap);
                self.stages[0].queue.push_back(r);
                sink.admitted(t, r);
                self.pull(0, t, sink);
            }
            return;
        }
        let up = j - 1;
        if let Some(r) = self.stages[up].blocked.take() {
            debug_assert!(self.stages[j].queue.len() < self.cap);
            self.stages[j].queue.push_back(r);
            sink.unblocked(t, up, r);
            self.pull(up, t, sink);
        }
    }

    fn finish<S: EngineSink>(&mut self, j: usize, epoch: u32, t: f64, sink: &mut S) {
        if self.stages[j].epoch != epoch {
            // the chiplet hosting this stage died mid-service: the
            // request this finish would complete was already shed
            return;
        }
        let r = self.stages[j].serving.take().expect("finish on idle stage");
        sink.serve_end(t, j, r);
        if j + 1 == self.stages.len() {
            self.complete(r, t, sink);
        } else if self.stages[j + 1].queue.len() < self.cap {
            self.stages[j + 1].queue.push_back(r);
            self.pull(j + 1, t, sink);
        } else {
            // downstream full: hold the finished request, stall
            self.stages[j].blocked = Some(r);
            sink.blocked(t, j, r);
            return;
        }
        self.pull(j, t, sink);
    }

    fn complete<S: EngineSink>(&mut self, r: u32, t: f64, sink: &mut S) {
        self.stats.completed += 1;
        let latency = t - self.arrival_ns[r as usize];
        self.stats.latencies_ns.push(latency);
        self.stats.completion_times_ns.push(t);
        self.stats.last_completion_ns = t;
        sink.completed(t, r, latency);
        if self.to_issue > 0 {
            self.to_issue -= 1;
            let next = self.new_request(t);
            self.admit_or_wait(next, t, sink);
        }
    }

    /// Closed-loop admission: queue at the ingress if a slot is free,
    /// otherwise wait (latency accrues from issue time).
    fn admit_or_wait<S: EngineSink>(&mut self, r: u32, t: f64, sink: &mut S) {
        if self.stages[0].queue.len() < self.cap {
            self.stages[0].queue.push_back(r);
            sink.admitted(t, r);
            self.pull(0, t, sink);
        } else {
            self.pending.push_back(r);
            sink.queued(t, r);
        }
    }

    /// Open-loop admission: shed when the ingress queue is full.
    fn arrive<S: EngineSink>(&mut self, r: u32, t: f64, sink: &mut S) {
        if self.stages[0].queue.len() < self.cap {
            self.stages[0].queue.push_back(r);
            sink.admitted(t, r);
            self.pull(0, t, sink);
        } else {
            self.stats.dropped += 1;
            sink.shed(t, r);
        }
    }

    /// The failure instant: dead stages shed their in-flight work and
    /// stop serving. Their freed queue slots immediately refill from
    /// the jammed upstream, so work keeps accumulating behind the dead
    /// stage during the outage (served after a resume, or stuck until
    /// the end of the run without one).
    fn fail<S: EngineSink>(&mut self, dead: &[usize], t: f64, sink: &mut S) {
        let mut shed_total = 0usize;
        for &j in dead {
            let st = &mut self.stages[j];
            st.down = true;
            st.epoch = st.epoch.wrapping_add(1);
            let mut shed = st.queue.len();
            st.queue.clear();
            if st.serving.take().is_some() {
                shed += 1;
            }
            if st.blocked.take().is_some() {
                shed += 1;
            }
            self.stats.failover_shed += shed;
            shed_total += shed;
            for _ in 0..self.cap {
                self.backfill(j, t, sink);
            }
        }
        sink.failed(t, dead, shed_total);
    }

    /// Remap complete: every stage comes back up with the degraded
    /// pipeline's service times and queued work drains.
    fn resume<S: EngineSink>(&mut self, services: &[f64], t: f64, sink: &mut S) {
        for (st, &s) in self.stages.iter_mut().zip(services) {
            st.down = false;
            st.service_ns = s;
        }
        sink.resumed(t);
        for j in 0..self.stages.len() {
            self.pull(j, t, sink);
            self.backfill(j, t, sink);
        }
    }
}

/// Run the pipeline of `service_ns` stages against a workload and
/// return the raw statistics. Deterministic: identical inputs produce
/// bit-identical outputs.
pub fn run(service_ns: &[f64], params: EngineParams, workload: Workload) -> RunStats {
    run_with_failover(service_ns, params, workload, None)
}

/// [`run`], optionally with a mid-run chiplet-failure scenario. With
/// `plan = None` this is exactly `run` — the zero-fault event sequence
/// is untouched, bit for bit. Deterministic either way.
pub fn run_with_failover(
    service_ns: &[f64],
    params: EngineParams,
    workload: Workload,
    plan: Option<&FailoverPlan>,
) -> RunStats {
    run_observed(service_ns, params, workload, plan, &mut NoopSink)
}

/// [`run_with_failover`] with an [`EngineSink`] observing the event
/// stream — the instrumented entry point behind `siam serve --trace`.
/// The sink sees every state transition (admission, shedding, service
/// spans, blocking, failure/resume) in simulated time; statistics are
/// bit-identical to the uninstrumented run.
pub fn run_observed<S: EngineSink>(
    service_ns: &[f64],
    params: EngineParams,
    workload: Workload,
    plan: Option<&FailoverPlan>,
    sink: &mut S,
) -> RunStats {
    assert!(!service_ns.is_empty(), "pipeline needs at least one stage");
    assert!(params.queue_depth > 0, "queues need at least one slot");
    if let Some(p) = plan {
        assert!(
            p.dead_stages.iter().all(|&j| j < service_ns.len()),
            "failover plan targets a stage outside the pipeline"
        );
        if let Some((t, s)) = &p.resume {
            assert!(*t >= p.fail_time_ns, "remap cannot complete before the failure");
            assert_eq!(
                s.len(),
                service_ns.len(),
                "remapped pipeline must keep the stage count"
            );
        }
    }
    let mut sim = Sim {
        stages: service_ns
            .iter()
            .map(|&s| Stage {
                queue: VecDeque::new(),
                serving: None,
                blocked: None,
                service_ns: s,
                busy_ns: 0.0,
                down: false,
                epoch: 0,
            })
            .collect(),
        cap: params.queue_depth,
        heap: BinaryHeap::new(),
        seq: 0,
        arrival_ns: Vec::new(),
        pending: VecDeque::new(),
        to_issue: 0,
        stats: RunStats::default(),
    };

    // failure/resume events first: at an equal timestamp the failure
    // precedes arrivals and finishes (their sequence numbers are later)
    if let Some(p) = plan {
        sim.push_event(p.fail_time_ns, Kind::Fail);
        if let Some((t, _)) = &p.resume {
            sim.push_event(*t, Kind::Resume);
        }
    }

    match workload {
        Workload::Open { arrivals } => {
            sim.stats.offered = arrivals.len();
            sim.stats.first_arrival_ns = arrivals.first().copied().unwrap_or(0.0);
            for &t in &arrivals {
                let id = sim.new_request(t);
                sim.push_event(t, Kind::Arrive(id));
            }
        }
        Workload::Closed { concurrency, requests } => {
            assert!(concurrency > 0, "closed loop needs at least one client");
            sim.stats.offered = requests;
            sim.stats.first_arrival_ns = 0.0;
            let initial = concurrency.min(requests);
            sim.to_issue = requests - initial;
            for _ in 0..initial {
                let id = sim.new_request(0.0);
                sim.admit_or_wait(id, 0.0, sink);
            }
        }
    }

    while let Some(Reverse(ev)) = sim.heap.pop() {
        match ev.kind {
            Kind::Arrive(r) => sim.arrive(r, ev.t, sink),
            Kind::Finish { j, epoch } => sim.finish(j as usize, epoch, ev.t, sink),
            Kind::Fail => {
                let dead = plan.expect("fail event without a plan").dead_stages.clone();
                sim.fail(&dead, ev.t, sink);
            }
            Kind::Resume => {
                let (_, services) =
                    plan.and_then(|p| p.resume.as_ref()).expect("resume event without a plan");
                sim.resume(services, ev.t, sink);
            }
        }
    }

    sim.stats.stage_busy_ns = sim.stages.iter().map(|s| s.busy_ns).collect();
    sim.stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open(rate_gap_ns: f64, n: usize) -> Workload {
        Workload::Open {
            arrivals: (1..=n).map(|i| i as f64 * rate_gap_ns).collect(),
        }
    }

    #[test]
    fn single_request_latency_is_service_sum() {
        let stages = [10.0, 20.0, 5.0];
        let stats = run(
            &stages,
            EngineParams { queue_depth: 4 },
            Workload::Closed { concurrency: 1, requests: 1 },
        );
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.latencies_ns[0], 35.0);
    }

    #[test]
    fn closed_loop_concurrency_one_paces_at_service_sum() {
        let stages = [10.0, 20.0, 5.0];
        let stats = run(
            &stages,
            EngineParams { queue_depth: 4 },
            Workload::Closed { concurrency: 1, requests: 50 },
        );
        assert_eq!(stats.completed, 50);
        // every sojourn is exactly the pipeline traversal
        assert!(stats.latencies_ns.iter().all(|&l| l == 35.0));
        let qps = stats.steady_throughput_qps();
        assert!((qps - 1.0e9 / 35.0).abs() / (1.0e9 / 35.0) < 1e-9, "{qps}");
    }

    #[test]
    fn saturated_pipeline_paces_at_bottleneck() {
        // bottleneck 20 ns => steady completions every 20 ns
        let stages = [10.0, 20.0, 5.0];
        let stats = run(
            &stages,
            EngineParams { queue_depth: 2 },
            Workload::Closed { concurrency: 8, requests: 200 },
        );
        assert_eq!(stats.completed, 200);
        let gaps: Vec<f64> = stats.completion_times_ns.windows(2).map(|w| w[1] - w[0]).collect();
        // after fill, every inter-completion gap equals the bottleneck
        assert!(gaps[gaps.len() / 2..].iter().all(|&g| (g - 20.0).abs() < 1e-9));
        let qps = stats.steady_throughput_qps();
        assert!((qps - 5.0e7).abs() / 5.0e7 < 1e-9, "{qps}");
    }

    #[test]
    fn open_loop_sheds_when_saturated() {
        // offered every 5 ns, bottleneck 20 ns, tiny queues => drops
        let stats = run(&[10.0, 20.0], EngineParams { queue_depth: 1 }, open(5.0, 400));
        assert!(stats.dropped > 0, "saturated ingress must shed");
        assert_eq!(stats.completed + stats.dropped, 400, "conservation");
        // delivered still paces at the bottleneck
        let qps = stats.steady_throughput_qps();
        assert!((qps - 5.0e7).abs() / 5.0e7 < 1e-6, "{qps}");
    }

    #[test]
    fn open_loop_below_saturation_delivers_offered_rate() {
        // offered every 50 ns >> bottleneck 20 ns: no queueing, no drops
        let stats = run(&[10.0, 20.0], EngineParams { queue_depth: 4 }, open(50.0, 200));
        assert_eq!(stats.dropped, 0);
        assert_eq!(stats.completed, 200);
        assert!(stats.latencies_ns.iter().all(|&l| l == 30.0));
    }

    #[test]
    fn back_pressure_bounds_buffered_requests() {
        // deep pipeline behind a slow tail stage: with queue depth Q the
        // requests resident in the system are bounded by stages*(Q+2)
        let stages = [1.0, 1.0, 1.0, 50.0];
        let q = 2;
        let stats = run(&stages, EngineParams { queue_depth: q }, open(1.0, 500));
        assert_eq!(stats.completed + stats.dropped, 500);
        // the tail stage admits one per 50 ns: most of the flood is shed
        assert!(stats.dropped > 300, "dropped {}", stats.dropped);
        // all completed latencies bounded by residency * bottleneck
        let bound = (stages.len() * (q + 2)) as f64 * 50.0;
        assert!(stats.latencies_ns.iter().all(|&l| l <= bound));
    }

    #[test]
    fn engine_is_bit_deterministic() {
        let stages = [3.0, 7.5, 2.25, 11.0];
        let w = || open(4.0, 300);
        let a = run(&stages, EngineParams { queue_depth: 2 }, w());
        let b = run(&stages, EngineParams { queue_depth: 2 }, w());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.latencies_ns), bits(&b.latencies_ns));
        assert_eq!(bits(&a.stage_busy_ns), bits(&b.stage_busy_ns));
    }

    #[test]
    fn failover_with_no_plan_is_bitwise_run() {
        let stages = [3.0, 7.5, 2.25, 11.0];
        let a = run(&stages, EngineParams { queue_depth: 2 }, open(4.0, 300));
        let b = run_with_failover(&stages, EngineParams { queue_depth: 2 }, open(4.0, 300), None);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(b.failover_shed, 0);
        assert_eq!(bits(&a.latencies_ns), bits(&b.latencies_ns));
        assert_eq!(bits(&a.completion_times_ns), bits(&b.completion_times_ns));
        assert_eq!(bits(&a.stage_busy_ns), bits(&b.stage_busy_ns));
    }

    #[test]
    fn failover_sheds_inflight_then_recovers() {
        // bottleneck stage 1 dies at t=1000 mid-stream, comes back 500 ns
        // later: its in-flight work is shed, everything else eventually
        // completes, and requests are conserved exactly
        let stages = [10.0, 20.0, 5.0];
        let plan = FailoverPlan {
            fail_time_ns: 1000.0,
            dead_stages: vec![1],
            resume: Some((1500.0, vec![10.0, 25.0, 5.0])),
        };
        let stats = run_with_failover(
            &stages,
            EngineParams { queue_depth: 4 },
            open(25.0, 200),
            Some(&plan),
        );
        assert!(stats.failover_shed > 0, "the dead stage held work at t=1000");
        assert_eq!(
            stats.completed + stats.dropped + stats.failover_shed,
            200,
            "conservation with shedding"
        );
        assert!(stats.completed > 150, "most of the stream survives a 500 ns outage");
        // the run outlives the outage: completions continue past resume
        assert!(stats.last_completion_ns > 1500.0);
        // degraded service time shows up in post-resume pacing
        let after: Vec<f64> = stats
            .completion_times_ns
            .iter()
            .copied()
            .filter(|&t| t > 1600.0)
            .collect();
        assert!(after.len() > 10, "pipeline drains after the remap");
        let gaps_ok = after.windows(2).all(|w| w[1] - w[0] >= 25.0 - 1e-9);
        assert!(gaps_ok, "post-resume completions pace at the degraded bottleneck");
    }

    #[test]
    fn failover_without_resume_jams_the_pipeline() {
        // no spare capacity: the dead stage never comes back, the jam
        // back-pressures to the ingress and the tail of the stream sheds
        let stages = [10.0, 20.0, 5.0];
        let plan = FailoverPlan { fail_time_ns: 1000.0, dead_stages: vec![1], resume: None };
        let stats = run_with_failover(
            &stages,
            EngineParams { queue_depth: 2 },
            open(25.0, 400),
            Some(&plan),
        );
        let healthy = run(&stages, EngineParams { queue_depth: 2 }, open(25.0, 400));
        assert_eq!(healthy.dropped, 0, "the healthy run keeps up at 25 ns spacing");
        assert!(stats.dropped > 300, "jammed ingress sheds the stream: {}", stats.dropped);
        assert!(stats.completed < 50);
        // requests stuck in queues at the end are neither completed nor
        // dropped — strict inequality
        assert!(stats.completed + stats.dropped + stats.failover_shed < 400);
        // downstream of the dead stage still drains what it held
        assert!(stats.last_completion_ns < 1100.0, "{}", stats.last_completion_ns);
    }

    #[test]
    fn stale_finish_after_death_is_ignored() {
        // a single request in service on the dying stage: its finish
        // event fires after the failure and must not complete it
        let stages = [1.0, 100.0];
        let plan = FailoverPlan { fail_time_ns: 50.0, dead_stages: vec![1], resume: None };
        let stats = run_with_failover(
            &stages,
            EngineParams { queue_depth: 2 },
            Workload::Open { arrivals: vec![10.0] },
            Some(&plan),
        );
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.failover_shed, 1);
        assert_eq!(stats.dropped, 0);
    }

    #[test]
    fn sink_observes_conserved_events_without_perturbing_stats() {
        #[derive(Default)]
        struct Counter {
            admitted: usize,
            shed: usize,
            starts: usize,
            ends: usize,
            completed: usize,
            blocked: usize,
            unblocked: usize,
        }
        impl EngineSink for Counter {
            fn admitted(&mut self, _t: f64, _r: u32) {
                self.admitted += 1;
            }
            fn shed(&mut self, _t: f64, _r: u32) {
                self.shed += 1;
            }
            fn serve_start(&mut self, _t: f64, _j: usize, _r: u32) {
                self.starts += 1;
            }
            fn serve_end(&mut self, _t: f64, _j: usize, _r: u32) {
                self.ends += 1;
            }
            fn blocked(&mut self, _t: f64, _j: usize, _r: u32) {
                self.blocked += 1;
            }
            fn unblocked(&mut self, _t: f64, _j: usize, _r: u32) {
                self.unblocked += 1;
            }
            fn completed(&mut self, _t: f64, _r: u32, _l: f64) {
                self.completed += 1;
            }
        }

        let stages = [3.0, 7.5, 2.25, 11.0];
        let mut sink = Counter::default();
        let observed = run_observed(
            &stages,
            EngineParams { queue_depth: 1 },
            open(4.0, 300),
            None,
            &mut sink,
        );
        let plain = run(&stages, EngineParams { queue_depth: 1 }, open(4.0, 300));

        // observation is free: stats bit-identical to the plain run
        assert_eq!(observed.completed, plain.completed);
        assert_eq!(observed.dropped, plain.dropped);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&observed.latencies_ns), bits(&plain.latencies_ns));
        assert_eq!(bits(&observed.stage_busy_ns), bits(&plain.stage_busy_ns));

        // and the event stream is conserved
        assert_eq!(sink.admitted, observed.completed + in_flight_at_end(&observed, &stages));
        assert_eq!(sink.shed, observed.dropped);
        assert_eq!(sink.completed, observed.completed);
        assert_eq!(sink.starts, sink.ends, "every service span closes");
        assert_eq!(sink.blocked, sink.unblocked, "every stall resolves in a drained run");
        assert!(sink.blocked > 0, "queue_depth 1 under load must stall");
    }

    /// Requests admitted but still resident when the event heap drained
    /// (none, for an open-loop run that fully drains).
    fn in_flight_at_end(stats: &RunStats, _stages: &[f64]) -> usize {
        stats.offered - stats.completed - stats.dropped
    }

    #[test]
    fn sink_sees_failure_and_resume() {
        #[derive(Default)]
        struct FailWatch {
            failed_at: Option<f64>,
            shed: usize,
            resumed_at: Option<f64>,
        }
        impl EngineSink for FailWatch {
            fn failed(&mut self, t: f64, dead: &[usize], shed: usize) {
                assert_eq!(dead, [1]);
                self.failed_at = Some(t);
                self.shed = shed;
            }
            fn resumed(&mut self, t: f64) {
                self.resumed_at = Some(t);
            }
        }
        let stages = [10.0, 20.0, 5.0];
        let plan = FailoverPlan {
            fail_time_ns: 1000.0,
            dead_stages: vec![1],
            resume: Some((1500.0, vec![10.0, 25.0, 5.0])),
        };
        let mut sink = FailWatch::default();
        let stats = run_observed(
            &stages,
            EngineParams { queue_depth: 4 },
            open(25.0, 200),
            Some(&plan),
            &mut sink,
        );
        assert_eq!(sink.failed_at, Some(1000.0));
        assert_eq!(sink.resumed_at, Some(1500.0));
        assert_eq!(sink.shed, stats.failover_shed);
    }

    #[test]
    fn busy_time_counts_work_not_blocking() {
        // stage 0 is fast but blocked most of the time by stage 1
        let stats = run(
            &[1.0, 10.0],
            EngineParams { queue_depth: 1 },
            Workload::Closed { concurrency: 4, requests: 100 },
        );
        assert_eq!(stats.stage_busy_ns[0], 100.0); // 100 × 1 ns of real work
        assert_eq!(stats.stage_busy_ns[1], 1000.0);
    }
}
