//! The deterministic discrete-event engine: a tandem of service stages
//! with bounded queues and blocking-after-service back-pressure.
//!
//! The engine is deliberately decoupled from the hardware model — it
//! consumes only a vector of per-stage service times (ns) — so its
//! invariants (conservation, determinism, back-pressure) are testable on
//! synthetic stage graphs without running the SIAM pipeline.
//!
//! Semantics:
//!
//! * Each stage serves one request at a time, in FIFO order, with a
//!   deterministic service time.
//! * Each stage owns a bounded input queue of `queue_depth` slots. A
//!   stage that finishes a request while the downstream queue is full
//!   **blocks**: it holds the finished request and cannot start another
//!   until space frees (blocking-after-service, the standard production
//!   back-pressure model).
//! * Open-loop arrivals that find the ingress queue full are shed and
//!   counted as `dropped` (admission control keeps the system stable
//!   past saturation). Closed-loop clients never shed — a client whose
//!   request cannot be admitted waits for an ingress slot.
//!
//! Events are processed in `(time, sequence)` order from a binary heap;
//! all state updates are pure f64/integer arithmetic in a fixed order,
//! so a given `(stage graph, workload)` input always produces
//! bit-identical statistics, on any machine and independent of any
//! thread pool the caller runs engines on.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Engine tuning knobs (from the `[serve]` config block).
#[derive(Debug, Clone, Copy)]
pub struct EngineParams {
    /// Bounded per-stage queue depth.
    pub queue_depth: usize,
}

/// The request stream fed to the engine.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Open loop: pre-generated arrival timestamps, ns (ascending).
    Open {
        /// Arrival time of each request, ns.
        arrivals: Vec<f64>,
    },
    /// Closed loop: `concurrency` clients keep exactly that many
    /// requests outstanding until `requests` have been issued.
    Closed {
        /// Outstanding requests held by the client pool.
        concurrency: usize,
        /// Total requests to issue.
        requests: usize,
    },
}

/// Raw outcome of one engine run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Requests offered (open: all arrivals; closed: the request budget).
    pub offered: usize,
    /// Requests that completed the full pipeline.
    pub completed: usize,
    /// Open-loop requests shed at the ingress queue.
    pub dropped: usize,
    /// Sojourn time (arrival → completion) per completed request, ns,
    /// in completion order.
    pub latencies_ns: Vec<f64>,
    /// Completion timestamp per completed request, ns, ascending.
    pub completion_times_ns: Vec<f64>,
    /// First request arrival, ns.
    pub first_arrival_ns: f64,
    /// Last completion, ns.
    pub last_completion_ns: f64,
    /// Accumulated busy time per stage, ns (blocked time excluded —
    /// blocking is starvation, not work).
    pub stage_busy_ns: Vec<f64>,
}

impl RunStats {
    /// Wall-clock window the run covered, ns.
    pub fn window_ns(&self) -> f64 {
        (self.last_completion_ns - self.first_arrival_ns).max(0.0)
    }

    /// Steady-state delivered throughput, inferences/s: completions per
    /// unit time over the post-warm-up completion window (the first 20 %
    /// of completions are treated as pipeline fill and excluded, which
    /// removes the fill/drain bias from short runs).
    pub fn steady_throughput_qps(&self) -> f64 {
        let n = self.completion_times_ns.len();
        if n < 2 {
            return if self.window_ns() > 0.0 {
                self.completed as f64 / self.window_ns() * 1.0e9
            } else {
                0.0
            };
        }
        let k = n / 5;
        let span = self.completion_times_ns[n - 1] - self.completion_times_ns[k];
        if span <= 0.0 {
            self.completed as f64 / self.window_ns().max(1e-9) * 1.0e9
        } else {
            (n - 1 - k) as f64 / span * 1.0e9
        }
    }
}

/// One pending event. Ordering is `(time, sequence)` — the sequence
/// number breaks simultaneous-event ties deterministically in push
/// order.
struct Ev {
    t: f64,
    seq: u64,
    kind: Kind,
}

enum Kind {
    /// Open-loop request `id` reaches the ingress.
    Arrive(u32),
    /// The stage finishes its in-service request.
    Finish(u32),
}

impl PartialEq for Ev {
    fn eq(&self, o: &Self) -> bool {
        self.t.total_cmp(&o.t) == std::cmp::Ordering::Equal && self.seq == o.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Ev {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.seq.cmp(&o.seq))
    }
}

struct Stage {
    queue: VecDeque<u32>,
    serving: Option<u32>,
    blocked: Option<u32>,
    service_ns: f64,
    busy_ns: f64,
}

struct Sim {
    stages: Vec<Stage>,
    cap: usize,
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
    /// Arrival time of every request ever created (indexed by id).
    arrival_ns: Vec<f64>,
    /// Closed-loop requests issued but waiting for an ingress slot.
    pending: VecDeque<u32>,
    /// Closed loop: requests still to issue (0 for open loop).
    to_issue: usize,
    stats: RunStats,
}

impl Sim {
    fn push_event(&mut self, t: f64, kind: Kind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Ev { t, seq, kind }));
    }

    fn new_request(&mut self, t: f64) -> u32 {
        let id = self.arrival_ns.len() as u32;
        self.arrival_ns.push(t);
        id
    }

    /// Stage `j` starts its next queued request if it is idle; popping
    /// the queue frees a slot, which back-fills from the blocked
    /// upstream stage (or, at the ingress, from waiting closed-loop
    /// clients), cascading as far up as space propagates.
    fn pull(&mut self, j: usize, t: f64) {
        if self.stages[j].serving.is_some() || self.stages[j].blocked.is_some() {
            return;
        }
        let Some(r) = self.stages[j].queue.pop_front() else {
            return;
        };
        self.stages[j].serving = Some(r);
        let s = self.stages[j].service_ns;
        self.stages[j].busy_ns += s;
        self.push_event(t + s, Kind::Finish(j as u32));
        self.backfill(j, t);
    }

    /// A slot just freed in stage `j`'s queue: refill it from upstream.
    fn backfill(&mut self, j: usize, t: f64) {
        if j == 0 {
            if let Some(r) = self.pending.pop_front() {
                debug_assert!(self.stages[0].queue.len() < self.cap);
                self.stages[0].queue.push_back(r);
                self.pull(0, t);
            }
            return;
        }
        let up = j - 1;
        if let Some(r) = self.stages[up].blocked.take() {
            debug_assert!(self.stages[j].queue.len() < self.cap);
            self.stages[j].queue.push_back(r);
            self.pull(up, t);
        }
    }

    fn finish(&mut self, j: usize, t: f64) {
        let r = self.stages[j].serving.take().expect("finish on idle stage");
        if j + 1 == self.stages.len() {
            self.complete(r, t);
        } else if self.stages[j + 1].queue.len() < self.cap {
            self.stages[j + 1].queue.push_back(r);
            self.pull(j + 1, t);
        } else {
            // downstream full: hold the finished request, stall
            self.stages[j].blocked = Some(r);
            return;
        }
        self.pull(j, t);
    }

    fn complete(&mut self, r: u32, t: f64) {
        self.stats.completed += 1;
        self.stats.latencies_ns.push(t - self.arrival_ns[r as usize]);
        self.stats.completion_times_ns.push(t);
        self.stats.last_completion_ns = t;
        if self.to_issue > 0 {
            self.to_issue -= 1;
            let next = self.new_request(t);
            self.admit_or_wait(next, t);
        }
    }

    /// Closed-loop admission: queue at the ingress if a slot is free,
    /// otherwise wait (latency accrues from issue time).
    fn admit_or_wait(&mut self, r: u32, t: f64) {
        if self.stages[0].queue.len() < self.cap {
            self.stages[0].queue.push_back(r);
            self.pull(0, t);
        } else {
            self.pending.push_back(r);
        }
    }

    /// Open-loop admission: shed when the ingress queue is full.
    fn arrive(&mut self, r: u32, t: f64) {
        if self.stages[0].queue.len() < self.cap {
            self.stages[0].queue.push_back(r);
            self.pull(0, t);
        } else {
            self.stats.dropped += 1;
        }
    }
}

/// Run the pipeline of `service_ns` stages against a workload and
/// return the raw statistics. Deterministic: identical inputs produce
/// bit-identical outputs.
pub fn run(service_ns: &[f64], params: EngineParams, workload: Workload) -> RunStats {
    assert!(!service_ns.is_empty(), "pipeline needs at least one stage");
    assert!(params.queue_depth > 0, "queues need at least one slot");
    let mut sim = Sim {
        stages: service_ns
            .iter()
            .map(|&s| Stage {
                queue: VecDeque::new(),
                serving: None,
                blocked: None,
                service_ns: s,
                busy_ns: 0.0,
            })
            .collect(),
        cap: params.queue_depth,
        heap: BinaryHeap::new(),
        seq: 0,
        arrival_ns: Vec::new(),
        pending: VecDeque::new(),
        to_issue: 0,
        stats: RunStats::default(),
    };

    match workload {
        Workload::Open { arrivals } => {
            sim.stats.offered = arrivals.len();
            sim.stats.first_arrival_ns = arrivals.first().copied().unwrap_or(0.0);
            for &t in &arrivals {
                let id = sim.new_request(t);
                sim.push_event(t, Kind::Arrive(id));
            }
        }
        Workload::Closed { concurrency, requests } => {
            assert!(concurrency > 0, "closed loop needs at least one client");
            sim.stats.offered = requests;
            sim.stats.first_arrival_ns = 0.0;
            let initial = concurrency.min(requests);
            sim.to_issue = requests - initial;
            for _ in 0..initial {
                let id = sim.new_request(0.0);
                sim.admit_or_wait(id, 0.0);
            }
        }
    }

    while let Some(Reverse(ev)) = sim.heap.pop() {
        match ev.kind {
            Kind::Arrive(r) => sim.arrive(r, ev.t),
            Kind::Finish(j) => sim.finish(j as usize, ev.t),
        }
    }

    sim.stats.stage_busy_ns = sim.stages.iter().map(|s| s.busy_ns).collect();
    sim.stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open(rate_gap_ns: f64, n: usize) -> Workload {
        Workload::Open {
            arrivals: (1..=n).map(|i| i as f64 * rate_gap_ns).collect(),
        }
    }

    #[test]
    fn single_request_latency_is_service_sum() {
        let stages = [10.0, 20.0, 5.0];
        let stats = run(
            &stages,
            EngineParams { queue_depth: 4 },
            Workload::Closed { concurrency: 1, requests: 1 },
        );
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.latencies_ns[0], 35.0);
    }

    #[test]
    fn closed_loop_concurrency_one_paces_at_service_sum() {
        let stages = [10.0, 20.0, 5.0];
        let stats = run(
            &stages,
            EngineParams { queue_depth: 4 },
            Workload::Closed { concurrency: 1, requests: 50 },
        );
        assert_eq!(stats.completed, 50);
        // every sojourn is exactly the pipeline traversal
        assert!(stats.latencies_ns.iter().all(|&l| l == 35.0));
        let qps = stats.steady_throughput_qps();
        assert!((qps - 1.0e9 / 35.0).abs() / (1.0e9 / 35.0) < 1e-9, "{qps}");
    }

    #[test]
    fn saturated_pipeline_paces_at_bottleneck() {
        // bottleneck 20 ns => steady completions every 20 ns
        let stages = [10.0, 20.0, 5.0];
        let stats = run(
            &stages,
            EngineParams { queue_depth: 2 },
            Workload::Closed { concurrency: 8, requests: 200 },
        );
        assert_eq!(stats.completed, 200);
        let gaps: Vec<f64> = stats.completion_times_ns.windows(2).map(|w| w[1] - w[0]).collect();
        // after fill, every inter-completion gap equals the bottleneck
        assert!(gaps[gaps.len() / 2..].iter().all(|&g| (g - 20.0).abs() < 1e-9));
        let qps = stats.steady_throughput_qps();
        assert!((qps - 5.0e7).abs() / 5.0e7 < 1e-9, "{qps}");
    }

    #[test]
    fn open_loop_sheds_when_saturated() {
        // offered every 5 ns, bottleneck 20 ns, tiny queues => drops
        let stats = run(&[10.0, 20.0], EngineParams { queue_depth: 1 }, open(5.0, 400));
        assert!(stats.dropped > 0, "saturated ingress must shed");
        assert_eq!(stats.completed + stats.dropped, 400, "conservation");
        // delivered still paces at the bottleneck
        let qps = stats.steady_throughput_qps();
        assert!((qps - 5.0e7).abs() / 5.0e7 < 1e-6, "{qps}");
    }

    #[test]
    fn open_loop_below_saturation_delivers_offered_rate() {
        // offered every 50 ns >> bottleneck 20 ns: no queueing, no drops
        let stats = run(&[10.0, 20.0], EngineParams { queue_depth: 4 }, open(50.0, 200));
        assert_eq!(stats.dropped, 0);
        assert_eq!(stats.completed, 200);
        assert!(stats.latencies_ns.iter().all(|&l| l == 30.0));
    }

    #[test]
    fn back_pressure_bounds_buffered_requests() {
        // deep pipeline behind a slow tail stage: with queue depth Q the
        // requests resident in the system are bounded by stages*(Q+2)
        let stages = [1.0, 1.0, 1.0, 50.0];
        let q = 2;
        let stats = run(&stages, EngineParams { queue_depth: q }, open(1.0, 500));
        assert_eq!(stats.completed + stats.dropped, 500);
        // the tail stage admits one per 50 ns: most of the flood is shed
        assert!(stats.dropped > 300, "dropped {}", stats.dropped);
        // all completed latencies bounded by residency * bottleneck
        let bound = (stages.len() * (q + 2)) as f64 * 50.0;
        assert!(stats.latencies_ns.iter().all(|&l| l <= bound));
    }

    #[test]
    fn engine_is_bit_deterministic() {
        let stages = [3.0, 7.5, 2.25, 11.0];
        let w = || open(4.0, 300);
        let a = run(&stages, EngineParams { queue_depth: 2 }, w());
        let b = run(&stages, EngineParams { queue_depth: 2 }, w());
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.latencies_ns), bits(&b.latencies_ns));
        assert_eq!(bits(&a.stage_busy_ns), bits(&b.stage_busy_ns));
    }

    #[test]
    fn busy_time_counts_work_not_blocking() {
        // stage 0 is fast but blocked most of the time by stage 1
        let stats = run(
            &[1.0, 10.0],
            EngineParams { queue_depth: 1 },
            Workload::Closed { concurrency: 4, requests: 100 },
        );
        assert_eq!(stats.stage_busy_ns[0], 100.0); // 100 × 1 ns of real work
        assert_eq!(stats.stage_busy_ns[1], 1000.0);
    }
}
